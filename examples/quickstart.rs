//! Quickstart: deploy one GEMM on a small SoftHier instance, simulate its
//! performance, execute it functionally, and (if `make artifacts` has run)
//! verify the numbers against the JAX/Pallas golden GEMM via PJRT.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator;
use dit::schedule::Schedule;
use dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // A 4×4 SoftHier instance (same template as the paper's 32×32 GH200
    // configuration, scaled down so this demo runs in milliseconds).
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(256, 256, 256);
    println!("SoftHier {} | problem {shape}", arch.name);

    // 1. Pick a deployment schedule (SUMMA dataflow, optimized layout).
    let sched = Schedule::summa(&arch, shape);
    println!("schedule: {}", sched.name());

    // 2. Lower to per-PE programs and simulate the deployment.
    let stats = coordinator::simulate_schedule(&arch, shape, &sched)?;
    println!(
        "simulated: {:.2} TFLOP/s ({:.1}% of peak), {} supersteps, {}",
        stats.tflops(),
        100.0 * stats.utilization(),
        stats.supersteps,
        dit::util::human_time_ns(stats.makespan_ns),
    );

    // 3. Execute the same programs functionally (real f32 data through the
    //    simulated HBM/NoC) and check against a plain CPU GEMM.
    let dep = coordinator::deploy_functional(&arch, shape, &sched)?;
    let mut rng = Rng::new(42);
    let a = rng.f32_vec(shape.m * shape.k);
    let b = rng.f32_vec(shape.k * shape.n);
    let got = dit::functional::run_gemm(&arch, &dep, &a, &b)?;
    let mut want = vec![0f32; shape.m * shape.n];
    dit::functional::mmad_f32(&a, &b, &mut want, shape.m, shape.n, shape.k);
    let diff = dit::functional::max_abs_diff(&got, &want);
    println!("functional vs CPU reference: max|diff| = {diff:.3e}");
    anyhow::ensure!(diff < 1e-3, "functional mismatch");

    // 4. Verify against the PJRT-executed JAX/Pallas golden GEMM.
    match dit::runtime::Oracle::open_default() {
        Ok(mut oracle) if oracle.has("gemm", shape.m, shape.n, shape.k) => {
            let report = coordinator::verify(&arch, shape, &sched, &mut oracle, 7)?;
            println!(
                "PJRT golden check: max|diff| = {:.3e} (tol {:.3e}) -> {}",
                report.max_abs_diff,
                report.tolerance,
                if report.passed() { "PASS" } else { "FAIL" }
            );
            anyhow::ensure!(report.passed(), "oracle mismatch");
        }
        _ => println!("(artifacts not built; run `make artifacts` for the PJRT check)"),
    }

    // 5. Let the autotuner pick the best schedule for this shape.
    let tuned = coordinator::autotune(&arch, shape)?;
    println!(
        "autotuner best: {} at {:.2} TFLOP/s ({} candidates ranked)",
        tuned.best().schedule.name(),
        tuned.best().stats.tflops(),
        tuned.ranking.len()
    );
    Ok(())
}

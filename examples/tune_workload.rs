//! Workload-level autotuning: tune a whole transformer serving mix —
//! prefill QKV / attention-out / FFN projections plus two flat decode
//! steps — in one parallel, memoized engine pass (§4.1.4 scaled from one
//! GEMM to the realistic traffic shape).
//!
//! ```sh
//! cargo run --release --example tune_workload
//! ```

use dit::arch::workload::Workload;
use dit::arch::ArchConfig;
use dit::coordinator::engine::Engine;
use dit::report::workload_summary;

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::gh200_like();
    let engine = Engine::new(&arch);
    let suite = Workload::builtin("transformer").expect("builtin suite");
    println!(
        "tuning workload '{}' ({} GEMMs) on {} with {} workers\n",
        suite.name,
        suite.items.len(),
        arch.name,
        engine.workers()
    );

    let rep = engine.tune_workload(&suite)?;
    print!("{}", workload_summary(&rep).markdown());
    println!(
        "\ntotal   : {} per forward pass ({:.0} TFLOP/s weighted over {} GEMM executions)",
        dit::util::human_time_ns(rep.total_time_ns()),
        rep.aggregate_tflops(),
        rep.total_count(),
    );
    println!(
        "engine  : {} simulations, {} cache hits (decode steps repeat shapes), {:.0} ms wall",
        rep.sim_calls, rep.cache_hits, rep.elapsed_ms
    );

    // Tuning the same suite again is free — everything is memoized.
    let rep2 = engine.tune_workload(&suite)?;
    println!(
        "re-tune : {} new simulations, {} cache hits (fully memoized)",
        rep2.sim_calls, rep2.cache_hits
    );
    anyhow::ensure!(rep2.sim_calls == 0, "second tuning pass should be free");
    Ok(())
}

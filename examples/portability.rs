//! Portability sweep (paper §4.2): the same deployment framework across
//! differently-sized SoftHier instances, plus an architecture config-file
//! round-trip (SoftHier is "fully configurable through architecture
//! configuration files").
//!
//! ```sh
//! cargo run --release --example portability
//! ```

use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::autotune;
use dit::report::Table;

fn main() -> anyhow::Result<()> {
    // Instances: A100-matched, GH200-matched, and a custom config loaded
    // from text (the file-driven flow the paper describes).
    let custom_text = ArchConfig::tiny(8, 8).to_text();
    let custom = ArchConfig::from_text(&custom_text)?;
    let instances = vec![ArchConfig::a100_like(), ArchConfig::gh200_like(), custom];

    let shapes = [
        GemmShape::new(4096, 4096, 7168),
        GemmShape::new(4096, 2112, 7168),
        GemmShape::new(64, 2112, 7168),
    ];

    let mut t = Table::new(
        "portability: autotuned utilization across SoftHier instances",
        &["instance", "peak TFLOPS", "shape", "best schedule", "util %", "HBM %"],
    );
    for arch in &instances {
        for shape in shapes {
            let result = autotune(arch, shape)?;
            let best = result.best();
            t.row(vec![
                arch.name.clone(),
                format!("{:.0}", arch.peak_tflops()),
                shape.to_string(),
                best.schedule.name(),
                format!("{:.1}", 100.0 * best.stats.utilization()),
                format!("{:.1}", 100.0 * best.stats.hbm_utilization()),
            ]);
        }
    }
    print!("{}", t.markdown());
    println!("\n(the deployment schedule abstraction re-tunes itself per instance —\n no kernel rewrites, matching the paper's portability claim)");
    Ok(())
}

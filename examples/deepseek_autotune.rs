//! DeepSeek-V3 GEMM autotuning on the GH200-matched SoftHier instance —
//! the paper's §4.1.4 evaluation as a runnable application.
//!
//! For every DeepSeek prefill (compute-bound) and decode (flat) GEMM shape,
//! the coordinator enumerates the schedule candidates, simulates each, and
//! reports the automatically-selected best deployment next to the modelled
//! CUTLASS/DeepGEMM GH200 baselines.
//!
//! ```sh
//! cargo run --release --example deepseek_autotune
//! ```

use dit::arch::ArchConfig;
use dit::coordinator::autotune;
use dit::perfmodel::{workloads, GpuSpec};
use dit::report::Table;

fn main() -> anyhow::Result<()> {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    println!(
        "autotuning DeepSeek-V3 GEMMs on {} ({} tiles, {:.0} TFLOPS peak)\n",
        arch.name,
        arch.num_tiles(),
        arch.peak_tflops()
    );

    for (title, shapes) in [
        ("prefill (compute-bound)", workloads::compute_bound()),
        ("decode (flat / memory-bound)", workloads::flat()),
    ] {
        let mut t = Table::new(
            format!("DeepSeek-V3 {title}"),
            &["shape", "best schedule", "TFLOP/s", "util %", "HBM %", "vs best GPU"],
        );
        for shape in shapes {
            let result = autotune(&arch, shape)?;
            let best = result.best();
            let gpu_best = gpu.cutlass_tflops(shape).max(gpu.deepgemm_tflops(shape));
            t.row(vec![
                shape.to_string(),
                best.schedule.name(),
                format!("{:.0}", best.stats.tflops()),
                format!("{:.1}", 100.0 * best.stats.utilization()),
                format!("{:.1}", 100.0 * best.stats.hbm_utilization()),
                format!("{:.2}x", best.stats.tflops() / gpu_best),
            ]);
        }
        print!("{}\n", t.markdown());
    }
    Ok(())
}

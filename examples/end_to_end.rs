//! End-to-end driver: the full DiT workflow (paper Fig. 4) over a suite of
//! real small workloads, proving all layers compose.
//!
//! For every GEMM shape shipped in `artifacts/manifest.txt`:
//!
//! 1. **Preload** — inputs are scattered into per-channel HBM images
//!    according to the schedule's data-layout description (and round-
//!    tripped through the binary preload-file format);
//! 2. **Generate & Optimize** — the deployment schedule is lowered to
//!    validated per-PE BSP programs (autotuner picks the schedule);
//! 3. **Benchmark (performance)** — the event-driven SoftHier model times
//!    the deployment and reports utilization, the paper's headline metric;
//! 4. **Benchmark (correctness)** — the same programs execute functionally
//!    over the preload image and the output is compared against the
//!    JAX/Pallas golden GEMM running under PJRT (Layer 1/2 ⇄ Layer 3).
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator;
use dit::layout::preload::Preload;
use dit::report::Table;
use dit::runtime::Oracle;
use dit::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut oracle = match Oracle::open_default() {
        Ok(o) => o,
        Err(e) => {
            println!("(PJRT artifacts unavailable: {e:#})");
            println!("(falling back to the f64-accumulation CPU reference oracle)\n");
            Oracle::cpu_reference()
        }
    };
    let arch = ArchConfig::tiny(4, 4);
    println!(
        "DiT end-to-end on {}: {} tiles, {:.1} TFLOPS peak, {:.0} GB/s HBM\n",
        arch.name,
        arch.num_tiles(),
        arch.peak_tflops(),
        arch.hbm.total_gbps()
    );

    let mut table = Table::new(
        "end-to-end: autotuned deployment + golden-oracle verification per workload",
        &["shape", "best schedule", "TFLOP/s", "util %", "supersteps", "max|diff|", "verdict"],
    );
    let mut failures = 0;

    for (m, n, k) in oracle.shapes("gemm") {
        let shape = GemmShape::new(m, n, k);

        // --- Generate & Optimize: autotune the schedule space.
        let tuned = coordinator::autotune(&arch, shape)?;
        let best = tuned.best().schedule.clone();
        let stats = tuned.best().stats.clone();

        // --- Preload: build + round-trip the HBM image file.
        let dep = coordinator::deploy_functional(&arch, shape, &best)?;
        let mut rng = Rng::new(0xE2E);
        let pad = dep.padded;
        let mut a = rng.f32_vec(shape.m * shape.k);
        let mut b = rng.f32_vec(shape.k * shape.n);
        // (padding handled inside run_gemm; preload file round-trip here)
        let mut img = Preload::new(arch.hbm.num_channels());
        let mut a_pad = vec![0f32; pad.m * pad.k];
        for r in 0..shape.m {
            a_pad[r * pad.k..r * pad.k + shape.k]
                .copy_from_slice(&a[r * shape.k..(r + 1) * shape.k]);
        }
        img.scatter_f32(&dep.layouts.a, &a_pad);
        let path = std::env::temp_dir().join(format!("dit_e2e_{m}x{n}x{k}.preload"));
        img.save(&path)?;
        let img2 = Preload::load(&path)?;
        std::fs::remove_file(&path).ok();
        anyhow::ensure!(img == img2, "preload file round-trip failed");

        // --- Benchmark: functional execution vs the PJRT golden GEMM.
        let got = dit::functional::run_gemm(&arch, &dep, &a, &b)?;
        let want = oracle.gemm(m, n, k, &a, &b)?;
        let diff = dit::functional::max_abs_diff(&got, &want);
        let tol = 1e-5 * (k as f32).sqrt().max(1.0) * 8.0;
        let pass = diff <= tol;
        failures += usize::from(!pass);

        table.row(vec![
            shape.to_string(),
            best.name(),
            format!("{:.2}", stats.tflops()),
            format!("{:.1}", 100.0 * stats.utilization()),
            stats.supersteps.to_string(),
            format!("{diff:.2e}"),
            if pass { "PASS".into() } else { "FAIL".into() },
        ]);
        // keep borrowck honest about a/b reuse
        a.clear();
        b.clear();
    }

    print!("\n{}", table.markdown());
    anyhow::ensure!(failures == 0, "{failures} workloads failed verification");
    if oracle.is_cpu_reference() {
        println!("\nall workloads verified against the f64 CPU reference oracle ✓");
    } else {
        println!("\nall workloads verified against the JAX/Pallas golden GEMM ✓");
    }
    Ok(())
}

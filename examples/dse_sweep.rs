//! Hardware design-space exploration as a library call.
//!
//! Sweeps square *and rectangular* mesh geometries of the GH200-like
//! template at two SPM capacities, co-tunes every candidate instance
//! over the DSE serving suite on one shared engine/memo-cache, and
//! prints the Pareto frontier of achieved TFLOP/s vs. the silicon-cost
//! proxy — then re-reads the same result through the energy objective:
//! the 3-axis (cost, TFLOP/s, energy) frontier, the TFLOP/s-per-Watt
//! winner, and a weighted scalarization that collapses all three axes
//! into one ranked choice.
//!
//! Run with: `cargo run --release --example dse_sweep`

use dit::dse::{self, DseOptions, Objective, SweepSpec};
use dit::report;

fn main() -> anyhow::Result<()> {
    let mut spec = SweepSpec::reduced();
    // Trim the mesh axis so the demo finishes in a few seconds; the full
    // reduced sweep (8..32, `dit dse --workload serving`) adds 24x24 and
    // 32x32. Alongside the squares, sweep the wide-short and tall-narrow
    // geometries of the same 64-tile budget as 8x8 — the shapes a
    // floorplan with HBM stacks on two edges actually offers, and the
    // ones skinny decode GEMMs favor (more columns = more N parallelism
    // for the same silicon).
    spec.meshes = SweepSpec::square_meshes(&[8, 12, 16]);
    spec.meshes.extend([(4, 16), (16, 4)]);

    let workload = dse::suite("serving").expect("builtin DSE suite");
    // Asking for the energy objective disables the roofline prune (it
    // only bounds throughput), so the sweep is exhaustive and the 3-axis
    // frontier is complete.
    let objectives = vec![Objective::Perf, Objective::Cost, Objective::Energy];
    let opts = DseOptions { objectives: objectives.clone(), ..DseOptions::default() };
    let res = dse::run_sweep(&spec, &workload, &opts)?;

    print!("{}", report::dse_summary(&res).markdown());
    print!("{}", report::dse_plot(&res).render());
    println!(
        "frontier: {} non-dominated of {} evaluated ({} pruned by roofline bound)",
        res.frontier().len(),
        res.points.len(),
        res.pruned.len()
    );
    if let Some(best) = res.best() {
        println!(
            "best: {} at {:.1} TFLOP/s ({:.1}% of its {:.0} TFLOP/s peak), cost {:.0}",
            best.arch.name,
            best.tflops,
            100.0 * best.utilization(),
            best.arch.peak_tflops(),
            best.cost
        );
    }
    // Same 64-tile compute, three geometries. Note this is a whole-
    // machine comparison, not floorplan-shape in isolation: the HBM rule
    // gives pct% of the *shorter* edge per edge, so at 100% the 4x16 and
    // 16x4 instances carry 8 channels to the 8x8's 16 (visible in the
    // cost column) — exactly the trade a two-edge floorplan imposes.
    if let (Some(sq), Some(wide), Some(tall)) =
        (res.best_at_square(8), res.best_at_mesh(4, 16), res.best_at_mesh(16, 4))
    {
        println!(
            "64-tile machines: 8x8/16ch {:.1} | 4x16/8ch {:.1} | 16x4/8ch {:.1} TFLOP/s",
            sq.tflops,
            wide.tflops,
            tall.tflops
        );
    }

    // --- The energy axis: 3-axis frontier and per-objective projections.
    println!();
    for plot in report::dse_plot_projections(&res) {
        print!("{}", plot.render());
    }
    println!("3-axis frontier over (cost, TFLOP/s, energy per pass):");
    for p in res.frontier3() {
        println!(
            "  {:<40} {:>7.1} TFLOP/s  cost {:>6.0}  {:>8.2} mJ/pass  {:>5.2} TFLOP/s/W",
            p.arch.name,
            p.tflops,
            p.cost,
            p.energy_j * 1e3,
            p.tflops_per_w
        );
    }
    if let Some(eff) = res.most_efficient() {
        println!(
            "efficiency winner: {} at {:.2} TFLOP/s/W ({:.2} mJ per pass)",
            eff.arch.name,
            eff.tflops_per_w,
            eff.energy_j * 1e3
        );
    }

    // --- Scalarization: one ranked winner from a weight vector.
    let weights = [0.5, 0.2, 0.3];
    if let Some((winner, score)) = res.best_scalarized(&objectives, &weights)? {
        println!(
            "scalarized winner (perf=0.5, cost=0.2, energy=0.3): {} at score {score:.3}",
            winner.arch.name
        );
    }
    println!(
        "engine: {} simulations, {} cache hits, {:.0} ms",
        res.sim_calls, res.cache_hits, res.elapsed_ms
    );
    Ok(())
}

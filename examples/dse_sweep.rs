//! Hardware design-space exploration as a library call.
//!
//! Sweeps three mesh sizes of the GH200-like template at two SPM
//! capacities, co-tunes every candidate instance over the DSE serving
//! suite on one shared engine/memo-cache, and prints the Pareto frontier
//! of achieved TFLOP/s vs. the silicon-cost proxy.
//!
//! Run with: `cargo run --release --example dse_sweep`

use dit::dse::{self, DseOptions, SweepSpec};
use dit::report;

fn main() -> anyhow::Result<()> {
    let mut spec = SweepSpec::reduced();
    // Trim the mesh axis so the demo finishes in a few seconds; the full
    // reduced sweep (8..32, `dit dse --workload serving`) adds 24x24 and
    // 32x32.
    spec.mesh = vec![8, 12, 16];

    let workload = dse::suite("serving").expect("builtin DSE suite");
    let res = dse::run_sweep(&spec, &workload, &DseOptions::default())?;

    print!("{}", report::dse_summary(&res).markdown());
    print!("{}", report::dse_plot(&res).render());
    println!(
        "frontier: {} non-dominated of {} evaluated ({} pruned by roofline bound)",
        res.frontier().len(),
        res.points.len(),
        res.pruned.len()
    );
    if let Some(best) = res.best() {
        println!(
            "best: {} at {:.1} TFLOP/s ({:.1}% of its {:.0} TFLOP/s peak), cost {:.0}",
            best.arch.name,
            best.tflops,
            100.0 * best.utilization(),
            best.arch.peak_tflops(),
            best.cost
        );
    }
    println!(
        "engine: {} simulations, {} cache hits, {:.0} ms",
        res.sim_calls, res.cache_hits, res.elapsed_ms
    );
    Ok(())
}

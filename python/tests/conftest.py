"""Make the ``compile`` package importable when pytest runs from the repo
root (``python -m pytest python/tests``), as the CI python job does."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

"""Layer-2 correctness: schedule-algebra references vs the golden GEMM.

The Rust codegen produces per-tile programs whose *algebra* (which block is
multiplied with which, when partials are reduced) follows exactly these
decompositions. Pinning them to ``gemm_ref`` here means a Rust functional
mismatch localizes to the Rust IR/codegen, not the maths.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model

RTOL, ATOL = 2e-5, 2e-4


def rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


def _check(got, a, b):
    want = np.asarray(model.gemm_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(np.asarray(got), want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("p,q", [(1, 1), (2, 2), (4, 4), (2, 4), (4, 2), (1, 8)])
def test_summa_algebra(p, q):
    a, b = rand((64, 128), 0), rand((128, 96), 1)
    _check(model.summa_ref(jnp.asarray(a), jnp.asarray(b), p, q), a, b)


@pytest.mark.parametrize("kp", [1, 2, 4, 8, 16])
def test_summa_kpanel_count_invariance(kp):
    a, b = rand((32, 64), 2), rand((64, 32), 3)
    _check(model.summa_ref(jnp.asarray(a), jnp.asarray(b), 2, 2, kp=kp), a, b)


@pytest.mark.parametrize("splits", [1, 2, 4, 8])
def test_splitk_algebra(splits):
    a, b = rand((48, 64), 4), rand((64, 80), 5)
    _check(model.splitk_ref(jnp.asarray(a), jnp.asarray(b), splits), a, b)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_systolic_algebra(p):
    a, b = rand((64, 64), 6), rand((64, 64), 7)
    _check(model.systolic_ref(jnp.asarray(a), jnp.asarray(b), p), a, b)


def test_systolic_equals_summa():
    """Different dataflows, identical numerics (paper §3.3.2)."""
    a, b = rand((32, 32), 8), rand((32, 32), 9)
    s1 = model.summa_ref(jnp.asarray(a), jnp.asarray(b), 4, 4)
    s2 = model.systolic_ref(jnp.asarray(a), jnp.asarray(b), 4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=RTOL, atol=ATOL)


def test_split_requires_divisibility():
    with pytest.raises(ValueError):
        model.summa_ref(jnp.zeros((30, 30)), jnp.zeros((30, 30)), 4, 4)


@settings(max_examples=15, deadline=None)
@given(
    p=st.sampled_from([1, 2, 4]),
    q=st.sampled_from([1, 2, 4]),
    scale_m=st.integers(1, 3),
    scale_k=st.integers(1, 3),
    seed=st.integers(0, 10**6),
)
def test_summa_hypothesis(p, q, scale_m, scale_k, seed):
    m, k, n = 16 * p * scale_m, 16 * max(p, q) * scale_k, 16 * q
    a, b = rand((m, k), seed), rand((k, n), seed + 1)
    _check(model.summa_ref(jnp.asarray(a), jnp.asarray(b), p, q), a, b)


def test_gemm_bias_relu():
    a, b, bias = rand((32, 48), 10), rand((48, 24), 11), rand((24,), 12)
    got = np.asarray(model.gemm_bias_relu(jnp.asarray(a), jnp.asarray(b), jnp.asarray(bias)))
    want = np.maximum(a @ b + bias[None, :], 0.0)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

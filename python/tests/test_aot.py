"""AOT path: the lowered HLO text must be loadable interchange.

These tests don't execute through PJRT from Python (that's the Rust side's
job); they check the text artifacts have the structure the Rust loader
depends on: an ENTRY computation, f32 parameters of the right shapes, and a
tuple root (the Rust side unwraps with ``to_tuple1``).
"""

import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def hlo_small():
    return aot.lower_gemm(8, 8, 8)


def test_hlo_has_entry(hlo_small):
    assert "ENTRY" in hlo_small


def test_hlo_parameters_and_tuple_root(hlo_small):
    assert "f32[8,8]" in hlo_small
    # return_tuple=True => root is a 1-tuple of the result
    assert re.search(r"\(f32\[8,8\]\s*(,|\))", hlo_small) or "tuple" in hlo_small


def test_hlo_shapes_propagate():
    text = aot.lower_gemm(16, 24, 32)
    assert "f32[16,32]" in text  # A
    assert "f32[32,24]" in text  # B
    assert "f32[16,24]" in text  # C


def test_epilogue_lowering_contains_relu():
    text = aot.lower_gemm_bias_relu(8, 8, 8)
    assert "maximum" in text
    assert "f32[8]" in text


def test_manifest_shape_list_is_consistent():
    for m, n, k in aot.GEMM_SHAPES:
        assert m > 0 and n > 0 and k > 0
    # the ragged §4.1.3 shape must be present: N = 2112/32 = 66
    assert any(n == 66 for _, n, _ in aot.GEMM_SHAPES)
    # a flat-GEMM analogue must be present (M much smaller than N)
    assert any(m <= 64 and n >= 8 * m for m, n, _ in aot.GEMM_SHAPES)

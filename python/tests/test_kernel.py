"""Layer-1 correctness: Pallas MMAD kernel vs the pure-jnp oracle.

This is the core numerical signal of the build path: if the kernel disagrees
with ``gemm_ref`` nothing downstream (artifacts, Rust verification) can be
trusted. Hypothesis sweeps shapes/dtypes/tilings; fixed cases pin the
geometries the artifacts use.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import mmad, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


def assert_matches_ref(a, b, **tiles):
    got = np.asarray(mmad.mmad(jnp.asarray(a), jnp.asarray(b), **tiles))
    want = np.asarray(ref.gemm_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize(
    "m,n,k",
    [
        (8, 8, 8),
        (64, 64, 64),
        (128, 128, 128),
        (128, 384, 256),
        (64, 528, 512),
        (96, 66, 128),   # ragged N: the paper's 2112/32 = 66 grain
        (1, 1, 1),
        (3, 5, 7),       # fully irregular, exercises padding
        (256, 192, 512),
    ],
)
def test_kernel_matches_ref_fixed(m, n, k):
    assert_matches_ref(rand((m, k), 1), rand((k, n), 2))


@pytest.mark.parametrize("tm,tn,tk", [(32, 32, 32), (64, 16, 128), (128, 128, 64), (16, 64, 32)])
def test_kernel_tile_shape_invariance(tm, tn, tk):
    """The result must not depend on the VMEM blocking choice."""
    a, b = rand((96, 80), 3), rand((80, 112), 4)
    assert_matches_ref(a, b, tm=tm, tn=tn, tk=tk)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    k=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(m, n, k, seed):
    assert_matches_ref(rand((m, k), seed), rand((k, n), seed + 1))


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([16, 48, 64]),
    n=st.sampled_from([16, 66, 128]),
    k=st.sampled_from([32, 96]),
    dtype=st.sampled_from([np.float32, np.float16, np.bfloat16 if hasattr(np, "bfloat16") else np.float16]),
)
def test_kernel_dtype_sweep(m, n, k, dtype):
    """Lower-precision inputs are accumulated in f32, like the FP8 engine."""
    a = rand((m, k), 7).astype(dtype)
    b = rand((k, m), 8)[:, :n].astype(dtype) if n <= m else rand((k, n), 8).astype(dtype)
    got = np.asarray(mmad.mmad(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.gemm_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_kernel_rejects_bad_shapes():
    with pytest.raises(ValueError):
        mmad.mmad(jnp.zeros((4, 5)), jnp.zeros((6, 4)))


def test_vmem_budget_of_default_tiling():
    """Default blocks must fit the SoftHier 384 KB L1 analogue."""
    assert mmad.vmem_bytes(128, 128, 128) <= 384 * 1024


def test_mxu_estimate_matches_paper_calibration():
    """§4.1.3: a ragged TN=66 tile sits near 50% engine utilization while a
    3D-tiled TN=528 tile is comfortably high."""
    ragged = mmad.mxu_utilization_estimate(128, 66, 128)
    wide = mmad.mxu_utilization_estimate(128, 528, 512)
    assert 0.40 <= ragged <= 0.60, ragged
    assert wide >= 0.85, wide
    assert wide > ragged

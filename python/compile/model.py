"""Layer-2 JAX model: the golden GEMM compute graph lowered for the runtime.

The Rust side (rust/src/runtime) never recomputes reference results in
Python — it loads the HLO artifacts produced from *this* module and executes
them on the PJRT CPU client. Two entry points are lowered:

* ``gemm``        — blocked GEMM whose inner tile product is the Layer-1
                    Pallas MMAD kernel. This is the numerical oracle against
                    which the functional simulation of every deployment
                    schedule is checked ("Benchmark" stage of the DiT
                    workflow, Fig. 4 of the paper).
* ``gemm_bias_relu`` — a fused epilogue variant exercised by the examples to
                    show the oracle path is not GEMM-shaped-only.

Schedule-algebra references (SUMMA / split-K / systolic decompositions) live
in ``kernels.ref`` and are pytest-pinned to ``gemm``; the Rust codegen is
checked against the same algebra through the functional executor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import mmad as mmad_kernel
from .kernels import ref as ref_oracle


def gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Golden GEMM through the Pallas tile kernel (f32 accumulate)."""
    return mmad_kernel.mmad(a, b)


def gemm_bias_relu(a: jax.Array, b: jax.Array, bias: jax.Array) -> jax.Array:
    """GEMM + bias + ReLU epilogue (used by the epilogue example/tests)."""
    return jnp.maximum(gemm(a, b) + bias[None, :], 0.0)


# Re-exported so `compile.model` is the single import surface for tests.
gemm_ref = ref_oracle.gemm_ref
summa_ref = ref_oracle.summa_ref
splitk_ref = ref_oracle.splitk_ref
systolic_ref = ref_oracle.systolic_ref

"""AOT lowering: JAX/Pallas golden GEMMs -> HLO *text* artifacts.

Run once by ``make artifacts`` (``python -m compile.aot --out-dir ../artifacts``).
The Rust runtime (rust/src/runtime) loads these with
``HloModuleProto::from_text_file`` and executes them on the PJRT CPU client;
Python is never on the simulate/verify request path.

HLO **text** — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact set: one executable per *verification shape*. These are the shapes
the Rust integration tests and examples deploy on small SoftHier grids and
then check numerically; they are chosen to cover square / rectangular /
ragged-irregular (TN = 66-grain, i.e. 2112/32) / flat-decode geometries.
A ``manifest.txt`` maps entry name + shape -> artifact file.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (M, N, K) verification shapes. Keep them CPU-PJRT-fast: the Rust test
# suite executes each artifact at least once.
GEMM_SHAPES = [
    (64, 64, 64),
    (128, 128, 128),
    (256, 256, 256),
    (128, 384, 256),
    (64, 528, 512),    # flat-GEMM analogue (LLM decode, Fig. 7d geometry)
    (96, 66, 128),     # ragged: 66 = 2112/32, the paper's §4.1.3 example
    (256, 192, 512),
]
EPILOGUE_SHAPES = [(64, 64, 64), (128, 96, 64)]


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gemm(m: int, n: int, k: int) -> str:
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return to_hlo_text(jax.jit(lambda x, y: (model.gemm(x, y),)).lower(a, b))


def lower_gemm_bias_relu(m: int, n: int, k: int) -> str:
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    bias = jax.ShapeDtypeStruct((n,), jnp.float32)
    return to_hlo_text(
        jax.jit(lambda x, y, z: (model.gemm_bias_relu(x, y, z),)).lower(a, b, bias)
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for m, n, k in GEMM_SHAPES:
        name = f"gemm_{m}x{n}x{k}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_gemm(m, n, k)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"gemm {m} {n} {k} {name}")
        print(f"wrote {path} ({len(text)} chars)")

    for m, n, k in EPILOGUE_SHAPES:
        name = f"gemm_bias_relu_{m}x{n}x{k}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_gemm_bias_relu(m, n, k)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"gemm_bias_relu {m} {n} {k} {name}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("# entry M N K file\n")
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()

"""Layer-1 Pallas kernel: the SoftHier compute-tile MMAD.

This kernel models the matrix engine of one SoftHier compute tile: a blocked
``C[TM, TN] += A[TM, TK] @ B[TK, TN]`` accumulation whose operand blocks are
staged through VMEM by ``BlockSpec`` — the Pallas analogue of the tile's
software-managed L1 SPM (384 KB in the GH200-like configuration).

Hardware adaptation (paper -> TPU, see DESIGN.md §Hardware-Adaptation):

* SoftHier L1 scratchpad        -> VMEM blocks via BlockSpec
* 64x16 CE array (FP8 MMAD)     -> MXU systolic array (f32 here; CPU PJRT has
                                   no FP8 — timing uses the paper's FP8 rates)
* HBM -> L1 DMA double-buffering-> the implicit BlockSpec HBM<->VMEM pipeline
* per-superstep local MMAD      -> the sequential K-grid accumulation below

``interpret=True`` everywhere: the artifacts must execute on the CPU PJRT
client used by the Rust runtime; real-TPU lowering would emit Mosaic
custom-calls the CPU plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The GH200-like SoftHier tile has a 64x16 CE array; these are the natural
# sub-tile quanta of the matrix engine and the default VMEM block sizes.
CE_M = 64
CE_N = 16


def _mmad_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One grid step: accumulate a TK-panel product into the output block.

    Grid is (M/TM, N/TN, K/TK) with K innermost; the output BlockSpec ignores
    the K index, so the same VMEM block is revisited across the K loop — the
    canonical Pallas accumulation idiom and the analogue of the SoftHier
    tile accumulating partial MMADs across BSP supersteps.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _ceil_to(x: int, q: int) -> int:
    return (x + q - 1) // q * q


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def mmad(a: jax.Array, b: jax.Array, *, tm: int = 128, tn: int = 128,
         tk: int = 128) -> jax.Array:
    """Blocked GEMM ``a @ b`` through the Pallas MMAD kernel.

    Pads M/N/K up to tile multiples (SoftHier DMA-pads ragged edge tiles the
    same way), runs the (M/TM, N/TN, K/TK) grid, then slices the result.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"mmad: bad shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    tm, tn, tk = min(tm, _ceil_to(m, 8)), min(tn, _ceil_to(n, 8)), min(tk, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(k, tk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    n_k = kp // tk

    out = pl.pallas_call(
        functools.partial(_mmad_kernel, n_k=n_k),
        grid=(mp // tm, np_ // tn, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p.astype(jnp.float32), b_p.astype(jnp.float32))
    return out[:m, :n]


def vmem_bytes(tm: int, tn: int, tk: int, itemsize: int = 4) -> int:
    """VMEM footprint of one grid step (A block + B block + C block).

    Used by the perf notes in DESIGN.md/EXPERIMENTS.md to check the blocks
    fit the 384 KB SoftHier L1 budget analogue.
    """
    return itemsize * (tm * tk + tk * tn + tm * tn)


def mxu_utilization_estimate(tm: int, tn: int, tk: int,
                             ce_m: int = CE_M, ce_n: int = CE_N) -> float:
    """Estimated matrix-engine (MXU-analogue) utilization for a tile shape.

    The CE array quantizes M to ce_m and N to ce_n (quantization loss), the
    systolic pipeline pays a ~ce_m-cycle fill per K panel (fill loss), and a
    ragged edge (tm % ce_m or tn % ce_n nonzero) breaks the wavefront and
    stalls the array (calibrated 0.7 factor, set so a TN=66 tile lands at
    the ~50% utilization the paper reports in §4.1.3). This is the same
    model the Rust simulator uses (rust/src/sim/tile.rs).
    """
    sub_m = -(-tm // ce_m)
    sub_n = -(-tn // ce_n)
    quant = (tm * tn) / (sub_m * ce_m * sub_n * ce_n)
    fill = tk / (tk + ce_n)
    ragged = 0.7 if (tm % ce_m or tn % ce_n) else 1.0
    return min(1.0, quant * fill * ragged)

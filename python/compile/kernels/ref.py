"""Pure-jnp oracles for the Pallas MMAD kernel and the schedule algebra.

These are the build-time correctness anchors:

* ``gemm_ref``            — plain ``a @ b`` in f32; the kernel must match it.
* ``summa_ref``           — GEMM computed the way a P×Q SUMMA deployment
                            decomposes it (K-panel broadcasts, per-tile local
                            MMADs) so the schedule *algebra* is checked in
                            numpy-land before the Rust codegen reproduces it.
* ``splitk_ref``          — 3D (split-K) decomposition with an explicit
                            partial-sum reduction, mirroring the NoC
                            reduction dataflow.
* ``systolic_ref``        — wavefront (Cannon-style skewed) decomposition.

The Rust functional executor (rust/src/functional) re-implements the same
decompositions over the simulated memory system; pytest pins these oracles
to ``gemm_ref`` so any disagreement localizes to the Rust side.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a, b):
    """Golden GEMM: f32 ``a @ b``."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def _split(x, parts, axis):
    """Split ``x`` into ``parts`` equal chunks along ``axis`` (must divide)."""
    if x.shape[axis] % parts:
        raise ValueError(f"{x.shape[axis]} not divisible by {parts}")
    return jnp.split(x, parts, axis=axis)


def summa_ref(a, b, p: int, q: int, kp: int | None = None):
    """GEMM via the SUMMA decomposition on a logical p×q tile grid.

    Iteration t broadcasts A's t-th K-panel along rows and B's t-th K-panel
    along columns; every (i, j) tile accumulates ``A[i, t] @ B[t, j]``.
    ``kp`` is the number of K panels (defaults to max(p, q) like classical
    SUMMA); the result is reassembled from the per-tile outputs.
    """
    kp = kp or max(p, q)
    a_rows = _split(a, p, 0)
    b_cols = _split(b, q, 1)
    out_rows = []
    for i in range(p):
        a_panels = _split(a_rows[i], kp, 1)
        row = []
        for j in range(q):
            b_panels = _split(b_cols[j], kp, 0)
            acc = jnp.zeros((a_rows[i].shape[0], b_cols[j].shape[1]), jnp.float32)
            for t in range(kp):  # the broadcast step
                acc = acc + gemm_ref(a_panels[t], b_panels[t])
            row.append(acc)
        out_rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(out_rows, axis=0)


def splitk_ref(a, b, splits: int):
    """GEMM via 3D (split-K) tiling: disjoint K-slices + final reduction."""
    a_parts = _split(a, splits, 1)
    b_parts = _split(b, splits, 0)
    partials = [gemm_ref(ap, bp) for ap, bp in zip(a_parts, b_parts)]
    acc = partials[0]
    for p in partials[1:]:  # the NoC reduction
        acc = acc + p
    return acc


def systolic_ref(a, b, p: int):
    """GEMM via a p×p systolic wavefront (Cannon-skewed block rotation).

    Tile (i, j) at step t multiplies A-block (i, (i + j + t) % p) with
    B-block ((i + j + t) % p, j): the same blocks a nearest-neighbour
    right/down propagation delivers.
    """
    a_blocks = [_split(row, p, 1) for row in _split(a, p, 0)]
    b_blocks = [_split(row, p, 1) for row in _split(b, p, 0)]
    out_rows = []
    for i in range(p):
        row = []
        for j in range(p):
            acc = jnp.zeros(
                (a_blocks[i][0].shape[0], b_blocks[0][j].shape[1]), jnp.float32
            )
            for t in range(p):
                kk = (i + j + t) % p
                acc = acc + gemm_ref(a_blocks[i][kk], b_blocks[kk][j])
            row.append(acc)
        out_rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(out_rows, axis=0)

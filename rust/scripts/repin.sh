#!/usr/bin/env bash
# Re-pin rust/bench_baseline.json from EXACTLY the CI bench subset.
#
# The gate is strict in both directions (pinned-but-missing AND
# produced-but-unpinned both fail), so the pin set must match the CI
# subset ids one for one. This script is the only supported way to
# refresh the baseline: it runs the subset, rewrites the pins from the
# results, and proves the gate is green against them before you commit.
#
# Use it to tighten the conservative simulator-side pins (fig9/fig10/
# workload/dse/energy were committed as wide floors/ceilings from an
# environment without a Rust toolchain) back to the exact 5% gate, or
# after an intentional model change. Never run a full-bench --update: it
# would pin fig7*/fig8/fig11/fig12 metrics CI never produces and every
# later gate run would fail them as MISSING.
#
# Floor pins ("floor": true — *.sims_per_sec, the tiered sims_saved_pct
# contract, and the serve.exact/neighbor_hit_rate serving floors backed
# by committed-trace arithmetic) are preserved VERBATIM by --update:
# they are tolerance-free hard lower bounds (machine-dependent
# throughput, or a deliberate policy contract), and re-pinning them from
# one run would either make the gate flake on slower CI runners or
# silently relax the contract. Tighten them only by hand-editing
# bench_baseline.json to a value every runner clears comfortably.
#
# The CI repin lane (workflow_dispatch) runs exactly this script on a
# real runner; dispatch it with commit_repin=true to push the result
# back to the branch without a local toolchain.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo bench --bench figures -- table1 fig1 fig9 fig10 workload dse energy tiered serve check graph \
    --json BENCH_results.json
cargo run --release --bin bench_gate -- --update
cargo run --release --bin bench_gate -- \
    --baseline bench_baseline.json --results BENCH_results.json

git diff --stat -- bench_baseline.json || true
echo "bench_baseline.json re-pinned from the CI subset and verified green;"
echo "review the diff and commit it."

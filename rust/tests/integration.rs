//! Integration tests: the full three-layer stack.
//!
//! These tests require `make artifacts` (the JAX/Pallas → HLO-text AOT
//! step) to have run: they load the golden GEMM executables through the
//! PJRT CPU client and check the Rust functional executor — i.e. the
//! *deployment's* data movement over the simulated HBM/NoC — against the
//! XLA numbers. This is the paper's "Benchmark" stage ("compares results
//! against reference outputs to validate correctness") end-to-end.

use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator;
use dit::runtime::Oracle;
use dit::schedule::{retune_tk, Dataflow, Schedule};
use dit::util::rng::Rng;

fn oracle() -> Oracle {
    Oracle::open("artifacts").expect("run `make artifacts` before `cargo test`")
}

#[test]
fn oracle_matches_cpu_reference() {
    let mut o = oracle();
    let (m, n, k) = (64, 64, 64);
    let mut rng = Rng::new(11);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let got = o.gemm(m, n, k, &a, &b).unwrap();
    // Plain CPU reference.
    let mut want = vec![0f32; m * n];
    dit::functional::mmad_f32(&a, &b, &mut want, m, n, k);
    let diff = dit::functional::max_abs_diff(&got, &want);
    assert!(diff < 1e-3, "PJRT vs CPU reference diff {diff}");
}

#[test]
fn oracle_epilogue_matches_reference() {
    let mut o = oracle();
    let (m, n, k) = (64, 64, 64);
    let mut rng = Rng::new(13);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let bias = rng.f32_vec(n);
    let got = o.gemm_bias_relu(m, n, k, &a, &b, &bias).unwrap();
    let mut c = vec![0f32; m * n];
    dit::functional::mmad_f32(&a, &b, &mut c, m, n, k);
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = (c[i * n + j] + bias[j]).max(0.0);
        }
    }
    let diff = dit::functional::max_abs_diff(&got, &c);
    assert!(diff < 1e-3, "epilogue diff {diff}");
}

#[test]
fn manifest_covers_required_shape_families() {
    let o = oracle();
    let shapes = o.shapes("gemm");
    assert!(shapes.len() >= 5, "{shapes:?}");
    // The ragged §4.1.3 analogue and a flat-decode analogue must exist.
    assert!(shapes.iter().any(|&(_, n, _)| n == 66));
    assert!(shapes.iter().any(|&(m, n, _)| m <= 64 && n >= 8 * m));
}

/// Every artifact shape × a representative schedule set, verified
/// functionally against the PJRT golden GEMM on a 4×4 SoftHier.
#[test]
fn functional_deployments_match_pjrt_oracle() {
    let mut o = oracle();
    let arch = ArchConfig::tiny(4, 4);
    for (m, n, k) in o.shapes("gemm") {
        let shape = GemmShape::new(m, n, k);
        let mut scheds: Vec<Schedule> = vec![
            Schedule::summa(&arch, shape),
            Schedule::baseline(&arch, shape),
            Schedule::systolic(&arch, shape),
        ];
        if k >= 128 {
            scheds.push(Schedule::splitk(&arch, shape, 2));
        }
        // Hierarchical variants re-derive tk (they stage more in L1).
        scheds.push(retune_tk(&arch, shape, &Schedule {
            dataflow: Dataflow::SystolicOverSumma { group: 2 },
            ..Schedule::summa(&arch, shape)
        }));
        scheds.push(retune_tk(&arch, shape, &Schedule {
            dataflow: Dataflow::SummaOverSystolic { group: 2 },
            ..Schedule::summa(&arch, shape)
        }));
        for sched in scheds {
            let report = coordinator::verify(&arch, shape, &sched, &mut o, 0xA5)
                .unwrap_or_else(|e| panic!("{} on {shape}: {e}", sched.name()));
            assert!(
                report.passed(),
                "{} on {shape}: diff {} > tol {}",
                report.schedule,
                report.max_abs_diff,
                report.tolerance
            );
        }
    }
}

/// The flat-GEMM cluster-remap path (Insight 4) against the oracle.
#[test]
fn flat_remap_verifies_against_oracle() {
    let mut o = oracle();
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(64, 528, 512);
    for splits in [4, 8] {
        let sched = Schedule::flat_remap(&arch, shape, splits);
        let report = coordinator::verify(&arch, shape, &sched, &mut o, 0x5A).unwrap();
        assert!(report.passed(), "{}: diff {}", report.schedule, report.max_abs_diff);
    }
}

/// Autotuning end-to-end: the selected best schedule must also be
/// numerically correct.
#[test]
fn autotuned_best_schedule_is_correct() {
    let mut o = oracle();
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(128, 128, 128);
    let result = coordinator::autotune(&arch, shape).unwrap();
    let best = result.best().schedule.clone();
    let report = coordinator::verify(&arch, shape, &best, &mut o, 0x77).unwrap();
    assert!(report.passed(), "best={} diff {}", report.schedule, report.max_abs_diff);
}

/// Preload files round-trip through disk (the workflow's Preload stage).
#[test]
fn preload_file_roundtrip_on_disk() {
    use dit::layout::{preload::Preload, MatrixLayout};
    let l = MatrixLayout::optimized(32, 32, 4, (2, 2), (16, 16), 4);
    let mut p = Preload::new(4);
    p.scatter_f32(&l, &Rng::new(3).f32_vec(1024));
    let path = std::env::temp_dir().join(format!("dit_preload_{}.bin", std::process::id()));
    p.save(&path).unwrap();
    let q = Preload::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(p, q);
}

/// The CLI verify command wires everything together.
#[test]
fn cli_verify_command() {
    let argv: Vec<String> = "verify --shape 128x128x128 --grid 4 --schedule summa"
        .split_whitespace()
        .map(String::from)
        .collect();
    dit::cli::run(&argv).unwrap();
}

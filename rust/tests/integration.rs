//! Integration tests: the full three-layer stack.
//!
//! The golden-number tests come in two flavours:
//!
//! * **PJRT** — require `make artifacts` (the JAX/Pallas → HLO-text AOT
//!   step) *and* a build with `--features pjrt`. When either is missing
//!   the test prints a `SKIP` notice and returns instead of panicking, so
//!   `cargo test` stays green on a bare checkout.
//! * **CPU reference** — always run. `Oracle::cpu_reference()` computes
//!   golden numbers with f64 accumulation over the same artifact shape
//!   families, so the deployment data path (layouts, collectives, K-panel
//!   accumulation) is still asserted numerically without PJRT.

use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator;
use dit::runtime::Oracle;
use dit::schedule::{retune_tk, Dataflow, Schedule};
use dit::util::rng::Rng;

/// The PJRT oracle, or `None` (with a printed notice) when the artifacts
/// or the `pjrt` feature are absent.
fn pjrt_oracle() -> Option<Oracle> {
    match Oracle::open("artifacts") {
        Ok(o) => Some(o),
        Err(e) => {
            eprintln!("SKIP: PJRT oracle unavailable ({e:#})");
            eprintln!(
                "      run `make artifacts`, add the `xla` dependency to rust/Cargo.toml, \
                 and build with `--features pjrt` to enable"
            );
            None
        }
    }
}

/// Representative schedule set for a shape on a 4×4 grid (all dataflow
/// families, hierarchical variants re-deriving tk for their L1 staging).
fn schedule_set(arch: &ArchConfig, shape: GemmShape) -> Vec<Schedule> {
    let mut scheds: Vec<Schedule> = vec![
        Schedule::summa(arch, shape),
        Schedule::baseline(arch, shape),
        Schedule::systolic(arch, shape),
    ];
    if shape.k >= 128 {
        scheds.push(Schedule::splitk(arch, shape, 2));
    }
    scheds.push(retune_tk(arch, shape, &Schedule {
        dataflow: Dataflow::SystolicOverSumma { group: 2 },
        ..Schedule::summa(arch, shape)
    }));
    scheds.push(retune_tk(arch, shape, &Schedule {
        dataflow: Dataflow::SummaOverSystolic { group: 2 },
        ..Schedule::summa(arch, shape)
    }));
    scheds
}

/// Every oracle shape × the representative schedule set, verified
/// functionally on a 4×4 SoftHier.
fn verify_all_shapes(mut oracle: Oracle, seed: u64) {
    let arch = ArchConfig::tiny(4, 4);
    for (m, n, k) in oracle.shapes("gemm") {
        let shape = GemmShape::new(m, n, k);
        for sched in schedule_set(&arch, shape) {
            let report = coordinator::verify(&arch, shape, &sched, &mut oracle, seed)
                .unwrap_or_else(|e| panic!("{} on {shape}: {e}", sched.name()));
            assert!(
                report.passed(),
                "{} on {shape}: diff {} > tol {}",
                report.schedule,
                report.max_abs_diff,
                report.tolerance
            );
        }
    }
}

// ---------------- PJRT-backed tests (skip gracefully) ----------------

#[test]
fn oracle_matches_cpu_reference() {
    let Some(mut o) = pjrt_oracle() else { return };
    let (m, n, k) = (64, 64, 64);
    let mut rng = Rng::new(11);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let got = o.gemm(m, n, k, &a, &b).unwrap();
    // Plain CPU reference.
    let mut want = vec![0f32; m * n];
    dit::functional::mmad_f32(&a, &b, &mut want, m, n, k);
    let diff = dit::functional::max_abs_diff(&got, &want);
    assert!(diff < 1e-3, "PJRT vs CPU reference diff {diff}");
}

#[test]
fn oracle_epilogue_matches_reference() {
    let Some(mut o) = pjrt_oracle() else { return };
    let (m, n, k) = (64, 64, 64);
    let mut rng = Rng::new(13);
    let a = rng.f32_vec(m * k);
    let b = rng.f32_vec(k * n);
    let bias = rng.f32_vec(n);
    let got = o.gemm_bias_relu(m, n, k, &a, &b, &bias).unwrap();
    let mut c = vec![0f32; m * n];
    dit::functional::mmad_f32(&a, &b, &mut c, m, n, k);
    for i in 0..m {
        for j in 0..n {
            c[i * n + j] = (c[i * n + j] + bias[j]).max(0.0);
        }
    }
    let diff = dit::functional::max_abs_diff(&got, &c);
    assert!(diff < 1e-3, "epilogue diff {diff}");
}

#[test]
fn manifest_covers_required_shape_families() {
    let Some(o) = pjrt_oracle() else { return };
    let shapes = o.shapes("gemm");
    assert!(shapes.len() >= 5, "{shapes:?}");
    // The ragged §4.1.3 analogue and a flat-decode analogue must exist.
    assert!(shapes.iter().any(|&(_, n, _)| n == 66));
    assert!(shapes.iter().any(|&(m, n, _)| m <= 64 && n >= 8 * m));
}

#[test]
fn functional_deployments_match_pjrt_oracle() {
    let Some(o) = pjrt_oracle() else { return };
    verify_all_shapes(o, 0xA5);
}

/// The flat-GEMM cluster-remap path (Insight 4) against the oracle.
#[test]
fn flat_remap_verifies_against_oracle() {
    let Some(mut o) = pjrt_oracle() else { return };
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(64, 528, 512);
    for splits in [4, 8] {
        let sched = Schedule::flat_remap(&arch, shape, splits);
        let report = coordinator::verify(&arch, shape, &sched, &mut o, 0x5A).unwrap();
        assert!(report.passed(), "{}: diff {}", report.schedule, report.max_abs_diff);
    }
}

/// Autotuning end-to-end: the selected best schedule must also be
/// numerically correct.
#[test]
fn autotuned_best_schedule_is_correct() {
    let Some(mut o) = pjrt_oracle() else { return };
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(128, 128, 128);
    let result = coordinator::autotune(&arch, shape).unwrap();
    let best = result.best().schedule.clone();
    let report = coordinator::verify(&arch, shape, &best, &mut o, 0x77).unwrap();
    assert!(report.passed(), "best={} diff {}", report.schedule, report.max_abs_diff);
}

// ---------------- CPU-reference fallback tests (always run) ----------------
// (Shape-family coverage of the CPU oracle itself is asserted in
// runtime::tests::cpu_reference_covers_required_families.)

#[test]
fn functional_deployments_match_cpu_oracle() {
    verify_all_shapes(Oracle::cpu_reference(), 0xA5);
}

#[test]
fn flat_remap_verifies_against_cpu_oracle() {
    let mut o = Oracle::cpu_reference();
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(64, 528, 512);
    for splits in [4, 8] {
        let sched = Schedule::flat_remap(&arch, shape, splits);
        let report = coordinator::verify(&arch, shape, &sched, &mut o, 0x5A).unwrap();
        assert!(report.passed(), "{}: diff {}", report.schedule, report.max_abs_diff);
    }
}

#[test]
fn autotuned_best_schedule_is_correct_vs_cpu_oracle() {
    let mut o = Oracle::cpu_reference();
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(128, 128, 128);
    let result = coordinator::autotune(&arch, shape).unwrap();
    let best = result.best().schedule.clone();
    let report = coordinator::verify(&arch, shape, &best, &mut o, 0x77).unwrap();
    assert!(report.passed(), "best={} diff {}", report.schedule, report.max_abs_diff);
}

// ---------------- oracle-independent tests ----------------

/// Preload files round-trip through disk (the workflow's Preload stage).
#[test]
fn preload_file_roundtrip_on_disk() {
    use dit::layout::{preload::Preload, MatrixLayout};
    let l = MatrixLayout::optimized(32, 32, 4, (2, 2), (16, 16), 4);
    let mut p = Preload::new(4);
    p.scatter_f32(&l, &Rng::new(3).f32_vec(1024));
    let path = std::env::temp_dir().join(format!("dit_preload_{}.bin", std::process::id()));
    p.save(&path).unwrap();
    let q = Preload::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(p, q);
}

/// The CLI verify command wires everything together (CPU oracle fallback
/// when no artifacts are present).
#[test]
fn cli_verify_command() {
    let argv: Vec<String> = "verify --shape 128x128x128 --grid 4 --schedule summa"
        .split_whitespace()
        .map(String::from)
        .collect();
    dit::cli::run(&argv).unwrap();
}

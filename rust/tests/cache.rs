//! Persistent simulation-cache acceptance tests.
//!
//! Two properties carry the whole feature:
//!
//! * **corruption tolerance** — a truncated file, a wrong or unparseable
//!   header, binary junk, a foreign architecture fingerprint, or stray
//!   concurrent-writer temp files all degrade to a (partial) cold start
//!   with a recorded warning. Never an error, never a panic, never a
//!   wrong result.
//! * **resume determinism** — a sweep killed mid-run and resumed with
//!   `--cache` produces a bit-identical `DseResult` to a cold sweep
//!   while re-simulating only the unfinished configs.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::cache::{DiskCache, DiskKey, FORMAT, VERSION};
use dit::coordinator::engine::{arch_fingerprint, Engine};
use dit::dse::{self, DseOptions, DseResult, SweepSpec};

static SEQ: AtomicUsize = AtomicUsize::new(0);

/// A unique temp path per call (tests run concurrently in one process).
fn temp_cache(tag: &str) -> PathBuf {
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "dit-cache-it-{tag}-{}-{seq}.jsonl",
        std::process::id()
    ))
}

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        name: "cache-test".into(),
        meshes: SweepSpec::square_meshes(&[2, 3]),
        ce: vec![(16, 8)],
        spm_kib: vec![128, 256],
        hbm_channel_gbps: vec![32.0],
        hbm_channels_pct: vec![100],
        dma_engines: vec![2],
        base: ArchConfig::tiny(4, 4),
    }
}

fn tiny_workload() -> Workload {
    let mut w = Workload::new("cache-test");
    w.push("square", GemmShape::new(64, 64, 64), 2);
    w.push("flat", GemmShape::new(16, 128, 128), 1);
    w
}

fn opts(cache: Option<&PathBuf>) -> DseOptions {
    DseOptions {
        workers: 2,
        config_parallelism: 3,
        cache_path: cache.cloned(),
        ..DseOptions::default()
    }
}

/// Every determinism-relevant field of two sweep results must agree, bit
/// for bit. (`elapsed_ms` is wall clock and deliberately excluded.)
fn assert_bit_identical(a: &DseResult, b: &DseResult) {
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.arch.name, y.arch.name);
        assert_eq!(x.cost.to_bits(), y.cost.to_bits(), "{}", x.arch.name);
        assert_eq!(x.tflops.to_bits(), y.tflops.to_bits(), "{}", x.arch.name);
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{}", x.arch.name);
        assert_eq!(x.tflops_per_w.to_bits(), y.tflops_per_w.to_bits(), "{}", x.arch.name);
        assert_eq!(x.on_frontier, y.on_frontier, "{}", x.arch.name);
        assert_eq!(x.on_frontier3, y.on_frontier3, "{}", x.arch.name);
        assert_eq!(
            x.report.total_time_ns().to_bits(),
            y.report.total_time_ns().to_bits(),
            "{}",
            x.arch.name
        );
    }
    let pa: Vec<_> = a.pruned.iter().map(|p| p.name.clone()).collect();
    let pb: Vec<_> = b.pruned.iter().map(|p| p.name.clone()).collect();
    assert_eq!(pa, pb, "prune decisions must match");
    assert_eq!(a.infeasible, b.infeasible);
}

/// Acceptance: a sweep killed mid-run resumes from its checkpoint with a
/// bit-identical result, re-simulating only what the checkpoint misses.
///
/// The "kill" is simulated faithfully: the engine checkpoints the cache
/// file atomically after every evaluated config, so a killed run leaves
/// a file holding a subset of the final entries — which is exactly what
/// keeping a prefix of the completed file's entry lines reconstructs.
#[test]
fn killed_sweep_resumes_bit_identical_with_disk_hits() {
    let full = temp_cache("resume-full");
    let partial = temp_cache("resume-partial");
    let spec = tiny_spec();
    let w = tiny_workload();

    // Reference cold sweep (no cache involved at all).
    let cold = dse::run_sweep(&spec, &w, &opts(None)).unwrap();

    // A complete cached run, from which we reconstruct the checkpoint a
    // mid-run kill would have left behind: header + half the entries.
    let done = dse::run_sweep(&spec, &w, &opts(Some(&full))).unwrap();
    assert_eq!(done.disk_hits, 0, "first cached run starts cold");
    assert_bit_identical(&cold, &done);
    let text = std::fs::read_to_string(&full).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() > 3, "sweep must persist several entries");
    let keep = 1 + (lines.len() - 1) / 2;
    let mut prefix = lines[..keep].join("\n");
    prefix.push('\n');
    std::fs::write(&partial, prefix).unwrap();

    // Resume from the partial checkpoint.
    let resumed = dse::run_sweep(&spec, &w, &opts(Some(&partial))).unwrap();
    assert_eq!(resumed.disk_loaded, keep - 1, "checkpoint entries preloaded");
    assert!(resumed.disk_hits >= 1, "resume must hit the disk cache");
    assert!(
        resumed.sim_calls < cold.sim_calls,
        "resume re-simulates only the unfinished part ({} vs {})",
        resumed.sim_calls,
        cold.sim_calls
    );
    assert_eq!(
        resumed.sim_calls + resumed.disk_hits,
        cold.sim_calls,
        "every candidate is either resumed from disk or re-simulated"
    );
    assert_bit_identical(&cold, &resumed);

    // And a fully-warm third run simulates nothing at all.
    let warm = dse::run_sweep(&spec, &w, &opts(Some(&full))).unwrap();
    assert_eq!(warm.sim_calls, 0, "complete checkpoint serves everything");
    assert!(warm.disk_hits > 0);
    assert_bit_identical(&cold, &warm);

    let _ = std::fs::remove_file(&full);
    let _ = std::fs::remove_file(&partial);
}

/// A refined sweep (extra axis values around the frontier) reuses every
/// overlapping point from the coarse sweep's cache.
#[test]
fn refined_sweep_reuses_overlapping_points() {
    let path = temp_cache("refine");
    let w = tiny_workload();
    let mut coarse = tiny_spec();
    coarse.meshes = vec![(2, 2)];
    let first = dse::run_sweep(&coarse, &w, &opts(Some(&path))).unwrap();
    assert!(first.sim_calls > 0);

    let mut fine = tiny_spec();
    fine.meshes = vec![(2, 2), (3, 3)]; // superset of the coarse sweep
    let second = dse::run_sweep(&fine, &w, &opts(Some(&path))).unwrap();
    let cold = dse::run_sweep(&fine, &w, &opts(None)).unwrap();
    assert!(second.disk_hits > 0, "overlapping configs come from disk");
    assert!(
        second.sim_calls < cold.sim_calls,
        "refinement must reuse the coarse sweep ({} vs {})",
        second.sim_calls,
        cold.sim_calls
    );
    assert_bit_identical(&cold, &second);
    let _ = std::fs::remove_file(&path);
}

/// Corruption suite: every damaged-file shape degrades to a cold start
/// (or partial load) with a warning — opening never fails or panics, and
/// a subsequent tuning run still produces correct results.
#[test]
fn corrupt_cache_files_degrade_to_cold_start() {
    let arch = ArchConfig::tiny(2, 2);
    let w = Workload::single("s", GemmShape::new(64, 64, 64));
    let reference = Engine::new(&arch).tune_workload(&w).unwrap();

    // Build one good cache file to mutate.
    let good = temp_cache("corrupt-good");
    Engine::new(&arch).with_cache(&good).tune_workload(&w).unwrap();
    let good_text = std::fs::read_to_string(&good).unwrap();
    let n_entries = good_text.lines().count() - 1;
    assert!(n_entries >= 2, "need several entries to truncate meaningfully");

    struct Case {
        name: &'static str,
        content: Vec<u8>,
        expect_loaded: usize,
        expect_warning: bool,
    }
    let cases = [
        Case {
            name: "truncated mid-entry",
            content: {
                // Cut the file in the middle of its final line.
                let cut = good_text.trim_end().len() - 20;
                good_text.as_bytes()[..cut].to_vec()
            },
            expect_loaded: n_entries - 1,
            expect_warning: true,
        },
        Case {
            name: "wrong version header",
            content: good_text
                .replacen(&format!("\"version\":{VERSION}"), "\"version\":999", 1)
                .into_bytes(),
            expect_loaded: 0,
            expect_warning: true,
        },
        Case {
            name: "foreign format header",
            content: good_text.replacen(FORMAT, "someone-elses-cache", 1).into_bytes(),
            expect_loaded: 0,
            expect_warning: true,
        },
        Case {
            name: "unparseable header",
            content: b"ceci n'est pas du json\n".to_vec(),
            expect_loaded: 0,
            expect_warning: true,
        },
        Case {
            name: "empty file",
            content: Vec::new(),
            expect_loaded: 0,
            expect_warning: true,
        },
        Case {
            name: "binary junk (invalid utf-8)",
            content: vec![0xff, 0xfe, 0x00, 0x80, 0xff],
            expect_loaded: 0,
            expect_warning: true,
        },
        Case {
            name: "garbled entry among good ones",
            content: {
                let mut lines: Vec<&str> = good_text.lines().collect();
                lines.insert(2, "{\"fp\":\"zz-not-hex\",\"shape\":1}");
                (lines.join("\n") + "\n").into_bytes()
            },
            expect_loaded: n_entries,
            expect_warning: true,
        },
    ];

    for case in cases {
        let path = temp_cache("corrupt-case");
        std::fs::write(&path, &case.content).unwrap();
        let cache = DiskCache::open(&path);
        assert_eq!(cache.loaded(), case.expect_loaded, "{}", case.name);
        assert_eq!(
            !cache.warnings().is_empty(),
            case.expect_warning,
            "{}: {:?}",
            case.name,
            cache.warnings()
        );
        // The engine still tunes correctly on top of the damaged file,
        // re-simulating whatever was lost.
        let engine = Engine::new(&arch).with_cache(&path);
        let rep = engine.tune_workload(&w).unwrap();
        assert_eq!(
            rep.sim_calls + rep.disk_hits,
            reference.sim_calls,
            "{}: every candidate must be served or re-simulated",
            case.name
        );
        assert_eq!(rep.disk_hits, case.expect_loaded, "{}", case.name);
        assert_eq!(
            rep.shapes[0].result.best().stats.makespan_ns.to_bits(),
            reference.shapes[0].result.best().stats.makespan_ns.to_bits(),
            "{}: results must match a cold run bit for bit",
            case.name
        );
        let _ = std::fs::remove_file(&path);
    }
    let _ = std::fs::remove_file(&good);
}

/// Entries for a different architecture (a "foreign" fingerprint) are
/// simply misses — never mis-hits — because the fingerprint is part of
/// the key. This is what makes the stable-hash bugfix load-bearing: with
/// an unstable fingerprint the same entries would go from hits to misses
/// (or worse) across toolchains.
#[test]
fn foreign_fingerprint_entries_never_mishit() {
    let path = temp_cache("foreign");
    let w = Workload::single("s", GemmShape::new(64, 64, 64));
    let a22 = ArchConfig::tiny(2, 2);
    let a44 = ArchConfig::tiny(4, 4);
    Engine::new(&a22).with_cache(&path).tune_workload(&w).unwrap();

    let engine = Engine::new(&a44).with_cache(&path);
    assert!(engine.disk_loaded() > 0, "the foreign entries do load");
    let rep = engine.tune_workload(&w).unwrap();
    assert_eq!(rep.disk_hits, 0, "foreign-arch entries must not hit");
    assert!(rep.sim_calls > 0, "everything re-simulates (cold start)");
    // Both architectures' entries now coexist in one file.
    let cache = DiskCache::open(&path);
    let fps: Vec<u64> = cache.fingerprint_counts().iter().map(|(fp, _)| *fp).collect();
    assert!(fps.contains(&arch_fingerprint(&a22)));
    assert!(fps.contains(&arch_fingerprint(&a44)));
    let _ = std::fs::remove_file(&path);
}

/// Rectangular-vs-square isolation: a 16×4 and an 8×8 instance with
/// identical per-tile parameters (and even the same name) share a tile
/// count but are different machines — their fingerprints differ, and a
/// cache warmed on one serves **zero** disk hits to the other, in both
/// directions.
#[test]
fn rectangular_mesh_never_aliases_square_with_same_tile_count() {
    let path = temp_cache("rect-square");
    let w = Workload::single("s", GemmShape::new(64, 64, 64));
    let mk = |rows, cols| {
        let mut a = ArchConfig::tiny(rows, cols);
        // Same name and HBM system: only the mesh geometry differs.
        a.name = "geom-test".into();
        a.hbm.channels_per_edge = 4;
        a
    };
    let rect = mk(16, 4);
    let square = mk(8, 8);
    assert_eq!(rect.num_tiles(), square.num_tiles());
    assert_eq!(rect.tile, square.tile);
    assert_ne!(
        arch_fingerprint(&rect),
        arch_fingerprint(&square),
        "equal tile counts must not collapse to one fingerprint"
    );

    Engine::new(&rect).with_cache(&path).tune_workload(&w).unwrap();
    let engine = Engine::new(&square).with_cache(&path);
    assert!(engine.disk_loaded() > 0, "the 16x4 entries do load");
    let rep = engine.tune_workload(&w).unwrap();
    assert_eq!(rep.disk_hits, 0, "16x4 entries must never serve the 8x8 mesh");
    assert!(rep.sim_calls > 0, "the square mesh tunes from a cold start");
    drop(engine);

    // The reverse direction, against the now-mixed file: 16x4 still hits
    // only its own entries, completely.
    let warm = Engine::new(&rect).with_cache(&path).tune_workload(&w).unwrap();
    assert_eq!(warm.sim_calls, 0, "every 16x4 candidate is served from disk");
    assert!(warm.disk_hits > 0);
    let cache = DiskCache::open(&path);
    let fps: Vec<u64> = cache.fingerprint_counts().iter().map(|(fp, _)| *fp).collect();
    assert!(fps.contains(&arch_fingerprint(&rect)));
    assert!(fps.contains(&arch_fingerprint(&square)));
    let _ = std::fs::remove_file(&path);
}

/// Stray temp files from a concurrently-killed writer neither break
/// loading nor leak: `clear` sweeps them up.
#[test]
fn concurrent_writer_temp_files_are_tolerated_and_cleared() {
    let path = temp_cache("straytmp");
    let arch = ArchConfig::tiny(2, 2);
    let w = Workload::single("s", GemmShape::new(64, 64, 64));
    Engine::new(&arch).with_cache(&path).tune_workload(&w).unwrap();

    // A killed concurrent writer leaves half-written temp files beside
    // the cache; loading must ignore them entirely.
    let name = path.file_name().unwrap().to_string_lossy().into_owned();
    let stray1 = path.with_file_name(format!("{name}.tmp.12345.0"));
    let stray2 = path.with_file_name(format!("{name}.tmp.12345.1"));
    std::fs::write(&stray1, "{\"format\":\"dit-sim-cache\",\"ver").unwrap();
    std::fs::write(&stray2, [0xffu8, 0x00]).unwrap();

    let engine = Engine::new(&arch).with_cache(&path);
    assert!(engine.disk_loaded() > 0);
    let rep = engine.tune_workload(&w).unwrap();
    assert_eq!(rep.sim_calls, 0, "main file unaffected by stray temps");
    assert!(rep.disk_hits > 0);

    let (removed, temps) = DiskCache::clear(&path).unwrap();
    assert!(removed);
    assert_eq!(temps, 2, "both stray temp files swept");
    assert!(!stray1.exists() && !stray2.exists());
    let _ = std::fs::remove_file(&path);
}

/// The disk key is stable text end to end: fingerprints come from the
/// specified FNV-1a (not the toolchain-dependent DefaultHasher), so an
/// entry written today is addressable by any future build.
#[test]
fn disk_keys_are_stable_text() {
    let path = temp_cache("stablekey");
    let arch = ArchConfig::tiny(2, 2);
    let w = Workload::single("s", GemmShape::new(64, 64, 64));
    Engine::new(&arch).with_cache(&path).tune_workload(&w).unwrap();

    let fp = arch_fingerprint(&arch);
    assert_eq!(fp, dit::util::fnv1a64(arch.to_text().as_bytes()), "specified hash");
    let text = std::fs::read_to_string(&path).unwrap();
    let hex = format!("{fp:016x}");
    assert!(
        text.lines().skip(1).all(|l| l.contains(&hex)),
        "every entry carries the canonical hex fingerprint"
    );
    assert!(text.contains("64x64x64"), "shape keys are MxNxK text");

    // The cache also answers direct DiskKey lookups built from public,
    // stable components (what an external tool would compute).
    let cache = DiskCache::open(&path);
    let sched = dit::schedule::Schedule::summa(&arch, GemmShape::new(64, 64, 64));
    let key = DiskKey { arch_fp: fp, shape: "64x64x64".into(), sched: sched.cache_key() };
    assert!(cache.get(&key).is_some(), "summa candidate addressable by stable key");
    let _ = std::fs::remove_file(&path);
}

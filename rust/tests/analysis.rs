//! Agreement suite for the static deployment checker.
//!
//! The checker's value rests on one contract: **accept ⟹ deployable and
//! simulable, reject ⟺ the deployment pipeline itself would fail.** These
//! tests pin that contract from outside the crate — randomized schedules
//! through the quickprop harness, the full candidate enumeration, every
//! committed preset and built-in suite (no false rejections), emitted
//! deployments per dataflow family, and a hand-corrupted deployment that
//! must be caught as a cross-superstep deadlock.

use dit::analysis::{
    check_arch, check_deployment, check_schedule, check_workload, codes, Severity,
};
use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::codegen::generate;
use dit::coordinator::{self, deploy_chunked};
use dit::ir::Op;
use dit::schedule::{candidates, Dataflow, Schedule};
use dit::util::quickprop::check;

/// Random (arch, shape, schedule) triples — including deliberately
/// broken ones (oversubscribed source grids, perturbed tk/stages) —
/// must satisfy: `rejected()` exactly when `deploy_chunked` errors, and
/// acceptance implies a panic-free simulation within physical bounds.
/// Replay failures with `DIT_PROP_SEED` (see `util::quickprop`).
#[test]
fn prop_checker_agrees_with_deployment() {
    check("checker/deploy agreement", 24, |rng| {
        let grids = [(2usize, 2usize), (2, 4), (4, 4), (4, 2)];
        let (r, c) = grids[rng.below(grids.len() as u64) as usize];
        let arch = ArchConfig::tiny(r, c);
        let shape = GemmShape::new(
            rng.range(1, 16) * 8,
            rng.range(1, 16) * 8,
            rng.range(1, 8) * 32,
        );
        // Build from the target arch or a deliberately larger one (the
        // oversubscription class), then perturb the knobs the checker
        // models so both accept and reject branches are exercised.
        let big = ArchConfig::tiny(8, 8);
        let src = if rng.below(4) == 0 { &big } else { &arch };
        let mut s = match rng.below(5) {
            0 => Schedule::summa(src, shape),
            1 => Schedule::baseline(src, shape),
            2 => Schedule::systolic(src, shape),
            3 => Schedule::splitk(src, shape, [1, 2, 4][rng.below(3) as usize]),
            _ => Schedule::flat_remap(src, shape, [2, 4, 8][rng.below(3) as usize]),
        };
        match rng.below(6) {
            0 => s.tk = [1, 8, 16, 64, 512][rng.below(5) as usize],
            1 => s.pipeline_stages = rng.range(0, 5),
            2 => s.double_buffer = !s.double_buffer,
            _ => {}
        }
        let rep = check_schedule(&arch, shape, &s);
        let deployed = deploy_chunked(&arch, shape, &s);
        assert_eq!(
            rep.rejected(),
            deployed.is_err(),
            "{} on {shape} ({r}x{c}): checker says {}, deploy says {}\n{}",
            s.name(),
            if rep.rejected() { "reject" } else { "accept" },
            match &deployed {
                Ok(_) => "deployable".to_string(),
                Err(e) => format!("error ({e:#})"),
            },
            rep.render()
        );
        if let Ok(deps) = &deployed {
            let stats = coordinator::simulate_chunked(&arch, deps)
                .unwrap_or_else(|e| panic!("accepted {} failed to simulate: {e:#}", s.name()));
            assert!(
                stats.makespan_ns.is_finite() && stats.makespan_ns > 0.0,
                "{}: makespan {}",
                s.name(),
                stats.makespan_ns
            );
            assert!(stats.utilization() <= 1.0 + 1e-9, "{}", s.name());
            assert!(stats.hbm_utilization() <= 1.0 + 1e-9, "{}", s.name());
        }
    });
}

/// Everything `candidates()` enumerates is checker-accepted — the
/// no-false-rejection half of the contract on the paths the engine
/// actually tunes (this is what makes the engine's pre-simulation gate
/// a no-op on enumerated candidates, and its counter zero).
#[test]
fn enumerated_candidates_are_never_rejected() {
    let shapes = [
        GemmShape::new(64, 64, 64),
        GemmShape::new(128, 96, 256),
        GemmShape::new(32, 264, 512),
    ];
    let mut checked = 0usize;
    for (r, c) in [(2, 2), (4, 4), (2, 4)] {
        let arch = ArchConfig::tiny(r, c);
        for shape in shapes {
            for s in candidates(&arch, shape) {
                let rep = check_schedule(&arch, shape, &s);
                assert!(
                    !rep.rejected(),
                    "{} on {shape} ({r}x{c}) falsely rejected:\n{}",
                    s.name(),
                    rep.render()
                );
                checked += 1;
            }
        }
    }
    assert!(checked >= 30, "candidate matrix shrank to {checked}");
}

/// The committed presets lint clean, and the built-in GEMM suites have
/// deployable candidates on the machines they are meant for.
#[test]
fn presets_and_builtin_suites_lint_clean() {
    for arch in [ArchConfig::gh200_like(), ArchConfig::a100_like(), ArchConfig::tiny(8, 8)] {
        let rep = check_arch(&arch);
        assert_eq!(rep.errors(), 0, "{}:\n{}", arch.name, rep.render());
    }
    let gh200 = ArchConfig::gh200_like();
    for name in Workload::builtin_names() {
        let w = Workload::builtin(name).unwrap();
        let rep = check_workload(&gh200, &w);
        assert_eq!(rep.errors(), 0, "suite {name} on gh200:\n{}", rep.render());
    }
    let tiny = ArchConfig::tiny(8, 8);
    let rep = check_workload(&tiny, &Workload::builtin("tiny").unwrap());
    assert_eq!(rep.errors(), 0, "tiny suite on tiny8:\n{}", rep.render());
}

/// A workload with no deployable candidate is rejected with `DIT-E081`
/// naming the shape — the DSE pre-prune path.
#[test]
fn undeployable_workload_reports_e081() {
    // 2x2 mesh squeezed to the 4 KiB L1 floor: no candidate fits a
    // 4096-cube even after the chunking ladder.
    let mut arch = ArchConfig::tiny(2, 2);
    arch.tile.l1_bytes = 4096;
    let w = Workload::single("s", GemmShape::new(4096, 4096, 4096));
    let rep = check_workload(&arch, &w);
    assert!(rep.has_code(codes::E081), "{}", rep.render());
    let d = rep.diags.iter().find(|d| d.code == codes::E081.0).unwrap();
    assert!(d.message.contains("4096x4096x4096"), "{}", d.message);
}

/// Post-emission audit: every deployment `codegen::generate` produces
/// across the dataflow families passes the IR, deadlock and HBM-layout
/// passes with zero errors.
#[test]
fn emitted_deployments_pass_the_checker() {
    let arch = ArchConfig::tiny(4, 4);
    let mut checked = 0usize;
    for shape in [
        GemmShape::new(64, 64, 64),
        GemmShape::new(128, 96, 256),
        GemmShape::new(32, 264, 512),
    ] {
        let scheds = [
            Schedule::summa(&arch, shape),
            Schedule::baseline(&arch, shape),
            Schedule::systolic(&arch, shape),
            Schedule::splitk(&arch, shape, 2),
            Schedule::flat_remap(&arch, shape, 2),
            Schedule {
                dataflow: Dataflow::SystolicOverSumma { group: 2 },
                ..Schedule::summa(&arch, shape)
            },
            Schedule {
                dataflow: Dataflow::SummaOverSystolic { group: 2 },
                ..Schedule::summa(&arch, shape)
            },
        ];
        for sched in scheds {
            // Undeployable combos are legitimate (and covered by the
            // agreement property above); the audit concerns emitted IR.
            let Ok(dep) = generate(&arch, shape, &sched, arch.elem_bytes) else {
                continue;
            };
            let rep = check_deployment(&arch, &dep);
            assert_eq!(rep.errors(), 0, "{} on {shape}:\n{}", sched.name(), rep.render());
            checked += 1;
        }
    }
    assert!(checked >= 10, "only {checked} deployable combos audited");
}

/// Moving one multicast receive leg a superstep later is the classic
/// cross-barrier deadlock; the checker must flag it as `DIT-E045` with
/// a per-superstep location and say where the stray partner sits.
#[test]
fn cross_superstep_rendezvous_is_flagged_as_deadlock() {
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(64, 64, 128);
    let mut dep = generate(&arch, shape, &Schedule::summa(&arch, shape), 4).unwrap();
    let mut moved = false;
    'outer: for p in &mut dep.programs {
        for si in 0..p.steps.len() {
            if let Some(pos) =
                p.steps[si].ops.iter().position(|o| matches!(o, Op::RecvMulticast { .. }))
            {
                let op = p.steps[si].ops.remove(pos);
                p.reserve_steps(si + 2);
                p.steps[si + 1].ops.push(op);
                moved = true;
                break 'outer;
            }
        }
    }
    assert!(moved, "SUMMA deployment unexpectedly has no RecvMulticast");
    let rep = check_deployment(&arch, &dep);
    assert!(rep.has_code(codes::E045), "{}", rep.render());
    let d = rep
        .diags
        .iter()
        .find(|d| d.code == codes::E045.0)
        .expect("deadlock diagnostic present");
    assert_eq!(d.severity, Severity::Error);
    assert!(d.message.contains("superstep"), "{}", d.message);
    assert!(d.loc.superstep.is_some(), "deadlock diag carries its superstep");
    // The "partner posted one barrier late" refinement names the stray step.
    assert!(
        rep.diags
            .iter()
            .any(|d| d.code == codes::E045.0 && d.message.contains("different barriers")),
        "{}",
        rep.render()
    );
}

/// Every stable diagnostic code — code string and kebab name — appears
/// in the README's "Diagnostic codes" table. Codes are user-facing API;
/// an undocumented code is a doc bug.
#[test]
fn readme_documents_every_diagnostic_code() {
    let readme = std::fs::read_to_string("README.md").expect("README.md");
    for (code, name) in codes::ALL {
        assert!(readme.contains(code), "README is missing {code}");
        assert!(readme.contains(name), "README is missing the name {name} ({code})");
    }
}

/// The committed config files stay in sync with the in-crate presets,
/// and the files the CI lint lane feeds to `dit check` lint clean.
#[test]
fn committed_configs_match_presets_and_lint_clean() {
    for (path, preset) in [
        ("configs/gh200.dit", ArchConfig::gh200_like()),
        ("configs/a100.dit", ArchConfig::a100_like()),
        ("configs/tiny8.dit", ArchConfig::tiny(8, 8)),
    ] {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path}: {e}"));
        let parsed =
            ArchConfig::from_text(&text).unwrap_or_else(|e| panic!("{path}: {e:#}"));
        assert_eq!(parsed, preset, "{path} drifted from its preset");
        assert_eq!(check_arch(&parsed).errors(), 0, "{path} does not lint clean");
    }
    // The committed sweep spec parses, enumerates a non-empty design
    // space, and drops no points (no DIT-W082 in the CI lint output).
    let text = std::fs::read_to_string("configs/sweep_reduced.dit").expect("committed spec");
    let spec = dit::dse::SweepSpec::from_text(&text).expect("sweep spec parses");
    let configs = spec.enumerate();
    assert_eq!(configs.len(), 10, "reduced sweep should enumerate 5 meshes x 2 SPM sizes");
}

/// Malformed user inputs across the boundary parsers error cleanly —
/// never panic — and zero dimensions are stopped at the gate.
#[test]
fn malformed_inputs_error_cleanly() {
    assert!(GemmShape::parse("axbxc").is_err());
    assert!(GemmShape::parse("64x64").is_err());
    assert!(GemmShape::parse("0x8x8").is_err(), "zero dims rejected");
    assert!(GemmShape::parse("8x8x0").is_err());

    assert!(dit::util::cfgtext::Doc::parse("[grid").is_err());
    assert!(dit::util::cfgtext::Doc::parse("x = \"unterminated").is_err());
    assert!(dit::util::cfgtext::Doc::parse("just some words").is_err());

    assert!(dit::coordinator::shapedb::parse_trace("64x64x64\nnot-a-shape\n").is_err());
    assert!(dit::coordinator::shapedb::parse_trace("# only comments\n").is_err());
    assert!(dit::coordinator::shapedb::parse_trace("64x64x64\n0x4x4\n").is_err());
}

//! The tiered-tuning calibration contract, asserted end to end:
//!
//! 1. **Calibration** — on every built-in-suite shape, across square and
//!    rectangular meshes, the tiered winner's *simulated* makespan stays
//!    within `EPSILON` of the exhaustive winner's (the candidate families
//!    covered include baseline, SUMMA, split-K, and the flat-GEMM remap —
//!    the tiny suite's square/ragged/flat shapes enumerate all of them).
//! 2. **Determinism** — the exploration band is a pure function of
//!    (architecture, shape, policy): two fresh tiered engines produce
//!    bit-identical selections, rankings, and makespans, regardless of
//!    worker count.
//! 3. **Cache interop** — tiering changes which candidates simulate, not
//!    how they are keyed: a tiered run populates the persistent cache
//!    with entries an exhaustive run reuses verbatim (and vice versa),
//!    so checkpoints stay valid across policy changes.

use dit::arch::workload::Workload;
use dit::arch::ArchConfig;
use dit::coordinator::engine::{Engine, TunePolicy};

/// Maximum relative drift of the tiered winner's simulated makespan above
/// the exhaustive winner's (the contract the bench baseline also pins).
const EPSILON: f64 = 0.10;

/// Square plus both rectangular orientations: the tiering policy must
/// hold wherever the rectangular HBM-edge rule changes the estimates.
fn meshes() -> [ArchConfig; 3] {
    [ArchConfig::tiny(4, 4), ArchConfig::tiny(2, 4), ArchConfig::tiny(4, 2)]
}

#[test]
fn tiered_winner_tracks_exhaustive_within_epsilon() {
    let w = Workload::builtin("tiny").unwrap();
    for arch in meshes() {
        let exh = Engine::new(&arch).tune_workload(&w).unwrap();
        let tier = Engine::new(&arch)
            .with_policy(TunePolicy::tiered_default())
            .tune_workload(&w)
            .unwrap();
        assert!(
            tier.sim_calls < exh.sim_calls,
            "{}: tiering saved nothing ({} vs {} sims)",
            arch.name,
            tier.sim_calls,
            exh.sim_calls
        );
        for (e, t) in exh.shapes.iter().zip(&tier.shapes) {
            let eb = e.result.best().stats.makespan_ns;
            let tb = t.result.best().stats.makespan_ns;
            // The tiered winner comes from a subset of the exhaustive
            // candidate set, so it can never be faster...
            assert!(
                tb >= eb,
                "{} on {}: tiered winner {tb} ns beats exhaustive {eb} ns",
                e.shape,
                arch.name
            );
            // ...and the contract is that it is never much slower.
            assert!(
                tb <= eb * (1.0 + EPSILON),
                "{} on {}: tiered winner {tb} ns drifts more than {:.0}% above \
                 exhaustive {eb} ns",
                e.shape,
                arch.name,
                EPSILON * 100.0
            );
        }
    }
}

#[test]
fn exploration_band_is_deterministic() {
    let w = Workload::builtin("tiny").unwrap();
    for arch in meshes() {
        let run = |workers: usize| {
            Engine::new(&arch)
                .with_workers(workers)
                .with_policy(TunePolicy::tiered_default())
                .tune_workload(&w)
                .unwrap()
        };
        let a = run(1);
        let b = run(3);
        assert_eq!(a.sim_calls, b.sim_calls, "{}", arch.name);
        assert_eq!(a.sims_saved, b.sims_saved, "{}", arch.name);
        for (x, y) in a.shapes.iter().zip(&b.shapes) {
            assert_eq!(x.result.ranking.len(), y.result.ranking.len(), "{}", x.shape);
            for (p, q) in x.result.ranking.iter().zip(&y.result.ranking) {
                assert_eq!(p.schedule, q.schedule, "{} on {}", x.shape, arch.name);
                assert_eq!(
                    p.stats.makespan_ns.to_bits(),
                    q.stats.makespan_ns.to_bits(),
                    "{} on {}",
                    x.shape,
                    arch.name
                );
            }
        }
    }
}

#[test]
fn tiered_shares_the_disk_cache_with_exhaustive() {
    let path =
        std::env::temp_dir().join(format!("dit-tiered-cache-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let arch = ArchConfig::tiny(4, 4);
    let w = Workload::builtin("tiny").unwrap();

    // Cold tiered run: simulates its selection, checkpoints it to disk.
    let cold_engine =
        Engine::new(&arch).with_policy(TunePolicy::tiered_default()).with_cache(&path);
    let cold = cold_engine.tune_workload(&w).unwrap();
    assert!(cold.sim_calls > 0, "cold tiered run simulates");
    assert_eq!(cold.disk_hits, 0, "nothing on disk yet");
    assert!(cold.sims_saved > 0, "tiering saved something");
    assert!(path.exists(), "tiered run checkpoints like any other");
    drop(cold_engine);

    // A fresh tiered engine resumes entirely from those entries: the
    // selection is cache-independent, so it re-selects the same set and
    // finds every member on disk.
    let warm_engine =
        Engine::new(&arch).with_policy(TunePolicy::tiered_default()).with_cache(&path);
    assert!(warm_engine.disk_loaded() > 0);
    let warm = warm_engine.tune_workload(&w).unwrap();
    assert_eq!(warm.sim_calls, 0, "warm tiered rerun must be fully disk-served");
    assert!(warm.disk_hits > 0);
    assert_eq!(
        warm.sims_saved, cold.sims_saved,
        "saved counts are pre-cache, so they do not depend on cache state"
    );
    for (c, h) in cold.shapes.iter().zip(&warm.shapes) {
        assert_eq!(c.result.ranking.len(), h.result.ranking.len(), "{}", c.shape);
        for (x, y) in c.result.ranking.iter().zip(&h.result.ranking) {
            assert_eq!(x.schedule, y.schedule);
            assert_eq!(x.stats.makespan_ns.to_bits(), y.stats.makespan_ns.to_bits());
        }
    }
    drop(warm_engine);

    // An exhaustive engine on the same path reuses the tiered entries
    // verbatim (same keys), so it only simulates the unselected
    // remainder — and its output is bit-identical to a cache-less
    // exhaustive run.
    let exh_cold = Engine::new(&arch).tune_workload(&w).unwrap();
    let exh_engine = Engine::new(&arch).with_cache(&path);
    let exh_cached = exh_engine.tune_workload(&w).unwrap();
    assert!(exh_cached.disk_hits > 0, "exhaustive run must hit the tiered entries");
    assert_eq!(
        exh_cached.sim_calls,
        exh_cold.sim_calls - cold.sim_calls,
        "exhaustive-after-tiered simulates exactly the unselected remainder"
    );
    for (a, b) in exh_cold.shapes.iter().zip(&exh_cached.shapes) {
        assert_eq!(a.result.ranking.len(), b.result.ranking.len(), "{}", a.shape);
        for (x, y) in a.result.ranking.iter().zip(&b.result.ranking) {
            assert_eq!(x.schedule, y.schedule);
            assert_eq!(x.stats.makespan_ns.to_bits(), y.stats.makespan_ns.to_bits());
        }
    }
    drop(exh_engine);
    let _ = std::fs::remove_file(&path);
}

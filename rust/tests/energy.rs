//! Energy-model and N-dimensional-frontier acceptance tests: monotonicity
//! of the energy model in every traffic counter, agreement of the K-D
//! Pareto calculus with the 2-D fast path, frontier inclusion laws, and
//! the end-to-end energy-aware sweep (determinism, prune soundness,
//! scalarization). Everything runs on tiny grids / synthetic points so
//! the suite stays fast in debug builds.

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::dse::pareto::Sense;
use dit::dse::{self, pareto, DseOptions, Objective, SweepSpec};
use dit::perfmodel::EnergyModel;
use dit::sim::RunStats;
use dit::util::quickprop::check;
use dit::util::rng::Rng;

/// A synthetic RunStats with the energy-relevant counters set explicitly.
fn stats(hbm: u64, noc: u64, spm: u64, flops: f64, makespan_ns: f64) -> RunStats {
    RunStats {
        makespan_ns,
        useful_flops: flops,
        total_flops: flops,
        hbm_read_bytes: hbm / 2,
        hbm_write_bytes: hbm - hbm / 2,
        noc_link_bytes: noc,
        spm_bytes: spm,
        peak_tflops: 10.0,
        hbm_peak_gbps: 100.0,
        supersteps: 1,
        compute_busy_ns: makespan_ns,
        num_tiles: 16,
        step_end_ns: vec![makespan_ns],
    }
}

/// Energy is monotone in HBM bytes and MAC count (and every other
/// counter): more traffic can never cost less energy.
#[test]
fn prop_energy_monotone_in_traffic() {
    check("energy monotone in hbm/mac/noc/spm/time", 64, |rng: &mut Rng| {
        let model = EnergyModel::default_table();
        let hbm = rng.below(1 << 30);
        let noc = rng.below(1 << 30);
        let spm = rng.below(1 << 30);
        let flops = rng.below(1 << 40) as f64;
        let t = 1.0 + rng.below(1 << 20) as f64;
        let base = model.energy_j(&stats(hbm, noc, spm, flops, t));
        let bump = 1 + rng.below(1 << 24);
        assert!(
            model.energy_j(&stats(hbm + bump, noc, spm, flops, t)) > base,
            "more HBM bytes must cost more energy"
        );
        assert!(
            model.energy_j(&stats(hbm, noc, spm, flops + 2.0 * bump as f64, t)) > base,
            "more MACs must cost more energy"
        );
        assert!(
            model.energy_j(&stats(hbm, noc + bump, spm, flops, t)) > base,
            "more NoC hop-bytes must cost more energy"
        );
        assert!(
            model.energy_j(&stats(hbm, noc, spm + bump, flops, t)) > base,
            "more SPM bytes must cost more energy"
        );
        assert!(
            model.energy_j(&stats(hbm, noc, spm, flops, t + 1000.0)) > base,
            "a longer makespan must cost more static energy"
        );
        assert!(base.is_finite() && base >= 0.0);
    });
}

/// `frontier_indices_nd` with (Min, Max) senses agrees with the 2-D fast
/// path exactly — including duplicate-keeps-first and NaN-disqualifies
/// tie rules, which the generator injects deliberately.
#[test]
fn prop_nd_frontier_matches_2d_fast_path() {
    check("frontier_indices_nd == frontier_indices on 2D", 64, |rng: &mut Rng| {
        let n = rng.range(1, 24);
        let mut pts2: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.below(12) as f64, rng.below(12) as f64))
            .collect();
        if rng.chance(0.3) && n >= 2 {
            pts2[0] = pts2[n - 1]; // exact duplicate across positions
        }
        if rng.chance(0.2) {
            let i = rng.range(0, n - 1);
            pts2[i].1 = f64::NAN;
        }
        let ptsv: Vec<Vec<f64>> = pts2.iter().map(|p| vec![p.0, p.1]).collect();
        assert_eq!(
            pareto::frontier_indices_nd(&ptsv, &[Sense::Min, Sense::Max]),
            pareto::frontier_indices(&pts2),
            "{pts2:?}"
        );
    });
}

/// Frontier laws on random tie-free 3-D points: the (cost, perf) frontier
/// is a subset of the 3-axis frontier (an extra axis only keeps more
/// trade-offs alive), and every excluded point is dominated by a frontier
/// member. Note the converse of the first law is deliberately NOT
/// asserted — a 3-D frontier point can be dominated in every 2-D
/// projection (see `frontier3_point_can_lose_every_projection` below), so
/// projection-optimality is not a valid completeness check.
#[test]
fn prop_frontier3_inclusion_and_completeness() {
    const SENSES: [Sense; 3] = [Sense::Min, Sense::Max, Sense::Min];
    check("2D frontier subset of 3D + completeness", 64, |rng: &mut Rng| {
        let n = rng.range(2, 24);
        // Continuous values make exact ties measure-zero, so the subset
        // law is exercised without its duplicate-tie edge cases.
        let mut f = || (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        let pts3: Vec<Vec<f64>> = (0..n).map(|_| vec![f(), f(), f()]).collect();
        let pts2: Vec<(f64, f64)> = pts3.iter().map(|p| (p[0], p[1])).collect();
        let f2 = pareto::frontier_indices(&pts2);
        let f3 = pareto::frontier_indices_nd(&pts3, &SENSES);
        for i in &f2 {
            assert!(f3.contains(i), "2D-frontier point {i} missing from 3D frontier");
        }
        for i in 0..n {
            if !f3.contains(&i) {
                assert!(
                    f3.iter().any(|&j| pareto::dominates_nd(&pts3[j], &pts3[i], &SENSES)),
                    "point {i} excluded from the 3D frontier but not dominated"
                );
            }
        }
    });
}

/// The classic counterexample: a point can be Pareto-optimal in 3-D while
/// being strictly dominated in every 2-D projection. This is why the
/// sweep computes the 3-axis frontier directly instead of intersecting or
/// unioning projections.
#[test]
fn frontier3_point_can_lose_every_projection() {
    const MIN3: [Sense; 3] = [Sense::Min, Sense::Min, Sense::Min];
    let pts = vec![
        vec![2.0, 2.0, 2.0], // x: balanced
        vec![1.0, 1.0, 3.0],
        vec![1.0, 3.0, 1.0],
        vec![3.0, 1.0, 1.0],
    ];
    let f3 = pareto::frontier_indices_nd(&pts, &MIN3);
    assert!(f3.contains(&0), "balanced point is 3D-Pareto-optimal");
    for (a, b) in [(0usize, 1usize), (0, 2), (1, 2)] {
        let proj: Vec<Vec<f64>> = pts.iter().map(|p| vec![p[a], p[b]]).collect();
        let f2 = pareto::frontier_indices_nd(&proj, &[Sense::Min, Sense::Min]);
        assert!(!f2.contains(&0), "balanced point is dominated in projection ({a},{b})");
    }
}

// ---------------------------------------------------------------------
// End-to-end energy-aware sweeps on tiny grids.

fn tiny_spec() -> SweepSpec {
    SweepSpec {
        name: "energy-test".into(),
        meshes: SweepSpec::square_meshes(&[2, 3, 4]),
        ce: vec![(16, 8), (8, 8)],
        spm_kib: vec![128, 256],
        hbm_channel_gbps: vec![32.0],
        hbm_channels_pct: vec![100],
        dma_engines: vec![2],
        base: ArchConfig::tiny(4, 4),
    }
}

fn tiny_workload() -> Workload {
    let mut w = Workload::new("energy-test");
    w.push("square", GemmShape::new(64, 64, 64), 2);
    w.push("flat", GemmShape::new(16, 128, 128), 1);
    w
}

fn energy_opts() -> DseOptions {
    DseOptions {
        workers: 2,
        config_parallelism: 3,
        objectives: vec![Objective::Perf, Objective::Cost, Objective::Energy],
        ..DseOptions::default()
    }
}

/// An energy-objective sweep evaluates exhaustively (the roofline prune
/// only bounds throughput) and attaches finite, positive energy metrics
/// consistent with the workload report on every point.
#[test]
fn energy_sweep_is_exhaustive_with_consistent_metrics() {
    let spec = tiny_spec();
    let res = dse::run_sweep(&spec, &tiny_workload(), &energy_opts()).unwrap();
    assert!(res.pruned.is_empty(), "energy objective must disable the prune");
    assert_eq!(
        res.points.len() + res.infeasible.len(),
        spec.enumerate().len(),
        "every config evaluated or infeasible"
    );
    assert_eq!(res.objectives, energy_opts().objectives);
    for p in &res.points {
        assert!(p.energy_j.is_finite() && p.energy_j > 0.0, "{}", p.arch.name);
        assert!(p.tflops_per_w.is_finite() && p.tflops_per_w > 0.0, "{}", p.arch.name);
        let flops = p.report.total_flops();
        assert!(
            (p.tflops_per_w - flops / p.energy_j / 1e12).abs() < 1e-9 * p.tflops_per_w,
            "tflops_per_w inconsistent with report on {}",
            p.arch.name
        );
        assert!(p.edp_js() > 0.0);
    }
    let eff = res.most_efficient().unwrap();
    assert!(res.points.iter().all(|p| p.tflops_per_w <= eff.tflops_per_w));
}

/// Real-sweep frontier laws: the 2-axis frontier is contained in the
/// 3-axis frontier, the 3-axis frontier is mutually non-dominating, and
/// both are non-empty.
#[test]
fn energy_sweep_frontier3_invariants() {
    let res = dse::run_sweep(&tiny_spec(), &tiny_workload(), &energy_opts()).unwrap();
    let f3: Vec<usize> = (0..res.points.len()).filter(|&i| res.points[i].on_frontier3).collect();
    assert!(!f3.is_empty());
    for (i, p) in res.points.iter().enumerate() {
        if p.on_frontier {
            assert!(
                p.on_frontier3,
                "{} on the 2-axis frontier but not the 3-axis one",
                p.arch.name
            );
        }
        let pi = [p.cost, p.tflops, p.energy_j];
        for (j, q) in res.points.iter().enumerate() {
            if i != j && p.on_frontier3 && q.on_frontier3 {
                let qj = [q.cost, q.tflops, q.energy_j];
                assert!(
                    !pareto::dominates_nd(&qj, &pi, &[Sense::Min, Sense::Max, Sense::Min]),
                    "{} dominates {} on the 3-axis frontier",
                    q.arch.name,
                    p.arch.name
                );
            }
        }
    }
}

/// Two energy-aware sweeps with different parallelism produce bit-identical
/// results — the energy axis must not break the determinism contract the
/// CI gate relies on.
#[test]
fn energy_sweep_is_deterministic() {
    let spec = tiny_spec();
    let w = tiny_workload();
    let r1 = dse::run_sweep(&spec, &w, &energy_opts()).unwrap();
    let o2 = DseOptions { workers: 4, config_parallelism: 1, ..energy_opts() };
    let r2 = dse::run_sweep(&spec, &w, &o2).unwrap();
    assert_eq!(r1.points.len(), r2.points.len());
    for (a, b) in r1.points.iter().zip(&r2.points) {
        assert_eq!(a.arch.name, b.arch.name);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.tflops_per_w.to_bits(), b.tflops_per_w.to_bits());
        assert_eq!(a.on_frontier3, b.on_frontier3);
    }
    // The machine-readable artifact is byte-identical too (wall-clock is
    // deliberately excluded from it).
    assert_eq!(r1.to_json().pretty(), r2.to_json().pretty());
}

/// Scalarization: the winner under strictly positive weights is always
/// 3-axis-Pareto-optimal, single-axis weights pick that axis's best
/// point, and malformed weights are rejected.
#[test]
fn energy_sweep_scalarization() {
    let res = dse::run_sweep(&tiny_spec(), &tiny_workload(), &energy_opts()).unwrap();
    let objectives = [Objective::Perf, Objective::Cost, Objective::Energy];
    let (winner, score) = res.best_scalarized(&objectives, &[0.5, 0.2, 0.3]).unwrap().unwrap();
    assert!(
        winner.on_frontier3,
        "scalarized winner {} must be 3-axis-Pareto-optimal",
        winner.arch.name
    );
    assert!((0.0..=1.0).contains(&score), "{score}");
    let (fastest, _) = res.best_scalarized(&objectives, &[1.0, 0.0, 0.0]).unwrap().unwrap();
    assert_eq!(fastest.arch.name, res.best().unwrap().arch.name);
    let (frugal, _) = res.best_scalarized(&objectives, &[0.0, 0.0, 1.0]).unwrap().unwrap();
    for p in &res.points {
        assert!(frugal.energy_j <= p.energy_j, "{} beats the energy winner", p.arch.name);
    }
    assert!(res.best_scalarized(&objectives, &[1.0]).is_err(), "ragged weights");
    assert!(res.best_scalarized(&objectives, &[0.0, 0.0, 0.0]).is_err(), "zero weights");
    assert!(res.best_scalarized(&objectives, &[-1.0, 1.0, 1.0]).is_err(), "negative weight");
    assert!(res.best_scalarized(&[], &[]).is_err(), "no objectives");
}

/// The JSON artifact carries the energy axes and frontier3 marking.
#[test]
fn energy_sweep_json_has_energy_axes() {
    let res = dse::run_sweep(&tiny_spec(), &tiny_workload(), &energy_opts()).unwrap();
    let json = res.to_json();
    let rendered = json.pretty();
    for key in ["energy_j", "tflops_per_w", "edp_js", "on_frontier3", "frontier3_size"] {
        assert!(rendered.contains(key), "missing {key} in artifact");
    }
    let objectives = json.get("objectives").and_then(|o| o.items()).unwrap();
    let names: Vec<&str> = objectives.iter().filter_map(|o| o.as_str()).collect();
    assert_eq!(names, vec!["perf", "cost", "energy"]);
    assert_eq!(
        json.get("frontier3_size").and_then(|v| v.as_f64()).unwrap() as usize,
        res.frontier3().len()
    );
}

/// Default (perf, cost) sweeps keep the prune enabled and still attach
/// energy metrics to every evaluated point.
#[test]
fn default_sweep_reports_energy_metrics() {
    let spec = SweepSpec {
        meshes: SweepSpec::square_meshes(&[2, 4]),
        ce: vec![(16, 8)],
        spm_kib: vec![256],
        ..tiny_spec()
    };
    let res = dse::run_sweep(&spec, &tiny_workload(), &DseOptions::default()).unwrap();
    assert_eq!(res.objectives, vec![Objective::Perf, Objective::Cost]);
    for p in &res.points {
        assert!(p.energy_j > 0.0 && p.tflops_per_w > 0.0);
    }
}

//! Engine tests: the parallel batched autotuner must be bit-identical to
//! the serial path, memoization must actually skip simulations, and the
//! chunked-deployment + calibrated-simulator corners are pinned.

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::engine::Engine;
use dit::coordinator::{autotune, deploy_chunked, simulate_chunked};
use dit::schedule::{candidates, l1_estimate, Schedule};
use dit::sim::{engine_time_ns, simulate};

fn suite(arch: &ArchConfig) -> Workload {
    let _ = arch;
    let mut w = Workload::new("suite");
    w.push("square", GemmShape::new(128, 128, 256), 2);
    w.push("ragged", GemmShape::new(96, 66, 128), 1);
    w.push("flat", GemmShape::new(16, 512, 512), 4);
    w
}

/// Acceptance: parallel `tune_workload` == serial `autotune` for every
/// shape in a suite — same candidate set, same order, bit-identical
/// simulated numbers — while using more than one worker thread.
#[test]
fn parallel_tune_workload_matches_serial_autotune() {
    let arch = ArchConfig::tiny(4, 4);
    let engine = Engine::new(&arch).with_workers(4);
    let rep = engine.tune_workload(&suite(&arch)).unwrap();
    assert!(rep.workers > 1, "engine used {} workers", rep.workers);
    assert_eq!(rep.shapes.len(), 3);
    for item in &rep.shapes {
        let serial = autotune(&arch, item.shape).unwrap();
        assert_eq!(
            item.result.ranking.len(),
            serial.ranking.len(),
            "candidate count for {}",
            item.shape
        );
        for (p, s) in item.result.ranking.iter().zip(&serial.ranking) {
            assert_eq!(p.schedule, s.schedule, "ranking order for {}", item.shape);
            assert_eq!(
                p.stats.makespan_ns.to_bits(),
                s.stats.makespan_ns.to_bits(),
                "{} / {}",
                item.shape,
                p.schedule.name()
            );
            assert_eq!(p.stats.tflops().to_bits(), s.stats.tflops().to_bits());
            assert_eq!(p.stats.hbm_read_bytes, s.stats.hbm_read_bytes);
            assert_eq!(p.stats.noc_link_bytes, s.stats.noc_link_bytes);
        }
    }
}

/// Repeated shapes inside one workload are deduplicated: the engine issues
/// fewer simulations than items × candidates and reports the difference as
/// cache hits.
#[test]
fn repeated_shapes_are_cache_hits() {
    let arch = ArchConfig::tiny(4, 4);
    let a = GemmShape::new(64, 64, 64);
    let b = GemmShape::new(96, 96, 96);
    let mut w = Workload::new("repeats");
    w.push("a", a, 1);
    w.push("b", b, 1);
    w.push("a-again", a, 1);
    let per_a = candidates(&arch, a).len();
    let per_b = candidates(&arch, b).len();

    let engine = Engine::new(&arch);
    let rep = engine.tune_workload(&w).unwrap();
    assert_eq!(rep.sim_calls, per_a + per_b, "unique candidates only");
    assert_eq!(rep.cache_hits, per_a, "repeat of shape a fully deduplicated");
    assert!(rep.sim_calls < (per_a + per_b + per_a), "fewer sims than items x candidates");
    // Identical items tune to identical results.
    assert_eq!(
        rep.shapes[0].result.best().schedule,
        rep.shapes[2].result.best().schedule
    );
    assert_eq!(
        rep.shapes[0].result.best().stats.makespan_ns.to_bits(),
        rep.shapes[2].result.best().stats.makespan_ns.to_bits()
    );
}

/// Tuning the same workload a second time performs zero new simulations
/// and returns a bit-identical report.
#[test]
fn second_tuning_of_same_workload_is_free() {
    let arch = ArchConfig::tiny(4, 4);
    let w = suite(&arch);
    let engine = Engine::new(&arch);
    let r1 = engine.tune_workload(&w).unwrap();
    assert!(r1.sim_calls > 0);
    let r2 = engine.tune_workload(&w).unwrap();
    assert_eq!(r2.sim_calls, 0, "second tuning must be fully memoized");
    assert!(r2.cache_hits >= r1.sim_calls);
    for (x, y) in r1.shapes.iter().zip(&r2.shapes) {
        assert_eq!(
            x.result.best().stats.makespan_ns.to_bits(),
            y.result.best().stats.makespan_ns.to_bits()
        );
        assert_eq!(x.result.best().schedule, y.result.best().schedule);
    }
    // Engine-lifetime counters agree.
    assert_eq!(engine.sim_calls(), r1.sim_calls);
    assert!(engine.cache_hits() >= r2.cache_hits);
}

/// Golden-value pin of the simulator's §4.1.3 calibration point: a ragged
/// TN=66 tile (2112/32 on the GH200-like instance) lands at ≈50% matrix-
/// engine utilization, decomposed as quantization 0.825 × pipeline-fill
/// 128/144 × ragged-edge 0.7.
#[test]
fn engine_time_pins_paper_calibration_point() {
    let arch = ArchConfig::gh200_like();
    let (m, n, k) = (128usize, 66usize, 128usize);
    let t = engine_time_ns(&arch, m, n, k);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let peak_flops_per_ns = arch.tile.peak_tflops() * 1e3;
    let eff = flops / (peak_flops_per_ns * t);
    // Exact model terms: 66 splits into ceil(66/16)=5 CE sub-tiles.
    let quant = (m * n) as f64 / (2.0 * 64.0 * 5.0 * 16.0);
    let expected_eff = quant * (128.0 / 144.0) * 0.7;
    assert!((quant - 0.825).abs() < 1e-12, "quantization term {quant}");
    assert!((eff - expected_eff).abs() < 1e-9, "eff {eff} vs model {expected_eff}");
    assert!((0.45..=0.55).contains(&eff), "§4.1.3 says ~50%, got {eff}");
    // And the absolute golden timing (ns) for this tile.
    let golden = flops / (peak_flops_per_ns * expected_eff);
    assert!((t - golden).abs() < 1e-6, "t {t} vs golden {golden}");
    assert!((t - 2181.5).abs() < 2.0, "golden drifted: {t} ns");
}

/// A shape whose working set exceeds L1 splits into >1 chunks, and
/// `simulate_chunked` is exactly the sum of the per-chunk simulations.
#[test]
fn oversized_shape_chunks_and_makespans_sum() {
    let arch = ArchConfig::tiny(4, 4);
    let shape = GemmShape::new(128, 65536, 256);
    let sched = Schedule::summa(&arch, shape);
    assert!(
        l1_estimate(&arch, shape, &sched) > arch.tile.l1_bytes as u64,
        "shape must overflow L1 for this test"
    );

    let deps = deploy_chunked(&arch, shape, &sched).unwrap();
    assert!(deps.len() > 1, "expected chunking, got {} deployment(s)", deps.len());
    // Chunks cover N exactly.
    let n_total: usize = deps.iter().map(|d| d.shape.n).sum();
    assert_eq!(n_total, shape.n);

    let combined = simulate_chunked(&arch, &deps).unwrap();
    let mut makespan_sum = 0.0f64;
    let mut reads = 0u64;
    let mut writes = 0u64;
    let mut steps = 0usize;
    for dep in &deps {
        let s = simulate(&arch, dep).unwrap();
        makespan_sum += s.makespan_ns;
        reads += s.hbm_read_bytes;
        writes += s.hbm_write_bytes;
        steps += s.supersteps;
    }
    assert!(
        (combined.makespan_ns - makespan_sum).abs() <= 1e-6 * makespan_sum,
        "chunk makespans must sum: {} vs {}",
        combined.makespan_ns,
        makespan_sum
    );
    assert_eq!(combined.hbm_read_bytes, reads);
    assert_eq!(combined.hbm_write_bytes, writes);
    assert_eq!(combined.supersteps, steps);
    assert_eq!(combined.step_end_ns.len(), steps);
    for w in combined.step_end_ns.windows(2) {
        assert!(w[1] >= w[0], "chunk-joined timeline must stay monotone");
    }
}

/// When no column chunking can make the working set fit L1 (the A panel
/// is M-bound), `deploy_chunked` fails with the no-fit error.
#[test]
fn unchunkable_shape_fails_with_no_fit_error() {
    let arch = ArchConfig::tiny(2, 2);
    let shape = GemmShape::new(1 << 20, 64, 256);
    let sched = Schedule::summa(&arch, shape);
    let err = deploy_chunked(&arch, shape, &sched).unwrap_err();
    assert!(err.to_string().contains("no chunking"), "{err}");
}

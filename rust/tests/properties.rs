//! Cross-module property tests and failure injection.
//!
//! These complement the per-module `#[cfg(test)]` properties: here whole
//! pipelines (schedule → codegen → functional / simulation) are exercised
//! under randomized inputs via the in-tree quickprop harness, plus
//! deliberate corruption of deployments to prove validation catches it.

use dit::arch::{ArchConfig, GemmShape};
use dit::codegen::generate;
use dit::coordinator;
use dit::functional::{max_abs_diff, mmad_f32, run_gemm};
use dit::ir::{validate, IrError, Op};
use dit::schedule::{candidates, Schedule};
use dit::sim;
use dit::util::quickprop::check;
use dit::util::rng::Rng;

/// Assert two `RunStats` are bit-identical: `to_bits` on every f64
/// (including the whole per-superstep timeline) and exact equality on
/// every counter. Tolerance-free by design — the golden fidelity tests
/// below pin the flat-arena simulator to the frozen reference model.
fn assert_runstats_bits_eq(a: &dit::sim::RunStats, b: &dit::sim::RunStats, ctx: &str) {
    assert_eq!(a.makespan_ns.to_bits(), b.makespan_ns.to_bits(), "{ctx}: makespan_ns");
    assert_eq!(a.useful_flops.to_bits(), b.useful_flops.to_bits(), "{ctx}: useful_flops");
    assert_eq!(a.total_flops.to_bits(), b.total_flops.to_bits(), "{ctx}: total_flops");
    assert_eq!(a.hbm_read_bytes, b.hbm_read_bytes, "{ctx}: hbm_read_bytes");
    assert_eq!(a.hbm_write_bytes, b.hbm_write_bytes, "{ctx}: hbm_write_bytes");
    assert_eq!(a.noc_link_bytes, b.noc_link_bytes, "{ctx}: noc_link_bytes");
    assert_eq!(a.spm_bytes, b.spm_bytes, "{ctx}: spm_bytes");
    assert_eq!(a.peak_tflops.to_bits(), b.peak_tflops.to_bits(), "{ctx}: peak_tflops");
    assert_eq!(a.hbm_peak_gbps.to_bits(), b.hbm_peak_gbps.to_bits(), "{ctx}: hbm_peak_gbps");
    assert_eq!(a.supersteps, b.supersteps, "{ctx}: supersteps");
    assert_eq!(
        a.compute_busy_ns.to_bits(),
        b.compute_busy_ns.to_bits(),
        "{ctx}: compute_busy_ns"
    );
    assert_eq!(a.num_tiles, b.num_tiles, "{ctx}: num_tiles");
    assert_eq!(a.step_end_ns.len(), b.step_end_ns.len(), "{ctx}: step count");
    for (i, (x, y)) in a.step_end_ns.iter().zip(&b.step_end_ns).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: step_end_ns[{i}]");
    }
}

/// Golden refactor-fidelity pin: the flat-arena simulator (fresh arena
/// *and* one shared arena reused across the whole matrix, exercising every
/// resize path) is bit-identical to the frozen hashed reference model
/// (`sim::reference`) across square and rectangular meshes, two shapes,
/// and four schedule families (multicast-heavy SUMMA, the unicast
/// baseline, split-K reduction trees, flat remap). An executable
/// reference is a stronger pin than committed constants: it holds on any
/// machine and for any future schedule added to this matrix.
#[test]
fn golden_runstats_flat_arena_matches_reference_model() {
    let mut arena = sim::SimArena::new();
    let mut checked = 0usize;
    for (rows, cols) in [(4usize, 4usize), (2, 4), (4, 2)] {
        let arch = ArchConfig::tiny(rows, cols);
        for shape in [GemmShape::new(128, 128, 256), GemmShape::new(96, 160, 128)] {
            let scheds = [
                Schedule::summa(&arch, shape),
                Schedule::baseline(&arch, shape),
                Schedule::splitk(&arch, shape, 2),
                Schedule::flat_remap(&arch, shape, 2),
            ];
            for sched in scheds {
                // Some (mesh, shape, schedule) combos are legitimately
                // undeployable (e.g. logical grid exceeds the mesh);
                // the fidelity property only concerns deployable ones.
                let Ok(dep) = generate(&arch, shape, &sched, arch.elem_bytes) else {
                    continue;
                };
                let ctx = format!("{rows}x{cols} {shape} {}", sched.name());
                let want = sim::reference::simulate(&arch, &dep).unwrap();
                let flat = sim::simulate(&arch, &dep).unwrap();
                assert_runstats_bits_eq(&flat, &want, &format!("{ctx} [fresh arena]"));
                let reused = sim::simulate_in(&arch, &dep, &mut arena).unwrap();
                assert_runstats_bits_eq(&reused, &want, &format!("{ctx} [shared arena]"));
                checked += 1;
            }
        }
    }
    assert!(checked >= 12, "golden matrix shrank to {checked} deployable cases");
}

/// Any random (shape, schedule-candidate) pair on a small grid computes
/// the same GEMM as the plain CPU reference.
#[test]
fn prop_random_shapes_all_candidates_correct() {
    check("random shape x candidate numerics", 6, |rng| {
        let arch = ArchConfig::tiny(4, 4);
        let m = rng.range(1, 12) * 8;
        let n = rng.range(1, 12) * 8;
        let k = rng.range(1, 8) * 16;
        let shape = GemmShape::new(m, n, k);
        let mut a_rng = Rng::new(rng.next_u64());
        let a = a_rng.f32_vec(m * k);
        let b = a_rng.f32_vec(k * n);
        let mut want = vec![0f32; m * n];
        mmad_f32(&a, &b, &mut want, m, n, k);
        let cands = candidates(&arch, shape);
        // Pick one candidate per case (full cross-product lives in the
        // lib tests); random selection over many runs covers the space.
        let sched = rng.choose(&cands).clone();
        let dep = generate(&arch, shape, &sched, 4)
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        let got = run_gemm(&arch, &dep, &a, &b).unwrap();
        let diff = max_abs_diff(&got, &want);
        assert!(diff < 1e-3, "{} on {shape}: {diff}", sched.name());
    });
}

/// Simulated makespans are strictly positive, finite, and deterministic;
/// utilization is bounded for every candidate.
#[test]
fn prop_simulation_invariants() {
    check("simulation invariants", 10, |rng| {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(rng.range(4, 40) * 8, rng.range(4, 40) * 8, rng.range(2, 16) * 32);
        let cands = candidates(&arch, shape);
        let sched = rng.choose(&cands).clone();
        let s1 = coordinator::simulate_schedule(&arch, shape, &sched).unwrap();
        let s2 = coordinator::simulate_schedule(&arch, shape, &sched).unwrap();
        assert!(s1.makespan_ns.is_finite() && s1.makespan_ns > 0.0);
        assert_eq!(s1.makespan_ns, s2.makespan_ns, "nondeterministic sim");
        assert!(s1.utilization() > 0.0 && s1.utilization() <= 1.0);
        assert!(s1.hbm_utilization() <= 1.0 + 1e-9);
        assert!(s1.total_flops >= s1.useful_flops);
    });
}

/// The autotuner's chosen schedule is never dominated by a candidate it
/// itself ranked (ranking is internally consistent).
#[test]
fn prop_autotune_ranking_consistent() {
    check("autotune ranking consistency", 4, |rng| {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(rng.range(8, 24) * 8, rng.range(8, 24) * 8, 256);
        let result = coordinator::autotune(&arch, shape).unwrap();
        let best = &result.ranking[0];
        for s in &result.ranking {
            assert!(best.stats.makespan_ns <= s.stats.makespan_ns + 1e-9);
        }
    });
}

// ---------------- failure injection ----------------

fn valid_dep(arch: &ArchConfig) -> dit::ir::Deployment {
    let shape = GemmShape::new(64, 64, 128);
    generate(arch, shape, &Schedule::summa(arch, shape), 4).unwrap()
}

/// Dropping any single receive op from a SUMMA deployment must be caught
/// by communication-matching validation.
#[test]
fn inject_dropped_recv_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    'outer: for p in &mut dep.programs {
        for s in &mut p.steps {
            if let Some(pos) =
                s.ops.iter().position(|o| matches!(o, Op::RecvMulticast { .. }))
            {
                s.ops.remove(pos);
                break 'outer;
            }
        }
    }
    let err = validate(&arch, &dep).unwrap_err();
    assert!(matches!(err, IrError::UnmatchedComm { .. }), "{err}");
}

/// Shrinking any buffer below its traffic must be caught.
#[test]
fn inject_shrunken_buffer_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    dep.programs[0].bufs[0].bytes = 4;
    let err = validate(&arch, &dep).unwrap_err();
    assert!(
        matches!(err, IrError::BufTooSmall { .. } | IrError::BufferRace { .. }),
        "{err}"
    );
}

/// Duplicating a tile's program must be caught.
#[test]
fn inject_duplicate_tile_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    let clone = dep.programs[0].clone();
    dep.programs.push(clone);
    let err = validate(&arch, &dep).unwrap_err();
    assert!(matches!(err, IrError::DuplicateProgram(_)), "{err}");
}

/// Moving a compute op into the superstep whose comm writes its operand
/// must be caught as a double-buffer race.
#[test]
fn inject_buffer_race_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    // Find a program with a Mmad and a comm-write of the same buffer in an
    // earlier step; move the Mmad there.
    'outer: for p in &mut dep.programs {
        for si in 1..p.steps.len() {
            let mmads: Vec<Op> = p.steps[si]
                .ops
                .iter()
                .filter(|o| matches!(o, Op::Mmad { .. }))
                .cloned()
                .collect();
            if mmads.is_empty() {
                continue;
            }
            let op = mmads[0].clone();
            if let Op::Mmad { a, .. } = op {
                let prev_writes: Vec<_> = p.steps[si - 1]
                    .ops
                    .iter()
                    .filter(|o| !o.is_compute())
                    .flat_map(|o| o.writes())
                    .collect();
                if prev_writes.contains(&a) {
                    let pos = p.steps[si]
                        .ops
                        .iter()
                        .position(|o| matches!(o, Op::Mmad { .. }))
                        .unwrap();
                    let op = p.steps[si].ops.remove(pos);
                    p.steps[si - 1].ops.push(op);
                    break 'outer;
                }
            }
        }
    }
    let err = validate(&arch, &dep).unwrap_err();
    assert!(matches!(err, IrError::BufferRace { .. }), "{err}");
}

/// An architecture too small for a schedule must be rejected before
/// anything is generated.
#[test]
fn inject_oversubscribed_schedule_rejected() {
    let big = ArchConfig::tiny(8, 8);
    let small = ArchConfig::tiny(2, 2);
    let shape = GemmShape::new(64, 64, 64);
    let sched = Schedule::summa(&big, shape); // logical 8x8
    assert!(generate(&small, shape, &sched, 4).is_err());
}

/// Zero-sized problems are rejected cleanly, not panicking.
#[test]
fn degenerate_problems_do_not_panic() {
    let arch = ArchConfig::tiny(2, 2);
    for (m, n, k) in [(1, 1, 1), (1, 64, 1), (7, 3, 5)] {
        let shape = GemmShape::new(m, n, k);
        let sched = Schedule::summa(&arch, shape);
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        let mut rng = Rng::new(1);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let got = run_gemm(&arch, &dep, &a, &b).unwrap();
        let mut want = vec![0f32; m * n];
        mmad_f32(&a, &b, &mut want, m, n, k);
        assert!(max_abs_diff(&got, &want) < 1e-4, "{shape}");
    }
}

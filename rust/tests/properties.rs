//! Cross-module property tests and failure injection.
//!
//! These complement the per-module `#[cfg(test)]` properties: here whole
//! pipelines (schedule → codegen → functional / simulation) are exercised
//! under randomized inputs via the in-tree quickprop harness, plus
//! deliberate corruption of deployments to prove validation catches it.

use dit::arch::{ArchConfig, GemmShape};
use dit::codegen::generate;
use dit::coordinator;
use dit::functional::{max_abs_diff, mmad_f32, run_gemm};
use dit::ir::{validate, IrError, Op};
use dit::schedule::{candidates, Schedule};
use dit::util::quickprop::check;
use dit::util::rng::Rng;

/// Any random (shape, schedule-candidate) pair on a small grid computes
/// the same GEMM as the plain CPU reference.
#[test]
fn prop_random_shapes_all_candidates_correct() {
    check("random shape x candidate numerics", 6, |rng| {
        let arch = ArchConfig::tiny(4, 4);
        let m = rng.range(1, 12) * 8;
        let n = rng.range(1, 12) * 8;
        let k = rng.range(1, 8) * 16;
        let shape = GemmShape::new(m, n, k);
        let mut a_rng = Rng::new(rng.next_u64());
        let a = a_rng.f32_vec(m * k);
        let b = a_rng.f32_vec(k * n);
        let mut want = vec![0f32; m * n];
        mmad_f32(&a, &b, &mut want, m, n, k);
        let cands = candidates(&arch, shape);
        // Pick one candidate per case (full cross-product lives in the
        // lib tests); random selection over many runs covers the space.
        let sched = rng.choose(&cands).clone();
        let dep = generate(&arch, shape, &sched, 4)
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        let got = run_gemm(&arch, &dep, &a, &b).unwrap();
        let diff = max_abs_diff(&got, &want);
        assert!(diff < 1e-3, "{} on {shape}: {diff}", sched.name());
    });
}

/// Simulated makespans are strictly positive, finite, and deterministic;
/// utilization is bounded for every candidate.
#[test]
fn prop_simulation_invariants() {
    check("simulation invariants", 10, |rng| {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(rng.range(4, 40) * 8, rng.range(4, 40) * 8, rng.range(2, 16) * 32);
        let cands = candidates(&arch, shape);
        let sched = rng.choose(&cands).clone();
        let s1 = coordinator::simulate_schedule(&arch, shape, &sched).unwrap();
        let s2 = coordinator::simulate_schedule(&arch, shape, &sched).unwrap();
        assert!(s1.makespan_ns.is_finite() && s1.makespan_ns > 0.0);
        assert_eq!(s1.makespan_ns, s2.makespan_ns, "nondeterministic sim");
        assert!(s1.utilization() > 0.0 && s1.utilization() <= 1.0);
        assert!(s1.hbm_utilization() <= 1.0 + 1e-9);
        assert!(s1.total_flops >= s1.useful_flops);
    });
}

/// The autotuner's chosen schedule is never dominated by a candidate it
/// itself ranked (ranking is internally consistent).
#[test]
fn prop_autotune_ranking_consistent() {
    check("autotune ranking consistency", 4, |rng| {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(rng.range(8, 24) * 8, rng.range(8, 24) * 8, 256);
        let result = coordinator::autotune(&arch, shape).unwrap();
        let best = &result.ranking[0];
        for s in &result.ranking {
            assert!(best.stats.makespan_ns <= s.stats.makespan_ns + 1e-9);
        }
    });
}

// ---------------- failure injection ----------------

fn valid_dep(arch: &ArchConfig) -> dit::ir::Deployment {
    let shape = GemmShape::new(64, 64, 128);
    generate(arch, shape, &Schedule::summa(arch, shape), 4).unwrap()
}

/// Dropping any single receive op from a SUMMA deployment must be caught
/// by communication-matching validation.
#[test]
fn inject_dropped_recv_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    'outer: for p in &mut dep.programs {
        for s in &mut p.steps {
            if let Some(pos) =
                s.ops.iter().position(|o| matches!(o, Op::RecvMulticast { .. }))
            {
                s.ops.remove(pos);
                break 'outer;
            }
        }
    }
    let err = validate(&arch, &dep).unwrap_err();
    assert!(matches!(err, IrError::UnmatchedComm { .. }), "{err}");
}

/// Shrinking any buffer below its traffic must be caught.
#[test]
fn inject_shrunken_buffer_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    dep.programs[0].bufs[0].bytes = 4;
    let err = validate(&arch, &dep).unwrap_err();
    assert!(
        matches!(err, IrError::BufTooSmall { .. } | IrError::BufferRace { .. }),
        "{err}"
    );
}

/// Duplicating a tile's program must be caught.
#[test]
fn inject_duplicate_tile_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    let clone = dep.programs[0].clone();
    dep.programs.push(clone);
    let err = validate(&arch, &dep).unwrap_err();
    assert!(matches!(err, IrError::DuplicateProgram(_)), "{err}");
}

/// Moving a compute op into the superstep whose comm writes its operand
/// must be caught as a double-buffer race.
#[test]
fn inject_buffer_race_is_caught() {
    let arch = ArchConfig::tiny(4, 4);
    let mut dep = valid_dep(&arch);
    // Find a program with a Mmad and a comm-write of the same buffer in an
    // earlier step; move the Mmad there.
    'outer: for p in &mut dep.programs {
        for si in 1..p.steps.len() {
            let mmads: Vec<Op> = p.steps[si]
                .ops
                .iter()
                .filter(|o| matches!(o, Op::Mmad { .. }))
                .cloned()
                .collect();
            if mmads.is_empty() {
                continue;
            }
            let op = mmads[0].clone();
            if let Op::Mmad { a, .. } = op {
                let prev_writes: Vec<_> = p.steps[si - 1]
                    .ops
                    .iter()
                    .filter(|o| !o.is_compute())
                    .flat_map(|o| o.writes())
                    .collect();
                if prev_writes.contains(&a) {
                    let pos = p.steps[si]
                        .ops
                        .iter()
                        .position(|o| matches!(o, Op::Mmad { .. }))
                        .unwrap();
                    let op = p.steps[si].ops.remove(pos);
                    p.steps[si - 1].ops.push(op);
                    break 'outer;
                }
            }
        }
    }
    let err = validate(&arch, &dep).unwrap_err();
    assert!(matches!(err, IrError::BufferRace { .. }), "{err}");
}

/// An architecture too small for a schedule must be rejected before
/// anything is generated.
#[test]
fn inject_oversubscribed_schedule_rejected() {
    let big = ArchConfig::tiny(8, 8);
    let small = ArchConfig::tiny(2, 2);
    let shape = GemmShape::new(64, 64, 64);
    let sched = Schedule::summa(&big, shape); // logical 8x8
    assert!(generate(&small, shape, &sched, 4).is_err());
}

/// Zero-sized problems are rejected cleanly, not panicking.
#[test]
fn degenerate_problems_do_not_panic() {
    let arch = ArchConfig::tiny(2, 2);
    for (m, n, k) in [(1, 1, 1), (1, 64, 1), (7, 3, 5)] {
        let shape = GemmShape::new(m, n, k);
        let sched = Schedule::summa(&arch, shape);
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        let mut rng = Rng::new(1);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let got = run_gemm(&arch, &dep, &a, &b).unwrap();
        let mut want = vec![0f32; m * n];
        mmad_f32(&a, &b, &mut want, m, n, k);
        assert!(max_abs_diff(&got, &want) < 1e-4, "{shape}");
    }
}

//! Serving-layer acceptance tests.
//!
//! Three properties carry the feature:
//!
//! * **the ε contract** — every neighbor-served schedule's analytic
//!   penalty on the true shape, recomputed here from first principles
//!   (full candidate enumeration, not the server's own bookkeeping), is
//!   at most the server's ε. Whatever ε is, however the donor was
//!   picked, concurrent or not.
//! * **replay determinism** — the same initial cache state plus the
//!   same request trace yields bit-identical served schedules: two cold
//!   servers on fresh cache paths agree, and two warm reopens of one
//!   path agree. (Cold and warm runs legitimately differ from *each
//!   other*: a warm database holds donors the cold run had not tuned
//!   yet.)
//! * **warm serving is free** — reopening a cache written by a
//!   same-policy server answers the whole working set with zero
//!   simulations and zero misses; exact hits never touch the engine.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::cache::ShardedDiskCache;
use dit::coordinator::shapedb::{
    analytic_best_ns, load_trace, ScheduleServer, ServeConfig, ServeOutcome, ServeResult,
};
use dit::perfmodel::analytic::estimate_ns;

static SEQ: AtomicUsize = AtomicUsize::new(0);

/// A unique temp directory per call (tests run concurrently in one
/// process, and the CI smoke lane raises --test-threads).
fn temp_dir(tag: &str) -> PathBuf {
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("dit-serve-it-{tag}-{}-{seq}", std::process::id()))
}

/// The ε contract, re-derived from first principles: the served
/// schedule's closed-form estimate on the *canonical request shape*,
/// relative to the analytic best over that shape's own full candidate
/// enumeration, is within ε — and matches the penalty the server
/// reported.
fn assert_neighbor_within_epsilon(arch: &ArchConfig, r: &ServeResult, eps: f64) {
    assert_eq!(r.outcome, ServeOutcome::Neighbor);
    assert!(r.donor.is_some(), "a borrow names its donor");
    let best = analytic_best_ns(arch, r.canonical).expect("canonical shape has candidates");
    let est = estimate_ns(arch, r.canonical, &r.schedule)
        .expect("a served schedule must deploy on the shape it answers");
    let penalty = est / best - 1.0;
    assert!(
        (penalty - r.penalty).abs() < 1e-9,
        "server reported penalty {} but it recomputes as {penalty}",
        r.penalty
    );
    assert!(penalty <= eps + 1e-12, "penalty {penalty} exceeds eps {eps}");
}

#[test]
fn neighbor_reuse_never_exceeds_epsilon() {
    let arch = ArchConfig::tiny(4, 4);
    // Whatever ε is — including 0, which only admits penalty-free
    // borrows — the invariant holds for every Neighbor outcome; tighter
    // ε may legitimately turn the borrow into a Miss instead.
    for eps in [0.0, 0.05, 0.25, 1.0] {
        let cfg = ServeConfig { epsilon: eps, ..ServeConfig::default() };
        let server = ScheduleServer::in_memory(&arch, cfg).unwrap();
        let seeded = server.serve(GemmShape::new(64, 512, 512)).unwrap();
        assert_eq!(seeded.outcome, ServeOutcome::Miss, "fresh server starts empty");
        let r = server.serve(GemmShape::new(63, 512, 512)).unwrap();
        match r.outcome {
            ServeOutcome::Neighbor => {
                assert_neighbor_within_epsilon(&arch, &r, eps);
                assert_eq!(server.queue_depth(), 1, "a borrow enqueues an exact retune");
            }
            ServeOutcome::Miss => assert_eq!(r.penalty, 0.0),
            ServeOutcome::Exact => panic!("63x512x512 was never tuned exactly"),
        }
    }
    // An effectively unbounded ε must admit the ΔM=1 donor (63 buckets
    // with 64; the candidate structures are arch-derived, so the donor's
    // schedule is a member of 63's own candidate family after tk
    // retuning, and its penalty is finite) — and borrowing must not
    // simulate.
    let cfg = ServeConfig { epsilon: 1e9, ..ServeConfig::default() };
    let server = ScheduleServer::in_memory(&arch, cfg).unwrap();
    server.serve(GemmShape::new(64, 512, 512)).unwrap();
    let sims = server.sim_calls();
    let r = server.serve(GemmShape::new(63, 512, 512)).unwrap();
    assert_eq!(r.outcome, ServeOutcome::Neighbor, "an unbounded eps must admit the ΔM=1 donor");
    assert_eq!(r.donor, Some(GemmShape::new(64, 512, 512)));
    assert_neighbor_within_epsilon(&arch, &r, 1e9);
    assert_eq!(server.sim_calls(), sims, "neighbor serving never simulates");
    // 65 buckets away from 64 (it rounds to 128): no donor, so a miss.
    let r = server.serve(GemmShape::new(65, 512, 512)).unwrap();
    assert_eq!(r.outcome, ServeOutcome::Miss, "65x512x512 has no in-bucket donor");
}

#[test]
fn exact_hits_skip_the_engine() {
    let arch = ArchConfig::tiny(4, 4);
    let server = ScheduleServer::in_memory(&arch, ServeConfig::default()).unwrap();
    let shape = GemmShape::new(64, 512, 512);
    let first = server.serve(shape).unwrap();
    assert_eq!(first.outcome, ServeOutcome::Miss);
    let sims = server.sim_calls();
    assert!(sims > 0, "a miss tunes synchronously");
    let again = server.serve(shape).unwrap();
    assert_eq!(again.outcome, ServeOutcome::Exact);
    assert_eq!(again.schedule, first.schedule);
    assert_eq!(again.penalty, 0.0);
    assert_eq!(server.sim_calls(), sims, "exact hits never touch the simulator");
    // A transposed arrival canonicalizes onto the same entry.
    let t = server.serve(GemmShape::new(512, 64, 512)).unwrap();
    assert_eq!(t.outcome, ServeOutcome::Exact);
    assert!(t.swapped, "512x64x512 arrives transposed relative to canonical");
    assert_eq!(t.canonical, shape);
    assert_eq!(t.schedule, first.schedule);
    assert_eq!(server.sim_calls(), sims);
}

#[test]
fn drain_retunes_upgrades_borrowed_entries() {
    let arch = ArchConfig::tiny(4, 4);
    let cfg = ServeConfig { epsilon: 1e9, ..ServeConfig::default() };
    let server = ScheduleServer::in_memory(&arch, cfg).unwrap();
    server.serve(GemmShape::new(64, 512, 512)).unwrap();
    let r = server.serve(GemmShape::new(63, 512, 512)).unwrap();
    assert_eq!(r.outcome, ServeOutcome::Neighbor);
    let st = server.stats();
    assert_eq!((st.db_exact, st.db_borrowed, st.queue_depth), (1, 1, 1));
    assert_eq!(server.drain_retunes(8).unwrap(), 1);
    let st = server.stats();
    assert_eq!((st.db_exact, st.db_borrowed, st.queue_depth), (2, 0, 0));
    assert_eq!(st.retunes_done, 1);
    // The shape now answers exactly, without touching the engine again.
    let sims = server.sim_calls();
    let r2 = server.serve(GemmShape::new(63, 512, 512)).unwrap();
    assert_eq!(r2.outcome, ServeOutcome::Exact);
    assert_eq!(r2.penalty, 0.0);
    assert_eq!(server.sim_calls(), sims);
    // Draining an empty queue is a no-op.
    assert_eq!(server.drain_retunes(4).unwrap(), 0);
}

/// Serve the whole committed trace through one server, returning the
/// bit-comparable answer sequence plus every full result.
fn serve_all(server: &ScheduleServer, trace: &[GemmShape]) -> Vec<ServeResult> {
    trace.iter().map(|&s| server.serve(s).unwrap()).collect()
}

fn answer_keys(results: &[ServeResult]) -> Vec<(ServeOutcome, String)> {
    results.iter().map(|r| (r.outcome, r.schedule.cache_key())).collect()
}

#[test]
fn committed_trace_replay_is_deterministic_and_warm_serving_is_free() {
    let arch = ArchConfig::tiny(4, 4);
    let trace = load_trace("traces/serve_zipf.txt").expect("committed trace");
    assert_eq!(trace.len(), 512, "the committed trace is seed 7, len 512");
    let cfg = ServeConfig { epsilon: 0.25, ..ServeConfig::default() };

    // Cold on two fresh cache paths: bit-identical served schedules.
    let (dir_a, dir_b) = (temp_dir("cold-a"), temp_dir("cold-b"));
    let a = ScheduleServer::open(&arch, &dir_a, cfg).unwrap();
    let b = ScheduleServer::open(&arch, &dir_b, cfg).unwrap();
    let cold_a = serve_all(&a, &trace);
    let cold_b = serve_all(&b, &trace);
    assert_eq!(
        answer_keys(&cold_a),
        answer_keys(&cold_b),
        "cold replays on fresh caches must be bit-identical"
    );
    for r in cold_a.iter().filter(|r| r.outcome == ServeOutcome::Neighbor) {
        assert_neighbor_within_epsilon(&arch, r, cfg.epsilon);
    }
    let cold = a.stats();
    assert_eq!(cold.requests, 512);
    assert!(cold.misses > 0, "a cold server must tune the bucket anchors");
    assert!(cold.sim_calls > 0);
    drop(b);
    let _ = ShardedDiskCache::clear(&dir_b);
    drop(a); // compacts dir_a

    // Warm twice on the surviving path: identical to each other, zero
    // simulations, zero misses, hit rate >= 0.9 (the acceptance floor;
    // it is in fact 1.0 — every cold miss answers exactly and every
    // cold borrow re-qualifies against a donor set that only grew).
    let w1 = ScheduleServer::open(&arch, &dir_a, cfg).unwrap();
    assert!(w1.disk_loaded() > 0, "warm open resumes from the cold run's cache");
    let warm_1 = serve_all(&w1, &trace);
    let s1 = w1.stats();
    assert_eq!(s1.sim_calls, 0, "warm rebuild + replay must not simulate");
    assert_eq!(s1.misses, 0, "warm replay answers everything from the database");
    assert!(s1.hit_rate() >= 0.9, "warm hit rate {} below the floor", s1.hit_rate());
    for r in warm_1.iter().filter(|r| r.outcome == ServeOutcome::Neighbor) {
        assert_neighbor_within_epsilon(&arch, r, cfg.epsilon);
    }
    drop(w1);
    let w2 = ScheduleServer::open(&arch, &dir_a, cfg).unwrap();
    let warm_2 = serve_all(&w2, &trace);
    assert_eq!(
        answer_keys(&warm_1),
        answer_keys(&warm_2),
        "warm replays of one cache must be bit-identical"
    );
    drop(w2);
    let _ = ShardedDiskCache::clear(&dir_a);
}

#[test]
fn concurrent_serving_smoke() {
    let arch = ArchConfig::tiny(4, 4);
    let trace = load_trace("traces/serve_zipf.txt").expect("committed trace");
    let cfg = ServeConfig { epsilon: 0.25, ..ServeConfig::default() };
    let dir = temp_dir("conc");
    let server = Arc::new(ScheduleServer::open(&arch, &dir, cfg).unwrap());
    let eps = server.epsilon();
    std::thread::scope(|scope| {
        for chunk in trace.chunks(trace.len().div_ceil(4)) {
            let server = Arc::clone(&server);
            let arch = &arch;
            scope.spawn(move || {
                for &shape in chunk {
                    let r = server.serve(shape).unwrap();
                    if r.outcome == ServeOutcome::Neighbor {
                        assert_neighbor_within_epsilon(arch, &r, eps);
                    }
                }
            });
        }
        // A drainer upgrades borrowed entries while serving threads are
        // still answering from (and adding to) the same database.
        let drainer = Arc::clone(&server);
        scope.spawn(move || {
            for _ in 0..8 {
                drainer.drain_retunes(2).unwrap();
            }
        });
    });
    let st = server.stats();
    assert_eq!(st.requests, trace.len());
    assert_eq!(
        st.exact_hits + st.neighbor_hits + st.misses,
        st.requests,
        "every request is counted exactly once"
    );
    // Database composition is consistent with the event counters even
    // under interleaving: exact entries only come from misses and
    // retunes (duplicates collapse), borrowed entries only from
    // first-time neighbor answers.
    assert!(st.db_exact <= st.misses + st.retunes_done, "{st:?}");
    assert!(st.db_borrowed <= st.neighbor_hits, "{st:?}");
    server.flush().unwrap();
    drop(server);
    let _ = ShardedDiskCache::clear(&dir);
}

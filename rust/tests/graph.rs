//! Integration tests for the multi-op workload-graph layer: functional
//! fused-vs-unfused equivalence, HBM traffic accounting against the
//! analytic estimate, and bit-identity of the degenerate (single-GEMM)
//! graph path with the flat tuning path.

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::deploy_functional;
use dit::coordinator::engine::{Engine, TunePolicy};
use dit::functional::run_gemm;
use dit::graph::{softmax_rows, WorkloadGraph};
use dit::perfmodel::analytic::estimate_graph;
use dit::schedule::Schedule;
use dit::util::rng::Rng;

/// The tuned best schedule per GEMM op, in graph order — the slice
/// [`estimate_graph`] expects.
fn best_schedules(rep: &dit::coordinator::engine::GraphReport) -> Vec<Schedule> {
    rep.report.shapes.iter().map(|s| s.result.best().schedule.clone()).collect()
}

/// Fusing is a traffic optimization, not a numerical one: lowering the
/// attention chain with the intermediates SPM-resident must produce the
/// exact same f32 bits as lowering it with every intermediate spilled
/// through an explicit byte round-trip (the HBM store + reload the fused
/// pass skips). Both paths run the real deployed GEMMs and the same host
/// softmax oracle.
#[test]
fn fused_and_unfused_lowerings_agree_bitwise() {
    let arch = ArchConfig::tiny(4, 4);
    let (seq, d) = (64, 32);
    let g = WorkloadGraph::attention_prefill("attn", seq, d, 1);
    let rep = Engine::new(&arch).tune_graph(&g).unwrap();

    // On this grid both intermediates fit next to the tuned working
    // sets: nothing in the chain round-trips through HBM.
    assert_eq!(rep.resident_edges(), 2, "{:?}", rep.edges);
    assert!(rep.hbm_transfers().is_empty(), "{:?}", rep.hbm_transfers());

    let qk_shape = GemmShape::new(seq, seq, d);
    let av_shape = GemmShape::new(seq, d, seq);
    assert_eq!(rep.report.shapes[0].shape, qk_shape);
    assert_eq!(rep.report.shapes[1].shape, av_shape);
    let scheds = best_schedules(&rep);
    let qk_dep = deploy_functional(&arch, qk_shape, &scheds[0]).unwrap();
    let av_dep = deploy_functional(&arch, av_shape, &scheds[1]).unwrap();

    let mut rng = Rng::new(0xD17);
    let q = rng.f32_vec(seq * d); // A of QK^T: seq x d
    let kt = rng.f32_vec(d * seq); // B of QK^T: d x seq
    let v = rng.f32_vec(seq * d); // B of PV: seq x d

    // Fused: scores/probs stay in on-fabric f32 buffers.
    let scores = run_gemm(&arch, &qk_dep, &q, &kt).unwrap();
    let probs = softmax_rows(&scores, seq, seq);
    let fused = run_gemm(&arch, &av_dep, &probs, &v).unwrap();

    // Unfused: every intermediate is serialized to little-endian f32
    // bytes and read back — an explicit HBM round-trip.
    let spill = |data: &[f32]| -> Vec<f32> {
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect()
    };
    let scores2 = spill(&run_gemm(&arch, &qk_dep, &q, &kt).unwrap());
    let probs2 = spill(&softmax_rows(&scores2, seq, seq));
    let unfused = run_gemm(&arch, &av_dep, &probs2, &v).unwrap();

    assert_eq!(fused.len(), unfused.len());
    for (i, (a, b)) in fused.iter().zip(&unfused).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "output {i} differs: {a} vs {b}");
    }
}

/// The engine's measured saving, the per-edge breakdown and the analytic
/// model's credit are the same arithmetic — they must agree exactly, and
/// a resident chain must make the fused pass strictly cheaper.
#[test]
fn fused_traffic_is_strictly_lower_and_matches_the_analytic_estimate() {
    let arch = ArchConfig::tiny(4, 4);
    let g = WorkloadGraph::attention_prefill("attn", 64, 32, 2);
    let rep = Engine::new(&arch).tune_graph(&g).unwrap();

    assert!(
        rep.fused_hbm_bytes < rep.unfused_hbm_bytes,
        "fused {} vs unfused {}",
        rep.fused_hbm_bytes,
        rep.unfused_hbm_bytes
    );
    let edge_sum: u64 = rep.edges.iter().map(|e| e.saved_hbm_bytes).sum();
    assert_eq!(rep.saved_hbm_bytes(), edge_sum, "delta is exactly the per-edge sum");

    let est = estimate_graph(&arch, &g, &best_schedules(&rep)).unwrap();
    assert_eq!(est.saved_hbm_bytes, rep.saved_hbm_bytes(), "analytic credit == measured delta");
    assert!(est.saved_ns > 0.0);
    assert!(est.total_ns < est.unfused_ns);
}

/// Acceptance: the builtin attention-prefill graph on the flagship
/// preset keeps both intermediates resident, moves strictly fewer HBM
/// bytes fused than edge-free, and the delta matches the analytic
/// estimate (tiered tuning keeps the simulation count small).
#[test]
fn builtin_attention_prefill_fuses_on_the_flagship_preset() {
    let arch = ArchConfig::gh200_like();
    let g = WorkloadGraph::builtin("attn-prefill").unwrap();
    let engine = Engine::new(&arch).with_policy(TunePolicy::Tiered { top_k: 2, explore: 1 });
    let rep = engine.tune_graph(&g).unwrap();

    assert_eq!(rep.resident_edges(), 2, "{:?}", rep.edges);
    assert!(rep.fused_hbm_bytes < rep.unfused_hbm_bytes);
    // One GEMM endpoint per edge (the other end is softmax glue):
    // 512x512 scores at 1 B/elem, 32 heads, twice.
    assert_eq!(rep.saved_hbm_bytes(), 2 * 512 * 512 * 32);
    let est = estimate_graph(&arch, &g, &best_schedules(&rep)).unwrap();
    assert_eq!(est.saved_hbm_bytes, rep.saved_hbm_bytes());
}

/// A single-GEMM workload expressed as a (degenerate, edge-free) graph
/// must tune bit-identically to the flat path: same best schedule, same
/// cache key, same simulated stats — the graph layer adds nothing but
/// the (empty) edge classification.
#[test]
fn single_gemm_graph_path_is_bit_identical_to_the_flat_path() {
    let arch = ArchConfig::tiny(4, 4);
    let mut w = Workload::new("one");
    w.push("gemm0", GemmShape::new(96, 64, 128), 3);
    let g = WorkloadGraph::from_workload(&w);
    let rt = g.to_workload();
    assert_eq!(rt.items.len(), w.items.len(), "lossless round-trip");
    for (x, y) in rt.items.iter().zip(&w.items) {
        assert_eq!(
            (x.label.as_str(), x.shape, x.count),
            (y.label.as_str(), y.shape, y.count)
        );
    }

    let flat = Engine::new(&arch).tune_workload(&w).unwrap();
    let graph = Engine::new(&arch).tune_graph(&g).unwrap();
    assert_eq!(graph.report.shapes.len(), 1);
    let a = flat.shapes[0].result.best();
    let b = graph.report.shapes[0].result.best();
    assert_eq!(a.schedule, b.schedule);
    assert_eq!(a.schedule.cache_key(), b.schedule.cache_key());
    assert_eq!(a.stats.makespan_ns.to_bits(), b.stats.makespan_ns.to_bits());

    assert!(graph.edges.is_empty(), "no edges on a degenerate graph");
    assert_eq!(graph.fused_hbm_bytes, graph.unfused_hbm_bytes);
    assert_eq!(graph.saved_hbm_bytes(), 0);
}

/// The committed graph config used by the CI lint lane is the builtin,
/// verbatim — parse it and compare canonical renderings.
#[test]
fn committed_attention_prefill_graph_matches_the_builtin() {
    let text = std::fs::read_to_string("configs/attention_prefill.graph").expect("committed graph");
    let parsed = WorkloadGraph::from_text(&text).unwrap();
    let builtin = WorkloadGraph::builtin("attn-prefill").unwrap();
    assert_eq!(parsed.to_text(), builtin.to_text());
}

//! DSE acceptance tests: Pareto-frontier invariants, prune soundness
//! against an exhaustive sweep, sweep determinism, and consistency with
//! the single-architecture tuning path. Everything runs on tiny grids so
//! the suite stays fast in debug builds.

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::engine::Engine;
use dit::dse::{self, pareto, DseOptions, SweepSpec, DEFAULT_PRUNE_SLACK};

/// A 12-config sweep over tiny grids: three meshes × two CE shapes × two
/// SPM capacities of the tiny template.
fn tiny_spec() -> SweepSpec {
    SweepSpec {
        name: "tiny-test".into(),
        meshes: SweepSpec::square_meshes(&[2, 3, 4]),
        ce: vec![(16, 8), (8, 8)],
        spm_kib: vec![128, 256],
        hbm_channel_gbps: vec![32.0],
        hbm_channels_pct: vec![100],
        dma_engines: vec![2],
        base: ArchConfig::tiny(4, 4),
    }
}

/// A rectangular sweep over tiny grids: wide-short (2×4) and tall-narrow
/// (4×2) geometries next to the square twins bracketing their tile count.
fn rect_spec() -> SweepSpec {
    SweepSpec {
        name: "rect-test".into(),
        meshes: vec![(2, 4), (4, 2), (2, 2), (4, 4)],
        ce: vec![(16, 8)],
        spm_kib: vec![128, 256],
        hbm_channel_gbps: vec![32.0],
        hbm_channels_pct: vec![100],
        dma_engines: vec![2],
        base: ArchConfig::tiny(4, 4),
    }
}

fn tiny_workload() -> Workload {
    let mut w = Workload::new("dse-test");
    w.push("square", GemmShape::new(64, 64, 64), 2);
    w.push("flat", GemmShape::new(16, 128, 128), 1);
    w
}

fn opts(prune: bool) -> DseOptions {
    DseOptions { workers: 2, config_parallelism: 3, prune, ..DseOptions::default() }
}

/// Frontier invariants: points are cost-sorted, no frontier point
/// dominates another, every dominated point is excluded, and the
/// best-throughput config is always on the frontier.
#[test]
fn frontier_invariants() {
    let res = dse::run_sweep(&tiny_spec(), &tiny_workload(), &opts(true)).unwrap();
    assert!(!res.points.is_empty());
    for w in res.points.windows(2) {
        assert!(w[0].cost <= w[1].cost, "points sorted by cost");
    }
    let frontier = res.frontier();
    assert!(!frontier.is_empty());
    for a in &frontier {
        for b in &frontier {
            if !std::ptr::eq(*a, *b) {
                assert!(
                    !pareto::dominates((a.cost, a.tflops), (b.cost, b.tflops)),
                    "{} dominates {} on the frontier",
                    a.arch.name,
                    b.arch.name
                );
            }
        }
    }
    for p in res.points.iter().filter(|p| !p.on_frontier) {
        assert!(
            frontier.iter().any(|f| {
                pareto::dominates((f.cost, f.tflops), (p.cost, p.tflops))
                    || (f.cost, f.tflops) == (p.cost, p.tflops)
            }),
            "{} excluded from the frontier but not dominated",
            p.arch.name
        );
    }
    let best = res.best().unwrap();
    assert!(best.on_frontier, "max-TFLOPS point must be non-dominated");
}

/// The roofline bound the pruner relies on really is an upper bound on
/// what the simulator achieves, for every evaluated config.
#[test]
fn roofline_bound_holds_for_every_point() {
    let res = dse::run_sweep(&tiny_spec(), &tiny_workload(), &opts(false)).unwrap();
    for p in &res.points {
        assert!(
            p.tflops <= p.roofline_tflops * 1.000001,
            "{}: achieved {} exceeds roofline bound {}",
            p.arch.name,
            p.tflops,
            p.roofline_tflops
        );
        assert!(p.tflops > 0.0, "{}", p.arch.name);
    }
}

/// Prune soundness, checked exhaustively: a sweep with pruning must
/// produce exactly the frontier of the exhaustive (prune-free) sweep, and
/// every pruned config must be beaten by a measured point even at its
/// slack-inflated ceiling.
#[test]
fn prune_is_sound_vs_exhaustive_sweep() {
    let spec = tiny_spec();
    let w = tiny_workload();
    let full = dse::run_sweep(&spec, &w, &opts(false)).unwrap();
    let pruned = dse::run_sweep(&spec, &w, &opts(true)).unwrap();

    assert!(full.pruned.is_empty(), "prune disabled must evaluate everything");
    let total = spec.enumerate().len();
    assert_eq!(full.points.len() + full.infeasible.len(), total);
    assert_eq!(
        pruned.points.len() + pruned.pruned.len() + pruned.infeasible.len(),
        total,
        "every config is evaluated, pruned, or infeasible"
    );

    let f1: Vec<_> = full.frontier().iter().map(|p| p.arch.name.clone()).collect();
    let f2: Vec<_> = pruned.frontier().iter().map(|p| p.arch.name.clone()).collect();
    assert_eq!(f1, f2, "pruning must not change the frontier");
    for (a, b) in full.frontier().iter().zip(pruned.frontier().iter()) {
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.tflops.to_bits(), b.tflops.to_bits());
    }

    // No pruned config could have joined the frontier: some evaluated
    // point beats even its slack-inflated ceiling at no greater cost.
    for px in &pruned.pruned {
        let bound = px.roofline_tflops * (1.0 + DEFAULT_PRUNE_SLACK);
        assert!(
            pruned.points.iter().any(|p| {
                (p.tflops > bound && p.cost <= px.cost) || (p.tflops >= bound && p.cost < px.cost)
            }),
            "{} pruned without a dominating witness",
            px.name
        );
        // And its measured twin in the exhaustive sweep (if it deployed at
        // all) is off-frontier.
        if let Some(twin) = full.points.iter().find(|p| p.arch.name == px.name) {
            assert!(!twin.on_frontier, "{} was pruned but is Pareto-optimal", px.name);
        }
    }
}

/// The prune-slack knob is validated before a sweep runs: out-of-range or
/// non-finite fractions are rejected, in-range ones accepted, and a wider
/// slack can only shrink the pruned set (it makes the bound harder to
/// beat).
#[test]
fn prune_slack_is_validated_and_monotone() {
    let spec = tiny_spec();
    let w = tiny_workload();
    for bad in [-0.01, 0.51, f64::NAN, f64::INFINITY] {
        let o = DseOptions { prune_slack: bad, ..opts(true) };
        let err = dse::run_sweep(&spec, &w, &o).unwrap_err().to_string();
        assert!(err.contains("prune slack"), "{bad}: {err}");
    }
    let tight = dse::run_sweep(&spec, &w, &DseOptions { prune_slack: 0.0, ..opts(true) })
        .unwrap();
    let wide = dse::run_sweep(&spec, &w, &DseOptions { prune_slack: 0.5, ..opts(true) })
        .unwrap();
    assert!(
        wide.pruned.len() <= tight.pruned.len(),
        "wider slack pruned more: {} > {}",
        wide.pruned.len(),
        tight.pruned.len()
    );
    // Both stay sound: same frontier as the current-default sweep.
    let base = dse::run_sweep(&spec, &w, &opts(true)).unwrap();
    let names = |r: &dse::DseResult| {
        r.frontier().iter().map(|p| p.arch.name.clone()).collect::<Vec<_>>()
    };
    assert_eq!(names(&tight), names(&base));
    assert_eq!(names(&wide), names(&base));
}

/// Two sweeps over the same spec produce identical results, bit for bit,
/// despite config-level and candidate-level parallelism.
#[test]
fn sweep_is_deterministic() {
    let spec = tiny_spec();
    let w = tiny_workload();
    let r1 = dse::run_sweep(&spec, &w, &opts(true)).unwrap();
    let o2 = DseOptions { workers: 4, config_parallelism: 1, ..opts(true) };
    let r2 = dse::run_sweep(&spec, &w, &o2).unwrap();
    assert_eq!(r1.points.len(), r2.points.len());
    for (a, b) in r1.points.iter().zip(&r2.points) {
        assert_eq!(a.arch.name, b.arch.name);
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.tflops.to_bits(), b.tflops.to_bits());
        assert_eq!(a.on_frontier, b.on_frontier);
    }
    let p1: Vec<_> = r1.pruned.iter().map(|p| p.name.clone()).collect();
    let p2: Vec<_> = r2.pruned.iter().map(|p| p.name.clone()).collect();
    assert_eq!(p1, p2, "prune decisions are scheduling-independent");
    assert_eq!(r1.infeasible.len(), r2.infeasible.len());
}

/// Rectangular sweeps keep every frontier invariant: points cost-sorted,
/// no frontier point dominated, the roofline bound holds for every
/// geometry, and both orientations actually evaluate (the old square-only
/// spec could not even express them).
#[test]
fn rectangular_frontier_invariants_and_roofline_bound() {
    let res = dse::run_sweep(&rect_spec(), &tiny_workload(), &opts(false)).unwrap();
    assert!(!res.points.is_empty());
    let has = |prefix: &str| res.points.iter().any(|p| p.arch.name.starts_with(prefix));
    assert!(has("dse-2x4-"), "wide-short geometry evaluated");
    assert!(has("dse-4x2-"), "tall-narrow geometry evaluated");
    for w in res.points.windows(2) {
        assert!(w[0].cost <= w[1].cost, "points sorted by cost");
    }
    for p in &res.points {
        assert!(
            p.tflops <= p.roofline_tflops * 1.000001,
            "{}: achieved {} exceeds roofline bound {}",
            p.arch.name,
            p.tflops,
            p.roofline_tflops
        );
        assert!(p.tflops > 0.0, "{}", p.arch.name);
    }
    let frontier = res.frontier();
    assert!(!frontier.is_empty());
    for a in &frontier {
        for b in &frontier {
            if !std::ptr::eq(*a, *b) {
                assert!(
                    !pareto::dominates((a.cost, a.tflops), (b.cost, b.tflops)),
                    "{} dominates {} on the frontier",
                    a.arch.name,
                    b.arch.name
                );
            }
        }
    }
    assert!(res.best().unwrap().on_frontier);
}

/// Prune soundness extends to rows != cols: a pruned rectangular sweep
/// produces exactly the exhaustive sweep's frontier, bit for bit, with a
/// dominating witness for everything it skipped — on both a wide-short
/// and a tall-narrow geometry.
#[test]
fn prune_is_sound_vs_exhaustive_on_rectangular_meshes() {
    let spec = rect_spec();
    let w = tiny_workload();
    let full = dse::run_sweep(&spec, &w, &opts(false)).unwrap();
    let pruned = dse::run_sweep(&spec, &w, &opts(true)).unwrap();

    assert!(full.pruned.is_empty(), "prune disabled must evaluate everything");
    let total = spec.enumerate().len();
    assert_eq!(full.points.len() + full.infeasible.len(), total);
    assert_eq!(
        pruned.points.len() + pruned.pruned.len() + pruned.infeasible.len(),
        total,
        "every config is evaluated, pruned, or infeasible"
    );

    let f1: Vec<_> = full.frontier().iter().map(|p| p.arch.name.clone()).collect();
    let f2: Vec<_> = pruned.frontier().iter().map(|p| p.arch.name.clone()).collect();
    assert_eq!(f1, f2, "pruning must not change the rectangular frontier");
    for (a, b) in full.frontier().iter().zip(pruned.frontier().iter()) {
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.tflops.to_bits(), b.tflops.to_bits());
    }
    for px in &pruned.pruned {
        let bound = px.roofline_tflops * (1.0 + DEFAULT_PRUNE_SLACK);
        assert!(
            pruned.points.iter().any(|p| {
                (p.tflops > bound && p.cost <= px.cost) || (p.tflops >= bound && p.cost < px.cost)
            }),
            "{} pruned without a dominating witness",
            px.name
        );
    }
}

/// Rectangular sweeps are as deterministic as square ones: two runs with
/// different parallelism settings agree bit for bit.
#[test]
fn rectangular_sweep_is_deterministic() {
    let spec = rect_spec();
    let w = tiny_workload();
    let r1 = dse::run_sweep(&spec, &w, &opts(true)).unwrap();
    let o2 = DseOptions { workers: 4, config_parallelism: 1, ..opts(true) };
    let r2 = dse::run_sweep(&spec, &w, &o2).unwrap();
    assert_eq!(r1.points.len(), r2.points.len());
    for (a, b) in r1.points.iter().zip(&r2.points) {
        assert_eq!(a.arch.name, b.arch.name);
        assert_eq!(a.cost.to_bits(), b.cost.to_bits());
        assert_eq!(a.tflops.to_bits(), b.tflops.to_bits());
        assert_eq!(a.on_frontier, b.on_frontier);
    }
    let p1: Vec<_> = r1.pruned.iter().map(|p| p.name.clone()).collect();
    let p2: Vec<_> = r2.pruned.iter().map(|p| p.name.clone()).collect();
    assert_eq!(p1, p2, "prune decisions are scheduling-independent");
}

/// Regression for the square-only `best_at_mesh` bug: a 16×4 point must
/// be findable by its exact geometry, must not answer for its transpose
/// or for the square mesh with the same tile count, and the square
/// convenience wrapper keeps the old call shape.
#[test]
fn best_at_mesh_finds_rectangular_points() {
    let spec = SweepSpec {
        name: "skinny".into(),
        meshes: vec![(16, 4), (4, 4)],
        ce: vec![(16, 8)],
        spm_kib: vec![256],
        hbm_channel_gbps: vec![32.0],
        hbm_channels_pct: vec![100],
        dma_engines: vec![2],
        base: ArchConfig::tiny(4, 4),
    };
    let w = Workload::single("one", GemmShape::new(64, 64, 64));
    let res = dse::run_sweep(&spec, &w, &opts(false)).unwrap();

    let p = res.best_at_mesh(16, 4).expect("the 16x4 point is findable");
    assert_eq!((p.arch.rows, p.arch.cols), (16, 4));
    assert!(res.best_at_mesh(4, 16).is_none(), "transpose was never swept");
    assert!(res.best_at_mesh(8, 8).is_none(), "same tile count must not alias");
    let sq = res.best_at_square(4).expect("square wrapper still works");
    assert_eq!((sq.arch.rows, sq.arch.cols), (4, 4));
    assert_eq!(
        res.best_at_square(4).unwrap().tflops.to_bits(),
        res.best_at_mesh(4, 4).unwrap().tflops.to_bits()
    );
}

/// A sweep that contains the reference machine can never do worse than
/// tuning that machine directly: the best sweep point is at least as fast,
/// and the included twin config reproduces the baseline bit for bit.
#[test]
fn best_config_matches_or_beats_included_baseline() {
    let base = ArchConfig::tiny(4, 4);
    let spec = SweepSpec {
        name: "baseline-inclusion".into(),
        meshes: SweepSpec::square_meshes(&[2, 4]),
        ce: vec![(base.tile.ce_m, base.tile.ce_n)],
        spm_kib: vec![base.tile.l1_bytes / 1024],
        hbm_channel_gbps: vec![base.hbm.channel_gbps],
        hbm_channels_pct: vec![100],
        dma_engines: vec![base.tile.dma_engines],
        base: base.clone(),
    };
    let w = tiny_workload();
    let res = dse::run_sweep(&spec, &w, &opts(true)).unwrap();

    let baseline = Engine::new(&base).tune_workload(&w).unwrap().aggregate_tflops();
    let best = res.best().unwrap();
    assert!(
        best.tflops >= baseline,
        "sweep best {} below included baseline {}",
        best.tflops,
        baseline
    );
    // The 4x4 twin differs from the baseline config only by name, so its
    // measured throughput must be identical bit for bit.
    let twin = res.points.iter().find(|p| p.arch.rows == 4 && p.arch.cols == 4).unwrap();
    assert_eq!(twin.tflops.to_bits(), baseline.to_bits());
}

/// The sweep shares one memo-cache: candidate configs that repeat between
/// two sweeps of the same engine re-simulate nothing. (Here we just check
/// that a second identical run_sweep call reports the same totals — each
/// call builds a fresh engine — and that a config repeated *within* a spec
/// is served from cache via the sim-call count.)
#[test]
fn duplicate_configs_tune_from_cache() {
    let base = ArchConfig::tiny(2, 2);
    let spec = SweepSpec {
        name: "dup".into(),
        meshes: vec![(2, 2), (2, 2)], // the same config twice
        ce: vec![(16, 8)],
        spm_kib: vec![256],
        hbm_channel_gbps: vec![32.0],
        hbm_channels_pct: vec![100],
        dma_engines: vec![2],
        base,
    };
    let w = Workload::single("one", GemmShape::new(64, 64, 64));
    // Serialize waves so the second copy deterministically sees the
    // first's cache entries (concurrent identical configs would race the
    // plan phase and split the sims/hits counts nondeterministically).
    let o = DseOptions { workers: 2, config_parallelism: 1, prune: false, ..DseOptions::default() };
    let res = dse::run_sweep(&spec, &w, &o).unwrap();
    assert_eq!(res.points.len(), 2);
    assert!(
        res.cache_hits >= res.sim_calls,
        "second copy must be all cache hits: {} sims, {} hits",
        res.sim_calls,
        res.cache_hits
    );
    assert_eq!(res.points[0].tflops.to_bits(), res.points[1].tflops.to_bits());
}

/// Infeasible configurations (SPM too small for any schedule) are
/// reported, not fatal, as long as something in the sweep deploys.
#[test]
fn infeasible_configs_are_reported_not_fatal() {
    let mut spec = tiny_spec();
    spec.meshes = vec![(2, 2)];
    spec.ce = vec![(16, 8)];
    spec.spm_kib = vec![4, 256]; // 4 KiB fails ArchConfig::validate (min 4096 B is 4 KiB exactly)
    let w = Workload::single("huge", GemmShape::new(1 << 10, 1 << 10, 1 << 10));
    // A 4 KiB SPM cannot hold any candidate's working set for this shape;
    // the 256 KiB config can (via chunking).
    let res = dse::run_sweep(&spec, &w, &opts(false)).unwrap();
    assert!(!res.points.is_empty());
    assert!(
        !res.infeasible.is_empty(),
        "expected the 4 KiB-SPM config to be infeasible: {:?}",
        res.points.iter().map(|p| p.arch.name.clone()).collect::<Vec<_>>()
    );
    let (name, err) = &res.infeasible[0];
    assert!(name.contains("spm4k"), "{name}");
    assert!(err.contains("no deployable schedule") || err.contains("no chunking"), "{err}");
}

//! Multi-op workload graphs — the program IR above single GEMMs.
//!
//! `arch::workload::Workload` is a flat list of independent GEMMs; a real
//! transformer block is a *chain*: QK^T feeds softmax feeds PV, and the
//! MLP's up-projection feeds an activation feeds the down-projection. A
//! [`WorkloadGraph`] names that structure — GEMM ops plus softmax /
//! elementwise glue, connected by named intermediate tensors — so the
//! tuning engine can decide per edge whether the intermediate stays
//! **SPM-resident** (producer's output is left on-fabric and consumed in
//! place, skipping the HBM store *and* the compulsory reload) or is
//! **spilled** through HBM like the flat path always does.
//!
//! Design notes:
//!
//! * A plain [`Workload`] round-trips losslessly as a degenerate edge-free
//!   graph ([`WorkloadGraph::from_workload`] / [`WorkloadGraph::to_workload`]),
//!   so the graph path reuses the engine's cache keys and produces
//!   bit-identical schedules for single-GEMM programs.
//! * Residency is decided per edge with one shared rule
//!   ([`edge_is_resident`]): the intermediate's per-tile share
//!   ([`tensor_share_bytes`]) must fit in L1 *alongside both endpoints'*
//!   working sets. The engine applies it with tuned schedules; the static
//!   checker (`analysis`) applies it optimistically over all candidates.
//! * The saved-traffic arithmetic ([`edge_saved_bytes`]) is defined here
//!   once and used by both the engine's measured report and
//!   `perfmodel::analytic`'s chain estimate, so the two agree exactly.
//!
//! Non-GEMM ops carry no FLOPs in the performance model — softmax and
//! elementwise glue are bandwidth-trivial next to their neighbouring GEMMs
//! — but they anchor edges, force shape agreement, and (functionally) run
//! on the host oracle via [`softmax_rows`].

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::arch::workload::Workload;
use crate::arch::{ArchConfig, GemmShape};

/// Index of an op within its graph (position in [`WorkloadGraph::ops`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OpId(pub usize);

/// What an op computes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// A GEMM of the given logical shape; the op's output tensor is M×N.
    Gemm(GemmShape),
    /// Row-wise softmax over its single input; output has the input's dims.
    Softmax,
    /// Pointwise map over its single input (activation, scale, mask);
    /// output has the input's dims.
    Elementwise,
}

impl OpKind {
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Gemm(_) => "gemm",
            OpKind::Softmax => "softmax",
            OpKind::Elementwise => "elementwise",
        }
    }
}

/// A named intermediate tensor flowing along an edge, with its logical
/// (unpadded) dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorRef {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
}

impl TensorRef {
    /// Total bytes at the architecture's element width.
    pub fn bytes(&self, arch: &ArchConfig) -> u64 {
        (self.rows * self.cols * arch.elem_bytes) as u64
    }
}

/// One op in a workload graph.
#[derive(Debug, Clone)]
pub struct GraphOp {
    pub id: OpId,
    /// Human-readable role, e.g. `attn/qk`.
    pub label: String,
    pub kind: OpKind,
    /// Executions per workload pass (e.g. once per layer or head). Edges
    /// may only connect ops with equal counts — a fused chain executes as
    /// a unit.
    pub count: usize,
}

/// A directed producer → consumer edge carrying a named intermediate.
#[derive(Debug, Clone)]
pub struct GraphEdge {
    pub from: OpId,
    pub to: OpId,
    pub tensor: TensorRef,
}

/// A small multi-op program: GEMMs plus softmax/elementwise glue with
/// named intermediate edges. See the module docs for the contract.
#[derive(Debug, Clone)]
pub struct WorkloadGraph {
    pub name: String,
    pub ops: Vec<GraphOp>,
    pub edges: Vec<GraphEdge>,
}

impl WorkloadGraph {
    pub fn new(name: impl Into<String>) -> WorkloadGraph {
        WorkloadGraph { name: name.into(), ops: Vec::new(), edges: Vec::new() }
    }

    fn add_op(&mut self, label: impl Into<String>, kind: OpKind, count: usize) -> OpId {
        let id = OpId(self.ops.len());
        self.ops.push(GraphOp { id, label: label.into(), kind, count });
        id
    }

    pub fn add_gemm(&mut self, label: impl Into<String>, shape: GemmShape, count: usize) -> OpId {
        self.add_op(label, OpKind::Gemm(shape), count)
    }

    pub fn add_softmax(&mut self, label: impl Into<String>, count: usize) -> OpId {
        self.add_op(label, OpKind::Softmax, count)
    }

    pub fn add_elementwise(&mut self, label: impl Into<String>, count: usize) -> OpId {
        self.add_op(label, OpKind::Elementwise, count)
    }

    pub fn op(&self, id: OpId) -> &GraphOp {
        &self.ops[id.0]
    }

    /// The dimensions of an op's output tensor: M×N for a GEMM, the input
    /// dims for softmax/elementwise (which need an incoming edge first).
    pub fn output_dims(&self, id: OpId) -> Option<(usize, usize)> {
        match self.op(id).kind {
            OpKind::Gemm(s) => Some((s.m, s.n)),
            OpKind::Softmax | OpKind::Elementwise => self
                .edges
                .iter()
                .find(|e| e.to == id)
                .map(|e| (e.tensor.rows, e.tensor.cols)),
        }
    }

    /// Connect `from`'s output to `to` as a named intermediate. The tensor
    /// dims are derived from the producer's output at call time, so wire
    /// chains front-to-back.
    pub fn connect(&mut self, from: OpId, to: OpId, tensor: impl Into<String>) -> Result<()> {
        ensure!(from.0 < self.ops.len(), "edge source {from:?} out of range");
        ensure!(to.0 < self.ops.len(), "edge target {to:?} out of range");
        let name = tensor.into();
        let (rows, cols) = self.output_dims(from).ok_or_else(|| {
            anyhow::anyhow!(
                "op {:?} has no derivable output dims (non-GEMM ops need an \
                 incoming edge before they can produce)",
                self.op(from).label
            )
        })?;
        self.edges.push(GraphEdge { from, to, tensor: TensorRef { name, rows, cols } });
        Ok(())
    }

    /// Ops in a stable topological order (ready ops taken in id order), or
    /// an error naming the ops stuck on a cycle.
    pub fn topo_order(&self) -> Result<Vec<OpId>> {
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            indeg[e.to.0] += 1;
        }
        let mut order = Vec::with_capacity(n);
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        while let Some(i) = ready.first().copied() {
            ready.remove(0);
            order.push(OpId(i));
            for e in self.edges.iter().filter(|e| e.from.0 == i) {
                indeg[e.to.0] -= 1;
                if indeg[e.to.0] == 0 {
                    // Keep the ready set sorted so the order is stable.
                    let pos = ready.binary_search(&e.to.0).unwrap_or_else(|p| p);
                    ready.insert(pos, e.to.0);
                }
            }
        }
        if order.len() != n {
            let stuck: Vec<&str> = (0..n)
                .filter(|&i| indeg[i] > 0)
                .map(|i| self.ops[i].label.as_str())
                .collect();
            bail!("workload graph {:?} has a cycle through {:?}", self.name, stuck);
        }
        Ok(order)
    }

    /// Structural validation: edges in range, acyclic, unique labels,
    /// counts agree along edges, non-GEMM ops have exactly one input, a
    /// GEMM consumes at most one fused input (its A operand) and the
    /// producer's dims must match that operand (M×K). `analysis`'s graph
    /// pass mirrors these clauses as `DIT-E` diagnostics.
    pub fn validate(&self) -> Result<()> {
        for (i, op) in self.ops.iter().enumerate() {
            ensure!(op.id.0 == i, "op {:?} id {:?} != position {i}", op.label, op.id);
            ensure!(op.count > 0, "op {:?} has zero count", op.label);
        }
        let mut labels: Vec<&str> = self.ops.iter().map(|o| o.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        ensure!(labels.len() == self.ops.len(), "graph {:?} has duplicate op labels", self.name);
        for e in &self.edges {
            ensure!(e.from.0 < self.ops.len(), "edge source {:?} out of range", e.from);
            ensure!(e.to.0 < self.ops.len(), "edge target {:?} out of range", e.to);
            ensure!(e.from != e.to, "op {:?} feeds itself", self.op(e.from).label);
            ensure!(
                self.op(e.from).count == self.op(e.to).count,
                "edge {:?}: producer {:?} count {} != consumer {:?} count {} (a fused \
                 chain executes as a unit)",
                e.tensor.name,
                self.op(e.from).label,
                self.op(e.from).count,
                self.op(e.to).label,
                self.op(e.to).count
            );
        }
        self.topo_order()?;
        for op in &self.ops {
            let incoming: Vec<&GraphEdge> = self.edges.iter().filter(|e| e.to == op.id).collect();
            match op.kind {
                OpKind::Gemm(s) => {
                    ensure!(
                        incoming.len() <= 1,
                        "GEMM {:?} has {} fused inputs; only the A operand can be \
                         consumed from an on-fabric producer",
                        op.label,
                        incoming.len()
                    );
                    if let Some(e) = incoming.first() {
                        ensure!(
                            (e.tensor.rows, e.tensor.cols) == (s.m, s.k),
                            "edge {:?}: producer output {}x{} does not match GEMM \
                             {:?} A operand {}x{}",
                            e.tensor.name,
                            e.tensor.rows,
                            e.tensor.cols,
                            op.label,
                            s.m,
                            s.k
                        );
                    }
                }
                OpKind::Softmax | OpKind::Elementwise => {
                    ensure!(
                        incoming.len() == 1,
                        "{} op {:?} needs exactly one input, has {}",
                        op.kind.name(),
                        op.label,
                        incoming.len()
                    );
                }
            }
        }
        Ok(())
    }

    /// The GEMM ops lowered to a flat [`Workload`] (graph name, op order,
    /// labels and counts preserved). For a graph built by
    /// [`WorkloadGraph::from_workload`] this reproduces the original
    /// workload exactly, which is what keeps the graph-backed tuning path
    /// bit-identical (same shapes, labels, and cache keys) for edge-free
    /// programs.
    pub fn to_workload(&self) -> Workload {
        let mut w = Workload::new(self.name.clone());
        for op in &self.ops {
            if let OpKind::Gemm(shape) = op.kind {
                w.push(op.label.clone(), shape, op.count);
            }
        }
        w
    }

    /// Lift a flat workload into a degenerate (edge-free) graph: one GEMM
    /// op per item, in order.
    pub fn from_workload(w: &Workload) -> WorkloadGraph {
        let mut g = WorkloadGraph::new(w.name.clone());
        for item in &w.items {
            g.add_gemm(item.label.clone(), item.shape, item.count);
        }
        g
    }

    /// Total FLOPs of one graph pass (GEMM ops only, counts applied).
    pub fn total_flops(&self) -> f64 {
        self.to_workload().total_flops()
    }

    /// Render to the committed text format (round-trips via
    /// [`WorkloadGraph::from_text`]).
    pub fn to_text(&self) -> String {
        let mut out = format!("graph {}\n", self.name);
        for op in &self.ops {
            match op.kind {
                OpKind::Gemm(s) => {
                    out.push_str(&format!("op {} gemm {} x{}\n", op.label, s, op.count))
                }
                OpKind::Softmax | OpKind::Elementwise => {
                    out.push_str(&format!("op {} {} x{}\n", op.label, op.kind.name(), op.count))
                }
            }
        }
        for e in &self.edges {
            out.push_str(&format!(
                "edge {} -> {} {}\n",
                self.op(e.from).label,
                self.op(e.to).label,
                e.tensor.name
            ));
        }
        out
    }

    /// Parse the text format:
    ///
    /// ```text
    /// # comment
    /// graph attn-prefill
    /// op qk gemm 512x512x64 x32
    /// op smax softmax x32
    /// op av gemm 512x64x512 x32
    /// edge qk -> smax scores
    /// edge smax -> av probs
    /// ```
    ///
    /// `xN` count suffixes are optional (default 1). The result is
    /// [`validate`](WorkloadGraph::validate)d before being returned.
    pub fn from_text(text: &str) -> Result<WorkloadGraph> {
        let mut g: Option<WorkloadGraph> = None;
        let mut by_label: BTreeMap<String, OpId> = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let at = |msg: &str| anyhow::anyhow!("line {}: {msg}: {raw:?}", lineno + 1);
            match toks[0] {
                "graph" => {
                    ensure!(toks.len() == 2, at("expected `graph NAME`"));
                    ensure!(g.is_none(), at("duplicate `graph` header"));
                    g = Some(WorkloadGraph::new(toks[1]));
                }
                "op" => {
                    let g = g.as_mut().ok_or_else(|| at("`op` before `graph` header"))?;
                    ensure!(toks.len() >= 3, at("expected `op LABEL KIND [SHAPE] [xN]`"));
                    let label = toks[1];
                    let parse_count = |tok: Option<&&str>| -> Result<usize> {
                        match tok {
                            None => Ok(1),
                            Some(t) => {
                                let n = t
                                    .strip_prefix('x')
                                    .ok_or_else(|| at("count must look like `x32`"))?;
                                Ok(n.parse::<usize>().map_err(|_| at("bad count"))?)
                            }
                        }
                    };
                    let id = match toks[2] {
                        "gemm" => {
                            ensure!(toks.len() >= 4, at("gemm op needs a MxNxK shape"));
                            let shape = GemmShape::parse(toks[3])?;
                            ensure!(toks.len() <= 5, at("trailing tokens"));
                            let count = parse_count(toks.get(4))?;
                            g.add_gemm(label, shape, count)
                        }
                        "softmax" | "elementwise" => {
                            ensure!(toks.len() <= 4, at("trailing tokens"));
                            let count = parse_count(toks.get(3))?;
                            if toks[2] == "softmax" {
                                g.add_softmax(label, count)
                            } else {
                                g.add_elementwise(label, count)
                            }
                        }
                        other => bail!(at(&format!("unknown op kind {other:?}"))),
                    };
                    ensure!(
                        by_label.insert(label.to_string(), id).is_none(),
                        at("duplicate op label")
                    );
                }
                "edge" => {
                    let g = g.as_mut().ok_or_else(|| at("`edge` before `graph` header"))?;
                    ensure!(
                        toks.len() == 5 && toks[2] == "->",
                        at("expected `edge FROM -> TO TENSOR`")
                    );
                    let from = *by_label.get(toks[1]).ok_or_else(|| at("unknown source op"))?;
                    let to = *by_label.get(toks[3]).ok_or_else(|| at("unknown target op"))?;
                    g.connect(from, to, toks[4])?;
                }
                other => bail!(at(&format!("unknown directive {other:?}"))),
            }
        }
        let g = g.ok_or_else(|| anyhow::anyhow!("no `graph NAME` header found"))?;
        g.validate()?;
        Ok(g)
    }

    /// Single-head attention prefill: QK^T (seq×seq×d_head) → softmax →
    /// PV (seq×d_head×seq), `count` heads per pass. The scores/probs
    /// intermediates are the fusion opportunity: seq×seq at 1–2 B/elem
    /// shares out to a few hundred bytes per tile on a real grid.
    pub fn attention_prefill(tag: &str, seq: usize, d_head: usize, count: usize) -> WorkloadGraph {
        let mut g = WorkloadGraph::new(tag.to_string());
        let qk = g.add_gemm(format!("{tag}/qk"), GemmShape::new(seq, seq, d_head), count);
        let sm = g.add_softmax(format!("{tag}/softmax"), count);
        let av = g.add_gemm(format!("{tag}/av"), GemmShape::new(seq, d_head, seq), count);
        g.connect(qk, sm, "scores").expect("builtin wiring");
        g.connect(sm, av, "probs").expect("builtin wiring");
        g
    }

    /// Attention at decode: one query row block per sequence (M = batch),
    /// same chain — the flat, memory-bound regime where skipping the HBM
    /// round-trip matters most.
    pub fn attention_decode(
        tag: &str,
        batch: usize,
        seq: usize,
        d_head: usize,
        count: usize,
    ) -> WorkloadGraph {
        let mut g = WorkloadGraph::new(tag.to_string());
        let qk = g.add_gemm(format!("{tag}/qk"), GemmShape::new(batch, seq, d_head), count);
        let sm = g.add_softmax(format!("{tag}/softmax"), count);
        let av = g.add_gemm(format!("{tag}/av"), GemmShape::new(batch, d_head, seq), count);
        g.connect(qk, sm, "scores").expect("builtin wiring");
        g.connect(sm, av, "probs").expect("builtin wiring");
        g
    }

    /// An MLP block: up-projection → activation → down-projection.
    pub fn mlp_chain(
        tag: &str,
        tokens: usize,
        d_model: usize,
        d_ff: usize,
        count: usize,
    ) -> WorkloadGraph {
        let mut g = WorkloadGraph::new(tag.to_string());
        let up = g.add_gemm(format!("{tag}/up"), GemmShape::new(tokens, d_ff, d_model), count);
        let act = g.add_elementwise(format!("{tag}/act"), count);
        let down = g.add_gemm(format!("{tag}/down"), GemmShape::new(tokens, d_model, d_ff), count);
        g.connect(up, act, "pre-act").expect("builtin wiring");
        g.connect(act, down, "act").expect("builtin wiring");
        g
    }

    /// Built-in graphs for the CLI / benches, keyed by name. Like
    /// [`Workload::builtin`] these use the paper's evaluation flavour
    /// (d_head = 64 attention heads, d_model = 1024 / d_ff = 4096 MLP).
    pub fn builtin(name: &str) -> Option<WorkloadGraph> {
        BUILTIN_GRAPHS.iter().find(|(n, _)| *n == name).map(|(_, f)| f())
    }

    /// Names accepted by [`WorkloadGraph::builtin`], from the same table.
    pub fn builtin_names() -> Vec<&'static str> {
        BUILTIN_GRAPHS.iter().map(|(n, _)| *n).collect()
    }
}

fn builtin_attn_prefill() -> WorkloadGraph {
    WorkloadGraph::attention_prefill("attn-prefill", 512, 64, 32)
}

fn builtin_attn_decode() -> WorkloadGraph {
    WorkloadGraph::attention_decode("attn-decode", 64, 512, 64, 32)
}

fn builtin_mlp_chain() -> WorkloadGraph {
    WorkloadGraph::mlp_chain("mlp-chain", 512, 1024, 4096, 4)
}

/// The single source of truth for builtin graph names (mirrors the
/// builtin-table pattern in `arch::workload`).
const BUILTIN_GRAPHS: &[(&str, fn() -> WorkloadGraph)] = &[
    ("attn-prefill", builtin_attn_prefill),
    ("attn-decode", builtin_attn_decode),
    ("mlp-chain", builtin_mlp_chain),
];

/// Per-tile SPM share of an intermediate tensor when it stays resident:
/// the tensor is distributed across the whole grid, so each tile holds
/// `ceil(bytes / num_tiles)`.
pub fn tensor_share_bytes(arch: &ArchConfig, t: &TensorRef) -> u64 {
    t.bytes(arch).div_ceil(arch.num_tiles() as u64)
}

/// The residency rule, shared by the engine (tuned working sets) and the
/// static checker (optimistic working sets): an edge's intermediate stays
/// on-fabric iff its per-tile share fits in L1 *alongside* both the
/// producer's and the consumer's working set.
pub fn edge_is_resident(arch: &ArchConfig, share: u64, need_from: u64, need_to: u64) -> bool {
    let l1 = arch.tile.l1_bytes as u64;
    // Saturating: a working set of u64::MAX models "no candidate fits".
    share.saturating_add(need_from) <= l1 && share.saturating_add(need_to) <= l1
}

/// Per-tile L1 working-set need of an op. GEMM needs come from the
/// caller-provided resolver (the engine passes `schedule::l1_estimate` of
/// the tuned best; the checker passes the minimum over all candidates);
/// softmax/elementwise ops stream their input in place, so their need is
/// the input tensor's share.
pub fn op_need_bytes(
    arch: &ArchConfig,
    g: &WorkloadGraph,
    op: &GraphOp,
    gemm_need: &mut dyn FnMut(&GraphOp, GemmShape) -> u64,
) -> u64 {
    match op.kind {
        OpKind::Gemm(s) => gemm_need(op, s),
        OpKind::Softmax | OpKind::Elementwise => g
            .edges
            .iter()
            .filter(|e| e.to == op.id)
            .map(|e| tensor_share_bytes(arch, &e.tensor))
            .sum(),
    }
}

/// HBM bytes one pass saves when this edge's intermediate stays resident:
/// the producer skips its C store and the consumer skips its A load, but
/// only GEMM endpoints count — softmax/elementwise glue never touches HBM
/// in the performance model, so a resident edge into or out of glue saves
/// nothing on that side. This keeps the saving a strict subset of the
/// traffic the simulator actually measured, which is what guarantees the
/// fused total stays positive (and strictly below unfused whenever a
/// GEMM-endpoint edge is resident).
pub fn edge_saved_bytes(arch: &ArchConfig, g: &WorkloadGraph, e: &GraphEdge) -> u64 {
    let mut endpoints = 0u64;
    if matches!(g.op(e.from).kind, OpKind::Gemm(_)) {
        endpoints += 1; // skipped C store
    }
    if matches!(g.op(e.to).kind, OpKind::Gemm(_)) {
        endpoints += 1; // skipped A load
    }
    e.tensor.bytes(arch) * endpoints * g.op(e.from).count as u64
}

/// Numerically-stable row-wise softmax (f32), the host-oracle companion to
/// [`OpKind::Softmax`] for functional fused-vs-unfused equivalence tests.
pub fn softmax_rows(data: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    assert_eq!(data.len(), rows * cols, "softmax_rows: data is not rows x cols");
    let mut out = vec![0.0f32; data.len()];
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for (c, &v) in row.iter().enumerate() {
            let e = (v - max).exp();
            out[r * cols + c] = e;
            denom += e;
        }
        for c in 0..cols {
            out[r * cols + c] /= denom;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attn() -> WorkloadGraph {
        WorkloadGraph::attention_prefill("attn", 64, 32, 2)
    }

    #[test]
    fn builder_derives_edge_tensors() {
        let g = attn();
        assert_eq!(g.ops.len(), 3);
        assert_eq!(g.edges.len(), 2);
        // QK output is seq x seq; softmax passes dims through.
        assert_eq!((g.edges[0].tensor.rows, g.edges[0].tensor.cols), (64, 64));
        assert_eq!((g.edges[1].tensor.rows, g.edges[1].tensor.cols), (64, 64));
        assert_eq!(g.edges[0].tensor.name, "scores");
        g.validate().unwrap();
    }

    #[test]
    fn topo_order_is_stable_and_cycles_are_rejected() {
        let g = attn();
        assert_eq!(g.topo_order().unwrap(), vec![OpId(0), OpId(1), OpId(2)]);

        let mut cyc = WorkloadGraph::new("cyc");
        let a = cyc.add_gemm("a", GemmShape::new(8, 8, 8), 1);
        let b = cyc.add_gemm("b", GemmShape::new(8, 8, 8), 1);
        cyc.connect(a, b, "ab").unwrap();
        cyc.connect(b, a, "ba").unwrap();
        let err = cyc.validate().unwrap_err().to_string();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn validate_rejects_shape_count_and_arity_violations() {
        // Producer output 8x8 does not match consumer A operand 16x8.
        let mut g = WorkloadGraph::new("bad-shape");
        let a = g.add_gemm("a", GemmShape::new(8, 8, 4), 1);
        let b = g.add_gemm("b", GemmShape::new(16, 4, 8), 1);
        g.connect(a, b, "t").unwrap();
        assert!(g.validate().unwrap_err().to_string().contains("does not match"));

        // Count mismatch along an edge.
        let mut g = WorkloadGraph::new("bad-count");
        let a = g.add_gemm("a", GemmShape::new(8, 8, 4), 2);
        let b = g.add_gemm("b", GemmShape::new(8, 4, 8), 3);
        g.connect(a, b, "t").unwrap();
        assert!(g.validate().unwrap_err().to_string().contains("count"));

        // Softmax with no input: connect() can't even derive its dims.
        let mut g = WorkloadGraph::new("dangling");
        let s = g.add_softmax("s", 1);
        let b = g.add_gemm("b", GemmShape::new(8, 4, 8), 1);
        assert!(g.connect(s, b, "t").is_err());
        // And validate() flags the input-less softmax itself.
        assert!(g.validate().unwrap_err().to_string().contains("exactly one input"));
    }

    #[test]
    fn workload_round_trips_as_degenerate_graph() {
        let w = Workload::builtin("tiny").unwrap();
        let g = WorkloadGraph::from_workload(&w);
        assert!(g.edges.is_empty());
        g.validate().unwrap();
        let back = g.to_workload();
        assert_eq!(back.name, w.name);
        assert_eq!(back.items.len(), w.items.len());
        for (a, b) in back.items.iter().zip(&w.items) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.count, b.count);
        }
    }

    #[test]
    fn text_format_round_trips() {
        for name in WorkloadGraph::builtin_names() {
            let g = WorkloadGraph::builtin(name).unwrap();
            let text = g.to_text();
            let back = WorkloadGraph::from_text(&text).unwrap();
            assert_eq!(back.name, g.name, "{name}");
            assert_eq!(back.ops.len(), g.ops.len(), "{name}");
            assert_eq!(back.edges.len(), g.edges.len(), "{name}");
            for (a, b) in back.ops.iter().zip(&g.ops) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.kind, b.kind);
                assert_eq!(a.count, b.count);
            }
            for (a, b) in back.edges.iter().zip(&g.edges) {
                assert_eq!(a.tensor, b.tensor);
                assert_eq!((a.from, a.to), (b.from, b.to));
            }
        }
    }

    #[test]
    fn from_text_rejects_malformed_input() {
        assert!(WorkloadGraph::from_text("").is_err());
        assert!(WorkloadGraph::from_text("op a gemm 8x8x8").is_err()); // no header
        assert!(WorkloadGraph::from_text("graph g\nop a wiggle\n").is_err());
        assert!(WorkloadGraph::from_text("graph g\nedge a -> b t\n").is_err());
        let dup = "graph g\nop a gemm 8x8x8\nop a gemm 8x8x8\n";
        assert!(WorkloadGraph::from_text(dup).is_err());
    }

    #[test]
    fn builtin_graphs_resolve_and_validate() {
        for name in WorkloadGraph::builtin_names() {
            let g = WorkloadGraph::builtin(name).unwrap();
            assert_eq!(g.name, name, "builtin name should match graph name");
            g.validate().unwrap();
            assert!(g.to_workload().items.len() >= 2, "{name}");
        }
        assert!(WorkloadGraph::builtin("nope").is_none());
    }

    #[test]
    fn residency_arithmetic() {
        let arch = ArchConfig::gh200_like();
        let g = WorkloadGraph::builtin("attn-prefill").unwrap();
        // scores: 512x512 at 1 B/elem over 1024 tiles = 256 B/tile.
        let share = tensor_share_bytes(&arch, &g.edges[0].tensor);
        assert_eq!(share, 256);
        assert!(edge_is_resident(&arch, share, 1024, 1024));
        let l1 = arch.tile.l1_bytes as u64;
        assert!(!edge_is_resident(&arch, share, l1, 0));

        // scores edge: qk (GEMM) -> softmax, only the producer side saves.
        let e = &g.edges[0];
        assert_eq!(edge_saved_bytes(&arch, &g, e), 512 * 512 * 32);
        // probs edge: softmax -> av (GEMM), only the consumer side saves.
        let e = &g.edges[1];
        assert_eq!(edge_saved_bytes(&arch, &g, e), 512 * 512 * 32);
    }

    #[test]
    fn softmax_rows_is_stable_and_normalized() {
        let out = softmax_rows(&[0.0, 0.0, 1000.0, 1000.0], 2, 2);
        for r in 0..2 {
            let sum: f32 = out[r * 2..(r + 1) * 2].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "row {r} sums to {sum}");
            assert!(out[r * 2].is_finite());
        }
        assert_eq!(out[0], 0.5);
        assert_eq!(out[2], 0.5);
    }
}

//! Closed-form per-schedule latency estimate (GOMA direction).
//!
//! The simulator is the source of truth, but it costs milliseconds per
//! candidate; this module prices a candidate in nanoseconds of host time
//! so the tuner can rank the whole candidate space analytically and
//! simulate only the promising head (see
//! [`crate::coordinator::engine::TunePolicy::Tiered`]).
//!
//! The estimate mirrors the simulator's structure rather than curve-
//! fitting it: per-superstep compute time reuses the *exact* matrix-engine
//! model ([`crate::sim::engine_time_ns`]), HBM phase time follows the
//! channel model (per-run request overhead + streamed bytes at
//! `channel_gbps · stream_efficiency`, runs-per-fetch from the §3.2
//! layout: one burst per panel under the optimized layout, one run per
//! row under the base layout) and the rectangular HBM-edge rule (channels
//! on the west and south edges, mean-route hop latency), and the NoC
//! phase prices the dataflow's per-step collective on one link plus the
//! mesh span. Double buffering overlaps the three phases (`max`);
//! single buffering serializes them (`+`). Working sets that exceed L1
//! are priced through the same column-chunking the deployment path uses
//! ([`crate::coordinator::chunking_for`]), so an estimate exists exactly
//! when the schedule is deployable.
//!
//! Calibration contract: the tiered tuner's winner must stay within ε of
//! the exhaustive winner's *simulated* makespan — asserted by
//! `tests/tiered.rs` and pinned by the `tiered` bench id in CI. The model
//! only has to *rank* well; absolute error is reported, not required.
//!
//! The serving layer leans on the same property: a neighbor-borrowed
//! schedule is admitted iff its estimate on the true shape is within ε
//! of the minimum estimate over that shape's own candidates
//! ([`crate::coordinator::shapedb`]) — a *relative* bound between two
//! estimates of near-identical problems, exactly where a
//! structure-mirroring model is most trustworthy. `tests/serve.rs`
//! re-derives that bound from first principles for every borrow.

use crate::arch::{ArchConfig, GemmShape};
use crate::graph::{OpKind, WorkloadGraph};
use crate::schedule::{Dataflow, Schedule};
use crate::sim::engine_time_ns;

/// Analytic phase breakdown for one schedule on one problem, in ns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalyticLatency {
    /// Serial matrix-engine time over all K-panels.
    pub compute_ns: f64,
    /// On-chip collective time (broadcasts/forwards + split-K reduction).
    pub noc_ns: f64,
    /// HBM channel time (operand fetches + C stores).
    pub hbm_ns: f64,
    /// Overlap-combined end-to-end estimate — the ranking key.
    pub total_ns: f64,
}

impl AnalyticLatency {
    fn zero() -> AnalyticLatency {
        AnalyticLatency { compute_ns: 0.0, noc_ns: 0.0, hbm_ns: 0.0, total_ns: 0.0 }
    }

    fn accumulate(&mut self, part: AnalyticLatency) {
        self.compute_ns += part.compute_ns;
        self.noc_ns += part.noc_ns;
        self.hbm_ns += part.hbm_ns;
        self.total_ns += part.total_ns;
    }
}

/// Estimate the end-to-end latency of `sched` on `shape`, chunking the
/// problem into column slices exactly as [`crate::coordinator::deploy_chunked`]
/// would when the working set exceeds L1. Returns `None` when the
/// schedule is invalid or no chunking fits — the same candidates the
/// simulation path rejects.
pub fn estimate(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> Option<AnalyticLatency> {
    if sched.validate(arch).is_err() {
        return None;
    }
    let l1 = arch.tile.l1_bytes as u64;
    if crate::schedule::l1_estimate(arch, shape, sched) <= l1 {
        return Some(estimate_resident(arch, shape, sched));
    }
    let (chunks, tuned) = crate::coordinator::chunking_for(arch, shape, sched)?;
    let chunk_n = shape.n.div_ceil(chunks);
    let mut total = AnalyticLatency::zero();
    let mut remaining = shape.n;
    while remaining > 0 {
        let n = remaining.min(chunk_n);
        total.accumulate(estimate_resident(arch, GemmShape::new(shape.m, n, shape.k), &tuned));
        remaining -= n;
    }
    Some(total)
}

/// [`estimate`] reduced to the ranking key.
pub fn estimate_ns(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> Option<f64> {
    estimate(arch, shape, sched).map(|l| l.total_ns)
}

/// Chain-aware estimate for a multi-op workload graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GraphEstimate {
    /// Σ count × per-GEMM [`estimate_ns`] with every edge spilled.
    pub unfused_ns: f64,
    /// HBM bytes the resident edges keep on-fabric per pass — the *same*
    /// arithmetic ([`crate::graph::edge_saved_bytes`] under
    /// [`crate::graph::edge_is_resident`]) the engine's
    /// `GraphReport` uses, so measured and estimated savings agree
    /// exactly.
    pub saved_hbm_bytes: u64,
    /// Time the saved traffic would have spent on the HBM channels.
    pub saved_ns: f64,
    /// `unfused_ns - saved_ns`, floored at zero.
    pub total_ns: f64,
}

/// Estimate one pass of a workload graph under the given per-GEMM
/// schedules (`scheds[k]` belongs to the k-th GEMM op in graph order —
/// the order `WorkloadGraph::to_workload` and the engine's report use).
/// Per-op latency reuses [`estimate`]; resident edges then credit back
/// the channel time of the intermediate store + reload they skip, priced
/// at the aggregate streamed rate every channel contributes
/// (`num_channels · channel_gbps · stream_efficiency`). Returns `None`
/// when the schedule list does not match the graph's GEMM ops or any op
/// is unestimable.
pub fn estimate_graph(
    arch: &ArchConfig,
    g: &WorkloadGraph,
    scheds: &[Schedule],
) -> Option<GraphEstimate> {
    let gemms: Vec<&crate::graph::GraphOp> =
        g.ops.iter().filter(|o| matches!(o.kind, OpKind::Gemm(_))).collect();
    if gemms.len() != scheds.len() {
        return None;
    }
    let mut unfused_ns = 0.0;
    let mut sched_of = std::collections::HashMap::new();
    for (op, sched) in gemms.iter().zip(scheds) {
        let OpKind::Gemm(shape) = op.kind else { unreachable!() };
        unfused_ns += op.count as f64 * estimate_ns(arch, shape, sched)?;
        sched_of.insert(op.id.0, sched);
    }
    let mut gemm_need = |op: &crate::graph::GraphOp, shape: GemmShape| -> u64 {
        crate::schedule::l1_estimate(arch, shape, sched_of[&op.id.0])
    };
    let mut saved_bytes = 0u64;
    for e in &g.edges {
        let share = crate::graph::tensor_share_bytes(arch, &e.tensor);
        let need_from = crate::graph::op_need_bytes(arch, g, g.op(e.from), &mut gemm_need);
        let need_to = crate::graph::op_need_bytes(arch, g, g.op(e.to), &mut gemm_need);
        if crate::graph::edge_is_resident(arch, share, need_from, need_to) {
            saved_bytes += crate::graph::edge_saved_bytes(arch, g, e);
        }
    }
    let agg_bw = arch.hbm.num_channels() as f64
        * arch.hbm.channel_gbps
        * arch.hbm.stream_efficiency;
    let saved_ns = saved_bytes as f64 / agg_bw;
    Some(GraphEstimate {
        unfused_ns,
        saved_hbm_bytes: saved_bytes,
        saved_ns,
        total_ns: (unfused_ns - saved_ns).max(0.0),
    })
}

/// Estimate one L1-resident pass (no chunking).
fn estimate_resident(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> AnalyticLatency {
    let plan = sched.plan(arch, shape);
    let (p, q) = sched.logical;
    let splits = plan.splits as f64;
    let kp = plan.kp as f64;
    let stages = sched.pipeline_stages.max(1);
    let e = arch.elem_bytes as f64;
    let a_b = (plan.tm * plan.tk) as f64 * e;
    let b_b = (plan.tk * plan.tn) as f64 * e;
    let c_b = (plan.tm * plan.tn) as f64 * e;

    // Phase 1: the matrix engine. Same model the simulator charges.
    let compute_step = engine_time_ns(arch, plan.tm, plan.tn, plan.tk);

    // Mesh geometry terms: a cross-mesh span (worst-case broadcast walk,
    // also the per-superstep barrier cost) and the mean HBM route from an
    // edge router to a tile.
    let hop = arch.noc.hop_ns;
    let span = (arch.rows + arch.cols) as f64 * hop;
    let route = (arch.rows + arch.cols) as f64 / 2.0 * hop;
    let link = arch.noc.link_gbps();

    // HBM channel service per fetched panel: each rectangular run pays the
    // request overhead, then the bytes stream at the efficiency-derated
    // channel rate. The optimized layout (§3.2) lands every panel as one
    // placement-tile burst; the base row-major layout pays one run per row.
    let ch_bw = arch.hbm.channel_gbps * arch.hbm.stream_efficiency;
    let req = arch.hbm.request_overhead_ns;
    let chans = arch.hbm.num_channels() as f64;
    let (a_runs, b_runs, c_runs) = if sched.opt_layout {
        (1.0, 1.0, 1.0)
    } else {
        (plan.tm as f64, plan.tk as f64, plan.tm as f64)
    };
    let a_serve = a_runs * req + a_b / ch_bw;
    let b_serve = b_runs * req + b_b / ch_bw;
    let c_serve = c_runs * req + c_b / ch_bw;

    // Per-superstep fetch population and NoC collective, by dataflow.
    // `extra` counts the non-steady supersteps (pipeline fill/drain).
    let (n_a, n_b, noc_step, extra) = match sched.dataflow {
        // Every tile fetches both operands itself; no collectives.
        Dataflow::Baseline => {
            let tiles = (p * q * plan.splits) as f64;
            (tiles, tiles, 0.0, 0.0)
        }
        // Edge tiles feed the array; interiors forward one hop per step.
        Dataflow::Systolic => {
            let fwd = a_b.max(b_b) / link + hop;
            (p as f64, q as f64, fwd, (p + q).saturating_sub(2) as f64)
        }
        // Row broadcast of A and column broadcast of B ride disjoint link
        // sets, so one panel's broadcast bounds the step. Pipeline bands
        // each fetch their own B copy; drained stages add offset steps.
        Dataflow::Summa | Dataflow::SplitKSumma { .. } => {
            let bcast = a_b.max(b_b) / link + span;
            let drain = ((stages - 1) * (plan.kp / stages).max(1)) as f64;
            (splits * p as f64, splits * (q * stages) as f64, bcast, 2.0 + drain)
        }
        // Group owners fetch; scatter + intra-group traffic share links,
        // so both panels are priced on the step's critical link.
        Dataflow::SystolicOverSumma { .. } | Dataflow::SummaOverSystolic { .. } => {
            let bcast = (a_b + b_b) / link + span;
            (splits * p as f64, splits * q as f64, bcast, 2.0)
        }
    };

    // Phase 3: HBM per superstep. The optimized layout round-robins every
    // matrix over all channels (west + south edges — the rectangular
    // HBM-edge rule); the base layout pins A and B to one channel each,
    // which serialize independently and overlap with each other.
    let hbm_step = if sched.opt_layout {
        (n_a * a_serve + n_b * b_serve) / chans + route
    } else {
        (n_a * a_serve).max(n_b * b_serve) + route
    };

    // Overlap model: double buffering runs fetch / collective / compute
    // concurrently, so the slowest phase paces the steady state; single
    // buffering serializes all three. Every superstep ends on a barrier.
    let step_time = if sched.double_buffer {
        compute_step.max(noc_step).max(hbm_step)
    } else {
        compute_step + noc_step + hbm_step
    };
    let steps = kp + extra;
    let barrier = span;

    // Epilogue: split-K reduction (tree over the K-groups), then one C
    // store per output tile.
    let reduce = if plan.splits > 1 { c_b / link + span } else { 0.0 };
    let stores = (p * q) as f64;
    let store = if sched.opt_layout {
        stores * c_serve / chans + route
    } else {
        stores * c_serve + route
    };

    AnalyticLatency {
        compute_ns: kp * compute_step,
        noc_ns: steps * noc_step + reduce,
        hbm_ns: kp * hbm_step + store,
        total_ns: steps * (step_time + barrier) + reduce + store,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::candidates;

    #[test]
    fn estimates_are_finite_positive_and_deterministic() {
        let arch = ArchConfig::tiny(4, 4);
        for shape in [GemmShape::new(128, 128, 256), GemmShape::new(16, 512, 512)] {
            for sched in candidates(&arch, shape) {
                let a = estimate(&arch, shape, &sched).expect("candidate must be estimable");
                let b = estimate(&arch, shape, &sched).unwrap();
                assert!(a.total_ns.is_finite() && a.total_ns > 0.0, "{}", sched.name());
                assert!(a.compute_ns > 0.0 && a.hbm_ns > 0.0);
                assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits(), "nondeterministic");
            }
        }
    }

    #[test]
    fn optimized_summa_beats_base_layout_baseline() {
        // The directional claim the tiering relies on: collectives + the
        // optimized layout are priced far below per-tile row-major DMA.
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let summa = Schedule { opt_layout: true, ..Schedule::summa(&arch, shape) };
        let base = Schedule { opt_layout: false, ..Schedule::baseline(&arch, shape) };
        let s = estimate_ns(&arch, shape, &summa).unwrap();
        let b = estimate_ns(&arch, shape, &base).unwrap();
        assert!(s < b, "summa {s} !< baseline {b}");
    }

    #[test]
    fn estimable_iff_deployable() {
        // `estimate` must exist exactly when `deploy_chunked` succeeds,
        // including shapes that only fit L1 after column chunking.
        let arch = ArchConfig::tiny(4, 4);
        for shape in [
            GemmShape::new(128, 128, 256),
            GemmShape::new(16, 512, 512),
            GemmShape::new(128, 4096, 128),
        ] {
            for sched in candidates(&arch, shape) {
                let deployable = crate::coordinator::deploy_chunked(&arch, shape, &sched).is_ok();
                let estimable = estimate(&arch, shape, &sched).is_some();
                assert_eq!(deployable, estimable, "{} {}", shape, sched.name());
            }
        }
    }

    #[test]
    fn oversize_shape_is_unestimable() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(1 << 20, 1 << 20, 1 << 20);
        let sched = Schedule::summa(&arch, shape);
        assert!(estimate(&arch, shape, &sched).is_none());
    }

    #[test]
    fn graph_estimate_credits_resident_edges() {
        let arch = ArchConfig::tiny(4, 4);
        let g = WorkloadGraph::attention_prefill("attn", 64, 32, 2);
        let scheds: Vec<Schedule> = g
            .ops
            .iter()
            .filter_map(|o| match o.kind {
                OpKind::Gemm(s) => Some(Schedule::summa(&arch, s)),
                _ => None,
            })
            .collect();
        assert_eq!(scheds.len(), 2);
        let est = estimate_graph(&arch, &g, &scheds).unwrap();
        // Both edges are trivially resident on a 256 KiB-L1 grid, and
        // each credits its single GEMM endpoint: 64·64·4 B × count 2.
        assert_eq!(est.saved_hbm_bytes, 2 * (64 * 64 * 4 * 2));
        assert!(est.saved_ns > 0.0);
        assert!(est.total_ns < est.unfused_ns);
        assert!(est.total_ns > 0.0);
        // The schedule list must cover the GEMM ops exactly.
        assert!(estimate_graph(&arch, &g, &scheds[..1]).is_none());
    }
}

//! Rooflines and analytical GPU baselines.
//!
//! The paper compares SoftHier against CUTLASS 3.9 and DeepGEMM running on
//! real A100/GH200 hardware. We have neither GPU, so (per DESIGN.md
//! §Substitutions) the GPU side is reproduced as an *analytical model*
//! whose efficiency terms are calibrated to the utilization levels those
//! libraries publish / the paper reports:
//!
//! * **wave quantization** — CTA tiles (128×128) schedule in waves over the
//!   SM count; partially-filled final waves waste throughput (exact term);
//! * **cache-hierarchy efficiency** — the paper's Fig. 1 observation: the
//!   bigger GH200 sustains a *lower* fraction of peak than A100 on the
//!   same shapes because hardware-managed caches thrash as the machine
//!   scales (calibrated constants: 0.88 for A100, 0.70 for GH200);
//! * **memory-bound regime** — flat GEMMs run at `intensity × BW × eff`
//!   with a bandwidth efficiency well below peak (GPUs cannot perfectly
//!   coalesce the decode GEMM access patterns).
//!
//! The point of the model is to preserve the paper's *ratios* (who wins,
//! by how much, where the crossover sits), not absolute GPU truth.

pub mod analytic;
pub mod energy;

pub use analytic::AnalyticLatency;
pub use energy::EnergyModel;

use crate::arch::{ArchConfig, GemmShape};

/// A GPU target for baseline comparison.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak throughput at the benchmark dtype, TFLOP/s.
    pub peak_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbps: f64,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// CTA output tile (M, N).
    pub cta: (usize, usize),
    /// Element width of the benchmark dtype.
    pub elem_bytes: usize,
    /// Calibrated cache-hierarchy efficiency (Fig. 1's utilization gap).
    pub cache_eff: f64,
    /// Calibrated achievable fraction of HBM peak in memory-bound kernels.
    pub bw_eff: f64,
    /// Fixed kernel efficiency (instruction overheads, epilogues).
    pub kernel_eff: f64,
}

impl GpuSpec {
    /// NVIDIA A100 (FP16 tensor core: 312 TFLOPS, 1.56 TB/s HBM2e).
    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "A100",
            peak_tflops: 312.0,
            hbm_gbps: 1555.0,
            sms: 108,
            cta: (128, 128),
            elem_bytes: 2,
            cache_eff: 0.88,
            bw_eff: 0.62,
            kernel_eff: 0.95,
        }
    }

    /// NVIDIA GH200 (H100-96GB die; FP8 tensor core: 1979 TFLOPS, 4 TB/s).
    pub fn gh200() -> GpuSpec {
        GpuSpec {
            name: "GH200",
            peak_tflops: 1979.0,
            hbm_gbps: 4022.0,
            sms: 132,
            cta: (128, 128),
            elem_bytes: 1,
            cache_eff: 0.70,
            bw_eff: 0.45,
            kernel_eff: 0.95,
        }
    }

    /// Wave-quantization efficiency for a shape.
    pub fn wave_efficiency(&self, shape: GemmShape) -> f64 {
        let ctas = (shape.m as f64 / self.cta.0 as f64).ceil()
            * (shape.n as f64 / self.cta.1 as f64).ceil();
        let waves = ctas / self.sms as f64;
        if waves <= 0.0 {
            return 1.0;
        }
        (waves / waves.ceil()).min(1.0)
    }

    /// Modelled CUTLASS throughput (TFLOP/s) for a shape.
    pub fn cutlass_tflops(&self, shape: GemmShape) -> f64 {
        let compute = self.peak_tflops
            * self.wave_efficiency(shape)
            * self.cache_eff
            * self.kernel_eff;
        // Memory-bound ceiling: intensity × achievable bandwidth.
        let mem = shape.intensity(self.elem_bytes) * self.hbm_gbps * self.bw_eff / 1e3;
        compute.min(mem)
    }

    /// Modelled DeepGEMM throughput: fine-grained-scaling FP8 kernels are
    /// slightly better on ragged shapes (less quantization waste) but pay
    /// a small scaling overhead on clean ones.
    pub fn deepgemm_tflops(&self, shape: GemmShape) -> f64 {
        let wave = self.wave_efficiency(shape);
        let wave = wave + (1.0 - wave) * 0.35; // persistent kernels recover part
        let compute = self.peak_tflops * wave * self.cache_eff * self.kernel_eff * 0.97;
        let mem = shape.intensity(self.elem_bytes) * self.hbm_gbps * (self.bw_eff + 0.05) / 1e3;
        compute.min(mem)
    }

    /// Modelled achieved HBM bandwidth (GB/s) — Fig. 11's GPU series.
    pub fn achieved_gbps(&self, shape: GemmShape, tflops: f64) -> f64 {
        let bytes = shape.min_elems() as f64 * self.elem_bytes as f64;
        let time_ns = shape.flops() / (tflops * 1e3);
        bytes / time_ns
    }

    pub fn utilization(&self, tflops: f64) -> f64 {
        tflops / self.peak_tflops
    }
}

/// Roofline ceiling for a SoftHier instance at a given operational
/// intensity (FLOP/byte): `min(peak, I × BW)` (Fig. 7a's ceilings).
pub fn roofline_tflops(arch: &ArchConfig, intensity: f64) -> f64 {
    (intensity * arch.hbm.total_gbps() / 1e3).min(arch.peak_tflops())
}

/// Ridge point of the roofline (FLOP/byte where compute == memory bound).
pub fn ridge_intensity(arch: &ArchConfig) -> f64 {
    arch.peak_tflops() * 1e3 / arch.hbm.total_gbps()
}

/// Roofline upper bound on the count-weighted aggregate throughput
/// (TFLOP/s) of a whole workload on an architecture: every item runs at
/// best at `min(peak, intensity × BW)`, so the aggregate can never exceed
/// `Σ flops / Σ (flops / per-item ceiling)`. No schedule, layout, or
/// simulation enters this bound — it is the cheap config-level screen the
/// DSE sweep uses to prune candidates that cannot beat an already-measured
/// Pareto point ([`crate::dse`]).
pub fn workload_roofline_tflops(arch: &ArchConfig, w: &crate::arch::workload::Workload) -> f64 {
    let mut time_lb_ns = 0.0f64;
    let mut flops = 0.0f64;
    for item in &w.items {
        let f = item.shape.flops();
        let ceiling = roofline_tflops(arch, item.shape.intensity(arch.elem_bytes));
        if ceiling <= 0.0 {
            return 0.0;
        }
        time_lb_ns += item.count as f64 * f / (ceiling * 1e3);
        flops += item.count as f64 * f;
    }
    if time_lb_ns <= 0.0 {
        0.0
    } else {
        flops / time_lb_ns / 1e3
    }
}

/// The DeepSeek-V3 GEMM workload suites the paper benchmarks (§4.1.4,
/// via the DeepGEMM benchmark set).
pub mod workloads {
    use crate::arch::GemmShape;

    /// Compute-bound / prefill shapes (Fig. 9 and Fig. 1/12 x-axis).
    pub fn compute_bound() -> Vec<GemmShape> {
        vec![
            GemmShape::new(4096, 2112, 7168),
            GemmShape::new(4096, 24576, 1536),
            GemmShape::new(4096, 32768, 512),
            GemmShape::new(4096, 7168, 16384),
            GemmShape::new(4096, 4096, 7168),
            GemmShape::new(4096, 7168, 2048),
        ]
    }

    /// Flat / decode shapes (Fig. 10/11): small M, LLM decode geometry.
    pub fn flat() -> Vec<GemmShape> {
        vec![
            GemmShape::new(64, 2112, 7168),
            GemmShape::new(64, 24576, 1536),
            GemmShape::new(64, 7168, 16384),
            GemmShape::new(128, 4096, 7168),
            GemmShape::new(128, 7168, 2048),
        ]
    }

    /// The store-intensive pipeline case study shape (Fig. 8b).
    pub fn store_intensive() -> GemmShape {
        GemmShape::new(16384, 32768, 512)
    }

    /// The compute-intensive pipeline case study shape (Fig. 8a).
    pub fn compute_intensive() -> GemmShape {
        GemmShape::new(4096, 2112, 7168)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_specs_match_datasheets() {
        let a = GpuSpec::a100();
        assert_eq!(a.peak_tflops, 312.0);
        let g = GpuSpec::gh200();
        assert_eq!(g.peak_tflops, 1979.0);
        assert!(g.cache_eff < a.cache_eff, "Fig. 1: GH200 utilization < A100");
    }

    #[test]
    fn wave_quantization_behaviour() {
        let g = GpuSpec::gh200();
        // A shape producing exactly SMs CTAs has perfect wave efficiency…
        let exact = GemmShape::new(128 * 12, 128 * 11, 1024);
        assert!((g.wave_efficiency(exact) - 1.0).abs() < 1e-9);
        // …one extra CTA row starts a nearly-empty second wave.
        let ragged = GemmShape::new(128 * 12 + 1, 128 * 11, 1024);
        assert!(g.wave_efficiency(ragged) < 0.6);
    }

    #[test]
    fn compute_bound_utilization_in_published_band() {
        // CUTLASS/DeepGEMM on GH200 for the DeepSeek prefill shapes sit
        // roughly in the 45–75% utilization band the paper's Fig. 9 shows.
        let g = GpuSpec::gh200();
        for shape in workloads::compute_bound() {
            let t = g.cutlass_tflops(shape);
            let u = g.utilization(t);
            assert!((0.30..=0.80).contains(&u), "{shape}: util {u}");
        }
    }

    #[test]
    fn a100_utilization_higher_than_gh200() {
        // Fig. 1 / Fig. 12: same shapes, higher utilization on A100.
        let a = GpuSpec::a100();
        let g = GpuSpec::gh200();
        for shape in workloads::compute_bound() {
            let ua = a.utilization(a.cutlass_tflops(shape));
            let ug = g.utilization(g.cutlass_tflops(shape));
            assert!(ua > ug, "{shape}: A100 {ua} <= GH200 {ug}");
        }
    }

    #[test]
    fn flat_shapes_are_memory_bound_on_gpu() {
        let g = GpuSpec::gh200();
        for shape in workloads::flat() {
            let t = g.cutlass_tflops(shape);
            // Memory-bound: throughput well below compute peak.
            assert!(t < 0.5 * g.peak_tflops, "{shape}: {t}");
        }
    }

    #[test]
    fn workload_roofline_bounds_single_item_exactly() {
        use crate::arch::workload::Workload;
        let arch = ArchConfig::gh200_like();
        let shape = GemmShape::new(4096, 2112, 7168);
        let w = Workload::single("one", shape);
        let bound = workload_roofline_tflops(&arch, &w);
        let direct = roofline_tflops(&arch, shape.intensity(arch.elem_bytes));
        assert!((bound - direct).abs() < 1e-9 * direct, "{bound} vs {direct}");
        // Mixing in a memory-bound item can only lower the aggregate bound.
        let mut mix = Workload::single("one", shape);
        mix.push("flat", GemmShape::new(64, 2112, 7168), 4);
        assert!(workload_roofline_tflops(&arch, &mix) < bound);
        // Empty workload degrades to zero, not NaN.
        assert_eq!(workload_roofline_tflops(&arch, &Workload::new("empty")), 0.0);
    }

    #[test]
    fn workload_roofline_scales_with_hardware() {
        use crate::arch::workload::Workload;
        let big = ArchConfig::gh200_like();
        let mut small = ArchConfig::gh200_like();
        small.rows = 8;
        small.cols = 8;
        small.hbm.channels_per_edge = 8;
        let w = Workload::builtin("tiny").unwrap();
        assert!(
            workload_roofline_tflops(&small, &w) < workload_roofline_tflops(&big, &w),
            "smaller machine must have a lower ceiling"
        );
    }

    #[test]
    fn workload_roofline_rectangular_axes_scale_independently() {
        // On a rectangular mesh the two roofline ceilings move on
        // different axes: compute scales with the tile count
        // (rows × cols), bandwidth with the HBM channel count. The
        // prune bound must track both, not a single square edge.
        use crate::arch::workload::Workload;
        let mk = |rows: usize, cols: usize, cpe: usize| {
            let mut a = ArchConfig::gh200_like();
            a.rows = rows;
            a.cols = cols;
            a.hbm.channels_per_edge = cpe;
            a
        };
        let compute = Workload::single("c", GemmShape::new(8192, 8192, 8192));
        let flat = Workload::single("f", GemmShape::new(64, 2112, 7168));

        // Orientation symmetry: transposing the mesh changes neither
        // ceiling, bit for bit.
        for w in [&compute, &flat] {
            assert_eq!(
                workload_roofline_tflops(&mk(32, 8, 8), w).to_bits(),
                workload_roofline_tflops(&mk(8, 32, 8), w).to_bits()
            );
        }

        // Doubling the long edge doubles the compute-bound ceiling...
        let b32 = workload_roofline_tflops(&mk(8, 32, 8), &compute);
        let b64 = workload_roofline_tflops(&mk(8, 64, 8), &compute);
        assert!((b64 - 2.0 * b32).abs() < 1e-6 * b64, "{b32} vs {b64}");
        // ...but leaves the bandwidth-bound ceiling untouched...
        let f32_ = workload_roofline_tflops(&mk(8, 32, 8), &flat);
        let f64_ = workload_roofline_tflops(&mk(8, 64, 8), &flat);
        assert!((f64_ - f32_).abs() < 1e-9 * f32_, "{f32_} vs {f64_}");
        // ...while doubling the channel count does the reverse.
        let fch = workload_roofline_tflops(&mk(8, 32, 16), &flat);
        assert!((fch - 2.0 * f32_).abs() < 1e-6 * fch, "{f32_} vs {fch}");
        let cch = workload_roofline_tflops(&mk(8, 32, 16), &compute);
        assert!((cch - b32).abs() < 1e-9 * b32, "{b32} vs {cch}");
    }

    #[test]
    fn roofline_ceilings() {
        let arch = ArchConfig::gh200_like();
        let ridge = ridge_intensity(&arch);
        assert!((roofline_tflops(&arch, ridge) - arch.peak_tflops()).abs() < 1.0);
        assert!(roofline_tflops(&arch, ridge / 2.0) < arch.peak_tflops() * 0.51);
        assert!((ridge - 483.0).abs() < 5.0, "GH200-like ridge ~483 FLOP/B, got {ridge}");
    }
}

//! Deterministic energy model over the simulator's traffic counters.
//!
//! The binding constraint for a GH200-class 32×32-tile instance is energy,
//! not area: related work ranks mappings by energy-delay product from
//! analytic data-movement counts (Moon et al., *Evaluating Spatial
//! Accelerator Architectures with Tiled Matrix-Matrix Multiplication*) and
//! reports utilization-per-watt as the headline generator metric (Yi et
//! al., *OpenGeMM*). This module folds one simulated run's traffic —
//! HBM bytes, NoC hop-bytes, SPM accesses, MAC count — into Joules via a
//! configurable pJ coefficient table, so every derived metric is a pure
//! deterministic function of [`RunStats`] and can be pinned by the CI
//! bench gate.
//!
//! The default coefficients are calibrated to the paper's Table 1
//! machine (4 TB/s HBM behind FP8 CE arrays) at published per-operation
//! energy scales: HBM3 access ≈ 3.75 pJ/bit, an on-chip mesh hop ≈ 1 pJ/B
//! (link + router), SRAM scratchpad access well under a tenth of an HBM
//! access, and an FP8 MAC a fraction of a pJ. The absolute scale matters
//! less than the *ratios* (off-chip ≫ on-chip ≫ compute): they are what
//! make the DSE energy axis order configurations the way the related work
//! observes.

use anyhow::{Context, Result};

use crate::coordinator::engine::{GraphReport, WorkloadReport};
use crate::sim::RunStats;
use crate::util::cfgtext::Doc;

/// The pJ coefficient table: energy per unit of each traffic counter the
/// simulator produces, plus a static (leakage + clock-tree) term per tile.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// pJ per byte moved across an HBM channel (read or write).
    pub pj_per_hbm_byte: f64,
    /// pJ per byte × link traversed on the mesh NoC.
    pub pj_per_noc_hop_byte: f64,
    /// pJ per byte read from / written to a tile's L1 SPM.
    pub pj_per_spm_byte: f64,
    /// pJ per multiply-accumulate (2 FLOPs) in the CE array.
    pub pj_per_mac: f64,
    /// Static power per tile, Watts (charged over the whole makespan).
    pub static_w_per_tile: f64,
}

impl Default for EnergyModel {
    fn default() -> EnergyModel {
        EnergyModel::default_table()
    }
}

impl EnergyModel {
    /// The GH200-class default table (see module docs for the sourcing).
    pub fn default_table() -> EnergyModel {
        EnergyModel {
            pj_per_hbm_byte: 30.0,
            pj_per_noc_hop_byte: 1.0,
            pj_per_spm_byte: 0.15,
            pj_per_mac: 0.25,
            static_w_per_tile: 0.05,
        }
    }

    /// Parse a coefficient table from config text (`util::cfgtext`
    /// grammar). All keys are optional and default to
    /// [`EnergyModel::default_table`]; the coefficients live in an
    /// `[energy]` section:
    ///
    /// ```text
    /// [energy]
    /// pj_per_hbm_byte = 30.0
    /// pj_per_noc_hop_byte = 1.0
    /// pj_per_spm_byte = 0.15
    /// pj_per_mac = 0.25
    /// static_w_per_tile = 0.05
    /// ```
    pub fn from_text(text: &str) -> Result<EnergyModel> {
        let doc = Doc::parse(text).context("energy coefficient table")?;
        let mut m = EnergyModel::default_table();
        let read = |key: &str, slot: &mut f64| -> Result<()> {
            if let Some(v) = doc.get("energy", key) {
                let v = match v {
                    crate::util::cfgtext::Value::Float(f) => *f,
                    crate::util::cfgtext::Value::Int(i) => *i as f64,
                    other => anyhow::bail!("energy.{key} must be a number, got {other}"),
                };
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "energy.{key} must be a finite non-negative number, got {v}"
                );
                *slot = v;
            }
            Ok(())
        };
        read("pj_per_hbm_byte", &mut m.pj_per_hbm_byte)?;
        read("pj_per_noc_hop_byte", &mut m.pj_per_noc_hop_byte)?;
        read("pj_per_spm_byte", &mut m.pj_per_spm_byte)?;
        read("pj_per_mac", &mut m.pj_per_mac)?;
        read("static_w_per_tile", &mut m.static_w_per_tile)?;
        Ok(m)
    }

    /// Total energy of one simulated run, Joules: the four dynamic traffic
    /// terms plus static power over the makespan. Monotone in every
    /// counter (the property tests rely on this).
    pub fn energy_j(&self, stats: &RunStats) -> f64 {
        let hbm = (stats.hbm_read_bytes + stats.hbm_write_bytes) as f64 * self.pj_per_hbm_byte;
        let noc = stats.noc_link_bytes as f64 * self.pj_per_noc_hop_byte;
        let spm = stats.spm_bytes as f64 * self.pj_per_spm_byte;
        let mac = stats.macs() * self.pj_per_mac;
        let static_j = self.static_w_per_tile * stats.num_tiles as f64 * stats.makespan_ns * 1e-9;
        (hbm + noc + spm + mac) * 1e-12 + static_j
    }

    /// Average power over the run, Watts (0 for a degenerate empty run).
    pub fn avg_power_w(&self, stats: &RunStats) -> f64 {
        if stats.makespan_ns <= 0.0 {
            0.0
        } else {
            self.energy_j(stats) / (stats.makespan_ns * 1e-9)
        }
    }

    /// Energy-delay product, J·s (Moon et al.'s ranking metric).
    pub fn edp(&self, stats: &RunStats) -> f64 {
        self.energy_j(stats) * stats.makespan_ns * 1e-9
    }

    /// Useful throughput per Watt, TFLOP/s/W (OpenGeMM's headline metric).
    /// Equals `useful_flops / energy` since both sides are averaged over
    /// the same makespan.
    pub fn tflops_per_w(&self, stats: &RunStats) -> f64 {
        let e = self.energy_j(stats);
        if e <= 0.0 {
            0.0
        } else {
            stats.useful_flops / e / 1e12
        }
    }

    /// Energy of one workload pass, Joules: Σ count × energy of each
    /// shape's best schedule (what the DSE energy objective minimizes).
    pub fn workload_energy_j(&self, rep: &WorkloadReport) -> f64 {
        rep.shapes
            .iter()
            .map(|s| s.count as f64 * self.energy_j(&s.result.best().stats))
            .sum()
    }

    /// Count-weighted throughput per Watt over a workload pass.
    pub fn workload_tflops_per_w(&self, rep: &WorkloadReport) -> f64 {
        let e = self.workload_energy_j(rep);
        if e <= 0.0 {
            0.0
        } else {
            rep.total_flops() / e / 1e12
        }
    }

    /// Energy of one fused graph pass, Joules: the edge-free workload
    /// energy minus the HBM energy of the bytes resident edges keep
    /// on-fabric. The saved bytes are credited at `pj_per_hbm_byte` only —
    /// the intermediate still transits the NoC and SPM either way, so
    /// those terms stand.
    pub fn graph_energy_j(&self, rep: &GraphReport) -> f64 {
        let unfused = self.workload_energy_j(&rep.report);
        let credit = rep.saved_hbm_bytes() as f64 * self.pj_per_hbm_byte * 1e-12;
        (unfused - credit).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(hbm: u64, noc: u64, spm: u64, flops: f64, makespan_ns: f64) -> RunStats {
        RunStats {
            makespan_ns,
            useful_flops: flops,
            total_flops: flops,
            hbm_read_bytes: hbm,
            hbm_write_bytes: 0,
            noc_link_bytes: noc,
            spm_bytes: spm,
            peak_tflops: 10.0,
            hbm_peak_gbps: 100.0,
            supersteps: 1,
            compute_busy_ns: makespan_ns,
            num_tiles: 16,
            step_end_ns: vec![makespan_ns],
        }
    }

    #[test]
    fn energy_terms_add_up() {
        let m = EnergyModel {
            pj_per_hbm_byte: 2.0,
            pj_per_noc_hop_byte: 1.0,
            pj_per_spm_byte: 0.5,
            pj_per_mac: 0.25,
            static_w_per_tile: 0.0,
        };
        // 100 HBM B + 10 hop-B + 8 SPM B + 4 FLOPs (2 MACs).
        let s = stats(100, 10, 8, 4.0, 1000.0);
        let want_pj = 100.0 * 2.0 + 10.0 * 1.0 + 8.0 * 0.5 + 2.0 * 0.25;
        assert!((m.energy_j(&s) - want_pj * 1e-12).abs() < 1e-24);
        // Static term: 0.1 W/tile × 16 tiles × 1 µs = 1.6 µJ.
        let m2 = EnergyModel { static_w_per_tile: 0.1, ..m };
        let s2 = stats(0, 0, 0, 0.0, 1000.0);
        assert!((m2.energy_j(&s2) - 1.6e-6).abs() < 1e-15);
    }

    #[test]
    fn derived_metrics_are_consistent() {
        let m = EnergyModel::default_table();
        let s = stats(1 << 20, 1 << 18, 1 << 22, 1e9, 5000.0);
        let e = m.energy_j(&s);
        assert!(e > 0.0);
        assert!((m.edp(&s) - e * 5e-6).abs() < 1e-18);
        assert!((m.avg_power_w(&s) - e / 5e-6).abs() < 1e-9 * m.avg_power_w(&s));
        assert!((m.tflops_per_w(&s) - s.useful_flops / e / 1e12).abs() < 1e-9);
        // A degenerate zero-makespan run has zero power, not inf/NaN.
        assert_eq!(m.avg_power_w(&stats(0, 0, 0, 0.0, 0.0)), 0.0);
        // Degenerate all-zero model never divides by zero.
        let z = EnergyModel {
            pj_per_hbm_byte: 0.0,
            pj_per_noc_hop_byte: 0.0,
            pj_per_spm_byte: 0.0,
            pj_per_mac: 0.0,
            static_w_per_tile: 0.0,
        };
        assert_eq!(z.tflops_per_w(&s), 0.0);
    }

    #[test]
    fn coefficient_table_parses_and_defaults() {
        let text = "[energy]\npj_per_hbm_byte = 12.5\npj_per_mac = 1\n";
        let m = EnergyModel::from_text(text).unwrap();
        assert_eq!(m.pj_per_hbm_byte, 12.5);
        assert_eq!(m.pj_per_mac, 1.0, "int promotes to float");
        let d = EnergyModel::default_table();
        assert_eq!(m.pj_per_noc_hop_byte, d.pj_per_noc_hop_byte, "unset keys default");
        assert_eq!(EnergyModel::from_text("").unwrap(), d);
    }

    #[test]
    fn coefficient_table_rejects_nonsense() {
        assert!(EnergyModel::from_text("[energy]\npj_per_mac = -1\n").is_err());
        assert!(EnergyModel::from_text("[energy]\npj_per_mac = \"lots\"\n").is_err());
        assert!(EnergyModel::from_text("[energy").is_err(), "cfgtext error propagates");
    }

    #[test]
    fn graph_energy_credits_exactly_the_saved_hbm_bytes() {
        let arch = crate::arch::ArchConfig::tiny(4, 4);
        let g = crate::graph::WorkloadGraph::attention_prefill("attn", 64, 32, 2);
        let engine = crate::coordinator::engine::Engine::new(&arch);
        let rep = engine.tune_graph(&g).unwrap();
        assert!(rep.saved_hbm_bytes() > 0, "tiny attention should fuse");
        let m = EnergyModel::default_table();
        let unfused = m.workload_energy_j(&rep.report);
        let fused = m.graph_energy_j(&rep);
        let want = rep.saved_hbm_bytes() as f64 * m.pj_per_hbm_byte * 1e-12;
        assert!(fused < unfused);
        assert!(((unfused - fused) - want).abs() <= 1e-12 * unfused.max(1.0));
    }

    #[test]
    fn default_ratios_are_physical() {
        // Off-chip ≫ on-chip ≫ compute: the ordering that makes the energy
        // axis meaningful, regardless of absolute calibration.
        let m = EnergyModel::default_table();
        assert!(m.pj_per_hbm_byte > 10.0 * m.pj_per_noc_hop_byte);
        assert!(m.pj_per_noc_hop_byte > m.pj_per_spm_byte);
        assert!(m.pj_per_spm_byte < m.pj_per_mac, "a MAC outweighs one SPM byte");
    }
}

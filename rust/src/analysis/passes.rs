//! The analysis passes. Each pass reads only the [`Ctx`](super::Ctx)
//! fields it needs and appends [`Diag`](super::Diag)s; everything is
//! closed-form — no pass ever lowers IR (unless handed a
//! [`Deployment`](crate::ir::Deployment) to inspect) and none simulates.
//!
//! The mirror passes ([`ArchSanity`], [`ScheduleCompat`]) transcribe the
//! clauses of `ArchConfig::validate` / `Schedule::validate` one-to-one so
//! each failure gets a specific stable code; a catch-all (`DIT-E008` /
//! `DIT-E059`) fires when the mirrored `validate` errors for a clause
//! with no specific mirror yet, keeping `rejected()` in exact lockstep
//! with the `validate` contract by construction.

use std::collections::HashMap;

use super::codes::*;
use super::{CheckReport, Ctx, Loc, Pass};
use crate::collective::{synthesize, Mask, TileCoord};
use crate::graph::OpKind;
use crate::ir::{IrError, Op, Program};
use crate::schedule::remap::Remap;
use crate::schedule::{l1_estimate, Dataflow};
use crate::util::is_pow2;

/// Mirrors [`crate::arch::ArchConfig::validate`] clause-for-clause.
pub struct ArchSanity;

impl Pass for ArchSanity {
    fn name(&self) -> &'static str {
        "arch-sanity"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let a = cx.arch;
        let before = out.errors();
        if a.rows == 0 || a.cols == 0 {
            out.error(E001, Loc::none(), format!("empty tile grid: {}x{}", a.rows, a.cols));
        }
        if a.tile.ce_m == 0 || a.tile.ce_n == 0 {
            out.error(
                E002,
                Loc::none(),
                format!("empty CE array: {}x{}", a.tile.ce_m, a.tile.ce_n),
            );
        }
        if a.tile.clock_ghz <= 0.0 {
            out.error(E003, Loc::none(), format!("tile clock {} GHz", a.tile.clock_ghz));
        }
        if a.tile.l1_bytes < 4096 {
            out.error(
                E004,
                Loc::none(),
                format!("L1 SPM of {} bytes is below the 4 KiB floor", a.tile.l1_bytes),
            );
        }
        if a.noc.link_bits < 8 {
            out.error(E005, Loc::none(), format!("NoC links of {} bits", a.noc.link_bits));
        }
        if a.hbm.channels_per_edge == 0 {
            out.error(E006, Loc::none(), "no HBM channels on either edge".into());
        }
        if !(1..=8).contains(&a.elem_bytes) {
            out.error(
                E007,
                Loc::none(),
                format!("element size of {} bytes is outside 1..=8", a.elem_bytes),
            );
        }
        // Lockstep catch-all: a validate clause with no mirror above.
        if out.errors() == before {
            if let Err(e) = a.validate() {
                out.error(E008, Loc::none(), format!("{e:#}"));
            }
        }
    }
}

/// The rectangular HBM edge rule: west channels attach along column 0
/// (wrapping at `rows`), south channels along the bottom row (wrapping
/// at `cols`). More channels than routers is legal but means shared
/// mesh injection points — worth a warning, not a rejection.
pub struct HbmEdgeRule;

impl Pass for HbmEdgeRule {
    fn name(&self) -> &'static str {
        "hbm-edge-rule"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let a = cx.arch;
        if a.rows == 0 || a.cols == 0 || a.hbm.channels_per_edge == 0 {
            return; // ArchSanity already rejected; router math is undefined.
        }
        let per_edge = a.hbm.channels_per_edge;
        for (edge, extent) in [("west", a.rows), ("south", a.cols)] {
            if per_edge > extent {
                out.warn(
                    W009,
                    Loc::none(),
                    format!(
                        "{per_edge} {edge}-edge channels wrap onto {extent} routers \
                         ({} channels share each mesh injection point)",
                        per_edge.div_ceil(extent)
                    ),
                );
            }
        }
    }
}

/// Mirrors [`crate::schedule::Schedule::validate`] clause-for-clause,
/// including the split-K reduce-group mask-expressibility rule.
pub struct ScheduleCompat;

impl Pass for ScheduleCompat {
    fn name(&self) -> &'static str {
        "schedule-compat"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let Some(s) = cx.sched else { return };
        let arch = cx.arch;
        let before = out.errors();
        if s.tk == 0 {
            out.error(E051, Loc::none(), "K-panel depth tk must be positive".into());
        }
        if s.logical.0 == 0 || s.logical.1 == 0 {
            out.error(
                E052,
                Loc::none(),
                format!("empty logical grid {}x{}", s.logical.0, s.logical.1),
            );
        }
        if s.tiles_used() > arch.num_tiles() {
            out.error(
                E053,
                Loc::none(),
                format!(
                    "schedule needs {} tiles, arch has {}",
                    s.tiles_used(),
                    arch.num_tiles()
                ),
            );
        }
        if s.pipeline_stages < 1 {
            out.error(E054, Loc::none(), "pipeline_stages must be >= 1".into());
        } else if s.pipeline_stages > s.logical.0.max(1) {
            out.error(
                E054,
                Loc::none(),
                format!(
                    "{} pipeline stages over {} logical rows",
                    s.pipeline_stages, s.logical.0
                ),
            );
        }
        match s.dataflow {
            Dataflow::Systolic => {
                if s.logical != (arch.rows, arch.cols) {
                    out.error(
                        E055,
                        Loc::none(),
                        format!(
                            "systolic runs on the physical grid {}x{}, not logical {}x{}",
                            arch.rows, arch.cols, s.logical.0, s.logical.1
                        ),
                    );
                }
            }
            Dataflow::SystolicOverSumma { group } | Dataflow::SummaOverSystolic { group } => {
                if !(is_pow2(group) && group >= 2) {
                    out.error(
                        E056,
                        Loc::none(),
                        format!("hierarchical group {group} must be a power of two >= 2"),
                    );
                } else if s.logical.0 % group != 0 || s.logical.1 % group != 0 {
                    out.error(
                        E056,
                        Loc::none(),
                        format!(
                            "group {group} does not divide the logical grid {}x{}",
                            s.logical.0, s.logical.1
                        ),
                    );
                }
            }
            Dataflow::SplitKSumma { splits } => {
                if splits < 1 {
                    out.error(E057, Loc::none(), "split-K needs at least one split".into());
                }
                if s.tiles_used() != arch.num_tiles() {
                    out.error(
                        E057,
                        Loc::none(),
                        format!(
                            "split-K mapping must cover the grid exactly: {} tiles used, {} in the grid",
                            s.tiles_used(),
                            arch.num_tiles()
                        ),
                    );
                } else if splits > 1 && s.logical.0 > 0 && s.logical.1 > 0 {
                    // The cross-K-group reduction is a hardware collective
                    // with no unicast fallback: every reduce group must be
                    // AND-mask expressible on the physical grid. (Guarded
                    // by exact coverage above so the remap arithmetic is
                    // in-bounds.)
                    let (p_dim, q_dim) = s.logical;
                    let remap = Remap {
                        phys_rows: arch.rows,
                        phys_cols: arch.cols,
                        log_rows: p_dim * splits,
                        log_cols: q_dim,
                    };
                    'groups: for p in 0..p_dim {
                        for q in 0..q_dim {
                            let members: Vec<TileCoord> = (0..splits)
                                .map(|ss| remap.to_phys(ss * p_dim + p, q))
                                .collect();
                            if synthesize(&members, arch.rows, arch.cols).is_none() {
                                out.error(
                                    E058,
                                    Loc::none(),
                                    format!(
                                        "reduce group (p={p}, q={q}) has no AND-mask on the \
                                         {}x{} grid (logical {p_dim}x{q_dim} x{splits})",
                                        arch.rows, arch.cols
                                    ),
                                );
                                break 'groups;
                            }
                        }
                    }
                }
            }
            _ => {}
        }
        // Lockstep catch-all: a validate clause with no mirror above.
        if out.errors() == before {
            if let Err(e) = s.validate(arch) {
                out.error(E059, Loc::none(), format!("{e:#}"));
            }
        }
    }
}

/// Double-buffer-aware per-superstep SPM capacity accounting: the A/B
/// panel pair (×2 when double-buffered), the C accumulator, and the
/// dataflow's staging buffers must fit the tile SPM — directly, or
/// after the coordinator's output chunking.
pub struct SpmCapacity;

impl Pass for SpmCapacity {
    fn name(&self) -> &'static str {
        "spm-capacity"
    }

    fn requires_clean(&self) -> bool {
        true // Plan arithmetic divides by the logical grid.
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let (Some(shape), Some(s)) = (cx.shape, cx.sched) else { return };
        let l1 = cx.arch.tile.l1_bytes as u64;
        let need = l1_estimate(cx.arch, shape, s);
        if need <= l1 {
            return;
        }
        let plan = s.plan(cx.arch, shape);
        let detail = format!(
            "per-superstep working set of {need} B (tm {} x tn {} x tk {}, {}) \
             exceeds the {l1} B SPM",
            plan.tm,
            plan.tn,
            plan.tk,
            if s.double_buffer { "double-buffered" } else { "single-buffered" },
        );
        match crate::coordinator::chunking_for(cx.arch, shape, s) {
            Some((chunks, tuned)) => out.warn(
                W012,
                Loc::none(),
                format!("{detail}; deploys as {chunks} output column chunks (tk {})", tuned.tk),
            ),
            None => out.error(
                E011,
                Loc::none(),
                format!("{detail} and no output chunking in the ladder fits"),
            ),
        }
    }
}

/// The chunked fallback itself must be legal: the retuned chunk
/// schedule still validates and its working set actually fits.
/// Defensive — [`crate::coordinator::chunking_for`] guarantees the fit
/// today, so `DIT-E013` firing means the chunking ladder and this
/// checker disagree.
pub struct ChunkingLegality;

impl Pass for ChunkingLegality {
    fn name(&self) -> &'static str {
        "chunking-legality"
    }

    fn requires_clean(&self) -> bool {
        true
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let (Some(shape), Some(s)) = (cx.shape, cx.sched) else { return };
        let l1 = cx.arch.tile.l1_bytes as u64;
        if l1_estimate(cx.arch, shape, s) <= l1 {
            return;
        }
        let Some((chunks, tuned)) = crate::coordinator::chunking_for(cx.arch, shape, s) else {
            return; // SpmCapacity already rejected with E011.
        };
        let chunk_shape =
            crate::arch::GemmShape::new(shape.m, shape.n.div_ceil(chunks), shape.k);
        let chunk_need = l1_estimate(cx.arch, chunk_shape, &tuned);
        if chunk_need > l1 {
            out.error(
                E013,
                Loc::none(),
                format!(
                    "chunking into {chunks} column slices still needs {chunk_need} B of {l1} B SPM"
                ),
            );
        } else if let Err(e) = tuned.validate(cx.arch) {
            out.error(
                E013,
                Loc::none(),
                format!("retuned chunk schedule is invalid: {e:#}"),
            );
        }
    }
}

/// Remap geometry over rectangular meshes: the logical→physical tile
/// map must be injective and in-bounds (the PR 5 aliasing bug class,
/// now a diagnostic instead of a release-mode silent corruption), and
/// under-coverage of the grid is reported.
pub struct RemapGeometry;

impl Pass for RemapGeometry {
    fn name(&self) -> &'static str {
        "remap-geometry"
    }

    fn requires_clean(&self) -> bool {
        true
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let (Some(shape), Some(s)) = (cx.shape, cx.sched) else { return };
        let arch = cx.arch;
        let r = s.plan(arch, shape).remap;
        let tiles = arch.num_tiles();
        if r.log_rows * r.log_cols > tiles {
            out.error(
                E021,
                Loc::none(),
                format!(
                    "logical grid {}x{} needs {} tiles, the physical grid has {tiles}",
                    r.log_rows,
                    r.log_cols,
                    r.log_rows * r.log_cols
                ),
            );
            return;
        }
        let mut seen = vec![false; tiles];
        for lr in 0..r.log_rows {
            for lc in 0..r.log_cols {
                let t = r.to_phys(lr, lc);
                if t.row >= arch.rows || t.col >= arch.cols {
                    out.error(
                        E021,
                        Loc::tile(t.row, t.col),
                        format!("logical ({lr},{lc}) maps off-grid to {t}"),
                    );
                    return;
                }
                let lin = t.linear(arch.cols);
                if seen[lin] {
                    out.error(
                        E021,
                        Loc::tile(t.row, t.col),
                        format!("logical ({lr},{lc}) aliases already-mapped physical {t}"),
                    );
                    return;
                }
                seen[lin] = true;
            }
        }
        let used = seen.iter().filter(|u| **u).count();
        if used < tiles {
            out.warn(
                W022,
                Loc::none(),
                format!("mapping uses {used} of {tiles} tiles ({} idle)", tiles - used),
            );
        }
    }
}

/// The lowered-IR contract ([`crate::ir::validate`]): buffer
/// declarations and sizes, the L1 budget, the double-buffer race rule,
/// and communication matching — surfaced with the matching stable code.
pub struct IrContract;

impl Pass for IrContract {
    fn name(&self) -> &'static str {
        "ir-contract"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let Some(dep) = cx.dep else { return };
        if let Err(e) = crate::ir::validate(cx.arch, dep) {
            let (code, loc) = match &e {
                IrError::L1OverBudget { tile, .. } => (E041, Loc::tile(tile.row, tile.col)),
                IrError::UndeclaredBuf { tile, .. } | IrError::BufTooSmall { tile, .. } => {
                    (E042, Loc::tile(tile.row, tile.col))
                }
                IrError::BufferRace { tile, step, .. } => {
                    (E043, Loc::at(*step, tile.row, tile.col))
                }
                IrError::UnmatchedComm { step, .. } => (E044, Loc::step(*step)),
                IrError::Malformed { tile, step, .. } => {
                    (E047, Loc::at(*step, tile.row, tile.col))
                }
                IrError::DuplicateProgram(tile) => (E046, Loc::tile(tile.row, tile.col)),
            };
            out.error(code, loc, e.to_string());
        }
    }
}

/// Cap on per-pass diagnostics so a thoroughly broken deployment stays
/// readable.
const MAX_DEADLOCK_DIAGS: usize = 16;

/// BSP rendezvous deadlock detection. Within a superstep every blocking
/// receive-side op (`Recv`, `RecvMulticast`, a `Reduce` member) needs
/// its partner posted **in the same superstep** — the barrier at
/// superstep end otherwise never releases. Unlike the first-error
/// [`IrContract`] pass this lists every unmatched rendezvous with its
/// `(superstep, tile)` location, and when the partner op exists in a
/// *different* superstep it says so: that is the classic cross-barrier
/// deadlock, and "partner is one superstep late" is the actionable
/// message.
pub struct DeadlockFree;

impl Pass for DeadlockFree {
    fn name(&self) -> &'static str {
        "deadlock-free"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let Some(dep) = cx.dep else { return };
        let arch = cx.arch;
        let mut by_tile: HashMap<TileCoord, &Program> = HashMap::new();
        for p in &dep.programs {
            by_tile.insert(p.tile, p); // duplicates: IrContract reports E046
        }
        let mut emitted = 0usize;
        for step in 0..dep.supersteps() {
            // (from, to, tag, bytes) for both legs of each rendezvous.
            let mut sends: Vec<(TileCoord, TileCoord, u32, u64)> = Vec::new();
            let mut recvs: Vec<(TileCoord, TileCoord, u32, u64)> = Vec::new();
            // (root, group, bytes, tag) / (member, root, bytes, tag).
            let mut mc_roots: Vec<(TileCoord, Mask, u64, u32)> = Vec::new();
            let mut mc_recvs: Vec<(TileCoord, TileCoord, u64, u32)> = Vec::new();
            for p in &dep.programs {
                let Some(ss) = p.steps.get(step) else { continue };
                for op in &ss.ops {
                    match op {
                        Op::Send { to, bytes, tag, .. } => sends.push((p.tile, *to, *tag, *bytes)),
                        Op::Recv { from, bytes, tag, .. } => {
                            recvs.push((*from, p.tile, *tag, *bytes))
                        }
                        Op::Multicast { group, bytes, tag, .. } => {
                            mc_roots.push((p.tile, *group, *bytes, *tag))
                        }
                        Op::RecvMulticast { from, bytes, tag, .. } => {
                            mc_recvs.push((p.tile, *from, *bytes, *tag))
                        }
                        _ => {}
                    }
                }
            }
            for (from, to, tag, bytes) in &sends {
                if recvs.iter().any(|r| r == &(*from, *to, *tag, *bytes)) {
                    continue;
                }
                let late = partner_step(by_tile.get(to), |op| {
                    matches!(op, Op::Recv { from: f, tag: g, .. } if f == from && g == tag)
                });
                emit(
                    out,
                    &mut emitted,
                    Loc::at(step, to.row, to.col),
                    format!(
                        "send {from}->{to} tag {tag} has no matching recv in superstep {step}{}",
                        late_note(late, step, "recv")
                    ),
                );
            }
            for (from, to, tag, bytes) in &recvs {
                if sends.iter().any(|s| s == &(*from, *to, *tag, *bytes)) {
                    continue;
                }
                let late = partner_step(by_tile.get(from), |op| {
                    matches!(op, Op::Send { to: t, tag: g, .. } if t == to && g == tag)
                });
                emit(
                    out,
                    &mut emitted,
                    Loc::at(step, to.row, to.col),
                    format!(
                        "recv {to}<-{from} tag {tag} blocks: no matching send in superstep {step}{}",
                        late_note(late, step, "send")
                    ),
                );
            }
            for (root, group, bytes, tag) in &mc_roots {
                for m in group.members(arch.rows, arch.cols) {
                    if m == *root || !by_tile.contains_key(&m) {
                        continue;
                    }
                    let posted = mc_recvs
                        .iter()
                        .any(|(t, f, b, g)| *t == m && f == root && b == bytes && g == tag);
                    if !posted {
                        let late = partner_step(by_tile.get(&m), |op| {
                            matches!(op, Op::RecvMulticast { from: f, tag: g, .. }
                                     if f == root && g == tag)
                        });
                        emit(
                            out,
                            &mut emitted,
                            Loc::at(step, m.row, m.col),
                            format!(
                                "multicast from {root} tag {tag}: member {m} posts no \
                                 RecvMulticast in superstep {step}{}",
                                late_note(late, step, "RecvMulticast")
                            ),
                        );
                    }
                }
            }
            for (member, root, _bytes, tag) in &mc_recvs {
                let rooted = mc_roots.iter().any(|(r, _, _, g)| r == root && g == tag);
                if !rooted {
                    let late = partner_step(by_tile.get(root), |op| {
                        matches!(op, Op::Multicast { tag: g, .. } if g == tag)
                    });
                    emit(
                        out,
                        &mut emitted,
                        Loc::at(step, member.row, member.col),
                        format!(
                            "RecvMulticast at {member} tag {tag} blocks: root {root} posts no \
                             Multicast in superstep {step}{}",
                            late_note(late, step, "Multicast")
                        ),
                    );
                }
            }
            // Reduce: every group member with a program must contribute
            // in this superstep (metadata agreement is IrContract's job).
            let mut reduce_tags: Vec<(u32, Mask, Vec<TileCoord>)> = Vec::new();
            for p in &dep.programs {
                let Some(ss) = p.steps.get(step) else { continue };
                for op in &ss.ops {
                    if let Op::Reduce { group, tag, .. } = op {
                        match reduce_tags.iter().position(|(g, _, _)| g == tag) {
                            Some(i) => reduce_tags[i].2.push(p.tile),
                            None => reduce_tags.push((*tag, *group, vec![p.tile])),
                        }
                    }
                }
            }
            for (tag, group, who) in &reduce_tags {
                for m in group.members(arch.rows, arch.cols) {
                    if !by_tile.contains_key(&m) || who.contains(&m) {
                        continue;
                    }
                    let late = partner_step(by_tile.get(&m), |op| {
                        matches!(op, Op::Reduce { tag: g, .. } if g == tag)
                    });
                    emit(
                        out,
                        &mut emitted,
                        Loc::at(step, m.row, m.col),
                        format!(
                            "reduce tag {tag}: group member {m} contributes nothing in \
                             superstep {step}{}",
                            late_note(late, step, "Reduce")
                        ),
                    );
                }
            }
            if emitted >= MAX_DEADLOCK_DIAGS {
                return;
            }
        }
    }
}

/// First superstep of `program` containing an op matching `pred`.
fn partner_step(program: Option<&&Program>, pred: impl Fn(&Op) -> bool) -> Option<usize> {
    program?.steps.iter().position(|s| s.ops.iter().any(&pred))
}

fn late_note(partner: Option<usize>, step: usize, what: &str) -> String {
    match partner {
        Some(s) if s != step => format!(
            "; the matching {what} is posted in superstep {s} — the tiles block at \
             different barriers"
        ),
        Some(_) => String::new(), // mismatched bytes in the same step: IrContract's E044
        None => format!("; no matching {what} exists in any superstep"),
    }
}

fn emit(out: &mut CheckReport, emitted: &mut usize, loc: Loc, message: String) {
    if *emitted < MAX_DEADLOCK_DIAGS {
        out.error(E045, loc, message);
        *emitted += 1;
    }
}

/// HBM-channel legality of the emitted layouts: every addressed channel
/// exists on the configured edges, each layout validates, and heavy
/// per-channel skew (worst extent > 4x the mean) is flagged.
pub struct HbmLayoutLegality;

impl Pass for HbmLayoutLegality {
    fn name(&self) -> &'static str {
        "hbm-layout-legality"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let Some(dep) = cx.dep else { return };
        let chans = cx.arch.hbm.num_channels();
        let l = &dep.layouts;
        for (name, layout) in [("A", &l.a), ("B", &l.b), ("C", &l.c)] {
            if let Err(e) = layout.validate() {
                out.error(E032, Loc::none(), format!("{name} layout: {e:#}"));
                continue;
            }
            for ch in layout.channels_used() {
                if ch >= chans {
                    out.error(
                        E031,
                        Loc::none(),
                        format!(
                            "{name} layout addresses HBM channel {ch}; the arch has {chans} \
                             (channels 0..{chans})"
                        ),
                    );
                }
            }
            let extents = layout.channel_extents();
            if extents.len() > 1 {
                let worst = extents.values().max().copied().unwrap_or(0);
                let mean = extents.values().sum::<u64>() as f64 / extents.len() as f64;
                if mean > 0.0 && worst as f64 > 4.0 * mean {
                    out.warn(
                        W033,
                        Loc::none(),
                        format!(
                            "{name} layout skews HBM traffic: worst channel holds {worst} B \
                             vs a {mean:.0} B mean"
                        ),
                    );
                }
            }
        }
    }
}

/// Mirrors [`crate::graph::WorkloadGraph::validate`]: cycles get
/// `DIT-E091`, edge shape disagreements get `DIT-E092`, and every other
/// structural violation (count mismatch along an edge, op arity,
/// duplicate labels, self-edges) falls to the `DIT-E093` catch-all — so
/// `rejected()` stays in exact lockstep with `validate` by construction.
pub struct GraphStructure;

impl Pass for GraphStructure {
    fn name(&self) -> &'static str {
        "graph-structure"
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let Some(g) = cx.graph else {
            return;
        };
        let before = out.errors();
        if let Err(e) = g.topo_order() {
            out.error(E091, Loc::none(), format!("{e:#}"));
        }
        for e in &g.edges {
            if e.from.0 >= g.ops.len() || e.to.0 >= g.ops.len() {
                continue; // out-of-range edges fall to the catch-all
            }
            if let OpKind::Gemm(s) = g.op(e.to).kind {
                if (e.tensor.rows, e.tensor.cols) != (s.m, s.k) {
                    out.error(
                        E092,
                        Loc::none(),
                        format!(
                            "edge {:?}: producer {} output {}x{} does not match GEMM \
                             {:?} A operand {}x{}",
                            e.tensor.name,
                            g.op(e.from).label,
                            e.tensor.rows,
                            e.tensor.cols,
                            g.op(e.to).label,
                            s.m,
                            s.k
                        ),
                    );
                }
            }
        }
        // Lockstep catch-all: a validate clause with no mirror above.
        if out.errors() == before {
            if let Err(e) = g.validate() {
                out.error(E093, Loc::none(), format!("{e:#}"));
            }
        }
    }
}

/// SPM residency capacity per edge, judged *optimistically*: each GEMM
/// endpoint is charged the minimum [`l1_estimate`] over its candidate
/// enumeration. If even the leanest candidate pair cannot host the
/// intermediate's per-tile share, no tuning outcome can keep the edge
/// on-fabric — the fused path will spill it through HBM. Spilling is
/// legal (the edge-free lowering always works), so this warns rather
/// than rejects: `DIT-W094`.
pub struct EdgeResidency;

impl Pass for EdgeResidency {
    fn name(&self) -> &'static str {
        "edge-residency"
    }

    fn requires_clean(&self) -> bool {
        true // needs a structurally valid graph (shapes, arity, DAG)
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport) {
        let Some(g) = cx.graph else {
            return;
        };
        let a = cx.arch;
        let mut lean = |op: &crate::graph::GraphOp, shape: crate::arch::GemmShape| -> u64 {
            crate::schedule::candidates(a, shape)
                .iter()
                .map(|s| l1_estimate(a, shape, s))
                .min()
                .unwrap_or(u64::MAX)
        };
        for e in &g.edges {
            let share = crate::graph::tensor_share_bytes(a, &e.tensor);
            let need_from = crate::graph::op_need_bytes(a, g, g.op(e.from), &mut lean);
            let need_to = crate::graph::op_need_bytes(a, g, g.op(e.to), &mut lean);
            if !crate::graph::edge_is_resident(a, share, need_from, need_to) {
                out.warn(
                    W094,
                    Loc::none(),
                    format!(
                        "edge {:?} ({} -> {}) can never stay SPM-resident: \
                         {share} B/tile share + working sets {need_from}/{need_to} B \
                         exceed the {} B L1 — the fused path will spill it through HBM",
                        e.tensor.name,
                        g.op(e.from).label,
                        g.op(e.to).label,
                        a.tile.l1_bytes
                    ),
                );
            }
        }
    }
}

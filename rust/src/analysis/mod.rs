//! Static deployment analysis: a pass-manager checker over
//! `(ArchConfig, GemmShape, Schedule / Deployment)`.
//!
//! DiT's premise is that mapping legality is deeply coupled with the
//! hardware configuration. Historically that coupling was enforced by
//! scattered `validate()` methods plus asserts deep inside codegen and
//! the simulator, so an illegal `(arch, schedule)` pair surfaced as a
//! panic or an `anyhow` chain with no structure. This module turns the
//! same legality rules into **structured diagnostics**: stable error
//! codes (`DIT-E011 spm-overflow`), a severity, an optional
//! per-superstep / per-tile location, a human message, and machine JSON
//! — cheap enough (purely closed-form, zero simulations) to run over
//! the entire candidate space, in the spirit of GOMA-style analytic
//! mapping checks.
//!
//! Entry points:
//!
//! * [`check_arch`] — architecture sanity + HBM edge rule.
//! * [`check_schedule`] — everything above plus schedule/dataflow
//!   compatibility, double-buffer-aware SPM capacity accounting,
//!   chunking legality, and remap geometry. **Hard-reject lockstep:**
//!   [`CheckReport::rejected`] is `true` exactly when
//!   [`Schedule::validate`] fails or the working set overflows L1 with
//!   no legal chunking — i.e. exactly when
//!   [`crate::coordinator::deploy_chunked`] would error — so the engine
//!   can skip simulating rejected candidates without changing any
//!   result ([`crate::coordinator::engine`] relies on this).
//! * [`check_deployment`] — the lowered-IR contract (buffer discipline,
//!   L1 budget) plus a BSP rendezvous deadlock check and HBM channel
//!   legality on the emitted layouts.
//! * [`check_workload`] — arch checks plus per-shape candidate
//!   coverage: a shape with zero deployable schedules is an error.

pub mod passes;

use std::fmt;

use crate::arch::workload::Workload;
use crate::arch::{ArchConfig, GemmShape};
use crate::graph::WorkloadGraph;
use crate::ir::Deployment;
use crate::schedule::Schedule;
use crate::util::json::Json;

/// Stable diagnostic codes. The numeric part is permanent: codes are
/// referenced from CI logs, docs, and tests, so a code is never reused
/// for a different condition (retire, don't recycle). `E` codes reject
/// (checker exit is nonzero, the engine skips simulation); `W` codes
/// inform.
pub mod codes {
    /// `(stable code, short kebab-case name)`.
    pub type Code = (&'static str, &'static str);

    // Architecture sanity (mirrors `ArchConfig::validate`).
    pub const E001: Code = ("DIT-E001", "empty-grid");
    pub const E002: Code = ("DIT-E002", "empty-ce-array");
    pub const E003: Code = ("DIT-E003", "bad-clock");
    pub const E004: Code = ("DIT-E004", "spm-too-small");
    pub const E005: Code = ("DIT-E005", "noc-too-narrow");
    pub const E006: Code = ("DIT-E006", "no-hbm-channels");
    pub const E007: Code = ("DIT-E007", "bad-elem-bytes");
    pub const E008: Code = ("DIT-E008", "arch-invalid");
    /// More HBM channels than edge routers: channels share injection
    /// points ([`crate::arch::ArchConfig::hbm_router`] wraps).
    pub const W009: Code = ("DIT-W009", "hbm-edge-wrap");

    // SPM capacity / chunking.
    pub const E011: Code = ("DIT-E011", "spm-overflow");
    pub const W012: Code = ("DIT-W012", "spm-chunked");
    pub const E013: Code = ("DIT-E013", "chunking-broken");

    // Remap geometry.
    pub const E021: Code = ("DIT-E021", "remap-aliasing");
    pub const W022: Code = ("DIT-W022", "idle-tiles");

    // HBM channel legality on emitted layouts.
    pub const E031: Code = ("DIT-E031", "hbm-channel-out-of-range");
    pub const E032: Code = ("DIT-E032", "hbm-layout-invalid");
    pub const W033: Code = ("DIT-W033", "hbm-imbalance");

    // Deployment IR contract.
    pub const E041: Code = ("DIT-E041", "l1-over-budget");
    pub const E042: Code = ("DIT-E042", "bad-buffer");
    pub const E043: Code = ("DIT-E043", "buffer-race");
    pub const E044: Code = ("DIT-E044", "comm-mismatch");
    pub const E045: Code = ("DIT-E045", "deadlock");
    pub const E046: Code = ("DIT-E046", "duplicate-program");
    pub const E047: Code = ("DIT-E047", "ir-malformed");

    // Schedule / dataflow compatibility (mirrors `Schedule::validate`).
    pub const E051: Code = ("DIT-E051", "bad-tk");
    pub const E052: Code = ("DIT-E052", "empty-logical-grid");
    pub const E053: Code = ("DIT-E053", "tile-oversubscription");
    pub const E054: Code = ("DIT-E054", "bad-pipeline-stages");
    pub const E055: Code = ("DIT-E055", "systolic-grid-mismatch");
    pub const E056: Code = ("DIT-E056", "bad-hier-group");
    pub const E057: Code = ("DIT-E057", "splitk-coverage");
    pub const E058: Code = ("DIT-E058", "splitk-reduce-mask");
    pub const E059: Code = ("DIT-E059", "schedule-invalid");

    // Input / CLI surface.
    pub const E071: Code = ("DIT-E071", "parse-error");
    pub const E072: Code = ("DIT-E072", "cache-unrecognized");

    // Workload coverage.
    pub const E081: Code = ("DIT-E081", "no-deployable-candidate");
    pub const W082: Code = ("DIT-W082", "spec-dropped-points");

    // Workload-graph structure (mirrors `WorkloadGraph::validate`).
    pub const E091: Code = ("DIT-E091", "graph-cycle");
    pub const E092: Code = ("DIT-E092", "edge-shape-mismatch");
    pub const E093: Code = ("DIT-E093", "graph-invalid");
    pub const W094: Code = ("DIT-W094", "residency-spill");

    /// Every code, for uniqueness tests and the README table check.
    pub const ALL: &[Code] = &[
        E001, E002, E003, E004, E005, E006, E007, E008, W009, E011, W012, E013, E021, W022,
        E031, E032, W033, E041, E042, E043, E044, E045, E046, E047, E051, E052, E053, E054,
        E055, E056, E057, E058, E059, E071, E072, E081, W082, E091, E092, E093, W094,
    ];
}

pub use codes::Code;

/// Diagnostic severity. Only [`Severity::Error`] rejects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// Where in the deployment a diagnostic points: a BSP superstep, a
/// physical tile, both, or neither (whole-subject diagnostics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Loc {
    pub superstep: Option<usize>,
    pub tile: Option<(usize, usize)>,
}

impl Loc {
    pub fn none() -> Loc {
        Loc::default()
    }

    pub fn step(superstep: usize) -> Loc {
        Loc { superstep: Some(superstep), tile: None }
    }

    pub fn tile(row: usize, col: usize) -> Loc {
        Loc { superstep: None, tile: Some((row, col)) }
    }

    pub fn at(superstep: usize, row: usize, col: usize) -> Loc {
        Loc { superstep: Some(superstep), tile: Some((row, col)) }
    }

    fn render(&self) -> String {
        match (self.superstep, self.tile) {
            (None, None) => String::new(),
            (Some(s), None) => format!(" (superstep {s})"),
            (None, Some((r, c))) => format!(" (tile ({r},{c}))"),
            (Some(s), Some((r, c))) => format!(" (superstep {s}, tile ({r},{c}))"),
        }
    }
}

/// One structured diagnostic.
#[derive(Debug, Clone)]
pub struct Diag {
    /// Stable code, e.g. `DIT-E011`.
    pub code: &'static str,
    /// Short kebab-case name, e.g. `spm-overflow`.
    pub name: &'static str,
    pub severity: Severity,
    pub loc: Loc,
    pub message: String,
}

impl Diag {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .field("code", self.code)
            .field("name", self.name)
            .field("severity", self.severity.to_string());
        if let Some(s) = self.loc.superstep {
            j = j.field("superstep", s as u64);
        }
        if let Some((r, c)) = self.loc.tile {
            j = j.field("tile", Json::arr().push(r as u64).push(c as u64));
        }
        j.field("message", self.message.as_str())
    }
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] {}: {}{}",
            self.severity,
            self.code,
            self.name,
            self.message,
            self.loc.render()
        )
    }
}

/// The outcome of checking one subject: which passes ran and every
/// diagnostic they produced.
#[derive(Debug, Clone)]
pub struct CheckReport {
    /// What was checked (arch name, shape, schedule — for humans).
    pub subject: String,
    /// Pass names, in execution order (skipped passes are absent).
    pub passes_run: Vec<&'static str>,
    pub diags: Vec<Diag>,
}

impl CheckReport {
    pub fn new(subject: impl Into<String>) -> CheckReport {
        CheckReport { subject: subject.into(), passes_run: Vec::new(), diags: Vec::new() }
    }

    /// Record an error diagnostic. `code` must be an `E` code.
    pub fn error(&mut self, code: Code, loc: Loc, message: String) {
        debug_assert!(code.0.contains("-E"), "{} recorded as error", code.0);
        self.diags.push(Diag { code: code.0, name: code.1, severity: Severity::Error, loc, message });
    }

    /// Record a warning diagnostic. `code` must be a `W` code.
    pub fn warn(&mut self, code: Code, loc: Loc, message: String) {
        debug_assert!(code.0.contains("-W"), "{} recorded as warning", code.0);
        self.diags.push(Diag {
            code: code.0,
            name: code.1,
            severity: Severity::Warning,
            loc,
            message,
        });
    }

    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// Hard rejection: any error-severity diagnostic. For
    /// [`check_schedule`] this is in exact lockstep with
    /// [`crate::coordinator::deploy_chunked`] failing (see module docs).
    pub fn rejected(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn has_code(&self, code: Code) -> bool {
        self.diags.iter().any(|d| d.code == code.0)
    }

    /// Multi-line human rendering (header + one line per diagnostic).
    pub fn render(&self) -> String {
        let mut out = if self.diags.is_empty() {
            format!("check {}: clean ({} passes)\n", self.subject, self.passes_run.len())
        } else {
            format!(
                "check {}: {} error(s), {} warning(s)\n",
                self.subject,
                self.errors(),
                self.warnings()
            )
        };
        for d in &self.diags {
            out.push_str(&format!("  {d}\n"));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut passes = Json::arr();
        for p in &self.passes_run {
            passes = passes.push(*p);
        }
        let mut diags = Json::arr();
        for d in &self.diags {
            diags = diags.push(d.to_json());
        }
        Json::obj()
            .field("subject", self.subject.as_str())
            .field("passes", passes)
            .field("errors", self.errors() as u64)
            .field("warnings", self.warnings() as u64)
            .field("diags", diags)
    }
}

/// What a pass sees. Passes only read the fields they need; a pass
/// whose inputs are absent is a no-op.
pub struct Ctx<'a> {
    pub arch: &'a ArchConfig,
    pub shape: Option<GemmShape>,
    pub sched: Option<&'a Schedule>,
    pub dep: Option<&'a Deployment>,
    pub graph: Option<&'a WorkloadGraph>,
}

impl<'a> Ctx<'a> {
    pub fn arch_only(arch: &'a ArchConfig) -> Ctx<'a> {
        Ctx { arch, shape: None, sched: None, dep: None, graph: None }
    }
}

/// One analysis pass.
pub trait Pass {
    fn name(&self) -> &'static str;

    /// Passes whose arithmetic is only defined on structurally valid
    /// inputs (e.g. `Schedule::plan` divides by the logical grid)
    /// return `true` here and are skipped once an earlier pass errored.
    fn requires_clean(&self) -> bool {
        false
    }

    fn run(&self, cx: &Ctx, out: &mut CheckReport);
}

/// An ordered pass pipeline.
#[derive(Default)]
pub struct Checker {
    passes: Vec<Box<dyn Pass>>,
}

impl Checker {
    pub fn new() -> Checker {
        Checker::default()
    }

    pub fn with(mut self, pass: impl Pass + 'static) -> Checker {
        self.passes.push(Box::new(pass));
        self
    }

    /// Architecture-only pipeline.
    pub fn for_arch() -> Checker {
        Checker::new().with(passes::ArchSanity).with(passes::HbmEdgeRule)
    }

    /// Full `(arch, shape, schedule)` pipeline.
    pub fn for_schedule() -> Checker {
        Checker::for_arch()
            .with(passes::ScheduleCompat)
            .with(passes::SpmCapacity)
            .with(passes::ChunkingLegality)
            .with(passes::RemapGeometry)
    }

    /// Lowered-deployment pipeline.
    pub fn for_deployment() -> Checker {
        Checker::for_arch()
            .with(passes::IrContract)
            .with(passes::DeadlockFree)
            .with(passes::HbmLayoutLegality)
    }

    /// Workload-graph pipeline: structure (DAG, edge shapes, counts,
    /// arity) then SPM residency capacity.
    pub fn for_graph() -> Checker {
        Checker::for_arch().with(passes::GraphStructure).with(passes::EdgeResidency)
    }

    pub fn run(&self, cx: &Ctx, subject: impl Into<String>) -> CheckReport {
        let mut rep = CheckReport::new(subject);
        for pass in &self.passes {
            if pass.requires_clean() && rep.rejected() {
                continue;
            }
            rep.passes_run.push(pass.name());
            pass.run(cx, &mut rep);
        }
        rep
    }
}

/// Lint an architecture description.
pub fn check_arch(arch: &ArchConfig) -> CheckReport {
    Checker::for_arch().run(&Ctx::arch_only(arch), arch.name.clone())
}

/// Lint a `(arch, shape, schedule)` triple. See the module docs for the
/// hard-reject lockstep contract the engine relies on.
pub fn check_schedule(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> CheckReport {
    let cx = Ctx { arch, shape: Some(shape), sched: Some(sched), dep: None, graph: None };
    Checker::for_schedule().run(&cx, format!("{} {} {}", arch.name, shape, sched.name()))
}

/// Lint a lowered deployment (post-emission IR contract).
pub fn check_deployment(arch: &ArchConfig, dep: &Deployment) -> CheckReport {
    let cx = Ctx { arch, shape: None, sched: None, dep: Some(dep), graph: None };
    Checker::for_deployment().run(&cx, format!("{} {} {}", arch.name, dep.shape, dep.descr))
}

/// Lint an architecture against a whole workload: every unique shape
/// must retain at least one checker-accepted schedule candidate.
pub fn check_workload(arch: &ArchConfig, w: &Workload) -> CheckReport {
    let mut rep =
        Checker::for_arch().run(&Ctx::arch_only(arch), format!("{} workload {}", arch.name, w.name));
    if rep.rejected() {
        return rep;
    }
    rep.passes_run.push("candidate-coverage");
    let mut seen: Vec<GemmShape> = Vec::new();
    for item in &w.items {
        if seen.contains(&item.shape) {
            continue;
        }
        seen.push(item.shape);
        let cands = crate::schedule::candidates(arch, item.shape);
        let accepted =
            cands.iter().filter(|s| !check_schedule(arch, item.shape, s).rejected()).count();
        if accepted == 0 {
            rep.error(
                codes::E081,
                Loc::none(),
                format!(
                    "{}: none of the {} enumerated schedule candidates deploys on {}",
                    item.shape,
                    cands.len(),
                    arch.name
                ),
            );
        }
    }
    rep
}

/// Lint a multi-op workload graph against an architecture: structural
/// validity (acyclic, edge shape/count agreement, op arity — lockstep
/// with [`WorkloadGraph::validate`]), SPM residency capacity per edge,
/// and candidate coverage for every unique GEMM shape (the same E081
/// contract [`check_workload`] enforces).
pub fn check_graph(arch: &ArchConfig, g: &WorkloadGraph) -> CheckReport {
    let cx = Ctx { arch, shape: None, sched: None, dep: None, graph: Some(g) };
    let mut rep = Checker::for_graph().run(&cx, format!("{} graph {}", arch.name, g.name));
    if rep.rejected() {
        return rep;
    }
    rep.passes_run.push("candidate-coverage");
    let mut seen: Vec<GemmShape> = Vec::new();
    for op in &g.ops {
        let crate::graph::OpKind::Gemm(shape) = op.kind else {
            continue;
        };
        if seen.contains(&shape) {
            continue;
        }
        seen.push(shape);
        let cands = crate::schedule::candidates(arch, shape);
        let accepted =
            cands.iter().filter(|s| !check_schedule(arch, shape, s).rejected()).count();
        if accepted == 0 {
            rep.error(
                codes::E081,
                Loc::none(),
                format!(
                    "{} ({}): none of the {} enumerated schedule candidates deploys on {}",
                    shape,
                    op.label,
                    cands.len(),
                    arch.name
                ),
            );
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{candidates, l1_estimate, Dataflow, Schedule};

    #[test]
    fn codes_are_unique_and_well_formed() {
        let mut seen = std::collections::HashSet::new();
        for (code, name) in codes::ALL {
            assert!(seen.insert(*code), "duplicate code {code}");
            assert!(
                code.starts_with("DIT-E") || code.starts_with("DIT-W"),
                "bad code {code}"
            );
            assert!(!name.is_empty() && !name.contains(' '), "bad name {name}");
        }
    }

    #[test]
    fn presets_check_clean() {
        for arch in [ArchConfig::gh200_like(), ArchConfig::a100_like(), ArchConfig::tiny(4, 4)] {
            let rep = check_arch(&arch);
            assert!(!rep.rejected(), "{}", rep.render());
            assert_eq!(rep.errors(), 0, "{}", rep.render());
        }
    }

    #[test]
    fn broken_arch_maps_to_specific_codes() {
        let mut a = ArchConfig::tiny(2, 2);
        a.rows = 0;
        let rep = check_arch(&a);
        assert!(rep.rejected());
        assert!(rep.has_code(codes::E001), "{}", rep.render());

        let mut b = ArchConfig::tiny(2, 2);
        b.elem_bytes = 0;
        assert!(check_arch(&b).has_code(codes::E007));

        let mut c = ArchConfig::tiny(2, 2);
        c.tile.l1_bytes = 16;
        assert!(check_arch(&c).has_code(codes::E004));
    }

    #[test]
    fn arch_reject_lockstep_with_validate() {
        // Every arch mutation agrees with ArchConfig::validate.
        let mut muts: Vec<ArchConfig> = Vec::new();
        let fns: [fn(&mut ArchConfig); 9] = [
            |a| a.rows = 0,
            |a| a.cols = 0,
            |a| a.tile.ce_m = 0,
            |a| a.tile.clock_ghz = 0.0,
            |a| a.tile.l1_bytes = 100,
            |a| a.noc.link_bits = 4,
            |a| a.hbm.channels_per_edge = 0,
            |a| a.elem_bytes = 9,
            |a| a.elem_bytes = 8, // still legal
        ];
        for f in fns {
            let mut a = ArchConfig::tiny(4, 4);
            f(&mut a);
            muts.push(a);
        }
        for a in &muts {
            assert_eq!(
                check_arch(a).rejected(),
                a.validate().is_err(),
                "lockstep broken for {a:?}"
            );
        }
    }

    #[test]
    fn all_candidates_accepted() {
        // Enumerated candidates are pre-filtered to be deployable; the
        // checker must never falsely reject one (the engine gate's
        // no-op guarantee on committed flows).
        for arch in [ArchConfig::tiny(4, 4), ArchConfig::tiny(2, 8)] {
            for shape in [
                GemmShape::new(128, 128, 256),
                GemmShape::new(96, 66, 128),
                GemmShape::new(16, 512, 512),
            ] {
                for s in candidates(&arch, shape) {
                    let rep = check_schedule(&arch, shape, &s);
                    assert!(!rep.rejected(), "{}", rep.render());
                }
            }
        }
    }

    #[test]
    fn schedule_reject_lockstep_with_deploy() {
        // The module-doc contract: rejected() ⟺ validate fails or the
        // working set overflows L1 with no legal chunking.
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(128, 128, 256);
        let base = Schedule::summa(&arch, shape);
        let muts = [
            Schedule { tk: 0, ..base.clone() },
            Schedule { logical: (0, 2), ..base.clone() },
            Schedule { logical: (4, 4), ..base.clone() },
            Schedule { pipeline_stages: 0, ..base.clone() },
            Schedule { pipeline_stages: 9, ..base.clone() },
            Schedule { dataflow: Dataflow::Systolic, logical: (1, 2), ..base.clone() },
            Schedule { dataflow: Dataflow::SystolicOverSumma { group: 3 }, ..base.clone() },
            Schedule { dataflow: Dataflow::SplitKSumma { splits: 2 }, ..base.clone() },
            base.clone(),
        ];
        for s in &muts {
            let rep = check_schedule(&arch, shape, s);
            let l1 = arch.tile.l1_bytes as u64;
            let expect = s.validate(&arch).is_err()
                || (l1_estimate(&arch, shape, s) > l1
                    && crate::coordinator::chunking_for(&arch, shape, s).is_none());
            assert_eq!(rep.rejected(), expect, "{}\n{}", s.name(), rep.render());
        }
    }

    #[test]
    fn overflow_without_chunking_is_spm_overflow() {
        let mut arch = ArchConfig::tiny(2, 2);
        arch.tile.l1_bytes = 4096;
        let shape = GemmShape::new(256, 256, 256);
        let s = crate::schedule::retune_tk(&arch, shape, &Schedule::summa(&arch, shape));
        let rep = check_schedule(&arch, shape, &s);
        assert!(rep.rejected(), "{}", rep.render());
        assert!(rep.has_code(codes::E011), "{}", rep.render());
        assert!(crate::coordinator::deploy_chunked(&arch, shape, &s).is_err());
    }

    #[test]
    fn chunkable_overflow_is_a_warning_not_an_error() {
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(128, 8192, 256);
        let s = Schedule::summa(&arch, shape);
        assert!(l1_estimate(&arch, shape, &s) > arch.tile.l1_bytes as u64);
        let rep = check_schedule(&arch, shape, &s);
        assert!(!rep.rejected(), "{}", rep.render());
        assert!(rep.has_code(codes::W012), "{}", rep.render());
        assert!(crate::coordinator::deploy_chunked(&arch, shape, &s).is_ok());
    }

    #[test]
    fn undersubscribed_logical_grid_warns_idle_tiles() {
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(64, 64, 64);
        let s = Schedule { logical: (1, 2), ..Schedule::summa(&arch, shape) };
        let rep = check_schedule(&arch, shape, &s);
        assert!(!rep.rejected(), "{}", rep.render());
        assert!(rep.has_code(codes::W022), "{}", rep.render());
    }

    #[test]
    fn diag_json_roundtrips() {
        let mut rep = CheckReport::new("unit");
        rep.error(codes::E011, Loc::at(3, 1, 2), "needs 1 B".into());
        rep.warn(codes::W012, Loc::none(), "chunked".into());
        let j = rep.to_json();
        let parsed = Json::parse(&j.render()).unwrap();
        assert_eq!(parsed.get("errors").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(parsed.get("warnings").and_then(|v| v.as_u64()), Some(1));
        let diags = parsed.get("diags").and_then(|d| d.items()).unwrap();
        assert_eq!(diags[0].get("code").and_then(|c| c.as_str()), Some("DIT-E011"));
        assert_eq!(diags[0].get("superstep").and_then(|s| s.as_u64()), Some(3));
        let disp = rep.render();
        assert!(disp.contains("error[DIT-E011] spm-overflow"), "{disp}");
        assert!(disp.contains("superstep 3, tile (1,2)"), "{disp}");
    }

    #[test]
    fn workload_coverage_flags_impossible_shapes() {
        let w = Workload::builtin("tiny").unwrap();
        let rep = check_workload(&ArchConfig::tiny(4, 4), &w);
        assert!(!rep.rejected(), "{}", rep.render());
        // An arch whose SPM cannot hold any candidate for a big shape.
        let mut small = ArchConfig::tiny(2, 2);
        small.tile.l1_bytes = 4096;
        let w1 = Workload::single("huge", GemmShape::new(4096, 4096, 4096));
        let rep = check_workload(&small, &w1);
        assert!(rep.rejected(), "{}", rep.render());
        assert!(rep.has_code(codes::E081), "{}", rep.render());
    }

    #[test]
    fn graph_checker_rejects_iff_validate_rejects() {
        use crate::graph::WorkloadGraph;
        let arch = ArchConfig::tiny(4, 4);

        // Clean builtins: validate Ok ⟺ checker accepts, zero errors.
        for name in WorkloadGraph::builtin_names() {
            let g = WorkloadGraph::builtin(name).unwrap();
            let rep = check_graph(&arch, &g);
            assert!(g.validate().is_ok());
            assert!(!rep.rejected(), "{name}: {}", rep.render());
            assert_eq!(rep.errors(), 0, "{name}: {}", rep.render());
        }

        // Cycle → E091.
        let mut cyc = WorkloadGraph::new("cyc");
        let a = cyc.add_gemm("a", GemmShape::new(64, 64, 64), 1);
        let b = cyc.add_gemm("b", GemmShape::new(64, 64, 64), 1);
        cyc.connect(a, b, "ab").unwrap();
        cyc.connect(b, a, "ba").unwrap();
        let rep = check_graph(&arch, &cyc);
        assert!(cyc.validate().is_err());
        assert!(rep.rejected() && rep.has_code(codes::E091), "{}", rep.render());

        // Edge shape mismatch → E092.
        let mut bad = WorkloadGraph::new("bad-shape");
        let a = bad.add_gemm("a", GemmShape::new(64, 64, 32), 1);
        let b = bad.add_gemm("b", GemmShape::new(128, 32, 64), 1);
        bad.connect(a, b, "t").unwrap();
        let rep = check_graph(&arch, &bad);
        assert!(bad.validate().is_err());
        assert!(rep.rejected() && rep.has_code(codes::E092), "{}", rep.render());

        // Count mismatch: no specific mirror → E093 catch-all.
        let mut cnt = WorkloadGraph::new("bad-count");
        let a = cnt.add_gemm("a", GemmShape::new(64, 64, 32), 2);
        let b = cnt.add_gemm("b", GemmShape::new(64, 32, 64), 3);
        cnt.connect(a, b, "t").unwrap();
        let rep = check_graph(&arch, &cnt);
        assert!(cnt.validate().is_err());
        assert!(rep.rejected() && rep.has_code(codes::E093), "{}", rep.render());
    }

    #[test]
    fn graph_residency_capacity_warns_on_forced_spills() {
        use crate::graph::WorkloadGraph;
        // A 1024x1024 f32 intermediate over 4 tiles shares out to 1 MiB
        // per tile — four times tiny's 256 KiB L1, so no tuning outcome
        // can keep the edge resident.
        let arch = ArchConfig::tiny(2, 2);
        let mut g = WorkloadGraph::new("spilly");
        let a = g.add_gemm("a", GemmShape::new(1024, 1024, 64), 1);
        let b = g.add_gemm("b", GemmShape::new(1024, 64, 1024), 1);
        g.connect(a, b, "wide").unwrap();
        g.validate().unwrap();
        let rep = check_graph(&arch, &g);
        assert!(rep.has_code(codes::W094), "{}", rep.render());

        // The builtin attention graph on the GH200 instance fuses: no
        // spill warnings.
        let attn = WorkloadGraph::builtin("attn-prefill").unwrap();
        let rep = check_graph(&ArchConfig::gh200_like(), &attn);
        assert!(!rep.has_code(codes::W094), "{}", rep.render());
        assert_eq!(rep.errors(), 0, "{}", rep.render());
    }
}

//! Zero-dependency substrates.
//!
//! The build environment is fully offline (vendored crates only), so the
//! conventional helpers — a config parser, a JSON writer, a deterministic
//! PRNG, a property-test harness — are implemented here from scratch.

pub mod cfgtext;
pub mod json;
pub mod quickprop;
pub mod rng;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `q`.
#[inline]
pub fn round_up(a: usize, q: usize) -> usize {
    ceil_div(a, q) * q
}

/// `true` if `x` is a power of two (and nonzero).
#[inline]
pub fn is_pow2(x: usize) -> bool {
    x != 0 && x & (x - 1) == 0
}

/// log2 of a power of two. Hard-asserts the precondition: on a
/// non-power-of-two a release build would silently return
/// `trailing_zeros` (e.g. `log2(12) == 2`) and corrupt every mask
/// derived from it.
#[inline]
pub fn log2(x: usize) -> u32 {
    assert!(is_pow2(x), "log2({x}): not a power of two");
    x.trailing_zeros()
}

/// 64-bit FNV-1a over a byte string.
///
/// The algorithm is fixed by specification (offset basis
/// `0xcbf29ce484222325`, prime `0x100000001b3`), so the digest is
/// identical on every platform, Rust release, and process run — unlike
/// `std::collections::hash_map::DefaultHasher`, whose algorithm is
/// explicitly unspecified and may change between Rust versions. Anything
/// persisted to disk (the simulation cache key's architecture
/// fingerprint) must hash through this, never through `DefaultHasher`.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET_BASIS;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Pretty-print a byte count (`1.5 MiB` style).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Pretty-print a nanosecond duration.
pub fn human_time_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(10, 8), 16);
        assert_eq!(round_up(16, 8), 16);
        assert_eq!(round_up(0, 8), 0);
    }

    #[test]
    fn pow2_and_log2() {
        assert!(is_pow2(1));
        assert!(is_pow2(1024));
        assert!(!is_pow2(0));
        assert!(!is_pow2(66));
        assert_eq!(log2(1), 0);
        assert_eq!(log2(32), 5);
    }

    #[test]
    fn fnv1a64_known_answer_vectors() {
        // Standard FNV-1a 64-bit test vectors (draft-eastlake-fnv): the
        // digest is pinned by specification, so these values must hold on
        // every platform and Rust release — that is the whole point of
        // using FNV for the on-disk cache key.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fnv1a64_distinguishes_and_repeats() {
        assert_ne!(fnv1a64(b"rows = 4"), fnv1a64(b"rows = 2"));
        assert_eq!(fnv1a64(b"rows = 4"), fnv1a64(b"rows = 4"));
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_time_ns(500.0), "500 ns");
        assert_eq!(human_time_ns(2.5e6), "2.50 ms");
    }
}

//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`](crate::util::rng::Rng); the
//! harness runs it for `cases` seeds derived from a base seed and, on panic,
//! reports the failing case seed so the case can be replayed exactly with
//! [`check_one`]. No shrinking — generators should be written so a single
//! failing seed is already small enough to debug (keep dimensions modest).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath link-args in this
//! // offline environment; the same property runs in unit tests below.)
//! use dit::util::quickprop::check;
//! check("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::rng::Rng;

/// Base seed; override with the `DIT_PROP_SEED` environment variable to
/// replay a CI failure locally.
fn base_seed() -> u64 {
    std::env::var("DIT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD17_5EED)
}

/// Derive the per-case seed. Public so failures can be replayed.
pub fn case_seed(base: u64, case: u64) -> u64 {
    // splitmix64 step keeps case streams decorrelated.
    let mut z = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Run `prop` for `cases` random cases; panic with the failing seed on error.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Rng)) {
    let base = base_seed();
    for case in 0..cases {
        let seed = case_seed(base, case);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay: DIT_PROP_SEED={base} or quickprop::check_one({seed:#x}, ..)"
            );
        }
    }
}

/// Replay a single case by exact seed.
pub fn check_one(seed: u64, prop: impl Fn(&mut Rng)) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("xor involution", 32, |rng| {
            let x = rng.next_u64();
            let k = rng.next_u64();
            assert_eq!((x ^ k) ^ k, x);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always fails", 4, |_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always fails"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn case_seeds_are_distinct() {
        let s: std::collections::HashSet<u64> = (0..1000).map(|c| case_seed(1, c)).collect();
        assert_eq!(s.len(), 1000);
    }
}

//! Deterministic xorshift64* PRNG.
//!
//! Used for test-data generation and the property-test harness; all
//! simulation paths are fully deterministic and never consume randomness,
//! so reproducibility of every experiment follows from the seed alone.

/// xorshift64* generator (Vigna 2014). Small, fast, good enough for
/// test-vector generation; NOT cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. A zero seed is remapped (xorshift state must be
    /// nonzero).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9e3779b97f4a7c15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is an empty range");
        // Modulo bias is irrelevant for test generation purposes.
        self.next_u64() % n
    }

    /// Uniform usize in `[lo, hi]` inclusive. Hard-asserts `lo <= hi`:
    /// in a release build the `hi - lo + 1` below would wrap and return
    /// an arbitrary in-bounds-looking value instead of failing.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi, "range({lo}, {hi}): empty interval");
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[-1, 1)` — the distribution used for GEMM test data.
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 * 2.0 - 1.0
    }

    /// Fill a vector with `n` uniform f32s in `[-1, 1)`.
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.unit_f32()).collect()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        ((self.next_u64() >> 11) as f64 / (1u64 << 53) as f64) < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn unit_f32_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.unit_f32();
            assert!((-1.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f32_vec_len() {
        assert_eq!(Rng::new(1).f32_vec(17).len(), 17);
    }
}

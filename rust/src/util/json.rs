//! Tiny JSON writer (serde is unavailable offline).
//!
//! Only what the report/bench layers need: building objects/arrays of
//! numbers and strings and rendering them compactly or pretty. No parser —
//! nothing in the system reads JSON back.

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a field (object builder, chainable).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value.into()));
        } else {
            panic!("field() on non-object Json");
        }
        self
    }

    /// Push an element (array builder, chainable).
    pub fn push(mut self, value: impl Into<Json>) -> Json {
        if let Json::Arr(ref mut items) = self {
            items.push(value.into());
        } else {
            panic!("push() on non-array Json");
        }
        self
    }

    /// Compact rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj()
            .field("name", "summa")
            .field("tflops", 1234.5)
            .field("steps", 56usize)
            .field("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"summa","tflops":1234.5,"steps":56,"ok":true}"#
        );
    }

    #[test]
    fn arrays_and_nesting() {
        let j = Json::arr().push(1i64).push(Json::obj().field("x", Json::Null));
        assert_eq!(j.render(), r#"[1,{"x":null}]"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn pretty_is_reparseable_shape() {
        let j = Json::obj().field("a", Json::arr().push(1i64).push(2i64));
        let p = j.pretty();
        assert!(p.contains("\n"), "{p}");
        assert!(p.contains("\"a\": ["), "{p}");
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(42.5).render(), "42.5");
    }
}

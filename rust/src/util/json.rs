//! Tiny JSON reader/writer (serde is unavailable offline).
//!
//! Only what the report/bench/gate layers need: building objects/arrays of
//! numbers and strings, rendering them compactly or pretty, and parsing
//! them back ([`Json::parse`]) so the CI perf-regression gate can compare
//! `BENCH_results.json` against a committed baseline.

use std::fmt::Write as _;

/// A JSON value under construction.
///
/// Numbers have two representations: [`Json::Num`] (f64) for measured /
/// derived quantities, and [`Json::Int`] (i128) for counters that must
/// round-trip **exactly**. An f64 only has 53 mantissa bits, so a `u64`
/// byte counter above 2^53 stored as `Num` silently loses its low bits —
/// the persistent simulation cache carries such counters, so integer
/// sources (`u64`/`i64`/`usize` conversions, integer-syntax parse input)
/// land in `Int` and keep full fidelity. The two kinds still compare
/// equal when they denote exactly the same value (`Int(42) == Num(42.0)`)
/// so existing callers that mix them keep working.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i128),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Int(a), Json::Int(b)) => a == b,
            // Cross-representation: equal only when the integer is
            // *exactly* the float's value (the round-trip check rejects
            // integers an f64 cannot represent, e.g. 2^53 + 1). The
            // explicit range guard matters at the extreme: `f as i128`
            // saturates, so without it Int(i128::MAX) would compare equal
            // to any Num >= 2^127.
            (Json::Num(f), Json::Int(i)) | (Json::Int(i), Json::Num(f)) => {
                let lim = 2f64.powi(127); // i128 range is [-2^127, 2^127)
                *i as f64 == *f && *f >= -lim && *f < lim && *i == *f as i128
            }
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Arr(Vec::new())
    }

    /// Insert a field (object builder, chainable).
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value.into()));
        } else {
            panic!("field() on non-object Json");
        }
        self
    }

    /// Push an element (array builder, chainable).
    pub fn push(mut self, value: impl Into<Json>) -> Json {
        if let Json::Arr(ref mut items) = self {
            items.push(value.into());
        } else {
            panic!("push() on non-array Json");
        }
        self
    }

    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array elements; `None` on non-arrays.
    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Numeric view. Lossy for an `Int` above 2^53 (the f64 nearest to it
    /// is returned); use [`Json::as_u64`] / [`Json::as_i64`] when the
    /// exact value matters.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Exact unsigned-integer view: an `Int` in `u64` range, or a `Num`
    /// whose value is a non-negative whole number (every integral f64 is
    /// exact for the value it actually holds). `None` otherwise — never a
    /// silently truncated value.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(v) => u64::try_from(*v).ok(),
            Json::Num(v)
                if v.fract() == 0.0 && *v >= 0.0 && *v < 18_446_744_073_709_551_616.0 =>
            {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    /// Exact signed-integer view (see [`Json::as_u64`]).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => i64::try_from(*v).ok(),
            Json::Num(v)
                if v.fract() == 0.0
                    && *v >= -9_223_372_036_854_775_808.0
                    && *v < 9_223_372_036_854_775_808.0 =>
            {
                Some(*v as i64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document (the subset this writer emits, which is plain
    /// standard JSON). Numbers with integer syntax (no `.`/`e`/`E`)
    /// become exact [`Json::Int`] values; everything else becomes `f64`.
    /// `\uXXXX` escapes are decoded (surrogate pairs included). Trailing
    /// non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json, JsonParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Compact rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty rendering with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    if *v == v.trunc() && v.abs() < 1e15 {
                        let _ = write!(out, "{}", *v as i64);
                    } else {
                        // Rust's float Display is the shortest string that
                        // round-trips to the same bits, so Num survives a
                        // render/parse cycle exactly.
                        let _ = write!(out, "{v}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Int(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    Json::Str(k.clone()).write(out, None, 0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i128)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Int(v as i128)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v as i128)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

/// JSON parse error with byte offset.
#[derive(Debug)]
pub struct JsonParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl Into<String>) -> JsonParseError {
        JsonParseError { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonParseError> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character {:?}", c as char))),
        }
    }

    fn number(&mut self) -> Result<Json, JsonParseError> {
        let start = self.pos;
        let mut float_syntax = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                if matches!(c, b'.' | b'e' | b'E') {
                    float_syntax = true;
                }
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii slice");
        if !float_syntax {
            // Integer syntax parses exactly (u64 counters above 2^53 must
            // not round); anything beyond i128 falls through to f64.
            if let Ok(v) = s.parse::<i128>() {
                return Ok(Json::Int(v));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonParseError { offset: start, msg: format!("bad number {s:?}") })
    }

    fn hex4(&mut self) -> Result<u32, JsonParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("non-ASCII in \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> Result<String, JsonParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("truncated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: expect a following \uXXXX.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("invalid \\u code point"))?,
                            );
                        }
                        other => {
                            return Err(self.err(format!("bad escape \\{}", other as char)));
                        }
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(_) => {
                    // Copy one UTF-8 scalar (multi-byte sequences intact).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let ch = rest.chars().next().expect("peeked non-empty");
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj()
            .field("name", "summa")
            .field("tflops", 1234.5)
            .field("steps", 56usize)
            .field("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"summa","tflops":1234.5,"steps":56,"ok":true}"#
        );
    }

    #[test]
    fn arrays_and_nesting() {
        let j = Json::arr().push(1i64).push(Json::obj().field("x", Json::Null));
        assert_eq!(j.render(), r#"[1,{"x":null}]"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn pretty_is_reparseable_shape() {
        let j = Json::obj().field("a", Json::arr().push(1i64).push(2i64));
        let p = j.pretty();
        assert!(p.contains("\n"), "{p}");
        assert!(p.contains("\"a\": ["), "{p}");
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(42.5).render(), "42.5");
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
        // Integer syntax lands in the exact representation; float syntax
        // (even with a whole value) stays f64.
        assert!(matches!(Json::parse("42").unwrap(), Json::Int(42)));
        assert!(matches!(Json::parse("-7").unwrap(), Json::Int(-7)));
        assert!(matches!(Json::parse("42.0").unwrap(), Json::Num(_)));
        assert!(matches!(Json::parse("1e3").unwrap(), Json::Num(_)));
    }

    #[test]
    fn cross_representation_equality_is_exact() {
        assert_eq!(Json::Int(42), Json::Num(42.0));
        assert_eq!(Json::Num(42.0), Json::Int(42));
        assert_ne!(Json::Int(42), Json::Num(42.5));
        // 2^53 + 1 is NOT representable as f64: the nearest float is 2^53,
        // and equality must not pretend otherwise.
        assert_ne!(Json::Int(9_007_199_254_740_993), Json::Num(9_007_199_254_740_992.0));
        assert_eq!(Json::Int(9_007_199_254_740_992), Json::Num(9_007_199_254_740_992.0));
        // At the i128 boundary the saturating float->int cast must not
        // fake equality: 2^127 is outside i128 range, so Int(i128::MAX)
        // equals no float at all.
        assert_ne!(Json::Int(i128::MAX), Json::Num(2f64.powi(127)));
        assert_ne!(Json::Int(i128::MAX), Json::Num(f64::INFINITY));
        assert_eq!(Json::Int(i128::MIN), Json::Num(-(2f64.powi(127))), "-2^127 is exact");
    }

    #[test]
    fn u64_counters_roundtrip_exactly_at_the_2_53_boundary() {
        // Regression: these used to go through f64, so 2^53 + 1 silently
        // collapsed to 2^53 on a render/parse cycle — fatal for the
        // persistent cache's byte counters.
        let boundary: u64 = 1 << 53;
        for v in [boundary - 1, boundary, boundary + 1, u64::MAX] {
            let j = Json::from(v);
            let back = Json::parse(&j.render()).unwrap();
            assert_eq!(back.as_u64(), Some(v), "{v}");
            let pretty = Json::parse(&Json::obj().field("v", v).pretty()).unwrap();
            assert_eq!(pretty.get("v").and_then(Json::as_u64), Some(v), "{v}");
        }
        assert_eq!(Json::parse("9007199254740993").unwrap().as_u64(), Some(9_007_199_254_740_993));
        assert_eq!(Json::parse("18446744073709551615").unwrap().as_u64(), Some(u64::MAX));
        // Signed boundary values survive too.
        for v in [i64::MIN, -(1 << 53) - 1, i64::MAX] {
            let back = Json::parse(&Json::from(v).render()).unwrap();
            assert_eq!(back.as_i64(), Some(v), "{v}");
        }
    }

    #[test]
    fn exact_accessors_refuse_lossy_reads() {
        // A huge Num holds an integral value (every f64 >= 2^52 is whole),
        // so the exact accessors accept it for the value it holds...
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), Some(1 << 53));
        // ...but reject non-integers, negatives (for u64), and
        // out-of-range values instead of truncating.
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Int(-1).as_u64(), None);
        assert_eq!(Json::Int(i128::from(u64::MAX) + 1).as_u64(), None);
        assert_eq!(Json::Int(i128::from(i64::MAX) + 1).as_i64(), None);
        assert_eq!(Json::Num(f64::NAN).as_u64(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_i64(), None);
        // as_f64 stays available as the (possibly lossy) numeric view.
        assert_eq!(Json::Int(3).as_f64(), Some(3.0));
    }

    #[test]
    fn parse_nested_and_whitespace() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5 , \"x\" ] , \"b\" : { } }\n").unwrap();
        assert_eq!(j.get("a").unwrap().items().unwrap().len(), 3);
        assert_eq!(j.get("a").unwrap().items().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(j.get("b").unwrap(), &Json::obj());
        assert!(j.get("missing").is_none());
    }

    #[test]
    fn parse_string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA\u{e9}"));
        // Surrogate pair escape: U+1F600; raw multi-byte UTF-8 also survives.
        assert_eq!(Json::parse(r#""\ud83d\ude00""#).unwrap().as_str(), Some("\u{1f600}"));
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str(), Some("\u{1f600}"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone surrogate");
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_render_parse() {
        let j = Json::obj()
            .field("name", "fig9")
            .field("value", 1234.5)
            .field("flags", Json::arr().push(true).push(Json::Null))
            .field("nested", Json::obj().field("k", -2i64));
        for text in [j.render(), j.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j, "{text}");
        }
    }

    #[test]
    fn accessors_on_wrong_kinds() {
        assert!(Json::Null.get("x").is_none());
        assert!(Json::Num(1.0).items().is_none());
        assert!(Json::Bool(true).as_f64().is_none());
        assert!(Json::Num(1.0).as_str().is_none());
        assert_eq!(Json::Bool(false).as_bool(), Some(false));
        assert!(Json::Str("7".into()).as_u64().is_none());
        assert!(Json::Null.as_i64().is_none());
    }
}

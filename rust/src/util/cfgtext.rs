//! Minimal config-text parser (TOML subset) for architecture files.
//!
//! Grammar (one statement per line):
//!
//! ```text
//! # comment
//! [section]
//! key = 42            # integer
//! key2 = 1.5          # float
//! key3 = "string"     # string
//! key4 = true         # bool
//! key5 = [1, 2, 3]    # integer list
//! ```
//!
//! Just enough for `configs/*.dit` architecture descriptions; no nesting, no
//! dotted keys, no dates. Unknown keys are preserved so callers can reject
//! or ignore them explicitly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar/list value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    IntList(Vec<i64>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(v) => write!(f, "{v}"),
            Value::Float(v) => write!(f, "{v}"),
            Value::Str(v) => write!(f, "\"{v}\""),
            Value::Bool(v) => write!(f, "{v}"),
            Value::IntList(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse error with line information.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: `section -> key -> value`. Keys before any `[section]`
/// land in the `""` section.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| ParseError {
                    line: line_no,
                    msg: format!("unterminated section header: {raw:?}"),
                })?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| ParseError {
                line: line_no,
                msg: format!("expected `key = value`, got {raw:?}"),
            })?;
            let value = parse_value(value.trim()).map_err(|msg| ParseError { line: line_no, msg })?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// Fetch a value.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Fetch an integer (accepting `Int` only).
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key)? {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    /// Fetch a float (accepting `Float` or `Int`).
    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key)? {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    /// Fetch a string.
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key)? {
            Value::Str(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize back to config text (stable ordering).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (name, entries) in &self.sections {
            if !name.is_empty() {
                out.push_str(&format!("[{name}]\n"));
            }
            for (k, v) in entries {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` inside quoted strings must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated list: {s:?}"))?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(
                part.parse::<i64>()
                    .map_err(|_| format!("bad list item: {part:?}"))?,
            );
        }
        return Ok(Value::IntList(items));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# SoftHier-ish sample
top_key = 3
[grid]
rows = 32
cols = 32            # trailing comment
[tile]
tflops = 1.93
name = "matrix # engine"
enabled = true
dims = [64, 16]
"#;

    #[test]
    fn parse_all_types() {
        let doc = Doc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_int("", "top_key"), Some(3));
        assert_eq!(doc.get_int("grid", "rows"), Some(32));
        assert_eq!(doc.get_f64("tile", "tflops"), Some(1.93));
        assert_eq!(doc.get_str("tile", "name"), Some("matrix # engine"));
        assert_eq!(doc.get("tile", "enabled"), Some(&Value::Bool(true)));
        assert_eq!(
            doc.get("tile", "dims"),
            Some(&Value::IntList(vec![64, 16]))
        );
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 4").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(4.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn unterminated_constructs_rejected() {
        assert!(Doc::parse("[grid").is_err());
        assert!(Doc::parse("s = \"abc").is_err());
        assert!(Doc::parse("l = [1, 2").is_err());
        assert!(Doc::parse("l = [1, x]").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = Doc::parse(SAMPLE).unwrap();
        let doc2 = Doc::parse(&doc.to_text()).unwrap();
        assert_eq!(doc.sections, doc2.sections);
    }
}

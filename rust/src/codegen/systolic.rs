//! Systolic wavefront code generation (paper §3.3.2, Fig. 6b).
//!
//! "A-tiles propagate rightward, B-tiles propagate downward. Computation
//! proceeds as a spatial wavefront driven entirely by nearest-neighbor
//! communication."
//!
//! Tile `(i, j)` computes K-panel `t` at superstep `t + i + j + 1`:
//! operands arrive from the west/north neighbour (or from HBM at the
//! grid edges — which on SoftHier are exactly where the west/south memory
//! controllers sit) one superstep earlier, and are forwarded east/south in
//! the same superstep they are consumed (both only *read* the buffer, so
//! BSP semantics allow the overlap). Tiles therefore do **not** start
//! simultaneously — the pipeline fill/drain of `rows + cols` supersteps is
//! the defining cost difference vs SUMMA analysed in Fig. 7b/8, while the
//! staggered C stores spread HBM bursts in the store-intensive regime.

use crate::collective::TileCoord;
use crate::ir::{Op, Program};

use super::Ctx;

pub fn gen(ctx: &Ctx) -> Vec<Program> {
    let plan = &ctx.plan;
    let (rows, cols) = ctx.sched.logical; // == physical grid (validated)
    let kp = plan.kp;
    let a_bytes = ctx.panel_bytes(plan.tm, plan.tk);
    let b_bytes = ctx.panel_bytes(plan.tk, plan.tn);

    // Tags must match between sender and receiver: key them determinis-
    // tically on (matrix, panel, receiver tile).
    let a_tag = |t: usize, i: usize, j: usize| (((t * rows + i) * cols + j) * 2) as u32;
    let b_tag = |t: usize, i: usize, j: usize| (((t * rows + i) * cols + j) * 2 + 1) as u32;

    let mut programs = Vec::with_capacity(rows * cols);
    for i in 0..rows {
        for j in 0..cols {
            let tile = TileCoord::new(i, j);
            let mut prog = Program::new(tile);
            let a_buf = [prog.buf("a0", a_bytes), prog.buf("a1", a_bytes)];
            let b_buf = [prog.buf("b0", b_bytes), prog.buf("b1", b_bytes)];
            let c_buf = prog.buf("c", ctx.panel_bytes(plan.tm, plan.tn));

            let (r0, r1) = (i * plan.tm, (i + 1) * plan.tm);
            let (c0, c1) = (j * plan.tn, (j + 1) * plan.tn);

            for t in 0..kp {
                let arrive = t + i + j; // operands land at end of this step
                let compute = arrive + 1;
                let (k0, k1) = (t * plan.tk, (t + 1) * plan.tk);
                let ab = a_buf[t % 2];
                let bb = b_buf[t % 2];

                // --- A operand: from HBM (west edge) or west neighbour.
                if j == 0 {
                    prog.push(arrive, Op::DmaIn {
                        runs: ctx.layouts.a.rect_runs(r0, r1, k0, k1),
                        dst: ab,
                    });
                } else {
                    prog.push(arrive, Op::Recv {
                        from: TileCoord::new(i, j - 1),
                        dst: ab,
                        bytes: a_bytes,
                        tag: a_tag(t, i, j),
                    });
                }
                // Forward east while computing (reads only).
                if j + 1 < cols {
                    prog.push(compute, Op::Send {
                        to: TileCoord::new(i, j + 1),
                        src: ab,
                        bytes: a_bytes,
                        tag: a_tag(t, i, j + 1),
                    });
                }

                // --- B operand: from HBM (north edge feed) or north
                // neighbour.
                if i == 0 {
                    prog.push(arrive, Op::DmaIn {
                        runs: ctx.layouts.b.rect_runs(k0, k1, c0, c1),
                        dst: bb,
                    });
                } else {
                    prog.push(arrive, Op::Recv {
                        from: TileCoord::new(i - 1, j),
                        dst: bb,
                        bytes: b_bytes,
                        tag: b_tag(t, i, j),
                    });
                }
                if i + 1 < rows {
                    prog.push(compute, Op::Send {
                        to: TileCoord::new(i + 1, j),
                        src: bb,
                        bytes: b_bytes,
                        tag: b_tag(t, i + 1, j),
                    });
                }

                prog.push(compute, Op::Mmad {
                    a: ab,
                    b: bb,
                    c: c_buf,
                    m: plan.tm,
                    n: plan.tn,
                    k: plan.tk,
                    init: t == 0,
                });
            }

            // Staggered store right after the last compute.
            let last_compute = (kp - 1) + i + j + 1;
            prog.push(last_compute + 1, Op::DmaOut {
                src: c_buf,
                runs: ctx.layouts.c.rect_runs(r0, r1, c0, c1),
            });
            programs.push(prog);
        }
    }
    programs
}

#[cfg(test)]
mod tests {
    use crate::arch::{ArchConfig, GemmShape};
    use crate::codegen::generate;
    use crate::ir::Op;
    use crate::schedule::Schedule;

    #[test]
    fn wavefront_has_fill_and_drain() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let mut sys = Schedule::systolic(&arch, shape);
        sys.tk = 32; // kp = 4
        let mut sum = Schedule::summa(&arch, shape);
        sum.tk = 32;
        let dep_sys = generate(&arch, shape, &sys, 4).unwrap();
        let dep_sum = generate(&arch, shape, &sum, 4).unwrap();
        // Systolic timeline is longer by ~rows+cols supersteps.
        assert!(
            dep_sys.supersteps() >= dep_sum.supersteps() + arch.rows + arch.cols - 4,
            "sys {} vs summa {}",
            dep_sys.supersteps(),
            dep_sum.supersteps()
        );
    }

    #[test]
    fn only_edge_tiles_fetch_operands() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(&arch, shape, &Schedule::systolic(&arch, shape), 4).unwrap();
        for p in &dep.programs {
            let fetches = p
                .steps
                .iter()
                .flat_map(|s| &s.ops)
                .filter(|o| matches!(o, Op::DmaIn { .. }))
                .count();
            let on_edge = p.tile.row == 0 || p.tile.col == 0;
            if on_edge {
                assert!(fetches > 0, "edge tile {} never fetches", p.tile);
            } else {
                assert_eq!(fetches, 0, "interior tile {} fetches from HBM", p.tile);
            }
        }
    }

    #[test]
    fn stores_are_staggered_by_wavefront() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(&arch, shape, &Schedule::systolic(&arch, shape), 4).unwrap();
        let mut steps = std::collections::BTreeSet::new();
        for p in &dep.programs {
            for (i, s) in p.steps.iter().enumerate() {
                if s.ops.iter().any(|o| matches!(o, Op::DmaOut { .. })) {
                    steps.insert(i);
                }
            }
        }
        // 4x4 grid: store steps span (rows-1)+(cols-1)+1 = 7 distinct steps.
        assert_eq!(steps.len(), 7, "{steps:?}");
    }
}

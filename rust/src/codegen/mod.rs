//! Schedule → per-PE IR lowering: the "Generate and Optimize" stage of the
//! DiT workflow (paper Fig. 4).
//!
//! Each dataflow primitive (§3.3.2, Fig. 6) has its own generator:
//!
//! * [`baseline`] — no on-chip sharing; every tile fetches its own panels.
//! * [`summa`] — SUMMA and split-K SUMMA (with cluster remap, pipeline
//!   staging and double-buffering knobs).
//! * [`systolic`] — nearest-neighbour wavefront.
//! * [`hier`] — the two hierarchical compositions (systolic-over-SUMMA and
//!   SUMMA-over-systolic).
//!
//! Generators emit [`Multicast`](crate::ir::Op::Multicast)/[`Reduce`]
//! (crate::ir::Op::Reduce) collectives whenever the group is expressible as
//! a hardware `(S, M)` mask (via [`crate::collective::synthesize`]); when a
//! group is *not* expressible the generator degrades to point-to-point
//! sends, so the cost of collective-unfriendly mappings is visible in the
//! simulation — the mechanism behind the paper's Insight 2.

pub mod baseline;
pub mod hier;
pub mod summa;
pub mod systolic;

use std::cell::Cell;

use crate::arch::{ArchConfig, GemmShape};
use crate::ir::Deployment;
use crate::layout::{ChannelAssign, GemmLayouts, MatrixLayout, Placement};
use crate::schedule::{Dataflow, Plan, Schedule};

/// Shared generator context.
pub struct Ctx<'a> {
    pub arch: &'a ArchConfig,
    pub shape: GemmShape,
    pub sched: &'a Schedule,
    pub plan: Plan,
    /// A/B element width in bytes (perf: `arch.elem_bytes`; functional: 4).
    pub elem: usize,
    pub layouts: GemmLayouts,
    tag: Cell<u32>,
}

impl<'a> Ctx<'a> {
    /// Fresh communication tag (globally unique within the deployment).
    pub fn tag(&self) -> u32 {
        let t = self.tag.get();
        self.tag.set(t + 1);
        t
    }

    /// Bytes of an `r × c` element panel at the generation element width.
    pub fn panel_bytes(&self, r: usize, c: usize) -> u64 {
        (r * c * self.elem) as u64
    }
}

/// Build the HBM layouts a schedule implies (padded dimensions).
///
/// Optimized layouts (§3.2) make the *placement tile equal the fetch unit*
/// and round-robin blocks over every channel; the base layout stores each
/// matrix row-major in a single channel (A→0, B→1, C→2), reproducing the
/// paper's unoptimized reference.
pub fn build_layouts(
    arch: &ArchConfig,
    sched: &Schedule,
    plan: &Plan,
    elem: usize,
) -> GemmLayouts {
    let p = sched.logical.0;
    let q = sched.logical.1;
    let kb = plan.splits * plan.kp; // K-panel blocks across the padded K
    let pad = plan.padded;
    if sched.opt_layout {
        let chans = arch.hbm.num_channels();
        let mut layouts = GemmLayouts {
            a: MatrixLayout {
                base_offset: 0,
                rows: pad.m,
                cols: pad.k,
                elem_bytes: elem,
                split: (p, kb),
                tile: (plan.tm, plan.tk),
                placement: Placement::RowMajor,
                channels: ChannelAssign::RoundRobin { first: 0, count: chans },
            },
            b: MatrixLayout {
                base_offset: 0,
                rows: pad.k,
                cols: pad.n,
                elem_bytes: elem,
                split: (kb, q),
                tile: (plan.tk, plan.tn),
                placement: Placement::RowMajor,
                channels: ChannelAssign::RoundRobin { first: 0, count: chans },
            },
            c: MatrixLayout {
                base_offset: 0,
                rows: pad.m,
                cols: pad.n,
                elem_bytes: elem,
                split: (p, q),
                tile: (plan.tm, plan.tn),
                placement: Placement::RowMajor,
                channels: ChannelAssign::RoundRobin { first: 0, count: chans },
            },
        };
        // Stack A, B, C back-to-back within the shared channels.
        layouts.b.base_offset = layouts.a.max_extent();
        layouts.c.base_offset = layouts.b.base_offset + layouts.b.max_extent();
        layouts
    } else {
        let mut layouts = GemmLayouts {
            a: MatrixLayout::base(pad.m, pad.k, elem, 0),
            b: MatrixLayout::base(pad.k, pad.n, elem, 1 % arch.hbm.num_channels()),
            c: MatrixLayout::base(pad.m, pad.n, elem, 2 % arch.hbm.num_channels()),
        };
        // On small channel counts the base layout wraps onto shared
        // channels: stack to avoid overlap there too.
        layouts.b.base_offset = layouts.a.max_extent();
        layouts.c.base_offset = layouts.b.base_offset + layouts.b.max_extent();
        layouts
    }
}

/// Lower a schedule to a validated [`Deployment`].
///
/// `elem` is the element width to generate at: `arch.elem_bytes` for
/// performance runs, 4 (f32) for functional verification.
pub fn generate(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
    elem: usize,
) -> anyhow::Result<Deployment> {
    sched.validate(arch)?;
    let plan = sched.plan(arch, shape);
    let layouts = build_layouts(arch, sched, &plan, elem);
    layouts.validate()?;
    let ctx = Ctx {
        arch,
        shape,
        sched,
        plan: plan.clone(),
        elem,
        layouts,
        tag: Cell::new(0),
    };
    let programs = match sched.dataflow {
        Dataflow::Baseline => baseline::gen(&ctx),
        Dataflow::Summa | Dataflow::SplitKSumma { .. } => summa::gen(&ctx),
        Dataflow::Systolic => systolic::gen(&ctx),
        Dataflow::SystolicOverSumma { group } => hier::gen_systolic_over_summa(&ctx, group),
        Dataflow::SummaOverSystolic { group } => hier::gen_summa_over_systolic(&ctx, group),
    };
    let dep = Deployment {
        rows: arch.rows,
        cols: arch.cols,
        programs,
        layouts: ctx.layouts,
        shape,
        padded: plan.padded,
        descr: sched.name(),
    };
    crate::ir::validate(arch, &dep)?;
    Ok(dep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::ir::Op;
    use crate::schedule::{candidates, Schedule};

    /// Every candidate schedule for a suite of shapes must lower to a
    /// *valid* deployment whose MMAD flop total covers the padded problem.
    #[test]
    fn all_candidates_lower_and_validate() {
        let arch = ArchConfig::tiny(4, 4);
        for shape in [
            GemmShape::new(64, 64, 64),
            GemmShape::new(128, 96, 256),
            GemmShape::new(32, 264, 512), // flat-ish, ragged N
        ] {
            for sched in candidates(&arch, shape) {
                let dep = generate(&arch, shape, &sched, 4)
                    .unwrap_or_else(|e| panic!("{} on {shape}: {e}", sched.name()));
                let total: f64 = dep.programs.iter().map(|p| p.flops()).sum();
                let padded_flops = dep.padded.flops();
                assert!(
                    (total - padded_flops).abs() < 1e-3,
                    "{}: mmad flops {} != padded {}",
                    sched.name(),
                    total,
                    padded_flops
                );
            }
        }
    }

    /// Every output element must be stored exactly once across the grid.
    #[test]
    fn c_store_coverage() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 64);
        for sched in candidates(&arch, shape) {
            let dep = generate(&arch, shape, &sched, 4).unwrap();
            let stored: u64 = dep
                .programs
                .iter()
                .flat_map(|p| p.steps.iter())
                .flat_map(|s| s.ops.iter())
                .map(|op| match op {
                    Op::DmaOut { runs, .. } => runs
                        .iter()
                        .filter(|r| {
                            dep.layouts.c.channels_used().contains(&r.channel)
                        })
                        .map(|r| r.bytes)
                        .sum::<u64>(),
                    _ => 0,
                })
                .sum();
            let c_bytes = (dep.padded.m * dep.padded.n * 4) as u64;
            assert_eq!(stored, c_bytes, "{}: stored {stored} != C {c_bytes}", sched.name());
        }
    }

    #[test]
    fn collective_schedules_emit_multicasts() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(&arch, shape, &Schedule::summa(&arch, shape), 4).unwrap();
        let n_mc = dep
            .programs
            .iter()
            .flat_map(|p| p.steps.iter())
            .flat_map(|s| s.ops.iter())
            .filter(|op| matches!(op, Op::Multicast { .. }))
            .count();
        assert!(n_mc > 0, "SUMMA must use hardware multicast");
    }

    #[test]
    fn baseline_never_uses_noc() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(&arch, shape, &Schedule::baseline(&arch, shape), 4).unwrap();
        for p in &dep.programs {
            for s in &p.steps {
                for op in &s.ops {
                    assert!(
                        matches!(op, Op::DmaIn { .. } | Op::DmaOut { .. } | Op::Mmad { .. }),
                        "baseline emitted {op:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn splitk_emits_reductions() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 256);
        let sched = Schedule::splitk(&arch, shape, 2);
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        let n_red = dep
            .programs
            .iter()
            .flat_map(|p| p.steps.iter())
            .flat_map(|s| s.ops.iter())
            .filter(|op| matches!(op, Op::Reduce { .. }))
            .count();
        // Every tile contributes one reduction.
        assert_eq!(n_red, arch.num_tiles(), "{}", sched.name());
    }
}

//! SUMMA and split-K SUMMA code generation (paper §3.3.2, Fig. 6a/6e).
//!
//! Per K-panel `t`: the owner tile of each logical row fetches that row's
//! A panel and multicasts it across the row; the owner of each logical
//! column multicasts the B panel down the column; everyone accumulates
//! `C += A_panel @ B_panel`. With split-K, the grid is carved into
//! `S` K-groups (bands of logical rows), each running SUMMA over its own
//! K-slice; partials then meet in a strided-mask NoC reduction whose root
//! (chosen by the [`ReducePolicy`](crate::schedule::ReducePolicy)) commits
//! the output tile to HBM.
//!
//! Knobs handled here:
//! * **double buffering** (§3.3.1) — pipelined fetch/broadcast/compute
//!   (3-stage software pipeline) vs strictly serialized supersteps;
//! * **pipeline stages** (Fig. 8) — logical rows are divided into stage
//!   bands whose timelines are offset by one superstep each, trading
//!   simultaneous-start compute for spread-out HBM store bursts;
//! * **cluster remap** (§3.1.2) — all tile coordinates go through
//!   [`Remap`](crate::schedule::remap::Remap), and collective masks are
//!   synthesized on the *physical* grid.

use crate::collective::{synthesize, TileCoord};
use crate::ir::{BufId, Op, Program};
use crate::schedule::ReducePolicy;

use super::Ctx;

/// Emit a multicast from `root` to `members` if the group is mask-
/// expressible, otherwise degrade to point-to-point sends (Insight 2's
/// fallback). Returns ops to add: (root ops, per-member ops).
pub(crate) fn bcast(
    ctx: &Ctx,
    root: TileCoord,
    members: &[TileCoord],
    src: BufId,
    dst_of: impl Fn(TileCoord) -> BufId,
    bytes: u64,
) -> (Vec<Op>, Vec<(TileCoord, Op)>) {
    let tag = ctx.tag();
    if let Some(mask) = synthesize(members, ctx.arch.rows, ctx.arch.cols) {
        let mut member_ops = Vec::new();
        for &m in members {
            if m != root {
                member_ops.push((m, Op::RecvMulticast { from: root, dst: dst_of(m), bytes, tag }));
            }
        }
        (
            vec![Op::Multicast { src, group: mask, dst: dst_of(root), bytes, tag }],
            member_ops,
        )
    } else {
        // Unicast fallback: one send per non-root member.
        let mut root_ops = Vec::new();
        let mut member_ops = Vec::new();
        for &m in members {
            if m == root {
                continue;
            }
            let t = ctx.tag();
            root_ops.push(Op::Send { to: m, src, bytes, tag: t });
            member_ops.push((m, Op::Recv { from: root, dst: dst_of(m), bytes, tag: t }));
        }
        (root_ops, member_ops)
    }
}

struct TileSlot {
    prog: Program,
    a_f: BufId,
    a_r: Vec<BufId>,
    b_f: BufId,
    b_r: Vec<BufId>,
    c: BufId,
}

pub fn gen(ctx: &Ctx) -> Vec<Program> {
    let plan = &ctx.plan;
    let (p_dim, q_dim) = ctx.sched.logical;
    let splits = plan.splits;
    let db = ctx.sched.double_buffer;
    let nbuf = if db { 2 } else { 1 };
    let stages = ctx.sched.pipeline_stages;
    let band_rows = p_dim.div_ceil(stages);
    // Stage bands are offset by kp/stages supersteps so each band's HBM
    // store burst lands inside the other bands' compute window (Fig. 8b's
    // store-contention relief); for compute-bound shapes the added drain
    // is pure loss (Fig. 8a).
    let stage_stride = (plan.kp / stages).max(1);

    let a_bytes = ctx.panel_bytes(plan.tm, plan.tk);
    let b_bytes = ctx.panel_bytes(plan.tk, plan.tn);
    // C accumulates at the output element width (the paper's DeepGEMM-
    // style FP8 pipeline stores FP8 C); functional runs are elem=4 (f32).
    let c_bytes = ctx.panel_bytes(plan.tm, plan.tn);
    let c_hbm_bytes = ctx.panel_bytes(plan.tm, plan.tn);

    // Index: slot[s][p][q]
    let mut slots: Vec<Vec<Vec<TileSlot>>> = (0..splits)
        .map(|s| {
            (0..p_dim)
                .map(|p| {
                    (0..q_dim)
                        .map(|q| {
                            let tile = plan.remap.to_phys(s * p_dim + p, q);
                            let mut prog = Program::new(tile);
                            // Staging buffers are single: a tile owns every
                            // Q-th (resp. band-th) panel, and BSP entry-state
                            // semantics let a fetch overwrite the buffer in
                            // the superstep after its broadcast read.
                            let a_f = prog.buf("a_f", a_bytes);
                            let a_r =
                                (0..nbuf).map(|i| prog.buf(format!("a_r{i}"), a_bytes)).collect();
                            let b_f = prog.buf("b_f", b_bytes);
                            let b_r =
                                (0..nbuf).map(|i| prog.buf(format!("b_r{i}"), b_bytes)).collect();
                            let c = prog.buf("c", c_bytes);
                            TileSlot { prog, a_f, a_r, b_f, b_r, c }
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    // Timeline per panel t (band offset `off`):
    //   db:  fetch @ off+t,   bcast @ off+t+1,   mmad @ off+t+2
    //   !db: fetch @ off+3t,  bcast @ off+3t+1,  mmad @ off+3t+2
    let fetch_step = |off: usize, t: usize| if db { off + t } else { off + 3 * t };
    let bcast_step = |off: usize, t: usize| fetch_step(off, t) + 1;
    let mmad_step = |off: usize, t: usize| fetch_step(off, t) + 2;
    let epilogue = |off: usize| {
        if db {
            off + plan.kp + 2
        } else {
            off + 3 * (plan.kp - 1) + 3
        }
    };

    for s in 0..splits {
        for p in 0..p_dim {
            let off = (p / band_rows) * stage_stride; // pipeline-stage offset
            let band = p / band_rows;
            let band_start = band * band_rows;
            let rows_in_band = band_rows.min(p_dim - band_start);
            for q in 0..q_dim {
                let (r0, r1) = (p * plan.tm, (p + 1) * plan.tm);
                let (cc0, cc1) = (q * plan.tn, (q + 1) * plan.tn);

                for t in 0..plan.kp {
                    // Global K range of this group's panel t.
                    let k0 = (s * plan.kp + t) * plan.tk;
                    let k1 = k0 + plan.tk;
                    let buf = t % nbuf;

                    // ---- A: row owner fetches + broadcasts along the row.
                    let a_owner_q = t % q_dim;
                    if q == a_owner_q {
                        let src = slots[s][p][q].a_f;
                        let dst_self = slots[s][p][q].a_r[buf];
                        slots[s][p][q].prog.push(fetch_step(off, t), Op::DmaIn {
                            runs: ctx.layouts.a.rect_runs(r0, r1, k0, k1),
                            dst: src,
                        });
                        let members: Vec<TileCoord> = (0..q_dim)
                            .map(|qq| plan.remap.to_phys(s * p_dim + p, qq))
                            .collect();
                        let root = plan.remap.to_phys(s * p_dim + p, q);
                        let (root_ops, member_ops) =
                            bcast(ctx, root, &members, src, |_| dst_self, a_bytes);
                        let step = bcast_step(off, t);
                        for op in root_ops {
                            slots[s][p][q].prog.push(step, op);
                        }
                        for (m, op) in member_ops {
                            let (lr, lq) = plan.remap.to_logical(m);
                            // Hard assert: a logical-row mismatch would
                            // retarget the broadcast into another split's
                            // slot and corrupt its accumulator silently.
                            assert_eq!(lr, s * p_dim + p, "broadcast member row mismatch");
                            // Fix dst buffer for the actual member slot.
                            let dst = slots[s][p][lq].a_r[buf];
                            let op = retarget(op, dst);
                            slots[s][p][lq].prog.push(step, op);
                        }
                    }

                    // ---- B: column owner within the stage band.
                    let b_owner_p = band_start + (t % rows_in_band);
                    if p == b_owner_p {
                        let src = slots[s][p][q].b_f;
                        let dst_self = slots[s][p][q].b_r[buf];
                        slots[s][p][q].prog.push(fetch_step(off, t), Op::DmaIn {
                            runs: ctx.layouts.b.rect_runs(k0, k1, cc0, cc1),
                            dst: src,
                        });
                        let members: Vec<TileCoord> = (band_start..band_start + rows_in_band)
                            .map(|pp| plan.remap.to_phys(s * p_dim + pp, q))
                            .collect();
                        let root = plan.remap.to_phys(s * p_dim + p, q);
                        let (root_ops, member_ops) =
                            bcast(ctx, root, &members, src, |_| dst_self, b_bytes);
                        let step = bcast_step(off, t);
                        for op in root_ops {
                            slots[s][p][q].prog.push(step, op);
                        }
                        for (m, op) in member_ops {
                            let (lr, lq) = plan.remap.to_logical(m);
                            let pp = lr - s * p_dim;
                            let dst = slots[s][pp][lq].b_r[buf];
                            let op = retarget(op, dst);
                            slots[s][pp][lq].prog.push(step, op);
                        }
                    }

                    // ---- Compute.
                    let slot = &mut slots[s][p][q];
                    slot.prog.push(mmad_step(off, t), Op::Mmad {
                        a: slot.a_r[buf],
                        b: slot.b_r[buf],
                        c: slot.c,
                        m: plan.tm,
                        n: plan.tn,
                        k: plan.tk,
                        init: t == 0,
                    });
                }

                // ---- Epilogue: direct store, or split-K reduction + store.
                let ep = epilogue(off);
                if splits == 1 {
                    let slot = &mut slots[s][p][q];
                    slot.prog.push(ep, Op::DmaOut {
                        src: slot.c,
                        runs: ctx.layouts.c.rect_runs(r0, r1, cc0, cc1),
                    });
                } else if s == 0 {
                    // Emit the reduction once per (p, q): all K-groups join.
                    let members: Vec<TileCoord> =
                        (0..splits).map(|ss| plan.remap.to_phys(ss * p_dim + p, q)).collect();
                    let root_s = match ctx.sched.reduce_policy {
                        ReducePolicy::FirstGroup => 0,
                        ReducePolicy::RoundRobin => (p * q_dim + q) % splits,
                    };
                    let root = members[root_s];
                    let mask = synthesize(&members, ctx.arch.rows, ctx.arch.cols)
                        .unwrap_or_else(|| {
                            panic!("split-K reduce group not mask-expressible: {members:?}")
                        });
                    let tag = ctx.tag();
                    for (ss, &m) in members.iter().enumerate() {
                        let slot = &mut slots[ss][p][q];
                        // Hard assert: pushing the Reduce onto a slot whose
                        // program belongs to a different tile would deadlock
                        // the collective at simulation time, far from here.
                        assert_eq!(slot.prog.tile, m, "split-K reduce slot/tile mismatch");
                        // In-place reduction: the root's own C accumulator
                        // receives the combined sum at the barrier.
                        slot.prog.push(ep, Op::Reduce {
                            group: mask,
                            root,
                            src: slot.c,
                            dst: slot.c,
                            bytes: c_hbm_bytes,
                            tag,
                        });
                        if m == root {
                            slot.prog.push(ep + 1, Op::DmaOut {
                                src: slot.c,
                                runs: ctx.layouts.c.rect_runs(r0, r1, cc0, cc1),
                            });
                        }
                    }
                }
            }
        }
    }

    slots
        .into_iter()
        .flatten()
        .flatten()
        .map(|s| s.prog)
        .collect()
}

/// Replace the destination buffer of a Recv/RecvMulticast op.
fn retarget(op: Op, dst: BufId) -> Op {
    match op {
        Op::RecvMulticast { from, bytes, tag, .. } => Op::RecvMulticast { from, dst, bytes, tag },
        Op::Recv { from, bytes, tag, .. } => Op::Recv { from, dst, bytes, tag },
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use crate::arch::{ArchConfig, GemmShape};
    use crate::codegen::generate;
    use crate::ir::Op;
    use crate::schedule::Schedule;

    #[test]
    fn summa_reuses_panels_via_broadcast() {
        // SUMMA fetches each operand byte exactly once (per padded matrix).
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(&arch, shape, &Schedule::summa(&arch, shape), 4).unwrap();
        let in_bytes: u64 = dep
            .programs
            .iter()
            .flat_map(|p| p.steps.iter())
            .flat_map(|s| s.ops.iter())
            .map(|op| match op {
                Op::DmaIn { runs, .. } => runs.iter().map(|r| r.bytes).sum::<u64>(),
                _ => 0,
            })
            .sum();
        let compulsory = ((dep.padded.m + dep.padded.n) * dep.padded.k * 4) as u64;
        assert_eq!(in_bytes, compulsory);
    }

    #[test]
    fn pipeline_stages_stagger_stores() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let mut sched = Schedule::summa(&arch, shape);
        sched.pipeline_stages = 2;
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        // Stores from different stage bands land in different supersteps.
        let mut store_steps = std::collections::BTreeSet::new();
        for p in &dep.programs {
            for (i, s) in p.steps.iter().enumerate() {
                if s.ops.iter().any(|o| matches!(o, Op::DmaOut { .. })) {
                    store_steps.insert(i);
                }
            }
        }
        assert!(store_steps.len() >= 2, "{store_steps:?}");
    }

    #[test]
    fn splitk_roundrobin_spreads_reduce_roots() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(32, 64, 256);
        let sched = Schedule::splitk(&arch, shape, 2);
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        let mut roots = std::collections::BTreeSet::new();
        for p in &dep.programs {
            for s in &p.steps {
                for op in &s.ops {
                    if let Op::Reduce { root, .. } = op {
                        roots.insert((root.row, root.col));
                    }
                }
            }
        }
        // RoundRobin policy must use more than one root tile row.
        let rows: std::collections::BTreeSet<usize> = roots.iter().map(|r| r.0).collect();
        assert!(rows.len() > 1, "{roots:?}");
    }

    #[test]
    fn flat_remap_generates_valid_summa() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(16, 264, 512); // flat, ragged N
        let sched = Schedule::flat_remap(&arch, shape, 4);
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        assert!(dep.programs.len() == 16);
        assert!(dep.supersteps() > 0);
    }
}

//! Hierarchical schedule code generation (paper §3.3.2, Fig. 6c/6d).
//!
//! Two compositions of the primitive dataflows over a `g × g` group
//! decomposition of the grid:
//!
//! * **Systolic-over-SUMMA** (Fig. 6c): the grid is partitioned into tile
//!   groups; *within* each group every K panel is distributed with SUMMA
//!   broadcasts (rectangle masks), while *across* groups the panels
//!   propagate east/south as a group-granular systolic wavefront.
//! * **SUMMA-over-systolic** (Fig. 6d): each K macro-panel is scattered
//!   from its owner group column/row to *all* groups at once using strided
//!   multicast masks (`col ≡ phase (mod g)` — the flexible mask-based
//!   addressing at work), pre-skewed Cannon-style; groups then perform `g`
//!   local systolic rotation steps with nearest-neighbour (wrapping)
//!   sends.

use std::collections::HashMap;

use crate::collective::{synthesize, Mask, TileCoord};
use crate::ir::{BufId, Op, Program};

use super::Ctx;

struct Grid {
    programs: HashMap<TileCoord, Program>,
    /// Per-tile named buffers.
    bufs: HashMap<(TileCoord, &'static str, usize), BufId>,
}

impl Grid {
    fn new(rows: usize, cols: usize) -> Grid {
        let mut programs = HashMap::new();
        for i in 0..rows {
            for j in 0..cols {
                let t = TileCoord::new(i, j);
                programs.insert(t, Program::new(t));
            }
        }
        Grid { programs, bufs: HashMap::new() }
    }

    fn buf(&mut self, t: TileCoord, name: &'static str, idx: usize, bytes: u64) -> BufId {
        if let Some(b) = self.bufs.get(&(t, name, idx)) {
            return *b;
        }
        let b = self.programs.get_mut(&t).unwrap().buf(format!("{name}{idx}"), bytes);
        self.bufs.insert((t, name, idx), b);
        b
    }

    fn push(&mut self, t: TileCoord, step: usize, op: Op) {
        self.programs.get_mut(&t).unwrap().push(step, op);
    }

    /// Emit a multicast (or unicast fallback) at `step`.
    fn bcast(
        &mut self,
        ctx: &Ctx,
        step: usize,
        root: TileCoord,
        members: &[TileCoord],
        src: BufId,
        dst: impl Fn(TileCoord) -> BufId,
        bytes: u64,
    ) {
        let tag = ctx.tag();
        if let Some(mask) = synthesize(members, ctx.arch.rows, ctx.arch.cols) {
            // If the root is not itself a member (e.g. the Cannon pre-skew
            // scatter), it has no receive buffer: use `src` as a benign
            // placeholder dst (the hardware writes member L1s only).
            let root_dst = if members.contains(&root) { dst(root) } else { src };
            self.push(root, step, Op::Multicast { src, group: mask, dst: root_dst, bytes, tag });
            for &m in members {
                if m != root {
                    self.push(m, step, Op::RecvMulticast { from: root, dst: dst(m), bytes, tag });
                }
            }
        } else {
            for &m in members {
                if m == root {
                    continue;
                }
                let t = ctx.tag();
                self.push(root, step, Op::Send { to: m, src, bytes, tag: t });
                self.push(m, step, Op::Recv { from: root, dst: dst(m), bytes, tag: t });
            }
        }
    }

    /// Emit a point-to-point transfer at `step`.
    fn xfer(
        &mut self,
        ctx: &Ctx,
        step: usize,
        from: TileCoord,
        to: TileCoord,
        src: BufId,
        dst: BufId,
        bytes: u64,
    ) {
        let tag = ctx.tag();
        self.push(from, step, Op::Send { to, src, bytes, tag });
        self.push(to, step, Op::Recv { from, dst, bytes, tag });
    }

    fn finish(self) -> Vec<Program> {
        let mut v: Vec<Program> = self.programs.into_values().collect();
        v.sort_by_key(|p| (p.tile.row, p.tile.col));
        v
    }
}

/// Fig. 6c: outer systolic over groups, inner SUMMA within each group.
pub fn gen_systolic_over_summa(ctx: &Ctx, g: usize) -> Vec<Program> {
    let plan = &ctx.plan;
    let (rows, cols) = ctx.sched.logical;
    let (gr, gc) = (rows / g, cols / g); // group-grid dimensions
    let kp = plan.kp;
    let a_bytes = ctx.panel_bytes(plan.tm, plan.tk);
    let b_bytes = ctx.panel_bytes(plan.tk, plan.tn);
    let c_bytes = ctx.panel_bytes(plan.tm, plan.tn);

    let mut grid = Grid::new(rows, cols);
    // Declare buffers up front (deterministic ids).
    for i in 0..rows {
        for j in 0..cols {
            let t = TileCoord::new(i, j);
            for idx in 0..2 {
                grid.buf(t, "a_f", idx, a_bytes);
                grid.buf(t, "a_r", idx, a_bytes);
                grid.buf(t, "b_f", idx, b_bytes);
                grid.buf(t, "b_r", idx, b_bytes);
            }
            grid.buf(t, "c", 0, c_bytes);
        }
    }

    for big_i in 0..gr {
        for big_j in 0..gc {
            let d = big_i + big_j; // wavefront delay of this group
            for t in 0..kp {
                let acquire = d + t;
                let exchange = acquire + 1;
                let compute = acquire + 2;
                let buf = t % 2;
                let (k0, k1) = (t * plan.tk, (t + 1) * plan.tk);

                // ---- A owners: one per group row, local column t % g.
                for p_local in 0..g {
                    let i = big_i * g + p_local;
                    let owner = TileCoord::new(i, big_j * g + (t % g));
                    let a_f = grid.buf(owner, "a_f", buf, a_bytes);
                    if big_j == 0 {
                        let (r0, r1) = (i * plan.tm, (i + 1) * plan.tm);
                        grid.push(owner, acquire, Op::DmaIn {
                            runs: ctx.layouts.a.rect_runs(r0, r1, k0, k1),
                            dst: a_f,
                        });
                    }
                    // Broadcast within the group row.
                    let members: Vec<TileCoord> =
                        (0..g).map(|q| TileCoord::new(i, big_j * g + q)).collect();
                    let dsts: HashMap<TileCoord, BufId> = members
                        .iter()
                        .map(|&m| (m, grid.buf(m, "a_r", buf, a_bytes)))
                        .collect();
                    grid.bcast(ctx, exchange, owner, &members, a_f, |m| dsts[&m], a_bytes);
                    // Forward to the east group's owner tile.
                    if big_j + 1 < gc {
                        let east_owner = TileCoord::new(i, (big_j + 1) * g + (t % g));
                        let dst = grid.buf(east_owner, "a_f", buf, a_bytes);
                        grid.xfer(ctx, exchange, owner, east_owner, a_f, dst, a_bytes);
                    }
                }

                // ---- B owners: one per group column, local row t % g.
                for q_local in 0..g {
                    let j = big_j * g + q_local;
                    let owner = TileCoord::new(big_i * g + (t % g), j);
                    let b_f = grid.buf(owner, "b_f", buf, b_bytes);
                    if big_i == 0 {
                        let (c0, c1) = (j * plan.tn, (j + 1) * plan.tn);
                        grid.push(owner, acquire, Op::DmaIn {
                            runs: ctx.layouts.b.rect_runs(k0, k1, c0, c1),
                            dst: b_f,
                        });
                    }
                    let members: Vec<TileCoord> =
                        (0..g).map(|p| TileCoord::new(big_i * g + p, j)).collect();
                    let dsts: HashMap<TileCoord, BufId> = members
                        .iter()
                        .map(|&m| (m, grid.buf(m, "b_r", buf, b_bytes)))
                        .collect();
                    grid.bcast(ctx, exchange, owner, &members, b_f, |m| dsts[&m], b_bytes);
                    if big_i + 1 < gr {
                        let south_owner = TileCoord::new((big_i + 1) * g + (t % g), j);
                        let dst = grid.buf(south_owner, "b_f", buf, b_bytes);
                        grid.xfer(ctx, exchange, owner, south_owner, b_f, dst, b_bytes);
                    }
                }

                // ---- Compute on every tile of the group.
                for p_local in 0..g {
                    for q_local in 0..g {
                        let t_coord = TileCoord::new(big_i * g + p_local, big_j * g + q_local);
                        let a_r = grid.buf(t_coord, "a_r", buf, a_bytes);
                        let b_r = grid.buf(t_coord, "b_r", buf, b_bytes);
                        let c = grid.buf(t_coord, "c", 0, c_bytes);
                        grid.push(t_coord, compute, Op::Mmad {
                            a: a_r,
                            b: b_r,
                            c,
                            m: plan.tm,
                            n: plan.tn,
                            k: plan.tk,
                            init: t == 0,
                        });
                    }
                }
            }

            // ---- Stores, staggered by group wavefront.
            for p_local in 0..g {
                for q_local in 0..g {
                    let i = big_i * g + p_local;
                    let j = big_j * g + q_local;
                    let t_coord = TileCoord::new(i, j);
                    let c = grid.buf(t_coord, "c", 0, c_bytes);
                    let (r0, r1) = (i * plan.tm, (i + 1) * plan.tm);
                    let (c0, c1) = (j * plan.tn, (j + 1) * plan.tn);
                    grid.push(t_coord, d + kp + 2, Op::DmaOut {
                        src: c,
                        runs: ctx.layouts.c.rect_runs(r0, r1, c0, c1),
                    });
                }
            }
        }
    }
    grid.finish()
}

/// Fig. 6d: outer SUMMA across groups (strided multicast), inner Cannon
/// rotation within each group.
pub fn gen_summa_over_systolic(ctx: &Ctx, g: usize) -> Vec<Program> {
    let plan = &ctx.plan;
    let (rows, cols) = ctx.sched.logical;
    let (gr, gc) = (rows / g, cols / g);
    let kp = plan.kp;
    assert!(plan.tk % g == 0, "tk {} must divide by group {g}", plan.tk);
    let tks = plan.tk / g; // sub-chunk K depth
    let a_bytes = ctx.panel_bytes(plan.tm, tks);
    let b_bytes = ctx.panel_bytes(tks, plan.tn);
    let c_bytes = ctx.panel_bytes(plan.tm, plan.tn);

    let mut grid = Grid::new(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            let t = TileCoord::new(i, j);
            for idx in 0..2 {
                grid.buf(t, "a_f", idx, a_bytes);
                grid.buf(t, "a_i", idx, a_bytes);
                grid.buf(t, "a_rot", idx, a_bytes);
                grid.buf(t, "b_f", idx, b_bytes);
                grid.buf(t, "b_i", idx, b_bytes);
                grid.buf(t, "b_rot", idx, b_bytes);
            }
            grid.buf(t, "c", 0, c_bytes);
        }
    }

    // Buffer holding the A sub-chunk consumed at rotation step `u`.
    let a_buf_at = |grid: &mut Grid, t: TileCoord, panel: usize, u: usize| {
        if u == 0 {
            grid.buf(t, "a_i", panel % 2, a_bytes)
        } else {
            grid.buf(t, "a_rot", u % 2, a_bytes)
        }
    };
    let b_buf_at = |grid: &mut Grid, t: TileCoord, panel: usize, u: usize| {
        if u == 0 {
            grid.buf(t, "b_i", panel % 2, b_bytes)
        } else {
            grid.buf(t, "b_rot", u % 2, b_bytes)
        }
    };

    for t in 0..kp {
        let fetch = t * g;
        let scatter = fetch + 1;
        let buf = t % 2;

        // ---- A: owner group column t % gc fetches + strided-multicasts.
        let big_j = t % gc;
        for i in 0..rows {
            for u in 0..g {
                let owner = TileCoord::new(i, big_j * g + u);
                let a_f = grid.buf(owner, "a_f", buf, a_bytes);
                let (r0, r1) = (i * plan.tm, (i + 1) * plan.tm);
                let (k0, k1) = (t * plan.tk + u * tks, t * plan.tk + (u + 1) * tks);
                grid.push(owner, fetch, Op::DmaIn {
                    runs: ctx.layouts.a.rect_runs(r0, r1, k0, k1),
                    dst: a_f,
                });
                // Cannon pre-skew: receiver (i, q) wants sub-chunk
                // (i%g + q%g) % g, i.e. q%g == (u - i%g) mod g.
                let phase = (u + g - i % g) % g;
                let members: Vec<TileCoord> = (0..cols)
                    .filter(|q| q % g == phase)
                    .map(|q| TileCoord::new(i, q))
                    .collect();
                let mask = Mask {
                    s_row: i,
                    m_row: crate::collective::full_mask(rows),
                    s_col: phase,
                    m_col: g - 1,
                };
                // Hard assert: a mask that over- or under-covers would
                // silently broadcast to the wrong tile group in release.
                assert!(
                    mask.covers_exactly(&members, rows, cols),
                    "phase-{phase} broadcast mask does not cover its member set"
                );
                let dsts: HashMap<TileCoord, BufId> = members
                    .iter()
                    .map(|&m| (m, grid.buf(m, "a_i", buf, a_bytes)))
                    .collect();
                grid.bcast(ctx, scatter, owner, &members, a_f, |m| dsts[&m], a_bytes);
            }
        }

        // ---- B: owner group row t % gr fetches + strided-multicasts.
        let big_i = t % gr;
        for j in 0..cols {
            for u in 0..g {
                let owner = TileCoord::new(big_i * g + u, j);
                let b_f = grid.buf(owner, "b_f", buf, b_bytes);
                let (k0, k1) = (t * plan.tk + u * tks, t * plan.tk + (u + 1) * tks);
                let (c0, c1) = (j * plan.tn, (j + 1) * plan.tn);
                grid.push(owner, fetch, Op::DmaIn {
                    runs: ctx.layouts.b.rect_runs(k0, k1, c0, c1),
                    dst: b_f,
                });
                // Receiver (p, j) wants sub-chunk (p%g + j%g) % g.
                let phase = (u + g - j % g) % g;
                let members: Vec<TileCoord> = (0..rows)
                    .filter(|p| p % g == phase)
                    .map(|p| TileCoord::new(p, j))
                    .collect();
                let dsts: HashMap<TileCoord, BufId> = members
                    .iter()
                    .map(|&m| (m, grid.buf(m, "b_i", buf, b_bytes)))
                    .collect();
                grid.bcast(ctx, scatter, owner, &members, b_f, |m| dsts[&m], b_bytes);
            }
        }

        // ---- Inner Cannon: g rotation steps per macro panel.
        for u in 0..g {
            let step = fetch + 2 + u;
            for i in 0..rows {
                for j in 0..cols {
                    let tile = TileCoord::new(i, j);
                    let a = a_buf_at(&mut grid, tile, t, u);
                    let b = b_buf_at(&mut grid, tile, t, u);
                    let c = grid.buf(tile, "c", 0, c_bytes);
                    grid.push(tile, step, Op::Mmad {
                        a,
                        b,
                        c,
                        m: plan.tm,
                        n: plan.tn,
                        k: tks,
                        init: t == 0 && u == 0,
                    });
                    if u + 1 < g {
                        // Rotate A west (wrap within group), B north.
                        let gj = j / g;
                        let west = TileCoord::new(i, gj * g + (j % g + g - 1) % g);
                        let a_dst = a_buf_at(&mut grid, west, t, u + 1);
                        grid.xfer(ctx, step, tile, west, a, a_dst, a_bytes);
                        let gi = i / g;
                        let north = TileCoord::new(gi * g + (i % g + g - 1) % g, j);
                        let b_dst = b_buf_at(&mut grid, north, t, u + 1);
                        grid.xfer(ctx, step, tile, north, b, b_dst, b_bytes);
                    }
                }
            }
        }
    }

    // ---- Stores.
    let last = (kp - 1) * g + 2 + (g - 1);
    for i in 0..rows {
        for j in 0..cols {
            let tile = TileCoord::new(i, j);
            let c = grid.buf(tile, "c", 0, c_bytes);
            let (r0, r1) = (i * plan.tm, (i + 1) * plan.tm);
            let (c0, c1) = (j * plan.tn, (j + 1) * plan.tn);
            grid.push(tile, last + 1, Op::DmaOut {
                src: c,
                runs: ctx.layouts.c.rect_runs(r0, r1, c0, c1),
            });
        }
    }
    grid.finish()
}

#[cfg(test)]
mod tests {
    use crate::arch::{ArchConfig, GemmShape};
    use crate::codegen::generate;
    use crate::ir::Op;
    use crate::schedule::{Dataflow, Schedule};

    fn sched_with(arch: &ArchConfig, shape: GemmShape, df: Dataflow) -> Schedule {
        Schedule { dataflow: df, ..Schedule::summa(arch, shape) }
    }

    #[test]
    fn systolic_over_summa_lowers() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(
            &arch,
            shape,
            &sched_with(&arch, shape, Dataflow::SystolicOverSumma { group: 2 }),
            4,
        )
        .unwrap();
        // Every tile computes and the wavefront staggers group stores.
        assert_eq!(dep.programs.len(), 16);
        let mut store_steps = std::collections::BTreeSet::new();
        for p in &dep.programs {
            for (i, s) in p.steps.iter().enumerate() {
                if s.ops.iter().any(|o| matches!(o, Op::DmaOut { .. })) {
                    store_steps.insert(i);
                }
            }
        }
        assert_eq!(store_steps.len(), 3, "{store_steps:?}"); // d in {0,1,2}
    }

    #[test]
    fn summa_over_systolic_uses_strided_masks() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 128);
        let dep = generate(
            &arch,
            shape,
            &sched_with(&arch, shape, Dataflow::SummaOverSystolic { group: 2 }),
            4,
        )
        .unwrap();
        // The outer SUMMA must emit strided multicasts (m_col == g-1 == 1
        // means "cols ≡ phase (mod 2)" — a strided group).
        let strided = dep
            .programs
            .iter()
            .flat_map(|p| p.steps.iter())
            .flat_map(|s| s.ops.iter())
            .any(|op| matches!(op, Op::Multicast { group, .. } if group.m_col == 1 || group.m_row == 1));
        assert!(strided, "no strided multicast found");
    }

    #[test]
    fn hierarchical_flops_match() {
        // (Also covered by the codegen-wide test; kept here for focus.)
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(32, 32, 64);
        for df in [
            Dataflow::SystolicOverSumma { group: 2 },
            Dataflow::SummaOverSystolic { group: 2 },
        ] {
            let dep = generate(&arch, shape, &sched_with(&arch, shape, df), 4).unwrap();
            let total: f64 = dep.programs.iter().map(|p| p.flops()).sum();
            assert!((total - dep.padded.flops()).abs() < 1e-3, "{df:?}");
        }
    }
}

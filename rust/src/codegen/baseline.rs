//! Baseline dataflow: "a reference without specialized data placement or
//! on-chip communication" (paper §4.1.1).
//!
//! Every tile independently DMAs its own A and B panels from HBM each
//! K-step and multiplies locally — the same operand bytes are fetched once
//! *per consumer tile*, so off-chip traffic is `Q×` (for A) and `P×` (for
//! B) the compulsory traffic. On the roofline this is the low-operational-
//! intensity point of Fig. 7a; with the base layout it is additionally
//! bandwidth-starved because all requests hit one channel per matrix.

use crate::collective::TileCoord;
use crate::ir::{Op, Program};

use super::Ctx;

pub fn gen(ctx: &Ctx) -> Vec<Program> {
    let (p_dim, q_dim) = ctx.sched.logical;
    let plan = &ctx.plan;
    let db = ctx.sched.double_buffer;
    let mut programs = Vec::with_capacity(p_dim * q_dim);

    for lp in 0..p_dim {
        for lq in 0..q_dim {
            let tile = plan.remap.to_phys(lp, lq);
            let mut prog = Program::new(tile);

            let a_bytes = ctx.panel_bytes(plan.tm, plan.tk);
            let b_bytes = ctx.panel_bytes(plan.tk, plan.tn);
            let c_bytes = ctx.panel_bytes(plan.tm, plan.tn);
            let nbuf = if db { 2 } else { 1 };
            let a_bufs: Vec<_> = (0..nbuf).map(|i| prog.buf(format!("a{i}"), a_bytes)).collect();
            let b_bufs: Vec<_> = (0..nbuf).map(|i| prog.buf(format!("b{i}"), b_bytes)).collect();
            let c_buf = prog.buf("c", c_bytes);

            let (r0, r1) = (lp * plan.tm, (lp + 1) * plan.tm);
            let (c0, c1) = (lq * plan.tn, (lq + 1) * plan.tn);

            for t in 0..plan.kp {
                let (k0, k1) = (t * plan.tk, (t + 1) * plan.tk);
                let (fetch_step, mmad_step) = if db {
                    // Software pipeline: fetch t while computing t-1.
                    (t, t + 1)
                } else {
                    // Strictly serial: comm and compute never share a step.
                    (2 * t, 2 * t + 1)
                };
                let ab = a_bufs[t % nbuf];
                let bb = b_bufs[t % nbuf];
                prog.push(fetch_step, Op::DmaIn {
                    runs: ctx.layouts.a.rect_runs(r0, r1, k0, k1),
                    dst: ab,
                });
                prog.push(fetch_step, Op::DmaIn {
                    runs: ctx.layouts.b.rect_runs(k0, k1, c0, c1),
                    dst: bb,
                });
                prog.push(mmad_step, Op::Mmad {
                    a: ab,
                    b: bb,
                    c: c_buf,
                    m: plan.tm,
                    n: plan.tn,
                    k: plan.tk,
                    init: t == 0,
                });
            }
            let last = if db { plan.kp + 1 } else { 2 * plan.kp };
            prog.push(last, Op::DmaOut {
                src: c_buf,
                runs: ctx.layouts.c.rect_runs(r0, r1, c0, c1),
            });
            programs.push(prog);
        }
    }
    let _ = TileCoord::new(0, 0); // (import anchor)
    programs
}

#[cfg(test)]
mod tests {
    use crate::arch::{ArchConfig, GemmShape};
    use crate::codegen::generate;
    use crate::ir::Op;
    use crate::schedule::Schedule;

    #[test]
    fn no_double_buffer_serializes_steps() {
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(32, 32, 128);
        let mut sched = Schedule::baseline(&arch, shape);
        sched.tk = 32; // 4 K-panels
        let dep_db = generate(&arch, shape, &sched, 4).unwrap();
        sched.double_buffer = false;
        let dep_nodb = generate(&arch, shape, &sched, 4).unwrap();
        assert!(dep_nodb.supersteps() > dep_db.supersteps());
    }

    #[test]
    fn fetches_cover_panels_redundantly() {
        // Baseline refetches B for every row of tiles: total A+B DMA bytes
        // = Q*|A| + P*|B| (the no-reuse signature).
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(32, 32, 64);
        let sched = Schedule::baseline(&arch, shape);
        let dep = generate(&arch, shape, &sched, 4).unwrap();
        let in_bytes: u64 = dep
            .programs
            .iter()
            .flat_map(|p| p.steps.iter())
            .flat_map(|s| s.ops.iter())
            .map(|op| match op {
                Op::DmaIn { runs, .. } => runs.iter().map(|r| r.bytes).sum::<u64>(),
                _ => 0,
            })
            .sum();
        let a = (dep.padded.m * dep.padded.k * 4) as u64;
        let b = (dep.padded.k * dep.padded.n * 4) as u64;
        assert_eq!(in_bytes, 2 * a + 2 * b);
    }
}

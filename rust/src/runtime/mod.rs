//! PJRT runtime: loads the AOT-compiled JAX/Pallas golden GEMMs and runs
//! them from Rust — Python is never on this path.
//!
//! `make artifacts` lowers `python/compile/model.py` (whose inner tile
//! product is the Layer-1 Pallas MMAD kernel) to HLO **text** files plus a
//! `manifest.txt`; this module compiles them on the PJRT CPU client
//! (`xla` crate) and exposes [`Oracle::gemm`] as the golden-number source
//! the functional executor is checked against.
//!
//! HLO text — not serialized protos — is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A loadable artifact as listed in `manifest.txt`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// Entry point name (`gemm`, `gemm_bias_relu`, …).
    pub entry: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

/// The PJRT-backed correctness oracle.
pub struct Oracle {
    client: xla::PjRtClient,
    dir: PathBuf,
    files: HashMap<ArtifactKey, String>,
    compiled: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
}

impl Oracle {
    /// Open an artifacts directory (parses `manifest.txt`; compiles
    /// executables lazily on first use).
    pub fn open(dir: impl AsRef<Path>) -> Result<Oracle> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {manifest:?} — run `make artifacts` first"))?;
        let mut files = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("bad manifest line: {line:?}");
            }
            let key = ArtifactKey {
                entry: parts[0].to_string(),
                m: parts[1].parse().context("manifest M")?,
                n: parts[2].parse().context("manifest N")?,
                k: parts[3].parse().context("manifest K")?,
            };
            files.insert(key, parts[4].to_string());
        }
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Oracle { client, dir, files, compiled: HashMap::new() })
    }

    /// Default artifacts location (`$DIT_ARTIFACTS` or `./artifacts`).
    pub fn open_default() -> Result<Oracle> {
        let dir =
            std::env::var("DIT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Oracle::open(dir)
    }

    /// Shapes available for an entry point.
    pub fn shapes(&self, entry: &str) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self
            .files
            .keys()
            .filter(|k| k.entry == entry)
            .map(|k| (k.m, k.n, k.k))
            .collect();
        v.sort();
        v
    }

    pub fn has(&self, entry: &str, m: usize, n: usize, k: usize) -> bool {
        self.files.contains_key(&ArtifactKey { entry: entry.into(), m, n, k })
    }

    fn executable(&mut self, key: &ArtifactKey) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(key) {
            let file = self
                .files
                .get(key)
                .with_context(|| format!("no artifact for {key:?}"))?;
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
            self.compiled.insert(key.clone(), exe);
        }
        Ok(self.compiled.get(key).unwrap())
    }

    fn run(&mut self, key: &ArtifactKey, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let exe = self.executable(key)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Golden `C = A @ B` through the Pallas-kerneled XLA executable.
    pub fn gemm(&mut self, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(a.len() == m * k, "A must be {m}x{k}");
        anyhow::ensure!(b.len() == k * n, "B must be {k}x{n}");
        let key = ArtifactKey { entry: "gemm".into(), m, n, k };
        let la = xla::Literal::vec1(a).reshape(&[m as i64, k as i64])?;
        let lb = xla::Literal::vec1(b).reshape(&[k as i64, n as i64])?;
        self.run(&key, &[la, lb])
    }

    /// Golden fused epilogue `relu(A @ B + bias)`.
    pub fn gemm_bias_relu(
        &mut self,
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(bias.len() == n, "bias must be length {n}");
        let key = ArtifactKey { entry: "gemm_bias_relu".into(), m, n, k };
        let la = xla::Literal::vec1(a).reshape(&[m as i64, k as i64])?;
        let lb = xla::Literal::vec1(b).reshape(&[k as i64, n as i64])?;
        let lbias = xla::Literal::vec1(bias);
        self.run(&key, &[la, lb, lbias])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/integration.rs (they need
    // `make artifacts`); here we only test the manifest parser paths that
    // don't require a client... but Oracle::open creates one eagerly, which
    // is cheap on CPU. Missing-artifacts is the one error path that's
    // environment-independent.
    #[test]
    fn open_missing_dir_fails_cleanly() {
        let err = match Oracle::open("/nonexistent/path/xyz") {
            Ok(_) => panic!("open should fail"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}

//! Correctness oracles: the golden-number sources the functional executor
//! is checked against.
//!
//! Two backends sit behind the same [`Oracle`] API:
//!
//! * **PJRT** (cargo feature `pjrt`): loads the AOT-compiled JAX/Pallas
//!   golden GEMMs (`artifacts/*.hlo.txt` + `manifest.txt`, produced by
//!   `make artifacts`) and runs them on the PJRT CPU client via the `xla`
//!   crate — Python is never on this path. HLO text, not serialized
//!   protos, is the interchange format: jax ≥ 0.5 emits 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects; the text parser
//!   reassigns ids (see /opt/xla-example/README.md). The feature is off
//!   by default because the `xla` crate is not available everywhere; see
//!   `Cargo.toml`.
//! * **CPU reference** ([`Oracle::cpu_reference`]): an always-available
//!   double-precision-accumulation GEMM over the same artifact shape
//!   families. Accumulating in f64 makes it numerically independent of
//!   the f32 accumulation order used by both the functional executor and
//!   the Pallas kernel, so it still exposes data-movement bugs (wrong
//!   element, wrong tile, dropped K-panel) even without PJRT.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// A loadable artifact as listed in `manifest.txt`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// Entry point name (`gemm`, `gemm_bias_relu`, …).
    pub entry: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

/// The verification shape families baked into the CPU reference oracle —
/// mirrors `python/compile/aot.py::GEMM_SHAPES` so the no-artifacts test
/// path covers the same geometry (square, ragged TN=66, flat decode).
const CPU_GEMM_SHAPES: &[(usize, usize, usize)] = &[
    (64, 64, 64),
    (128, 128, 128),
    (256, 256, 256),
    (128, 384, 256),
    (64, 528, 512), // flat-GEMM analogue (LLM decode, Fig. 7d geometry)
    (96, 66, 128),  // ragged: 66 = 2112/32, the paper's §4.1.3 example
    (256, 192, 512),
];

/// Mirrors `python/compile/aot.py::EPILOGUE_SHAPES`.
const CPU_EPILOGUE_SHAPES: &[(usize, usize, usize)] = &[(64, 64, 64), (128, 96, 64)];

enum Backend {
    /// f64-accumulation CPU reference; always available.
    Cpu,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::Pjrt),
}

/// A correctness oracle (PJRT-backed or CPU reference).
pub struct Oracle {
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    dir: PathBuf,
    files: HashMap<ArtifactKey, String>,
    backend: Backend,
}

/// Parse `manifest.txt` into artifact-key → file-name entries.
fn parse_manifest(text: &str) -> Result<HashMap<ArtifactKey, String>> {
    let mut files = HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 5 {
            bail!("bad manifest line: {line:?}");
        }
        let key = ArtifactKey {
            entry: parts[0].to_string(),
            m: parts[1].parse().context("manifest M")?,
            n: parts[2].parse().context("manifest N")?,
            k: parts[3].parse().context("manifest K")?,
        };
        files.insert(key, parts[4].to_string());
    }
    Ok(files)
}

impl Oracle {
    /// Open an artifacts directory (parses `manifest.txt`; compiles
    /// executables lazily on first use). Requires the `pjrt` feature —
    /// without it this returns an error explaining the fallback.
    pub fn open(dir: impl AsRef<Path>) -> Result<Oracle> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {manifest:?} — run `make artifacts` first"))?;
        let files = parse_manifest(&text)?;
        #[cfg(feature = "pjrt")]
        {
            Ok(Oracle { dir, files, backend: Backend::Pjrt(pjrt::Pjrt::new()?) })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = files;
            bail!(
                "artifacts present at {dir:?} but dit was built without the `pjrt` \
                 feature; add the `xla` dependency to rust/Cargo.toml and rebuild \
                 with `--features pjrt`, or use Oracle::cpu_reference()"
            )
        }
    }

    /// Default artifacts location (`$DIT_ARTIFACTS` or `./artifacts`).
    pub fn open_default() -> Result<Oracle> {
        let dir = std::env::var("DIT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Oracle::open(dir)
    }

    /// The pure-CPU reference oracle: computes golden numbers with f64
    /// accumulation over the builtin verification shape families. Always
    /// available — no artifacts, no PJRT, no Python.
    pub fn cpu_reference() -> Oracle {
        let mut files = HashMap::new();
        for &(m, n, k) in CPU_GEMM_SHAPES {
            files.insert(ArtifactKey { entry: "gemm".into(), m, n, k }, String::new());
        }
        for &(m, n, k) in CPU_EPILOGUE_SHAPES {
            files.insert(ArtifactKey { entry: "gemm_bias_relu".into(), m, n, k }, String::new());
        }
        Oracle { dir: PathBuf::new(), files, backend: Backend::Cpu }
    }

    /// Is this the CPU reference backend (vs PJRT-backed)?
    pub fn is_cpu_reference(&self) -> bool {
        matches!(self.backend, Backend::Cpu)
    }

    /// Shapes available for an entry point.
    pub fn shapes(&self, entry: &str) -> Vec<(usize, usize, usize)> {
        let mut v: Vec<_> = self
            .files
            .keys()
            .filter(|k| k.entry == entry)
            .map(|k| (k.m, k.n, k.k))
            .collect();
        v.sort();
        v
    }

    /// Can this oracle produce golden numbers for a shape? The CPU
    /// reference can compute anything; PJRT needs a compiled artifact.
    pub fn has(&self, entry: &str, m: usize, n: usize, k: usize) -> bool {
        if self.is_cpu_reference() {
            return true;
        }
        self.files.contains_key(&ArtifactKey { entry: entry.into(), m, n, k })
    }

    /// Golden `C = A @ B`.
    pub fn gemm(&mut self, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(a.len() == m * k, "A must be {m}x{k}");
        anyhow::ensure!(b.len() == k * n, "B must be {k}x{n}");
        match &mut self.backend {
            Backend::Cpu => Ok(cpu_gemm(m, n, k, a, b, None)),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.gemm(&self.dir, &self.files, m, n, k, a, b),
        }
    }

    /// Golden fused epilogue `relu(A @ B + bias)`.
    pub fn gemm_bias_relu(
        &mut self,
        m: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(a.len() == m * k, "A must be {m}x{k}");
        anyhow::ensure!(b.len() == k * n, "B must be {k}x{n}");
        anyhow::ensure!(bias.len() == n, "bias must be length {n}");
        match &mut self.backend {
            Backend::Cpu => Ok(cpu_gemm(m, n, k, a, b, Some(bias))),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.gemm_bias_relu(&self.dir, &self.files, m, n, k, a, b, bias),
        }
    }
}

/// f64-accumulation reference GEMM (with optional bias+ReLU epilogue).
fn cpu_gemm(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias_relu: Option<&[f32]>,
) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            if let Some(bias) = bias_relu {
                acc = (acc + bias[j] as f64).max(0.0);
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

/// The PJRT-backed executor (requires the `xla` crate; see Cargo.toml).
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{Context, Result};

    use super::ArtifactKey;

    pub struct Pjrt {
        client: xla::PjRtClient,
        compiled: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
    }

    impl Pjrt {
        pub fn new() -> Result<Pjrt> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Pjrt { client, compiled: HashMap::new() })
        }

        fn executable(
            &mut self,
            dir: &Path,
            files: &HashMap<ArtifactKey, String>,
            key: &ArtifactKey,
        ) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.compiled.contains_key(key) {
                let file = files.get(key).with_context(|| format!("no artifact for {key:?}"))?;
                let path = dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("parse HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe =
                    self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
                self.compiled.insert(key.clone(), exe);
            }
            Ok(self.compiled.get(key).unwrap())
        }

        fn run(
            &mut self,
            dir: &Path,
            files: &HashMap<ArtifactKey, String>,
            key: &ArtifactKey,
            inputs: &[xla::Literal],
        ) -> Result<Vec<f32>> {
            let exe = self.executable(dir, files, key)?;
            let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        #[allow(clippy::too_many_arguments)]
        pub fn gemm(
            &mut self,
            dir: &Path,
            files: &HashMap<ArtifactKey, String>,
            m: usize,
            n: usize,
            k: usize,
            a: &[f32],
            b: &[f32],
        ) -> Result<Vec<f32>> {
            let key = ArtifactKey { entry: "gemm".into(), m, n, k };
            let la = xla::Literal::vec1(a).reshape(&[m as i64, k as i64])?;
            let lb = xla::Literal::vec1(b).reshape(&[k as i64, n as i64])?;
            self.run(dir, files, &key, &[la, lb])
        }

        #[allow(clippy::too_many_arguments)]
        pub fn gemm_bias_relu(
            &mut self,
            dir: &Path,
            files: &HashMap<ArtifactKey, String>,
            m: usize,
            n: usize,
            k: usize,
            a: &[f32],
            b: &[f32],
            bias: &[f32],
        ) -> Result<Vec<f32>> {
            let key = ArtifactKey { entry: "gemm_bias_relu".into(), m, n, k };
            let la = xla::Literal::vec1(a).reshape(&[m as i64, k as i64])?;
            let lb = xla::Literal::vec1(b).reshape(&[k as i64, n as i64])?;
            let lbias = xla::Literal::vec1(bias);
            self.run(dir, files, &key, &[la, lb, lbias])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_fails_cleanly() {
        let err = match Oracle::open("/nonexistent/path/xyz") {
            Ok(_) => panic!("open should fail"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn manifest_parses_and_rejects_garbage() {
        let files = parse_manifest(
            "# comment\n\ngemm 64 64 64 gemm_64.hlo.txt\ngemm_bias_relu 128 96 64 e.hlo.txt\n",
        )
        .unwrap();
        assert_eq!(files.len(), 2);
        assert_eq!(
            files[&ArtifactKey { entry: "gemm".into(), m: 64, n: 64, k: 64 }],
            "gemm_64.hlo.txt"
        );
        assert!(parse_manifest("gemm 64 64\n").is_err());
        assert!(parse_manifest("gemm a b c d\n").is_err());
    }

    #[test]
    fn cpu_reference_covers_required_families() {
        let o = Oracle::cpu_reference();
        assert!(o.is_cpu_reference());
        let shapes = o.shapes("gemm");
        assert!(shapes.len() >= 5, "{shapes:?}");
        // The ragged §4.1.3 analogue and a flat-decode analogue must exist.
        assert!(shapes.iter().any(|&(_, n, _)| n == 66));
        assert!(shapes.iter().any(|&(m, n, _)| m <= 64 && n >= 8 * m));
        // The CPU backend can compute any shape, listed or not.
        assert!(o.has("gemm", 13, 7, 5));
    }

    #[test]
    fn cpu_reference_gemm_matches_f32_reference() {
        let mut o = Oracle::cpu_reference();
        let (m, n, k) = (16, 8, 32);
        let mut rng = crate::util::rng::Rng::new(5);
        let a = rng.f32_vec(m * k);
        let b = rng.f32_vec(k * n);
        let got = o.gemm(m, n, k, &a, &b).unwrap();
        let mut want = vec![0f32; m * n];
        crate::functional::mmad_f32(&a, &b, &mut want, m, n, k);
        let diff = crate::functional::max_abs_diff(&got, &want);
        assert!(diff < 1e-4, "f64-accum vs f32-accum diff {diff}");
    }

    #[test]
    fn cpu_reference_epilogue_applies_bias_relu() {
        let mut o = Oracle::cpu_reference();
        let (m, n, k) = (4, 4, 8);
        let a = vec![0.5f32; m * k];
        let b = vec![-0.25f32; k * n];
        let bias = vec![0.1f32; n];
        // A@B = 8 * 0.5 * -0.25 = -1.0; +0.1 = -0.9; relu -> 0.
        let got = o.gemm_bias_relu(m, n, k, &a, &b, &bias).unwrap();
        assert!(got.iter().all(|&v| v == 0.0), "{got:?}");
        let pos_bias = vec![1.5f32; n];
        let got = o.gemm_bias_relu(m, n, k, &a, &b, &pos_bias).unwrap();
        assert!(got.iter().all(|&v| (v - 0.5).abs() < 1e-6), "{got:?}");
    }

    #[test]
    fn gemm_rejects_bad_dims() {
        let mut o = Oracle::cpu_reference();
        assert!(o.gemm(4, 4, 4, &[0.0; 15], &[0.0; 16]).is_err());
        assert!(o.gemm_bias_relu(4, 4, 4, &[0.0; 16], &[0.0; 16], &[0.0; 3]).is_err());
    }
}

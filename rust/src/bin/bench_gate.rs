//! CI perf-regression gate over machine-readable bench artifacts.
//!
//! `cargo bench -- ... --json BENCH_results.json` writes every headline
//! bench metric (TFLOP/s, utilization, speedup ratios — all deterministic
//! outputs of the performance model, so they are machine-independent).
//! This binary compares such an artifact against the committed
//! `rust/bench_baseline.json` and exits non-zero when any pinned metric
//! regresses by more than the tolerance (default 5%), which fails the CI
//! `bench-gate` job.
//!
//! ```text
//! bench_gate [--baseline bench_baseline.json] [--results BENCH_results.json]
//!            [--tolerance 0.05]     # override the baseline's tolerance
//!            [--update]             # rewrite the baseline from the results
//!            [--self-check]         # prove a synthetic 10% regression fails
//!            [--allow-unpinned]     # tolerate produced-but-unpinned metrics
//! ```
//!
//! The gate is strict in both directions: a pinned metric missing from the
//! results fails (a bench id silently dropped from CI would otherwise
//! un-gate its metrics), and a produced metric with no pin fails too (a
//! new metric would otherwise ship ungated forever). The second check has
//! an `--allow-unpinned` escape hatch for bring-up of a new bench id;
//! the durable fix is `--update`, which re-pins the baseline from the
//! results. After a model change that intentionally shifts numbers,
//! refresh with `--update` and commit the new baseline.
//!
//! Pins come in two classes. A plain pin records the expected value and
//! tolerates `tolerance` relative drift in the bad direction — right for
//! deterministic model outputs. A **floor** pin (`"floor": true`,
//! higher-is-better only) is a hard lower bound with *no* tolerance:
//! the result must be ≥ the pinned value, full stop. Floors gate
//! machine-dependent throughput metrics like `sims_per_sec`, where the
//! committed value is a deliberately conservative minimum rather than a
//! measurement — so `--update` preserves committed floor pins verbatim
//! instead of overwriting them with whatever this machine measured;
//! tighten them by hand (see `scripts/repin.sh`).

use std::process::ExitCode;

use dit::report::Table;
use dit::util::json::Json;

const DEFAULT_TOLERANCE: f64 = 0.05;

/// One named, directional metric. `floor` marks the hard-lower-bound pin
/// class (never set on result-side metrics; only baselines carry it).
#[derive(Debug, Clone, PartialEq)]
struct Metric {
    figure: String,
    metric: String,
    value: f64,
    higher_is_better: bool,
    floor: bool,
}

impl Metric {
    fn key(&self) -> String {
        format!("{}.{}", self.figure, self.metric)
    }
}

/// Extract the `metrics` array of a bench/baseline document.
fn metrics_of(doc: &Json) -> Result<Vec<Metric>, String> {
    let arr = doc
        .get("metrics")
        .and_then(|m| m.items())
        .ok_or("document has no `metrics` array")?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, m) in arr.iter().enumerate() {
        let field = |k: &str| m.get(k).ok_or_else(|| format!("metrics[{i}] missing `{k}`"));
        let str_field = |k: &str| -> Result<String, String> {
            Ok(field(k)?
                .as_str()
                .ok_or_else(|| format!("metrics[{i}].{k} not a string"))?
                .to_string())
        };
        let floor = match m.get("floor") {
            None => false,
            Some(f) => f.as_bool().ok_or_else(|| format!("metrics[{i}].floor not a bool"))?,
        };
        let metric = Metric {
            figure: str_field("figure")?,
            metric: str_field("metric")?,
            value: field("value")?
                .as_f64()
                .ok_or_else(|| format!("metrics[{i}].value not a number"))?,
            higher_is_better: field("higher_is_better")?
                .as_bool()
                .ok_or_else(|| format!("metrics[{i}].higher_is_better not a bool"))?,
            floor,
        };
        if metric.floor && !metric.higher_is_better {
            return Err(format!(
                "metrics[{i}] ({}): a floor pin must be higher_is_better (a lower bound on a \
                 lower-is-better metric gates nothing)",
                metric.key()
            ));
        }
        out.push(metric);
    }
    Ok(out)
}

/// Gate verdict for one pinned metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Verdict {
    Pass,
    Regressed,
    Missing,
}

/// Compare the results against every pinned baseline metric. Returns one
/// row per pinned metric; `tolerance` is the allowed relative regression.
fn gate(
    baseline: &[Metric],
    results: &[Metric],
    tolerance: f64,
) -> Vec<(Metric, Option<f64>, Verdict)> {
    baseline
        .iter()
        .map(|pin| {
            let got = results
                .iter()
                .find(|m| m.figure == pin.figure && m.metric == pin.metric)
                .map(|m| m.value);
            let verdict = match got {
                None => Verdict::Missing,
                Some(v) => {
                    let regressed = if pin.floor {
                        // Hard lower bound: no tolerance. The pinned value
                        // is already conservative; any reading below it is
                        // a real throughput regression.
                        v < pin.value
                    } else if pin.value == 0.0 {
                        // Degenerate pin (e.g. a 0/1 flag at 0): any drop
                        // below it is impossible, any direction-bad move is
                        // a regression only for lower-is-better pins.
                        if pin.higher_is_better { v < 0.0 } else { v > 0.0 }
                    } else if pin.higher_is_better {
                        v < pin.value * (1.0 - tolerance)
                    } else {
                        v > pin.value * (1.0 + tolerance)
                    };
                    if regressed {
                        Verdict::Regressed
                    } else {
                        Verdict::Pass
                    }
                }
            };
            (pin.clone(), got, verdict)
        })
        .collect()
}

fn render(rows: &[(Metric, Option<f64>, Verdict)], tolerance: f64) -> (Table, usize) {
    let mut t = Table::new(
        format!("bench gate (tolerance {:.1}%)", tolerance * 100.0),
        &["metric", "direction", "baseline", "result", "delta %", "verdict"],
    );
    let mut failures = 0usize;
    for (pin, got, verdict) in rows {
        let delta = match got {
            Some(v) if pin.value != 0.0 => {
                format!("{:+.2}", 100.0 * (v - pin.value) / pin.value)
            }
            _ => "-".into(),
        };
        if *verdict != Verdict::Pass {
            failures += 1;
        }
        t.row(vec![
            pin.key(),
            if pin.floor {
                "floor"
            } else if pin.higher_is_better {
                "higher"
            } else {
                "lower"
            }
            .into(),
            format!("{:.4}", pin.value),
            got.map(|v| format!("{v:.4}")).unwrap_or_else(|| "MISSING".into()),
            delta,
            match verdict {
                Verdict::Pass => "pass".into(),
                Verdict::Regressed => "REGRESSED".into(),
                Verdict::Missing => "MISSING".into(),
            },
        ]);
    }
    (t, failures)
}

/// Keys present in the results but pinned by no baseline metric. These
/// fail the gate unless `--allow-unpinned` is passed: an unpinned metric
/// is an un-gated metric, and silence here is how regressions ship.
fn unpinned_keys(baseline: &[Metric], results: &[Metric]) -> Vec<String> {
    let pinned: Vec<String> = baseline.iter().map(|m| m.key()).collect();
    results
        .iter()
        .map(|m| m.key())
        .filter(|k| !pinned.contains(k))
        .collect()
}

fn load(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn write_baseline(path: &str, pins: &[Metric], tolerance: f64) -> Result<(), String> {
    let mut metrics = Json::arr();
    for m in pins {
        let mut obj = Json::obj()
            .field("figure", m.figure.as_str())
            .field("metric", m.metric.as_str())
            .field("value", m.value)
            .field("higher_is_better", m.higher_is_better);
        if m.floor {
            obj = obj.field("floor", true);
        }
        metrics = metrics.push(obj);
    }
    let doc = Json::obj()
        .field("schema", 1i64)
        .field("tolerance", tolerance)
        .field("note", "pinned bench metrics; refresh with `cargo run --bin bench_gate -- --update` after intentional model changes (floor pins are conservative hand-set lower bounds and survive --update; tighten via scripts/repin.sh)")
        .field("metrics", metrics);
    std::fs::write(path, doc.pretty()).map_err(|e| format!("writing {path}: {e}"))
}

/// The pin set `--update` writes: results re-pin every plain metric, but a
/// committed floor pin survives verbatim — its value is a hand-set
/// conservative bound, and overwriting it with one machine's measurement
/// would either gut the gate (fast dev box) or flake CI (slow runner).
/// A result metric under a floor key keeps the old pin; a floor pin whose
/// metric vanished from the results is kept too (the Missing verdict on
/// the next gate run is the signal to deal with it deliberately).
fn merged_pins(old_baseline: &[Metric], results: &[Metric]) -> Vec<Metric> {
    let mut pins: Vec<Metric> = Vec::with_capacity(results.len());
    for m in results {
        match old_baseline.iter().find(|p| p.floor && p.key() == m.key()) {
            Some(floor_pin) => pins.push(floor_pin.clone()),
            None => pins.push(m.clone()),
        }
    }
    for p in old_baseline.iter().filter(|p| p.floor) {
        if !results.iter().any(|m| m.key() == p.key()) {
            pins.push(p.clone());
        }
    }
    pins
}

/// Prove the gate mechanism catches a synthetic 10% regression (and does
/// not fire on a 3% drift) without touching any file.
fn self_check() -> Result<(), String> {
    let pin = |figure: &str, metric: &str, value: f64, higher: bool| Metric {
        figure: figure.into(),
        metric: metric.into(),
        value,
        higher_is_better: higher,
        floor: false,
    };
    let baseline =
        vec![pin("fig9", "mean_speedup", 1.0, true), pin("fig8", "store_best_us", 100.0, false)];
    // 10% regression on a higher-is-better metric must fail.
    let bad =
        vec![pin("fig9", "mean_speedup", 0.9, true), pin("fig8", "store_best_us", 100.0, false)];
    let (_, failures) = render(&gate(&baseline, &bad, DEFAULT_TOLERANCE), DEFAULT_TOLERANCE);
    if failures != 1 {
        return Err(format!("synthetic -10% speedup regression not caught ({failures} failures)"));
    }
    // 10% slowdown on a lower-is-better metric must fail.
    let slow =
        vec![pin("fig9", "mean_speedup", 1.0, true), pin("fig8", "store_best_us", 110.0, false)];
    let (_, failures) = render(&gate(&baseline, &slow, DEFAULT_TOLERANCE), DEFAULT_TOLERANCE);
    if failures != 1 {
        return Err(format!("synthetic +10% makespan regression not caught ({failures} failures)"));
    }
    // 3% drift inside the tolerance must pass; a missing metric must fail.
    let drift =
        vec![pin("fig9", "mean_speedup", 0.97, true), pin("fig8", "store_best_us", 103.0, false)];
    let (_, failures) = render(&gate(&baseline, &drift, DEFAULT_TOLERANCE), DEFAULT_TOLERANCE);
    if failures != 0 {
        return Err(format!("3% drift flagged as regression ({failures} failures)"));
    }
    let (_, failures) = render(&gate(&baseline, &[], DEFAULT_TOLERANCE), DEFAULT_TOLERANCE);
    if failures != 2 {
        return Err(format!("missing metrics not flagged ({failures} failures)"));
    }
    // A floor pin is a hard bound: 1% under fails even though the 5%
    // tolerance would forgive it on a plain pin; at/above the floor passes.
    let floor_pin = Metric { floor: true, ..pin("dse", "sims_per_sec", 100.0, true) };
    let under = vec![pin("dse", "sims_per_sec", 99.0, true)];
    let (_, failures) =
        render(&gate(&[floor_pin.clone()], &under, DEFAULT_TOLERANCE), DEFAULT_TOLERANCE);
    if failures != 1 {
        return Err(format!("1% under a floor pin not caught ({failures} failures)"));
    }
    let at = vec![pin("dse", "sims_per_sec", 100.0, true)];
    let (_, failures) =
        render(&gate(&[floor_pin], &at, DEFAULT_TOLERANCE), DEFAULT_TOLERANCE);
    if failures != 0 {
        return Err(format!("exactly-at-floor flagged as regression ({failures} failures)"));
    }
    println!(
        "self-check OK: 10% synthetic regressions fail, 3% drift passes, missing metrics fail, \
         floor pins are tolerance-free"
    );
    Ok(())
}

struct Opts {
    baseline: String,
    results: String,
    tolerance: Option<f64>,
    update: bool,
    self_check: bool,
    allow_unpinned: bool,
}

fn parse_args(argv: &[String]) -> Result<Opts, String> {
    let mut o = Opts {
        baseline: "bench_baseline.json".into(),
        results: "BENCH_results.json".into(),
        tolerance: None,
        update: false,
        self_check: false,
        allow_unpinned: false,
    };
    let mut it = argv.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--baseline" => o.baseline = it.next().ok_or("--baseline needs a value")?.clone(),
            "--results" => o.results = it.next().ok_or("--results needs a value")?.clone(),
            "--tolerance" => {
                o.tolerance = Some(
                    it.next()
                        .ok_or("--tolerance needs a value")?
                        .parse()
                        .map_err(|e| format!("--tolerance: {e}"))?,
                )
            }
            "--update" => o.update = true,
            "--self-check" => o.self_check = true,
            "--allow-unpinned" => o.allow_unpinned = true,
            other => return Err(format!("unknown flag {other:?}")),
        }
    }
    Ok(o)
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&argv) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };
    if opts.self_check {
        return match self_check() {
            Ok(()) => ExitCode::SUCCESS,
            Err(e) => {
                eprintln!("self-check FAILED: {e}");
                ExitCode::FAILURE
            }
        };
    }

    let run = || -> Result<usize, String> {
        let results_doc = load(&opts.results)?;
        let results = metrics_of(&results_doc)?;
        if opts.update {
            // Preserve a committed custom tolerance unless --tolerance
            // explicitly overrides it, and committed floor pins always.
            let old_doc = load(&opts.baseline).ok();
            let old_tol =
                old_doc.as_ref().and_then(|doc| doc.get("tolerance").and_then(|t| t.as_f64()));
            let old_pins = match &old_doc {
                Some(doc) => metrics_of(doc)?,
                None => Vec::new(),
            };
            let tol = opts.tolerance.or(old_tol).unwrap_or(DEFAULT_TOLERANCE);
            let pins = merged_pins(&old_pins, &results);
            let floors = pins.iter().filter(|p| p.floor).count();
            write_baseline(&opts.baseline, &pins, tol)?;
            println!(
                "pinned {} metrics from {} into {} ({} floor pin(s) preserved)",
                pins.len(),
                opts.results,
                opts.baseline,
                floors
            );
            return Ok(0);
        }
        let baseline_doc = load(&opts.baseline)?;
        let baseline = metrics_of(&baseline_doc)?;
        let tolerance = opts
            .tolerance
            .or_else(|| baseline_doc.get("tolerance").and_then(|t| t.as_f64()))
            .unwrap_or(DEFAULT_TOLERANCE);
        let rows = gate(&baseline, &results, tolerance);
        let (table, mut failures) = render(&rows, tolerance);
        print!("{}", table.markdown());
        let unpinned = unpinned_keys(&baseline, &results);
        if !unpinned.is_empty() {
            if opts.allow_unpinned {
                println!("informational (not pinned, --allow-unpinned): {}", unpinned.join(", "));
            } else {
                println!(
                    "UNPINNED: {} — every produced metric must be pinned; \
                     re-pin with --update or pass --allow-unpinned",
                    unpinned.join(", ")
                );
                failures += unpinned.len();
            }
        }
        if failures > 0 {
            println!("bench gate: {failures} metric(s) regressed, missing, or unpinned");
        } else {
            println!("bench gate: all {} pinned metric(s) within tolerance", baseline.len());
        }
        Ok(failures)
    };
    match run() {
        Ok(0) => ExitCode::SUCCESS,
        Ok(_) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(figure: &str, metric: &str, value: f64, higher: bool) -> Metric {
        Metric {
            figure: figure.into(),
            metric: metric.into(),
            value,
            higher_is_better: higher,
            floor: false,
        }
    }

    fn floor(figure: &str, metric: &str, value: f64) -> Metric {
        Metric { floor: true, ..m(figure, metric, value, true) }
    }

    #[test]
    fn gate_passes_within_tolerance_both_directions() {
        let base = vec![m("f", "up", 100.0, true), m("f", "down", 100.0, false)];
        let res = vec![m("f", "up", 96.0, true), m("f", "down", 104.0, false)];
        let rows = gate(&base, &res, 0.05);
        assert!(rows.iter().all(|(_, _, v)| *v == Verdict::Pass), "{rows:?}");
        // Improvements never fail, however large.
        let res = vec![m("f", "up", 500.0, true), m("f", "down", 1.0, false)];
        let rows = gate(&base, &res, 0.05);
        assert!(rows.iter().all(|(_, _, v)| *v == Verdict::Pass), "{rows:?}");
    }

    #[test]
    fn gate_fails_on_ten_percent_regression() {
        let base = vec![m("fig9", "mean_speedup", 1.31, true)];
        let res = vec![m("fig9", "mean_speedup", 1.31 * 0.9, true)];
        let rows = gate(&base, &res, 0.05);
        assert_eq!(rows[0].2, Verdict::Regressed);
        // Lower-is-better: +10% wall fails too.
        let base = vec![m("fig8", "store_best_us", 50.0, false)];
        let res = vec![m("fig8", "store_best_us", 55.1, false)];
        assert_eq!(gate(&base, &res, 0.05)[0].2, Verdict::Regressed);
    }

    #[test]
    fn gate_flags_missing_metrics() {
        // A pinned metric absent from the results is a hard failure (a
        // bench id dropped from the CI subset must not silently un-gate).
        let base = vec![m("fig9", "mean_speedup", 1.31, true)];
        let rows = gate(&base, &[], 0.05);
        assert_eq!(rows[0].2, Verdict::Missing);
        let (_, failures) = render(&rows, 0.05);
        assert_eq!(failures, 1);
    }

    #[test]
    fn unpinned_metrics_are_detected() {
        // A produced metric with no pin is a gate failure by default; the
        // verdict table itself stays pin-driven, so the failure comes from
        // the unpinned count (added unless --allow-unpinned).
        let base = vec![m("fig9", "mean_speedup", 1.0, true)];
        let res = vec![
            m("fig9", "mean_speedup", 1.0, true),
            m("energy", "best_tflops_per_w", 4.0, true),
            m("energy", "min_energy_mj", 25.0, false),
        ];
        assert_eq!(
            unpinned_keys(&base, &res),
            vec!["energy.best_tflops_per_w".to_string(), "energy.min_energy_mj".to_string()]
        );
        let (_, gate_failures) = render(&gate(&base, &res, 0.05), 0.05);
        assert_eq!(gate_failures, 0, "pinned metric itself is fine");
        // Strict mode: total failures = gate failures + unpinned count.
        assert_eq!(gate_failures + unpinned_keys(&base, &res).len(), 2);
        // Fully pinned results produce no unpinned keys.
        assert!(unpinned_keys(&base, &res[..1]).is_empty());
    }

    #[test]
    fn floor_pins_are_hard_lower_bounds() {
        let base = vec![floor("dse", "sims_per_sec", 100.0)];
        // 1% under the floor fails despite the 5% tolerance.
        let rows = gate(&base, &[m("dse", "sims_per_sec", 99.0, true)], 0.05);
        assert_eq!(rows[0].2, Verdict::Regressed);
        // At or above the floor passes; headroom is expected and fine.
        for v in [100.0, 101.0, 5000.0] {
            let rows = gate(&base, &[m("dse", "sims_per_sec", v, true)], 0.05);
            assert_eq!(rows[0].2, Verdict::Pass, "value {v}");
        }
        // Missing still fails, and the direction column names the class.
        let rows = gate(&base, &[], 0.05);
        assert_eq!(rows[0].2, Verdict::Missing);
        let (table, _) = render(&rows, 0.05);
        assert!(table.markdown().contains("floor"), "{}", table.markdown());
    }

    #[test]
    fn floor_pins_parse_and_reject_lower_is_better() {
        let doc = Json::obj().field(
            "metrics",
            Json::arr().push(
                Json::obj()
                    .field("figure", "dse")
                    .field("metric", "sims_per_sec")
                    .field("value", 5.0)
                    .field("higher_is_better", true)
                    .field("floor", true),
            ),
        );
        let pins = metrics_of(&doc).unwrap();
        assert!(pins[0].floor);
        // floor + lower-is-better is a baseline authoring error.
        let bad = Json::obj().field(
            "metrics",
            Json::arr().push(
                Json::obj()
                    .field("figure", "f")
                    .field("metric", "t_us")
                    .field("value", 5.0)
                    .field("higher_is_better", false)
                    .field("floor", true),
            ),
        );
        let err = metrics_of(&bad).unwrap_err();
        assert!(err.contains("floor"), "{err}");
        // Absent floor field defaults to a plain pin.
        let plain = Json::obj().field(
            "metrics",
            Json::arr().push(
                Json::obj()
                    .field("figure", "f")
                    .field("metric", "x")
                    .field("value", 1.0)
                    .field("higher_is_better", true),
            ),
        );
        assert!(!metrics_of(&plain).unwrap()[0].floor);
    }

    #[test]
    fn update_preserves_floor_pins_verbatim() {
        let old = vec![floor("dse", "sims_per_sec", 5.0), m("fig9", "mean_speedup", 1.3, true)];
        let results = vec![
            m("dse", "sims_per_sec", 12345.0, true), // this machine is fast — don't pin that
            m("fig9", "mean_speedup", 1.4, true),    // plain pin tracks the results
        ];
        let pins = merged_pins(&old, &results);
        assert_eq!(pins.len(), 2);
        let spin = pins.iter().find(|p| p.metric == "sims_per_sec").unwrap();
        assert!(spin.floor && spin.value == 5.0, "floor pin overwritten: {spin:?}");
        let speed = pins.iter().find(|p| p.metric == "mean_speedup").unwrap();
        assert!(!speed.floor && speed.value == 1.4);
        // A floor pin absent from the results survives the merge too.
        let pins = merged_pins(&old, &results[1..]);
        assert!(pins.iter().any(|p| p.floor && p.metric == "sims_per_sec"));
        // And a floor flag roundtrips through the written baseline.
        let mut arr = Json::arr();
        for p in &merged_pins(&old, &results) {
            let mut obj = Json::obj()
                .field("figure", p.figure.as_str())
                .field("metric", p.metric.as_str())
                .field("value", p.value)
                .field("higher_is_better", p.higher_is_better);
            if p.floor {
                obj = obj.field("floor", true);
            }
            arr = arr.push(obj);
        }
        let parsed = metrics_of(&Json::obj().field("metrics", arr)).unwrap();
        assert_eq!(parsed, merged_pins(&old, &results));
    }

    #[test]
    fn metrics_roundtrip_through_json_files() {
        let results = vec![m("table1", "peak_tflops", 1977.614336, true)];
        let mut arr = Json::arr();
        for x in &results {
            arr = arr.push(
                Json::obj()
                    .field("figure", x.figure.as_str())
                    .field("metric", x.metric.as_str())
                    .field("value", x.value)
                    .field("higher_is_better", x.higher_is_better),
            );
        }
        let doc = Json::obj().field("schema", 1i64).field("metrics", arr);
        let parsed = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(metrics_of(&parsed).unwrap(), results);
    }

    #[test]
    fn malformed_documents_are_rejected() {
        assert!(metrics_of(&Json::obj()).is_err(), "no metrics array");
        let doc = Json::obj().field("metrics", Json::arr().push(Json::obj().field("figure", "f")));
        assert!(metrics_of(&doc).is_err(), "missing fields");
    }

    #[test]
    fn self_check_is_green() {
        self_check().unwrap();
    }

    #[test]
    fn arg_parsing() {
        let argv: Vec<String> =
            ["--baseline", "b.json", "--results", "r.json", "--tolerance", "0.1", "--update"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let o = parse_args(&argv).unwrap();
        assert_eq!(o.baseline, "b.json");
        assert_eq!(o.results, "r.json");
        assert_eq!(o.tolerance, Some(0.1));
        assert!(o.update && !o.self_check);
        assert!(!o.allow_unpinned, "strict by default");
        assert!(parse_args(&["--allow-unpinned".to_string()]).unwrap().allow_unpinned);
        assert!(parse_args(&["--tolerance".to_string()]).is_err());
        assert!(parse_args(&["--bogus".to_string()]).is_err());
        let d = parse_args(&[]).unwrap();
        assert_eq!(d.baseline, "bench_baseline.json");
        assert_eq!(d.results, "BENCH_results.json");
    }
}

//! Parametric SoftHier architecture descriptions.
//!
//! SoftHier (paper §2.1) is a template: a `rows × cols` grid of compute
//! tiles (matrix engine + DMAs + software-managed L1 SPM) joined by a 2D
//! mesh NoC with hardware collective support; HBM channels sit on the west
//! and south die edges behind memory controllers. Everything is
//! configurable, mirroring the paper's "fully configurable through
//! architecture configuration files".
//!
//! Two calibrated presets reproduce the paper's evaluation instances:
//! [`ArchConfig::gh200_like`] (Table 1: 32×32 tiles, 1979 TFLOPS FP8,
//! 4 TB/s) and [`ArchConfig::a100_like`] (312 TFLOPS, 1.56 TB/s), plus
//! [`ArchConfig::tiny`] grids for functional verification.

pub mod workload;

use crate::collective::TileCoord;
use crate::util::cfgtext::Doc;

/// A GEMM problem: `C[M,N] = A[M,K] @ B[K,N]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        GemmShape { m, n, k }
    }

    /// Parse the canonical `MxNxK` text form (the inverse of `Display`,
    /// modulo surrounding whitespace) — the grammar the CLI, the
    /// persistent cache, and serve traces all share.
    pub fn parse(s: &str) -> anyhow::Result<GemmShape> {
        use anyhow::Context;
        let parts: Vec<&str> = s.split('x').collect();
        anyhow::ensure!(parts.len() == 3, "shape must be MxNxK, got {s:?}");
        let g = GemmShape::new(
            parts[0].trim().parse().context("M")?,
            parts[1].trim().parse().context("N")?,
            parts[2].trim().parse().context("K")?,
        );
        // A zero dimension is representable but meaningless, and it
        // reaches division and modulo logic all over the scheduler —
        // reject it at the boundary instead.
        anyhow::ensure!(
            g.m > 0 && g.n > 0 && g.k > 0,
            "shape dimensions must be positive, got {s:?}"
        );
        Ok(g)
    }

    /// Total floating-point work (multiply + add).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Compulsory off-chip traffic in elements (read A, read B, write C).
    pub fn min_elems(&self) -> usize {
        self.m * self.k + self.k * self.n + self.m * self.n
    }

    /// Arithmetic intensity at `elem_bytes` per element (FLOP/byte).
    pub fn intensity(&self, elem_bytes: usize) -> f64 {
        self.flops() / (self.min_elems() as f64 * elem_bytes as f64)
    }

    /// "Flat" GEMMs (LLM decode: small M, huge N·K) are the paper's
    /// memory-bound regime (§4.1.4).
    pub fn is_flat(&self) -> bool {
        self.m <= 128 && self.n.max(self.k) >= 8 * self.m
    }
}

impl std::fmt::Display for GemmShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.n, self.k)
    }
}

/// One compute tile: matrix engine + DMA + L1 scratchpad.
#[derive(Debug, Clone, PartialEq)]
pub struct TileSpec {
    /// CE array rows (M dimension of one engine pass).
    pub ce_m: usize,
    /// CE array columns (N dimension of one engine pass).
    pub ce_n: usize,
    /// Engine clock in GHz. Peak tile TFLOPS = 2·ce_m·ce_n·clock.
    pub clock_ghz: f64,
    /// L1 scratchpad bytes (384 KB in Table 1).
    pub l1_bytes: usize,
    /// L1 bandwidth, bytes/ns (== GB/s).
    pub l1_gbps: f64,
    /// Independent DMA engines per tile.
    pub dma_engines: usize,
}

impl TileSpec {
    /// Peak tile throughput in TFLOP/s.
    pub fn peak_tflops(&self) -> f64 {
        2.0 * self.ce_m as f64 * self.ce_n as f64 * self.clock_ghz * 1e9 / 1e12
    }
}

/// The 2D-mesh NoC.
#[derive(Debug, Clone, PartialEq)]
pub struct NocSpec {
    /// Link width in bits (Table 1: 4096).
    pub link_bits: usize,
    /// NoC clock, GHz. Link bandwidth = link_bits/8 · clock GB/s.
    pub clock_ghz: f64,
    /// Per-hop router latency, ns.
    pub hop_ns: f64,
}

impl NocSpec {
    /// One link's bandwidth in bytes/ns (== GB/s).
    pub fn link_gbps(&self) -> f64 {
        self.link_bits as f64 / 8.0 * self.clock_ghz
    }
}

/// Which die edge a set of HBM channels attaches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Edge {
    West,
    South,
}

/// The distributed multi-channel HBM system.
#[derive(Debug, Clone, PartialEq)]
pub struct HbmSpec {
    /// Channels per edge; total = 2 × per_edge (west + south, Table 1).
    /// On a rectangular grid the west edge spans `rows` routers and the
    /// south edge `cols`; each edge hosts the same channel count, and a
    /// count beyond an edge's length wraps onto its routers
    /// ([`ArchConfig::hbm_router`]).
    pub channels_per_edge: usize,
    /// Per-channel bandwidth, bytes/ns (GB/s).
    pub channel_gbps: f64,
    /// Fixed per-request service overhead, ns (row activation, controller).
    pub request_overhead_ns: f64,
    /// Efficiency floor for single-burst (well-coalesced) streams.
    pub stream_efficiency: f64,
}

impl HbmSpec {
    pub fn num_channels(&self) -> usize {
        2 * self.channels_per_edge
    }

    /// Aggregate bandwidth, GB/s.
    pub fn total_gbps(&self) -> f64 {
        self.num_channels() as f64 * self.channel_gbps
    }
}

/// A complete SoftHier instance.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Human-readable preset name.
    pub name: String,
    /// Physical tile-grid rows.
    pub rows: usize,
    /// Physical tile-grid columns.
    pub cols: usize,
    pub tile: TileSpec,
    pub noc: NocSpec,
    pub hbm: HbmSpec,
    /// Element size for *performance* accounting (1 = FP8 like the paper;
    /// functional verification always computes in f32).
    pub elem_bytes: usize,
}

impl ArchConfig {
    /// The paper's Table 1 instance: spec-matched to an NVIDIA GH200.
    ///
    /// 32×32 tiles; per-tile 64×16 CE array at 0.943 GHz → 1.93 TFLOPS FP8
    /// (grid total 1979 TFLOPS); 4096-bit NoC links; 32×2 HBM channels
    /// split over the west and south edges totalling 4 TB/s.
    pub fn gh200_like() -> ArchConfig {
        ArchConfig {
            name: "softhier-gh200".into(),
            rows: 32,
            cols: 32,
            tile: TileSpec {
                ce_m: 64,
                ce_n: 16,
                clock_ghz: 0.943,
                l1_bytes: 384 * 1024,
                l1_gbps: 512.0,
                dma_engines: 2,
            },
            noc: NocSpec {
                link_bits: 4096,
                clock_ghz: 1.0,
                hop_ns: 1.0,
            },
            hbm: HbmSpec {
                channels_per_edge: 32,
                channel_gbps: 64.0,
                request_overhead_ns: 6.0,
                stream_efficiency: 0.92,
            },
            elem_bytes: 1, // FP8
        }
    }

    /// SoftHier instance spec-matched to an NVIDIA A100 (312 TFLOPS FP16,
    /// 1.56 TB/s HBM2e) for the portability study (§4.2 / Fig. 12).
    pub fn a100_like() -> ArchConfig {
        ArchConfig {
            name: "softhier-a100".into(),
            rows: 16,
            cols: 16,
            tile: TileSpec {
                ce_m: 32,
                ce_n: 16,
                clock_ghz: 1.19,
                l1_bytes: 256 * 1024,
                l1_gbps: 384.0,
                dma_engines: 2,
            },
            noc: NocSpec {
                link_bits: 2048,
                clock_ghz: 1.0,
                hop_ns: 1.0,
            },
            hbm: HbmSpec {
                channels_per_edge: 16,
                channel_gbps: 48.6,
                request_overhead_ns: 6.0,
                stream_efficiency: 0.92,
            },
            elem_bytes: 2, // FP16
        }
    }

    /// A small instance for functional verification and unit tests: the
    /// same template scaled down so whole-system runs finish in
    /// milliseconds and every byte can be checked.
    pub fn tiny(rows: usize, cols: usize) -> ArchConfig {
        ArchConfig {
            name: format!("softhier-tiny-{rows}x{cols}"),
            rows,
            cols,
            tile: TileSpec {
                ce_m: 16,
                ce_n: 8,
                clock_ghz: 1.0,
                l1_bytes: 256 * 1024,
                l1_gbps: 256.0,
                dma_engines: 2,
            },
            noc: NocSpec {
                link_bits: 1024,
                clock_ghz: 1.0,
                hop_ns: 1.0,
            },
            hbm: HbmSpec {
                channels_per_edge: rows.max(1),
                channel_gbps: 32.0,
                request_overhead_ns: 6.0,
                stream_efficiency: 0.92,
            },
            elem_bytes: 4, // functional runs are f32
        }
    }

    pub fn num_tiles(&self) -> usize {
        self.rows * self.cols
    }

    /// System peak throughput, TFLOP/s.
    pub fn peak_tflops(&self) -> f64 {
        self.num_tiles() as f64 * self.tile.peak_tflops()
    }

    /// The mesh router an HBM channel is attached to. West-edge channels
    /// attach along column 0 (top to bottom, wrapping if there are more
    /// channels than rows); south-edge channels along the bottom row.
    pub fn hbm_router(&self, channel: usize) -> TileCoord {
        assert!(channel < self.hbm.num_channels(), "channel {channel} out of range");
        let per_edge = self.hbm.channels_per_edge;
        if channel < per_edge {
            TileCoord::new(channel % self.rows, 0) // west
        } else {
            TileCoord::new(self.rows - 1, (channel - per_edge) % self.cols) // south
        }
    }

    /// Sanity-check all derived quantities.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.rows > 0 && self.cols > 0, "empty grid");
        anyhow::ensure!(self.tile.ce_m > 0 && self.tile.ce_n > 0, "empty CE array");
        anyhow::ensure!(self.tile.clock_ghz > 0.0, "zero tile clock");
        anyhow::ensure!(self.tile.l1_bytes >= 4096, "L1 too small");
        anyhow::ensure!(self.noc.link_bits >= 8, "NoC link too narrow");
        anyhow::ensure!(self.hbm.channels_per_edge > 0, "no HBM channels");
        anyhow::ensure!(
            (1..=8).contains(&self.elem_bytes),
            "unreasonable element size {}",
            self.elem_bytes
        );
        Ok(())
    }

    /// Serialize to the `.dit` config-text format.
    pub fn to_text(&self) -> String {
        format!(
            "# SoftHier architecture description\n\
             name = \"{}\"\nelem_bytes = {}\n\n\
             [grid]\nrows = {}\ncols = {}\n\n\
             [tile]\nce_m = {}\nce_n = {}\nclock_ghz = {}\nl1_bytes = {}\nl1_gbps = {}\ndma_engines = {}\n\n\
             [noc]\nlink_bits = {}\nclock_ghz = {}\nhop_ns = {}\n\n\
             [hbm]\nchannels_per_edge = {}\nchannel_gbps = {}\nrequest_overhead_ns = {}\nstream_efficiency = {}\n",
            self.name, self.elem_bytes, self.rows, self.cols,
            self.tile.ce_m, self.tile.ce_n, self.tile.clock_ghz, self.tile.l1_bytes,
            self.tile.l1_gbps, self.tile.dma_engines,
            self.noc.link_bits, self.noc.clock_ghz, self.noc.hop_ns,
            self.hbm.channels_per_edge, self.hbm.channel_gbps,
            self.hbm.request_overhead_ns, self.hbm.stream_efficiency,
        )
    }

    /// Parse from config text; starts from [`ArchConfig::gh200_like`]
    /// defaults so partial configs are valid.
    pub fn from_text(text: &str) -> anyhow::Result<ArchConfig> {
        let a = ArchConfig::from_text_unchecked(text)?;
        a.validate()?;
        Ok(a)
    }

    /// Parse from config text **without** the final
    /// [`ArchConfig::validate`] call. This is the static checker's entry
    /// point ([`crate::analysis`]): a syntactically valid but
    /// semantically broken config reaches [`crate::analysis::check_arch`]
    /// intact and earns specific `DIT-E00x` diagnostics instead of one
    /// opaque error. Everything else should use
    /// [`ArchConfig::from_text`].
    pub fn from_text_unchecked(text: &str) -> anyhow::Result<ArchConfig> {
        let doc = Doc::parse(text)?;
        let mut a = ArchConfig::gh200_like();
        if let Some(name) = doc.get_str("", "name") {
            a.name = name.to_string();
        }
        if let Some(v) = doc.get_int("", "elem_bytes") {
            a.elem_bytes = v as usize;
        }
        let geti = |sec: &str, key: &str, dflt: usize| -> usize {
            doc.get_int(sec, key).map(|v| v as usize).unwrap_or(dflt)
        };
        let getf = |sec: &str, key: &str, dflt: f64| -> f64 {
            doc.get_f64(sec, key).unwrap_or(dflt)
        };
        a.rows = geti("grid", "rows", a.rows);
        a.cols = geti("grid", "cols", a.cols);
        a.tile.ce_m = geti("tile", "ce_m", a.tile.ce_m);
        a.tile.ce_n = geti("tile", "ce_n", a.tile.ce_n);
        a.tile.clock_ghz = getf("tile", "clock_ghz", a.tile.clock_ghz);
        a.tile.l1_bytes = geti("tile", "l1_bytes", a.tile.l1_bytes);
        a.tile.l1_gbps = getf("tile", "l1_gbps", a.tile.l1_gbps);
        a.tile.dma_engines = geti("tile", "dma_engines", a.tile.dma_engines);
        a.noc.link_bits = geti("noc", "link_bits", a.noc.link_bits);
        a.noc.clock_ghz = getf("noc", "clock_ghz", a.noc.clock_ghz);
        a.noc.hop_ns = getf("noc", "hop_ns", a.noc.hop_ns);
        a.hbm.channels_per_edge = geti("hbm", "channels_per_edge", a.hbm.channels_per_edge);
        a.hbm.channel_gbps = getf("hbm", "channel_gbps", a.hbm.channel_gbps);
        a.hbm.request_overhead_ns = getf("hbm", "request_overhead_ns", a.hbm.request_overhead_ns);
        a.hbm.stream_efficiency = getf("hbm", "stream_efficiency", a.hbm.stream_efficiency);
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gh200_matches_table1() {
        let a = ArchConfig::gh200_like();
        a.validate().unwrap();
        assert_eq!(a.num_tiles(), 1024);
        // Table 1: 1979 TFLOPS peak, 1.93 TFLOPS/tile, 4 TB/s HBM.
        assert!((a.tile.peak_tflops() - 1.93).abs() < 0.01, "{}", a.tile.peak_tflops());
        assert!((a.peak_tflops() - 1979.0).abs() < 10.0, "{}", a.peak_tflops());
        assert_eq!(a.hbm.num_channels(), 64);
        assert!((a.hbm.total_gbps() - 4096.0).abs() < 1.0);
        // 4096-bit NoC at 1 GHz = 512 GB/s per link.
        assert!((a.noc.link_gbps() - 512.0).abs() < 1e-9);
    }

    #[test]
    fn a100_matches_spec() {
        let a = ArchConfig::a100_like();
        a.validate().unwrap();
        assert!((a.peak_tflops() - 312.0).abs() < 5.0, "{}", a.peak_tflops());
        assert!((a.hbm.total_gbps() - 1555.0).abs() < 5.0, "{}", a.hbm.total_gbps());
    }

    #[test]
    fn hbm_router_placement() {
        let a = ArchConfig::gh200_like();
        // West channels on column 0.
        assert_eq!(a.hbm_router(0), TileCoord::new(0, 0));
        assert_eq!(a.hbm_router(31), TileCoord::new(31, 0));
        // South channels on the bottom row.
        assert_eq!(a.hbm_router(32), TileCoord::new(31, 0));
        assert_eq!(a.hbm_router(63), TileCoord::new(31, 31));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn hbm_router_rejects_bad_channel() {
        ArchConfig::gh200_like().hbm_router(64);
    }

    #[test]
    fn hbm_router_placement_rectangular() {
        // West channels walk column 0 over `rows` routers, south
        // channels walk the bottom row over `cols`; each edge wraps at
        // its own length, so a wide-short grid keeps every channel on a
        // real router.
        let mut a = ArchConfig::tiny(4, 8);
        a.hbm.channels_per_edge = 8;
        a.validate().unwrap();
        assert_eq!(a.hbm.num_channels(), 16);
        assert_eq!(a.hbm_router(0), TileCoord::new(0, 0));
        assert_eq!(a.hbm_router(3), TileCoord::new(3, 0));
        assert_eq!(a.hbm_router(4), TileCoord::new(0, 0), "west wraps at rows");
        assert_eq!(a.hbm_router(8), TileCoord::new(3, 0), "first south channel");
        assert_eq!(a.hbm_router(15), TileCoord::new(3, 7));
    }

    #[test]
    fn config_text_roundtrip() {
        for a in [ArchConfig::gh200_like(), ArchConfig::a100_like(), ArchConfig::tiny(4, 4)] {
            let b = ArchConfig::from_text(&a.to_text()).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn partial_config_uses_defaults() {
        let a = ArchConfig::from_text("[grid]\nrows = 8\ncols = 8\n").unwrap();
        assert_eq!(a.rows, 8);
        assert_eq!(a.tile.ce_m, 64); // GH200 default
    }

    #[test]
    fn validate_rejects_nonsense() {
        let mut a = ArchConfig::tiny(2, 2);
        a.elem_bytes = 0;
        assert!(a.validate().is_err());
        let mut b = ArchConfig::tiny(2, 2);
        b.rows = 0;
        assert!(b.validate().is_err());
    }

    #[test]
    fn gemm_shape_math() {
        let s = GemmShape::new(64, 2112, 7168);
        assert_eq!(s.flops(), 2.0 * 64.0 * 2112.0 * 7168.0);
        assert!(s.is_flat());
        assert!(!GemmShape::new(4096, 2112, 7168).is_flat());
        // flat GEMM: intensity below the GH200 ridge point (~483 FLOP/B).
        assert!(s.intensity(1) < 200.0);
    }
}

//! Named GEMM workload suites — the traffic shapes a deployment engine
//! tunes as a batch instead of one shape at a time.
//!
//! The realistic unit of work for an LLM accelerator is not a single GEMM
//! but a transformer layer's worth of them: prefill QKV / attention-output
//! / FFN projections (compute-bound) and the flat decode GEMMs of token
//! generation (memory-bound, §4.1.4's regime). A [`Workload`] names such a
//! suite; `coordinator::engine` tunes every shape in it concurrently and
//! memoizes repeated shapes (decode traffic repeats the *same* GEMMs every
//! step, so a serving mix is mostly cache hits).

use super::GemmShape;

/// One GEMM instance in a workload.
#[derive(Debug, Clone)]
pub struct WorkloadItem {
    /// Human-readable role, e.g. `prefill/qkv`.
    pub label: String,
    pub shape: GemmShape,
    /// How many times this GEMM executes per workload pass (e.g. once per
    /// transformer layer). Weights the aggregate report; tuning cost is
    /// per unique shape, not per count.
    pub count: usize,
}

/// A named suite of GEMM shapes.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub items: Vec<WorkloadItem>,
}

impl Workload {
    pub fn new(name: impl Into<String>) -> Workload {
        Workload { name: name.into(), items: Vec::new() }
    }

    /// A single-shape workload (what `Engine::tune` wraps).
    pub fn single(name: impl Into<String>, shape: GemmShape) -> Workload {
        let mut w = Workload::new(name);
        w.push("gemm", shape, 1);
        w
    }

    pub fn push(&mut self, label: impl Into<String>, shape: GemmShape, count: usize) -> &mut Self {
        self.items.push(WorkloadItem { label: label.into(), shape, count });
        self
    }

    /// Append another workload's items (serving mixes compose suites).
    pub fn extend(&mut self, other: Workload) -> &mut Self {
        self.items.extend(other.items);
        self
    }

    /// Item shapes in order (repeats included).
    pub fn shapes(&self) -> Vec<GemmShape> {
        self.items.iter().map(|i| i.shape).collect()
    }

    /// Total FLOPs of one workload pass (counts applied).
    pub fn total_flops(&self) -> f64 {
        self.items.iter().map(|i| i.count as f64 * i.shape.flops()).sum()
    }

    /// Total GEMM executions per pass (counts applied).
    pub fn total_count(&self) -> usize {
        self.items.iter().map(|i| i.count).sum()
    }

    /// One transformer layer's prefill GEMMs for `tokens` tokens
    /// (batch × sequence), repeated `layers` times per pass: QKV
    /// projection, attention output projection, FFN up and FFN down.
    pub fn transformer_prefill(
        tag: &str,
        tokens: usize,
        d_model: usize,
        d_ff: usize,
        layers: usize,
    ) -> Workload {
        let mut w = Workload::new(tag.to_string());
        w.push(format!("{tag}/qkv"), GemmShape::new(tokens, 3 * d_model, d_model), layers);
        w.push(format!("{tag}/attn-out"), GemmShape::new(tokens, d_model, d_model), layers);
        w.push(format!("{tag}/ffn-up"), GemmShape::new(tokens, d_ff, d_model), layers);
        w.push(format!("{tag}/ffn-down"), GemmShape::new(tokens, d_model, d_ff), layers);
        w
    }

    /// The decode step: same four projections at M = `batch` tokens — the
    /// flat, memory-bound GEMMs of autoregressive generation.
    pub fn transformer_decode(
        tag: &str,
        batch: usize,
        d_model: usize,
        d_ff: usize,
        layers: usize,
    ) -> Workload {
        Workload::transformer_prefill(tag, batch, d_model, d_ff, layers)
    }

    /// A serving mix: one prefill pass plus `decode_steps` decode steps.
    /// Every decode step issues the *same* GEMM shapes, so all steps after
    /// the first are pure cache hits in the tuning engine — the realistic
    /// traffic profile batched autotuning exists for.
    pub fn transformer_serving(
        prefill_tokens: usize,
        decode_batch: usize,
        decode_steps: usize,
        d_model: usize,
        d_ff: usize,
        layers: usize,
    ) -> Workload {
        let mut w = Workload::new("transformer-serving");
        w.extend(Workload::transformer_prefill(
            "prefill",
            prefill_tokens,
            d_model,
            d_ff,
            layers,
        ));
        for step in 0..decode_steps {
            w.extend(Workload::transformer_decode(
                &format!("decode[t+{step}]"),
                decode_batch,
                d_model,
                d_ff,
                layers,
            ));
        }
        w
    }

    /// Built-in suites for the CLI / benches. Model dimensions follow the
    /// paper's DeepSeek-V3-flavoured evaluation set (d_model = 7168, MoE
    /// expert FFN d_ff = 2048, 61 layers; `4096x7168x2048` is literally a
    /// Fig. 9 shape). Names and constructors live in one table
    /// ([`BUILTINS`]) so the name list cannot drift from the dispatch.
    pub fn builtin(name: &str) -> Option<Workload> {
        BUILTINS.iter().find(|(n, _)| *n == name).map(|(_, f)| f())
    }

    /// Names accepted by [`Workload::builtin`], derived from the same
    /// table the lookup uses.
    pub fn builtin_names() -> Vec<&'static str> {
        BUILTINS.iter().map(|(n, _)| *n).collect()
    }
}

fn builtin_prefill() -> Workload {
    Workload::transformer_prefill("prefill", 4096, 7168, 2048, 61)
}

fn builtin_decode() -> Workload {
    Workload::transformer_decode("decode", 64, 7168, 2048, 61)
}

fn builtin_transformer() -> Workload {
    Workload::transformer_serving(4096, 64, 2, 7168, 2048, 61)
}

fn builtin_tiny() -> Workload {
    // Small suite that fits tiny test grids (smoke runs).
    let mut w = Workload::new("tiny");
    w.push("square", GemmShape::new(128, 128, 256), 1);
    w.push("ragged", GemmShape::new(96, 66, 128), 1);
    w.push("flat", GemmShape::new(16, 512, 512), 1);
    w.push("square-again", GemmShape::new(128, 128, 256), 1);
    w
}

/// The single source of truth for builtin suites: `builtin()` dispatches
/// through it and `builtin_names()` projects it.
const BUILTINS: &[(&str, fn() -> Workload)] = &[
    ("prefill", builtin_prefill),
    ("decode", builtin_decode),
    ("transformer", builtin_transformer),
    ("tiny", builtin_tiny),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_prefill_shapes() {
        let w = Workload::transformer_prefill("p", 4096, 7168, 2048, 61);
        assert_eq!(w.items.len(), 4);
        assert_eq!(w.items[0].shape, GemmShape::new(4096, 3 * 7168, 7168));
        assert_eq!(w.items[3].shape, GemmShape::new(4096, 7168, 2048)); // Fig. 9 shape
        assert!(w.items.iter().all(|i| i.count == 61));
        assert_eq!(w.total_count(), 4 * 61);
        assert!(w.total_flops() > 0.0);
    }

    #[test]
    fn decode_shapes_are_flat() {
        let w = Workload::transformer_decode("d", 64, 7168, 2048, 61);
        for item in &w.items {
            assert!(item.shape.is_flat(), "{}: {}", item.label, item.shape);
        }
    }

    #[test]
    fn serving_mix_repeats_decode_shapes() {
        let w = Workload::transformer_serving(4096, 64, 2, 7168, 2048, 61);
        assert_eq!(w.items.len(), 12); // 4 prefill + 2 × 4 decode
        let shapes = w.shapes();
        let mut uniq = shapes.clone();
        uniq.sort_by_key(|s| (s.m, s.n, s.k));
        uniq.dedup();
        assert!(uniq.len() < shapes.len(), "serving mix must repeat shapes");
        assert_eq!(uniq.len(), 8);
    }

    #[test]
    fn builtins_resolve() {
        for name in Workload::builtin_names() {
            let w = Workload::builtin(name).unwrap();
            assert!(!w.items.is_empty(), "{name}");
        }
        assert!(Workload::builtin("nope").is_none());
    }

    #[test]
    fn every_builtin_name_round_trips_through_the_table() {
        // The registry is one table: every advertised name must resolve,
        // the list must be duplicate-free, and nothing outside the list
        // may resolve (guards against match-arm / name-list drift).
        let names = Workload::builtin_names();
        let mut uniq = names.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), names.len(), "duplicate builtin names");
        for name in &names {
            assert!(Workload::builtin(name).is_some(), "{name} does not resolve");
        }
        for bogus in ["", "prefill ", "Prefill", "tiny2"] {
            assert!(Workload::builtin(bogus).is_none(), "{bogus:?} should not resolve");
        }
    }

    #[test]
    fn single_wraps_one_shape() {
        let w = Workload::single("s", GemmShape::new(1, 2, 3));
        assert_eq!(w.items.len(), 1);
        assert_eq!(w.total_count(), 1);
    }
}

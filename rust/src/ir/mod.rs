//! The per-PE program IR (paper contribution 2).
//!
//! "An Intermediate Representation (IR) explicitly models per-PE workload,
//! including data movement, workload mapping and inter-tile communication."
//!
//! A [`Program`] is one compute tile's fully-unrolled instruction stream,
//! organized as BSP supersteps (§3.3.3). Within a superstep the tile's
//! engines run **concurrently**:
//!
//! * the *compute phase* — [`Op::Mmad`] tasklets, executed in program order
//!   on the matrix engine, reading L1 state as of superstep entry (plus
//!   their own chain of writes);
//! * the *communication phase* — DMA transfers and NoC sends/collectives,
//!   each **reading L1 state as of superstep entry** and making writes
//!   visible only at the superstep boundary.
//!
//! The barrier at superstep end waits for both phases on every tile. These
//! semantics make double buffering (§3.3.1) a first-class property: a
//! buffer may not be both compute-touched and comm-written in the same
//! superstep — [`validate`] rejects programs that race, which is exactly
//! the discipline the AST-based superstep description in the paper encodes
//! ("designating the buffers used for computation and those used
//! concurrently for communication within each superstep").

use std::collections::HashMap;

use crate::arch::{ArchConfig, GemmShape};
use crate::collective::{Mask, TileCoord};
use crate::layout::{GemmLayouts, Run};

/// Index of an L1 buffer within a tile's [`Program`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufId(pub u32);

/// An L1 buffer declaration. Sizes are in **bytes** for the element width
/// the deployment was generated at (perf runs use `arch.elem_bytes`,
/// functional runs are always f32).
#[derive(Debug, Clone, PartialEq)]
pub struct BufDecl {
    pub name: String,
    pub bytes: u64,
}

/// One IR operation. Communication ops carry a `tag` that pairs senders
/// with receivers inside the same superstep.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// HBM → L1. `runs` are the coalesced channel bursts (from
    /// [`MatrixLayout::rect_runs`](crate::layout::MatrixLayout::rect_runs)).
    DmaIn { runs: Vec<Run>, dst: BufId },
    /// L1 → HBM.
    DmaOut { src: BufId, runs: Vec<Run> },
    /// Hardware collective multicast: this tile is the root; every member
    /// of `group` (which may include the root) gets `bytes` from `src`
    /// into its own `dst` buffer. Non-root members must post a matching
    /// [`Op::RecvMulticast`].
    Multicast { src: BufId, group: Mask, dst: BufId, bytes: u64, tag: u32 },
    /// Receive leg of a multicast rooted at `from`.
    RecvMulticast { from: TileCoord, dst: BufId, bytes: u64, tag: u32 },
    /// Point-to-point send (systolic neighbour traffic).
    Send { to: TileCoord, src: BufId, bytes: u64, tag: u32 },
    /// Point-to-point receive.
    Recv { from: TileCoord, dst: BufId, bytes: u64, tag: u32 },
    /// Hardware collective reduction: every member of `group` (the root
    /// included) posts this op with its `src` contribution; the elementwise
    /// f32 sum lands in the **root's** `dst` at the superstep boundary.
    Reduce { group: Mask, root: TileCoord, src: BufId, dst: BufId, bytes: u64, tag: u32 },
    /// Matrix-engine tasklet: `c (+)= a[m×k] @ b[k×n]` (f32 accumulate;
    /// `init` zeroes `c` first). Dimensions are in elements.
    Mmad { a: BufId, b: BufId, c: BufId, m: usize, n: usize, k: usize, init: bool },
}

impl Op {
    /// Buffers this op reads during the superstep.
    pub fn reads(&self) -> Vec<BufId> {
        match self {
            Op::DmaIn { .. } | Op::RecvMulticast { .. } | Op::Recv { .. } => vec![],
            Op::DmaOut { src, .. } | Op::Send { src, .. } => vec![*src],
            Op::Multicast { src, .. } => vec![*src],
            Op::Reduce { src, .. } => vec![*src],
            Op::Mmad { a, b, c, init, .. } => {
                if *init {
                    vec![*a, *b]
                } else {
                    vec![*a, *b, *c]
                }
            }
        }
    }

    /// Buffers this op writes (visible at superstep end for comm ops,
    /// immediately within the compute chain for Mmad).
    pub fn writes(&self) -> Vec<BufId> {
        match self {
            Op::DmaIn { dst, .. } | Op::RecvMulticast { dst, .. } | Op::Recv { dst, .. } => {
                vec![*dst]
            }
            Op::Multicast { dst, .. } => vec![*dst],
            Op::Reduce { .. } => vec![], // root's dst handled separately
            Op::DmaOut { .. } | Op::Send { .. } => vec![],
            Op::Mmad { c, .. } => vec![*c],
        }
    }

    pub fn is_compute(&self) -> bool {
        matches!(self, Op::Mmad { .. })
    }
}

/// One BSP superstep of one tile.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Superstep {
    pub ops: Vec<Op>,
}

/// One tile's complete program.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub tile: TileCoord,
    pub bufs: Vec<BufDecl>,
    pub steps: Vec<Superstep>,
}

impl Program {
    pub fn new(tile: TileCoord) -> Program {
        Program { tile, bufs: Vec::new(), steps: Vec::new() }
    }

    /// Declare a buffer, returning its id.
    pub fn buf(&mut self, name: impl Into<String>, bytes: u64) -> BufId {
        let id = BufId(self.bufs.len() as u32);
        self.bufs.push(BufDecl { name: name.into(), bytes });
        id
    }

    /// Total L1 bytes declared.
    pub fn l1_bytes(&self) -> u64 {
        self.bufs.iter().map(|b| b.bytes).sum()
    }

    /// Ensure the program has at least `n` supersteps.
    pub fn reserve_steps(&mut self, n: usize) {
        if self.steps.len() < n {
            self.steps.resize(n, Superstep::default());
        }
    }

    /// Append `op` to superstep `step` (growing as needed).
    pub fn push(&mut self, step: usize, op: Op) {
        self.reserve_steps(step + 1);
        self.steps[step].ops.push(op);
    }

    /// Total MMAD flops in this program.
    pub fn flops(&self) -> f64 {
        self.steps
            .iter()
            .flat_map(|s| &s.ops)
            .map(|op| match op {
                Op::Mmad { m, n, k, .. } => 2.0 * *m as f64 * *n as f64 * *k as f64,
                _ => 0.0,
            })
            .sum()
    }
}

/// A deployed GEMM: per-tile programs + the layouts they address.
///
/// This is the artifact the "Generate and Optimize" stage of the DiT
/// workflow produces, and what both executors (performance [`crate::sim`],
/// functional [`crate::functional`]) consume.
#[derive(Debug, Clone)]
pub struct Deployment {
    /// Physical grid the programs target.
    pub rows: usize,
    pub cols: usize,
    /// One program per participating tile.
    pub programs: Vec<Program>,
    /// HBM layouts (padded dimensions).
    pub layouts: GemmLayouts,
    /// Original (unpadded) problem.
    pub shape: GemmShape,
    /// Padded problem actually computed.
    pub padded: GemmShape,
    /// Human-readable schedule description (for reports).
    pub descr: String,
}

impl Deployment {
    /// Useful flops (of the *unpadded* problem — padding work is overhead).
    pub fn useful_flops(&self) -> f64 {
        self.shape.flops()
    }

    /// Number of supersteps (max across tiles).
    pub fn supersteps(&self) -> usize {
        self.programs.iter().map(|p| p.steps.len()).max().unwrap_or(0)
    }
}

/// IR validation errors.
#[derive(Debug)]
pub enum IrError {
    UndeclaredBuf { tile: TileCoord, buf: BufId, op: String },
    L1OverBudget { tile: TileCoord, used: u64, cap: u64 },
    BufTooSmall { tile: TileCoord, buf: BufId, need: u64, have: u64 },
    BufferRace { tile: TileCoord, step: usize, buf: BufId },
    UnmatchedComm { step: usize, tag: u32, detail: String },
    Malformed { tile: TileCoord, step: usize, detail: String },
    DuplicateProgram(TileCoord),
}

impl std::fmt::Display for IrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IrError::UndeclaredBuf { tile, buf, op } => {
                write!(f, "tile {tile}: buffer {buf:?} undeclared (op {op})")
            }
            IrError::L1OverBudget { tile, used, cap } => {
                write!(f, "tile {tile}: L1 over budget: {used} > {cap} bytes")
            }
            IrError::BufTooSmall { tile, buf, need, have } => {
                write!(f, "tile {tile}: buffer {buf:?} too small: needs {need}, has {have}")
            }
            IrError::BufferRace { tile, step, buf } => write!(
                f,
                "tile {tile} step {step}: double-buffer race on {buf:?}: compute touches while comm writes"
            ),
            IrError::UnmatchedComm { step, tag, detail } => {
                write!(f, "step {step} tag {tag}: unmatched communication: {detail}")
            }
            IrError::Malformed { tile, step, detail } => {
                write!(f, "tile {tile} step {step}: {detail}")
            }
            IrError::DuplicateProgram(tile) => write!(f, "duplicate program for tile {tile}"),
        }
    }
}

impl std::error::Error for IrError {}

/// Validate a deployment against an architecture: buffer discipline,
/// L1 capacity, communication matching, mask sanity.
pub fn validate(arch: &ArchConfig, dep: &Deployment) -> Result<(), IrError> {
    let mut by_tile: HashMap<TileCoord, &Program> = HashMap::new();
    for p in &dep.programs {
        if by_tile.insert(p.tile, p).is_some() {
            return Err(IrError::DuplicateProgram(p.tile));
        }
    }

    // Per-tile checks.
    for p in &dep.programs {
        let cap = arch.tile.l1_bytes as u64;
        if p.l1_bytes() > cap {
            return Err(IrError::L1OverBudget { tile: p.tile, used: p.l1_bytes(), cap });
        }
        for (step_idx, step) in p.steps.iter().enumerate() {
            let mut compute_touched: Vec<BufId> = Vec::new();
            let mut comm_written: Vec<BufId> = Vec::new();
            for op in &step.ops {
                for b in op.reads().iter().chain(op.writes().iter()) {
                    if b.0 as usize >= p.bufs.len() {
                        return Err(IrError::UndeclaredBuf {
                            tile: p.tile,
                            buf: *b,
                            op: format!("{op:?}"),
                        });
                    }
                }
                check_sizes(p, step_idx, op)?;
                if op.is_compute() {
                    compute_touched.extend(op.reads());
                    compute_touched.extend(op.writes());
                } else {
                    comm_written.extend(op.writes());
                    if let Op::Reduce { root, dst, .. } = op {
                        if *root == p.tile {
                            comm_written.push(*dst);
                        }
                    }
                }
            }
            // Double-buffer discipline: comm writes may not touch buffers
            // the compute phase touches in the same superstep.
            for b in &comm_written {
                if compute_touched.contains(b) {
                    return Err(IrError::BufferRace { tile: p.tile, step: step_idx, buf: *b });
                }
            }
        }
    }

    // Communication matching, per superstep and tag.
    let max_steps = dep.supersteps();
    for step in 0..max_steps {
        validate_comm_step(arch, dep, &by_tile, step)?;
    }
    Ok(())
}

fn check_sizes(p: &Program, step: usize, op: &Op) -> Result<(), IrError> {
    let have = |b: &BufId| p.bufs[b.0 as usize].bytes;
    let need_check = |b: &BufId, need: u64| -> Result<(), IrError> {
        if have(b) < need {
            Err(IrError::BufTooSmall { tile: p.tile, buf: *b, need, have: have(b) })
        } else {
            Ok(())
        }
    };
    match op {
        Op::DmaIn { runs, dst } => {
            let total: u64 = runs.iter().map(|r| r.bytes).sum();
            if total == 0 {
                return Err(IrError::Malformed {
                    tile: p.tile,
                    step,
                    detail: "zero-byte DmaIn".into(),
                });
            }
            need_check(dst, total)
        }
        Op::DmaOut { src, runs } => {
            let total: u64 = runs.iter().map(|r| r.bytes).sum();
            need_check(src, total)
        }
        Op::Multicast { src, dst, bytes, .. } => {
            need_check(src, *bytes)?;
            need_check(dst, *bytes)
        }
        Op::RecvMulticast { dst, bytes, .. } | Op::Recv { dst, bytes, .. } => {
            need_check(dst, *bytes)
        }
        Op::Send { src, bytes, .. } => need_check(src, *bytes),
        Op::Reduce { src, dst, bytes, root, .. } => {
            need_check(src, *bytes)?;
            if *root == p.tile {
                need_check(dst, *bytes)
            } else {
                Ok(())
            }
        }
        Op::Mmad { .. } => Ok(()), // element-size dependent; executors check
    }
}

fn validate_comm_step(
    arch: &ArchConfig,
    dep: &Deployment,
    by_tile: &HashMap<TileCoord, &Program>,
    step: usize,
) -> Result<(), IrError> {
    let mut mc_roots: HashMap<u32, (TileCoord, Mask, u64)> = HashMap::new();
    let mut mc_recvs: HashMap<u32, Vec<(TileCoord, TileCoord, u64)>> = HashMap::new();
    let mut sends: HashMap<(u32, TileCoord, TileCoord), u64> = HashMap::new();
    let mut recvs: HashMap<(u32, TileCoord, TileCoord), u64> = HashMap::new();
    let mut reduces: HashMap<u32, Vec<(TileCoord, Mask, TileCoord, u64)>> = HashMap::new();

    for p in &dep.programs {
        let Some(s) = p.steps.get(step) else { continue };
        for op in &s.ops {
            match op {
                Op::Multicast { group, bytes, tag, .. } => {
                    if mc_roots.insert(*tag, (p.tile, *group, *bytes)).is_some() {
                        return Err(IrError::UnmatchedComm {
                            step,
                            tag: *tag,
                            detail: "two multicast roots share a tag".into(),
                        });
                    }
                }
                Op::RecvMulticast { from, bytes, tag, .. } => {
                    mc_recvs.entry(*tag).or_default().push((p.tile, *from, *bytes));
                }
                Op::Send { to, bytes, tag, .. } => {
                    sends.insert((*tag, p.tile, *to), *bytes);
                }
                Op::Recv { from, bytes, tag, .. } => {
                    recvs.insert((*tag, *from, p.tile), *bytes);
                }
                Op::Reduce { group, root, bytes, tag, .. } => {
                    reduces.entry(*tag).or_default().push((p.tile, *group, *root, *bytes));
                }
                _ => {}
            }
        }
    }

    for (tag, (root, group, bytes)) in &mc_roots {
        let members = group.members(arch.rows, arch.cols);
        if members.is_empty() {
            return Err(IrError::UnmatchedComm {
                step,
                tag: *tag,
                detail: format!("multicast from {root} to empty group"),
            });
        }
        for m in &members {
            if *m == *root {
                continue; // self-delivery is local
            }
            if by_tile.contains_key(m) {
                let got = mc_recvs
                    .get(tag)
                    .map(|v| v.iter().any(|(t, f, b)| t == m && f == root && b == bytes));
                if got != Some(true) {
                    return Err(IrError::UnmatchedComm {
                        step,
                        tag: *tag,
                        detail: format!("member {m} missing RecvMulticast from {root}"),
                    });
                }
            }
        }
    }
    for (tag, rs) in &mc_recvs {
        for (tile, from, bytes) in rs {
            match mc_roots.get(tag) {
                Some((root, group, b)) if root == from && b == bytes && group.contains(*tile) => {}
                _ => {
                    return Err(IrError::UnmatchedComm {
                        step,
                        tag: *tag,
                        detail: format!("{tile} RecvMulticast without matching root {from}"),
                    })
                }
            }
        }
    }
    for ((tag, from, to), bytes) in &sends {
        match recvs.get(&(*tag, *from, *to)) {
            Some(b) if b == bytes => {}
            _ => {
                return Err(IrError::UnmatchedComm {
                    step,
                    tag: *tag,
                    detail: format!("send {from}->{to} has no matching recv"),
                })
            }
        }
    }
    for ((tag, from, to), bytes) in &recvs {
        match sends.get(&(*tag, *from, *to)) {
            Some(b) if b == bytes => {}
            _ => {
                return Err(IrError::UnmatchedComm {
                    step,
                    tag: *tag,
                    detail: format!("recv {to}<-{from} has no matching send"),
                })
            }
        }
    }
    for (tag, contribs) in &reduces {
        let (_, group, root, bytes) = contribs[0];
        let members = group.members(arch.rows, arch.cols);
        for (tile, g, r, b) in contribs {
            if *g != group || *r != root || *b != bytes {
                return Err(IrError::UnmatchedComm {
                    step,
                    tag: *tag,
                    detail: "reduce members disagree on group/root/bytes".into(),
                });
            }
            if !group.contains(*tile) {
                return Err(IrError::UnmatchedComm {
                    step,
                    tag: *tag,
                    detail: format!("{tile} reduces but is not in the group"),
                });
            }
        }
        let contributing: Vec<TileCoord> = contribs.iter().map(|c| c.0).collect();
        for m in &members {
            if by_tile.contains_key(m) && !contributing.contains(m) {
                return Err(IrError::UnmatchedComm {
                    step,
                    tag: *tag,
                    detail: format!("group member {m} missing Reduce contribution"),
                });
            }
        }
        if !group.contains(root) {
            return Err(IrError::UnmatchedComm {
                step,
                tag: *tag,
                detail: format!("reduce root {root} outside its group"),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::layout::{GemmLayouts, MatrixLayout};

    fn tiny_layouts() -> GemmLayouts {
        GemmLayouts {
            a: MatrixLayout::base(16, 16, 4, 0),
            b: MatrixLayout::base(16, 16, 4, 1),
            c: MatrixLayout::base(16, 16, 4, 2),
        }
    }

    fn dep_of(programs: Vec<Program>) -> Deployment {
        Deployment {
            rows: 2,
            cols: 2,
            programs,
            layouts: tiny_layouts(),
            shape: GemmShape::new(16, 16, 16),
            padded: GemmShape::new(16, 16, 16),
            descr: "test".into(),
        }
    }

    fn arch() -> ArchConfig {
        ArchConfig::tiny(2, 2)
    }

    #[test]
    fn minimal_valid_program() {
        let l = tiny_layouts();
        let mut p = Program::new(TileCoord::new(0, 0));
        let a = p.buf("a", 1024);
        let b = p.buf("b", 1024);
        let c = p.buf("c", 1024);
        p.push(0, Op::DmaIn { runs: l.a.rect_runs(0, 16, 0, 16), dst: a });
        p.push(0, Op::DmaIn { runs: l.b.rect_runs(0, 16, 0, 16), dst: b });
        p.push(1, Op::Mmad { a, b, c, m: 16, n: 16, k: 16, init: true });
        p.push(2, Op::DmaOut { src: c, runs: l.c.rect_runs(0, 16, 0, 16) });
        validate(&arch(), &dep_of(vec![p])).unwrap();
    }

    #[test]
    fn l1_over_budget_rejected() {
        let mut p = Program::new(TileCoord::new(0, 0));
        p.buf("huge", 10 << 20);
        let err = validate(&arch(), &dep_of(vec![p])).unwrap_err();
        assert!(matches!(err, IrError::L1OverBudget { .. }), "{err}");
    }

    #[test]
    fn buffer_race_rejected() {
        let l = tiny_layouts();
        let mut p = Program::new(TileCoord::new(0, 0));
        let a = p.buf("a", 1024);
        let b = p.buf("b", 1024);
        let c = p.buf("c", 1024);
        // DmaIn writes `a` while Mmad reads `a` in the same superstep:
        // a double-buffering violation.
        p.push(0, Op::DmaIn { runs: l.a.rect_runs(0, 16, 0, 16), dst: a });
        p.push(0, Op::Mmad { a, b, c, m: 16, n: 16, k: 16, init: true });
        let err = validate(&arch(), &dep_of(vec![p])).unwrap_err();
        assert!(matches!(err, IrError::BufferRace { .. }), "{err}");
    }

    #[test]
    fn small_buffer_rejected() {
        let l = tiny_layouts();
        let mut p = Program::new(TileCoord::new(0, 0));
        let a = p.buf("a", 16); // too small for 16x16 f32
        p.push(0, Op::DmaIn { runs: l.a.rect_runs(0, 16, 0, 16), dst: a });
        let err = validate(&arch(), &dep_of(vec![p])).unwrap_err();
        assert!(matches!(err, IrError::BufTooSmall { .. }), "{err}");
    }

    #[test]
    fn multicast_requires_matching_recvs() {
        let mut root = Program::new(TileCoord::new(0, 0));
        let src = root.buf("src", 64);
        let dst = root.buf("dst", 64);
        root.push(
            0,
            Op::Multicast { src, group: Mask::row(0, 2), dst, bytes: 64, tag: 7 },
        );
        // (0,1) is in row 0 but posts no RecvMulticast.
        let mut other = Program::new(TileCoord::new(0, 1));
        other.buf("x", 64);
        other.reserve_steps(1);
        let err = validate(&arch(), &dep_of(vec![root, other])).unwrap_err();
        assert!(matches!(err, IrError::UnmatchedComm { .. }), "{err}");
    }

    #[test]
    fn multicast_with_recvs_ok() {
        let mut root = Program::new(TileCoord::new(0, 0));
        let src = root.buf("src", 64);
        let dst = root.buf("dst", 64);
        root.push(
            0,
            Op::Multicast { src, group: Mask::row(0, 2), dst, bytes: 64, tag: 7 },
        );
        let mut other = Program::new(TileCoord::new(0, 1));
        let d2 = other.buf("dst", 64);
        other.push(
            0,
            Op::RecvMulticast { from: TileCoord::new(0, 0), dst: d2, bytes: 64, tag: 7 },
        );
        validate(&arch(), &dep_of(vec![root, other])).unwrap();
    }

    #[test]
    fn send_without_recv_rejected() {
        let mut s = Program::new(TileCoord::new(0, 0));
        let b = s.buf("b", 64);
        s.push(0, Op::Send { to: TileCoord::new(0, 1), src: b, bytes: 64, tag: 1 });
        let mut r = Program::new(TileCoord::new(0, 1));
        r.buf("x", 64);
        let err = validate(&arch(), &dep_of(vec![s, r])).unwrap_err();
        assert!(matches!(err, IrError::UnmatchedComm { .. }), "{err}");
    }

    #[test]
    fn reduce_all_members_must_contribute() {
        let root_t = TileCoord::new(0, 0);
        let group = Mask::col(0, 2); // (0,0) and (1,0)
        let mk = |t: TileCoord| {
            let mut p = Program::new(t);
            let src = p.buf("src", 64);
            let dst = p.buf("dst", 64);
            p.push(0, Op::Reduce { group, root: root_t, src, dst, bytes: 64, tag: 3 });
            p
        };
        validate(&arch(), &dep_of(vec![mk(TileCoord::new(0, 0)), mk(TileCoord::new(1, 0))]))
            .unwrap();
        // One member silent: rejected.
        let mut silent = Program::new(TileCoord::new(1, 0));
        silent.buf("x", 64);
        silent.reserve_steps(1);
        let err =
            validate(&arch(), &dep_of(vec![mk(TileCoord::new(0, 0)), silent])).unwrap_err();
        assert!(matches!(err, IrError::UnmatchedComm { .. }), "{err}");
    }

    #[test]
    fn duplicate_programs_rejected() {
        let p1 = Program::new(TileCoord::new(0, 0));
        let p2 = Program::new(TileCoord::new(0, 0));
        let err = validate(&arch(), &dep_of(vec![p1, p2])).unwrap_err();
        assert!(matches!(err, IrError::DuplicateProgram(_)));
    }

    #[test]
    fn flops_accounting() {
        let mut p = Program::new(TileCoord::new(0, 0));
        let a = p.buf("a", 4096);
        let b = p.buf("b", 4096);
        let c = p.buf("c", 4096);
        p.push(0, Op::Mmad { a, b, c, m: 8, n: 8, k: 8, init: true });
        p.push(1, Op::Mmad { a, b, c, m: 8, n: 8, k: 8, init: false });
        assert_eq!(p.flops(), 2.0 * 2.0 * 512.0);
    }
}

//! The `dit` command-line interface (hand-rolled; no clap offline).
//!
//! ```text
//! dit arch      --preset gh200|a100|tiny4            # show/save a config
//! dit candidates --preset P --shape MxNxK            # list schedules
//! dit simulate  --preset P --shape MxNxK [--schedule NAME] [--tk N] ...
//! dit autotune  --preset P --shape MxNxK             # rank all candidates
//! dit tune-workload --preset P --suite transformer   # batch-tune a suite
//! dit tune-workload --preset P --graph attn-prefill  # tune a multi-op graph
//! dit dse       --workload serving [--spec FILE]     # hardware design-space sweep
//! dit serve     --preset P --trace FILE [--cache DIR] # replay a schedule-request trace
//! dit check     --config FILE [--spec FILE] [--trace FILE]  # static lint, zero simulations
//! dit verify    --shape MxNxK [--grid RxC] [--schedule NAME]   # vs oracle
//! dit fig       --id 7a|7b|7c|7d|8|9|10|11|12|1|table1  # regen a figure
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::arch::workload::Workload;
use crate::arch::{ArchConfig, GemmShape};
use crate::coordinator;
use crate::coordinator::engine::{Engine, TunePolicy, DEFAULT_EXPLORE, DEFAULT_TOP_K};
use crate::dse::{DseOptions, Objective, SweepSpec};
use crate::report::Table;
use crate::schedule::{candidates, Dataflow, Schedule};

/// Parsed CLI arguments: positional command + `--key value` flags.
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let command = argv.first().cloned().unwrap_or_else(|| "help".to_string());
        Args::with_flags(command, argv.get(1..).unwrap_or_default())
    }

    /// Parse `--key value` pairs under an already-known command (used by
    /// commands with a positional sub-action, e.g. `cache stats`).
    pub fn with_flags(command: String, rest: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut it = rest.iter();
        while let Some(arg) = it.next() {
            let key = arg
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {arg:?}"))?;
            let value = it.next().with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), value.clone());
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, dflt: &'a str) -> &'a str {
        self.get(key).unwrap_or(dflt)
    }
}

/// Parse `MxNxK` into a [`GemmShape`] (the shared grammar lives on
/// [`GemmShape::parse`] so the CLI, the cache and serve traces agree).
pub fn parse_shape(s: &str) -> Result<GemmShape> {
    GemmShape::parse(s)
}

/// Resolve an architecture preset or config file.
pub fn parse_arch(spec: &str) -> Result<ArchConfig> {
    match spec {
        "gh200" => Ok(ArchConfig::gh200_like()),
        "a100" => Ok(ArchConfig::a100_like()),
        _ if spec.starts_with("tiny") => {
            // Bare `tiny` means the 4x4 default; any other suffix must be
            // a number. `unwrap_or(4)` here used to map typos like
            // `tinyzzz` to a silently different machine.
            let digits = &spec["tiny".len()..];
            let n: usize = if digits.is_empty() {
                4
            } else {
                digits.parse().with_context(|| {
                    format!("unknown preset {spec:?} (tinyN takes a numeric grid size)")
                })?
            };
            let a = ArchConfig::tiny(n, n);
            a.validate().with_context(|| format!("invalid tiny grid {spec:?}"))?;
            Ok(a)
        }
        path => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("unknown preset and unreadable file: {path:?}"))?;
            ArchConfig::from_text(&text)
                .with_context(|| format!("invalid architecture config {path:?}"))
        }
    }
}

/// Resolve a builtin workload-graph name or a `.graph` text file.
pub fn parse_graph(spec: &str) -> Result<crate::graph::WorkloadGraph> {
    use crate::graph::WorkloadGraph;
    if let Some(g) = WorkloadGraph::builtin(spec) {
        return Ok(g);
    }
    let text = std::fs::read_to_string(spec).with_context(|| {
        format!(
            "unknown builtin graph and unreadable file: {spec:?} (builtins: {:?})",
            WorkloadGraph::builtin_names()
        )
    })?;
    WorkloadGraph::from_text(&text).with_context(|| format!("invalid workload graph {spec:?}"))
}

/// Build a schedule from CLI flags.
pub fn parse_schedule(args: &Args, arch: &ArchConfig, shape: GemmShape) -> Result<Schedule> {
    let name = args.get_or("schedule", "summa");
    let mut s = match name {
        "summa" => Schedule::summa(arch, shape),
        "baseline" => Schedule::baseline(arch, shape),
        "systolic" => Schedule::systolic(arch, shape),
        "splitk" => {
            let splits: usize = args.get_or("splits", "4").parse().context("--splits")?;
            Schedule::splitk(arch, shape, splits)
        }
        "flat" => {
            let splits: usize = args.get_or("splits", "8").parse().context("--splits")?;
            Schedule::flat_remap(arch, shape, splits)
        }
        "systolic-over-summa" => Schedule {
            dataflow: Dataflow::SystolicOverSumma {
                group: args.get_or("group", "2").parse().context("--group")?,
            },
            ..Schedule::summa(arch, shape)
        },
        "summa-over-systolic" => Schedule {
            dataflow: Dataflow::SummaOverSystolic {
                group: args.get_or("group", "2").parse().context("--group")?,
            },
            ..Schedule::summa(arch, shape)
        },
        other => bail!("unknown schedule {other:?}"),
    };
    if let Some(tk) = args.get("tk") {
        s.tk = tk.parse().context("--tk")?;
    }
    if let Some(ps) = args.get("stages") {
        s.pipeline_stages = ps.parse().context("--stages")?;
    }
    if let Some(db) = args.get("double-buffer") {
        s.double_buffer = db.parse().context("--double-buffer")?;
    }
    if let Some(ol) = args.get("opt-layout") {
        s.opt_layout = ol.parse().context("--opt-layout")?;
    }
    Ok(s)
}

/// Parse the tiered-tuning flags shared by `tune-workload` and `dse`:
/// `--tiered bool` switches the engine to the analytic-first policy;
/// `--top-k N` / `--explore N` size the simulated head and the
/// deterministic exploration band (defaults 4 and 2). The knobs are
/// rejected without `--tiered true` so a typo cannot silently run
/// exhaustively.
pub fn parse_policy(args: &Args) -> Result<TunePolicy> {
    let tiered: bool = match args.get("tiered") {
        Some(v) => v.parse().context("--tiered")?,
        None => false,
    };
    if !tiered {
        anyhow::ensure!(
            args.get("top-k").is_none() && args.get("explore").is_none(),
            "--top-k/--explore only apply with --tiered true"
        );
        return Ok(TunePolicy::Exhaustive);
    }
    let top_k: usize = match args.get("top-k") {
        Some(v) => v.parse().context("--top-k")?,
        None => DEFAULT_TOP_K,
    };
    let explore: usize = match args.get("explore") {
        Some(v) => v.parse().context("--explore")?,
        None => DEFAULT_EXPLORE,
    };
    anyhow::ensure!(top_k >= 1, "--top-k must be at least 1");
    Ok(TunePolicy::Tiered { top_k, explore })
}

const HELP: &str = "\
dit — Design in Tiles: automated GEMM deployment on tile-based many-PE accelerators

USAGE: dit <command> [--flag value]...

COMMANDS:
  arch        --preset gh200|a100|tiny4 [--save FILE]   show or save a config
  candidates  --preset P --shape MxNxK                  list candidate schedules
  simulate    --preset P --shape MxNxK [--schedule S]   simulate one deployment
              [--tk N] [--stages N] [--double-buffer b] [--opt-layout b]
              [--splits N] [--group N]
  autotune    --preset P --shape MxNxK                  rank all candidates
  tune-workload --preset P [--suite NAME]               batch-tune a GEMM suite
              [--shapes MxNxK,MxNxK,...] [--workers N]  (suites: prefill, decode,
              [--csv true] [--cache FILE]                transformer, tiny)
              [--tiered true] [--top-k N] [--explore N] analytic-first tiering: rank
                                                        candidates closed-form, simulate
                                                        only the top-k + exploration band
              [--graph NAME|FILE]                       tune a multi-op workload graph
                                                        instead: co-tunes every GEMM op
                                                        and classifies each edge as
                                                        SPM-resident (fused, skips HBM)
                                                        or spilled (builtin graphs:
                                                        attn-prefill, attn-decode,
                                                        mlp-chain)
  dse         [--workload serving|prefill|decode|tiny]  hardware design-space sweep:
              [--spec FILE] [--full true]               co-tune every config, print the
              [--base PRESET] [--mesh 8,16x4,4x16]      Pareto frontier over the chosen
              [--spm 256,384] [--workers N] [--wave N]  objectives (RxC = rectangular
              [--prune bool] [--csv true] [--json FILE]  mesh, N = square sugar)
              [--prune-slack 0.05]                      roofline prune safety margin,
                                                        a fraction in [0, 0.5]
              [--static-precheck bool]                  statically reject undeployable
                                                        configs before simulating
                                                        (default true)
              [--tiered true] [--top-k N] [--explore N] tiered per-config inner loop
              [--objectives perf,cost,energy]           3-axis frontier + projections
              [--weights 0.5,0.3,0.2]                   scalarized single winner
              [--energy-coeffs FILE]                    pJ table ([energy] section)
              [--cache FILE]                            persistent simulation cache:
                                                        killed sweeps resume, refined
                                                        sweeps reuse overlapping points
  serve       --preset P --trace FILE                   replay a GEMM request trace
              [--cache DIR] [--epsilon E] [--shards N]  through the schedule server:
              [--workers N] [--drain N]                 exact hits, analytically
              [--tiered bool] [--top-k N] [--explore N] eps-bounded neighbor reuse
                                                        (penalty <= E vs the analytic
                                                        best), misses tune + persist;
                                                        tiered policy is the default
  serve       --gen-trace PATH [--seed N] [--len N]     write a deterministic Zipf
                                                        request trace and exit
  cache       stats --cache FILE|DIR                    inspect a simulation cache
              clear --cache FILE|DIR                    delete it (+ stray temp files;
                                                        DIR = sharded serve cache)
  check       [--preset P] [--config FILE,...]          static deployment checker:
              [--spec FILE,...] [--shapes MxNxK,...]    lint configs, sweep specs and
              [--suite NAME] [--trace FILE]             workloads with structured
              [--graph NAME|FILE,...]                   DIT-Exxx diagnostics; zero
              [--json true]                             simulations, errors exit
                                                        non-zero (warnings stay green);
                                                        --graph lints multi-op workload
                                                        graphs (structure, edge shapes,
                                                        SPM residency)
  verify      --shape MxNxK [--grid N] [--schedule S]   functional vs golden oracle
              [--artifacts DIR] [--seed N]               (CPU reference if no PJRT)
  help                                                  this text

EXAMPLES:
  dit simulate --preset gh200 --shape 4096x2112x7168 --schedule summa
  dit autotune --preset gh200 --shape 64x2112x7168
  dit tune-workload --preset gh200 --suite transformer
  dit tune-workload --preset gh200 --suite transformer --tiered true --top-k 4
  dit tune-workload --preset gh200 --graph attn-prefill
  dit dse      --workload serving
  dit dse      --workload serving --tiered true        # analytic-first inner loop
  dit dse      --workload serving --objectives perf,cost,energy --weights 0.5,0.2,0.3
  dit dse      --workload serving --cache sweep.cache   # re-run resumes from disk
  dit cache    stats --cache sweep.cache
  dit serve    --gen-trace traces/serve_zipf.txt --seed 7 --len 512
  dit serve    --preset tiny8 --trace traces/serve_zipf.txt --cache serve.cache --drain 4
  dit check    --config configs/gh200.dit --spec configs/sweep_reduced.dit
  dit check    --preset gh200 --graph configs/attention_prefill.graph
  dit check    --preset tiny8 --trace traces/serve_zipf.txt
  dit verify   --shape 128x128x128 --grid 4 --schedule splitk --splits 2
";

/// CLI entry point (called from main).
pub fn run(argv: &[String]) -> Result<()> {
    // `cache` takes a positional sub-action (`dit cache stats --cache F`).
    if argv.first().map(String::as_str) == Some("cache") {
        let action = argv.get(1).map(String::as_str).unwrap_or("stats");
        if action.starts_with("--") {
            bail!("usage: dit cache <stats|clear> --cache FILE|DIR");
        }
        let args = Args::with_flags("cache".to_string(), argv.get(2..).unwrap_or_default())?;
        return cmd_cache(action, &args);
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "arch" => cmd_arch(&args),
        "candidates" => cmd_candidates(&args),
        "simulate" => cmd_simulate(&args),
        "autotune" => cmd_autotune(&args),
        "tune-workload" => cmd_tune_workload(&args),
        "dse" => cmd_dse(&args),
        "serve" => cmd_serve(&args),
        "check" => cmd_check(&args),
        "verify" => cmd_verify(&args),
        other => bail!("unknown command {other:?}; try `dit help`"),
    }
}

/// Inspect or delete a persistent simulation cache — a single `.jsonl`
/// file, or a sharded directory written by the schedule server
/// ([`crate::coordinator::cache::ShardedDiskCache`]). A directory is
/// inspected by scanning its actual `shard-*.jsonl` files, so stats work
/// regardless of the shard count the server was opened with.
fn cmd_cache(action: &str, args: &Args) -> Result<()> {
    use crate::coordinator::cache::{DiskCache, ShardedDiskCache, FORMAT, VERSION};
    let path = args.get("cache").context("--cache FILE|DIR required")?;
    let sharded = std::path::Path::new(path).is_dir();
    match action {
        "stats" => {
            // A path that is neither a shard directory nor a v1 cache
            // file gets a DIT-E072 diagnostic, not zero-entry stats.
            if !sharded {
                probe_cache_v1(path)?;
            }
            // A sharded directory aggregates per-shard caches; a plain
            // file is a one-element aggregate of itself.
            let shard_files: Vec<std::path::PathBuf> = if sharded {
                let mut files: Vec<_> = std::fs::read_dir(path)
                    .with_context(|| format!("reading cache directory {path}"))?
                    .filter_map(|e| e.ok())
                    .map(|e| e.path())
                    .filter(|p| {
                        p.file_name()
                            .and_then(|n| n.to_str())
                            .map(|n| n.starts_with("shard-") && n.ends_with(".jsonl"))
                            .unwrap_or(false)
                    })
                    .collect();
                files.sort();
                files
            } else {
                vec![std::path::PathBuf::from(path)]
            };
            let mut entries = 0usize;
            let mut infeasible = 0usize;
            let mut size = 0u64;
            let mut counts: HashMap<u64, usize> = HashMap::new();
            for file in &shard_files {
                let cache = DiskCache::open(file);
                let name = file.file_name().and_then(|n| n.to_str()).unwrap_or("?");
                for w in cache.warnings() {
                    if sharded {
                        println!("warning    : {name}: {w}");
                    } else {
                        println!("warning    : {w}");
                    }
                }
                entries += cache.len();
                infeasible += cache.infeasible_count();
                size += std::fs::metadata(file).map(|m| m.len()).unwrap_or(0);
                for (fp, n) in cache.fingerprint_counts() {
                    *counts.entry(fp).or_insert(0) += n;
                }
            }
            if sharded {
                println!("cache dir  : {path} ({} shard files)", shard_files.len());
            } else {
                println!("cache file : {path}");
            }
            println!("format     : {FORMAT} v{VERSION}");
            println!(
                "entries    : {} ({} deployable, {} recorded-infeasible), {} on disk",
                entries,
                entries - infeasible,
                infeasible,
                crate::util::human_bytes(size)
            );
            let mut counts: Vec<(u64, usize)> = counts.into_iter().collect();
            counts.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            if !counts.is_empty() {
                let mut t = Table::new(
                    "entries per architecture fingerprint",
                    &["fingerprint", "entries"],
                );
                for (fp, n) in counts {
                    t.row(vec![format!("{fp:016x}"), n.to_string()]);
                }
                print!("{}", t.markdown());
            }
            Ok(())
        }
        "clear" => {
            if sharded {
                let (files, temps) = ShardedDiskCache::clear(path)?;
                println!(
                    "removed {files} shard file{} at {path} ({temps} stray temp file{} removed)",
                    if files == 1 { "" } else { "s" },
                    if temps == 1 { "" } else { "s" }
                );
            } else {
                let (removed, temps) = DiskCache::clear(path)?;
                println!(
                    "{} {path} ({temps} stray temp file{} removed)",
                    if removed { "removed" } else { "no cache file at" },
                    if temps == 1 { "" } else { "s" }
                );
            }
            Ok(())
        }
        other => bail!("unknown cache action {other:?}; usage: dit cache <stats|clear>"),
    }
}

/// Refuse to "inspect" something that is not a simulation cache. A
/// missing path, an unreadable file, or a file whose first line is not
/// the v1 header used to fall through to `DiskCache::open` and print
/// zero-entry stats for, say, a typo'd path — now it is a
/// [`crate::analysis::codes::E072`] diagnostic.
fn probe_cache_v1(path: &str) -> Result<()> {
    use crate::analysis::{codes, Diag, Loc, Severity};
    use crate::coordinator::cache::{FORMAT, VERSION};
    use crate::util::json::Json;
    let fail = |message: String| {
        anyhow::anyhow!(
            "{}",
            Diag {
                code: codes::E072.0,
                name: codes::E072.1,
                severity: Severity::Error,
                loc: Loc::none(),
                message,
            }
        )
    };
    let text = std::fs::read_to_string(path).map_err(|e| {
        fail(format!("{path} is not a readable cache file or shard directory ({e})"))
    })?;
    let first = text.lines().next().unwrap_or("").trim();
    let v1 = Json::parse(first).ok().is_some_and(|h| {
        h.get("format").and_then(Json::as_str) == Some(FORMAT)
            && h.get("version").and_then(Json::as_i64) == Some(VERSION)
    });
    if !v1 {
        return Err(fail(format!(
            "{path} is not a {FORMAT} v{VERSION} cache (header line is {first:?}); \
             pass a cache .jsonl file or a sharded serve-cache directory"
        )));
    }
    Ok(())
}

/// Replay a GEMM request trace through the schedule server (or, with
/// `--gen-trace`, write a deterministic Zipf trace and exit). Serving
/// defaults to the tiered tuning policy — a cache-miss on the serving
/// path should simulate as little as possible; pass `--tiered false`
/// to force exhaustive tuning on misses.
fn cmd_serve(args: &Args) -> Result<()> {
    use crate::coordinator::shapedb::{self, ScheduleServer, ServeConfig};

    if let Some(path) = args.get("gen-trace") {
        let seed: u64 = args.get_or("seed", "7").parse().context("--seed")?;
        let len: usize = args.get_or("len", "512").parse().context("--len")?;
        anyhow::ensure!(len > 0, "--len must be positive");
        let trace = shapedb::zipf_trace(seed, len);
        std::fs::write(path, shapedb::render_trace(&trace, seed))
            .with_context(|| format!("writing trace {path:?}"))?;
        println!("wrote      : {len} requests (seed {seed}) to {path}");
        return Ok(());
    }

    let arch = parse_arch(args.get_or("preset", "gh200"))?;
    let trace_path =
        args.get("trace").context("--trace FILE required (or --gen-trace PATH)")?;
    let trace = shapedb::load_trace(trace_path)?;

    let mut cfg = ServeConfig::default();
    // parse_policy defaults to Exhaustive when the tiering flags are
    // absent; serving defaults to tiered, so only consult it when the
    // user said something.
    if args.get("tiered").is_some() || args.get("top-k").is_some() || args.get("explore").is_some()
    {
        cfg.policy = parse_policy(args)?;
    }
    if let Some(e) = args.get("epsilon") {
        cfg.epsilon = e.parse().context("--epsilon")?;
    }
    if let Some(s) = args.get("shards") {
        cfg.shards = s.parse().context("--shards")?;
        anyhow::ensure!(cfg.shards >= 1, "--shards must be at least 1");
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = Some(w.parse().context("--workers")?);
    }
    let server = match args.get("cache") {
        Some(dir) => ScheduleServer::open(&arch, dir, cfg)?,
        None => ScheduleServer::in_memory(&arch, cfg)?,
    };

    for &shape in &trace {
        server.serve(shape)?;
    }
    let drain: usize = args.get_or("drain", "0").parse().context("--drain")?;
    if drain > 0 {
        let done = server.drain_retunes(drain)?;
        println!("drained    : {done} queued retune{}", if done == 1 { "" } else { "s" });
    }
    if args.get("cache").is_some() {
        server.flush()?;
    }

    let stats = server.stats();
    print!("{}", crate::report::serve_summary(&stats).markdown());
    println!(
        "replay     : {} from {trace_path}, eps {} ({:.1}% answered without tuning)",
        trace.len(),
        server.epsilon(),
        100.0 * stats.hit_rate()
    );
    println!("{}", crate::report::serve_counters(&stats));
    if let Some(dir) = args.get("cache") {
        println!(
            "cache dir  : {dir} ({} entries, {} preloaded this run)",
            server.disk_len(),
            server.disk_loaded()
        );
    }
    Ok(())
}

fn cmd_arch(args: &Args) -> Result<()> {
    let arch = parse_arch(args.get_or("preset", "gh200"))?;
    let text = arch.to_text();
    if let Some(path) = args.get("save") {
        std::fs::write(path, &text)?;
        println!("saved {} to {path}", arch.name);
    } else {
        print!("{text}");
        println!(
            "# derived: {} tiles, {:.0} TFLOPS peak, {:.0} GB/s HBM",
            arch.num_tiles(),
            arch.peak_tflops(),
            arch.hbm.total_gbps()
        );
    }
    Ok(())
}

fn cmd_candidates(args: &Args) -> Result<()> {
    let arch = parse_arch(args.get_or("preset", "gh200"))?;
    let shape = parse_shape(args.get("shape").context("--shape required")?)?;
    let mut t = Table::new(
        format!("candidate schedules for {shape} on {}", arch.name),
        &["schedule", "logical", "tk", "l1_bytes"],
    );
    for s in candidates(&arch, shape) {
        t.row(vec![
            s.name(),
            format!("{}x{}x{}", s.logical.0, s.logical.1, s.splits()),
            s.tk.to_string(),
            crate::schedule::l1_estimate(&arch, shape, &s).to_string(),
        ]);
    }
    print!("{}", t.markdown());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let arch = parse_arch(args.get_or("preset", "gh200"))?;
    let shape = parse_shape(args.get("shape").context("--shape required")?)?;
    let sched = parse_schedule(args, &arch, shape)?;
    let stats = coordinator::simulate_schedule(&arch, shape, &sched)?;
    println!("schedule   : {}", sched.name());
    println!("supersteps : {}", stats.supersteps);
    println!("makespan   : {}", crate::util::human_time_ns(stats.makespan_ns));
    println!("throughput : {:.1} TFLOP/s ({:.1}% of {:.0} peak)",
        stats.tflops(), 100.0 * stats.utilization(), stats.peak_tflops);
    println!("hbm traffic: {} read, {} write ({:.0} GB/s, {:.1}% of peak)",
        crate::util::human_bytes(stats.hbm_read_bytes),
        crate::util::human_bytes(stats.hbm_write_bytes),
        stats.hbm_gbps(),
        100.0 * stats.hbm_utilization());
    println!("intensity  : {:.1} FLOP/B", stats.intensity());
    if args.get("steps").is_some() {
        let mut prev = 0.0;
        for (i, end) in stats.step_end_ns.iter().enumerate() {
            println!("  step {i:>3}: {:>10}", crate::util::human_time_ns(end - prev));
            prev = *end;
        }
    }
    Ok(())
}

fn cmd_autotune(args: &Args) -> Result<()> {
    let arch = parse_arch(args.get_or("preset", "gh200"))?;
    let shape = parse_shape(args.get("shape").context("--shape required")?)?;
    let result = coordinator::autotune(&arch, shape)?;
    let mut t = Table::new(
        format!("autotune {shape} on {}", arch.name),
        &["rank", "schedule", "TFLOP/s", "util %", "HBM %", "makespan"],
    );
    for (i, s) in result.ranking.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            s.schedule.name(),
            format!("{:.1}", s.stats.tflops()),
            format!("{:.1}", 100.0 * s.stats.utilization()),
            format!("{:.1}", 100.0 * s.stats.hbm_utilization()),
            crate::util::human_time_ns(s.stats.makespan_ns),
        ]);
    }
    print!("{}", t.markdown());
    Ok(())
}

/// Batch-tune a named (or ad-hoc `--shapes`) GEMM suite on the parallel
/// memoizing engine and print the per-shape + aggregate report. With
/// `--graph` the subject is a multi-op [`crate::graph::WorkloadGraph`]
/// instead: every GEMM op is co-tuned through the same engine and each
/// edge is classified SPM-resident vs spilled, with the fused HBM
/// traffic reported next to the edge-free lowering.
fn cmd_tune_workload(args: &Args) -> Result<()> {
    let arch = parse_arch(args.get_or("preset", "gh200"))?;
    if let Some(spec) = args.get("graph") {
        anyhow::ensure!(
            args.get("shapes").is_none() && args.get("suite").is_none(),
            "--graph replaces --shapes/--suite; pass one or the other"
        );
        let g = parse_graph(spec)?;
        let mut engine = Engine::new(&arch).with_policy(parse_policy(args)?);
        if let Some(n) = args.get("workers") {
            engine = engine.with_workers(n.parse().context("--workers")?);
        }
        if let Some(path) = args.get("cache") {
            engine = engine.with_cache(path);
        }
        let grep = engine.tune_graph(&g)?;
        print!("{}", crate::report::workload_summary(&grep.report).markdown());
        print!("{}", crate::report::graph_edges(&grep).markdown());
        println!(
            "aggregate  : {} per pass, {:.1} TFLOP/s weighted over {} GEMM executions",
            crate::util::human_time_ns(grep.report.total_time_ns()),
            grep.report.aggregate_tflops(),
            grep.report.total_count(),
        );
        println!("{}", crate::report::workload_counters(&grep.report));
        println!("{}", crate::report::graph_counters(&grep));
        if let Some(path) = args.get("cache") {
            engine.flush_cache()?;
            println!(
                "cache file : {path} ({} entries, {} preloaded this run)",
                engine.disk_len(),
                engine.disk_loaded()
            );
        }
        return Ok(());
    }
    let workload = match args.get("shapes") {
        Some(list) => {
            let mut w = Workload::new("custom");
            for (i, spec) in list.split(',').enumerate() {
                w.push(format!("gemm{i}"), parse_shape(spec.trim())?, 1);
            }
            w
        }
        None => {
            let name = args.get_or("suite", "transformer");
            Workload::builtin(name).with_context(|| {
                format!("unknown suite {name:?}; available: {:?}", Workload::builtin_names())
            })?
        }
    };
    let mut engine = Engine::new(&arch).with_policy(parse_policy(args)?);
    if let Some(n) = args.get("workers") {
        engine = engine.with_workers(n.parse().context("--workers")?);
    }
    if let Some(path) = args.get("cache") {
        engine = engine.with_cache(path);
    }
    let csv: bool = match args.get("csv") {
        Some(v) => v.parse().context("--csv")?,
        None => false,
    };
    let rep = engine.tune_workload(&workload)?;
    let table = crate::report::workload_summary(&rep);
    if csv {
        print!("{}", table.csv());
    } else {
        print!("{}", table.markdown());
    }
    println!(
        "aggregate  : {} per pass, {:.1} TFLOP/s weighted over {} GEMM executions",
        crate::util::human_time_ns(rep.total_time_ns()),
        rep.aggregate_tflops(),
        rep.total_count(),
    );
    println!("{}", crate::report::workload_counters(&rep));
    if let Some(path) = args.get("cache") {
        engine.flush_cache()?;
        println!(
            "cache file : {path} ({} entries, {} preloaded this run)",
            engine.disk_len(),
            engine.disk_loaded()
        );
    }
    Ok(())
}

/// Hardware design-space sweep: enumerate the spec's configurations,
/// co-tune each over the chosen workload on one shared engine, and print
/// the Pareto frontier of achieved TFLOP/s vs. the silicon-cost proxy.
fn cmd_dse(args: &Args) -> Result<()> {
    let mut spec = match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("unreadable sweep spec {path:?}"))?;
            SweepSpec::from_text(&text).with_context(|| format!("invalid sweep spec {path:?}"))?
        }
        None => {
            let full: bool = match args.get("full") {
                Some(v) => v.parse().context("--full")?,
                None => false,
            };
            if full {
                SweepSpec::full()
            } else {
                SweepSpec::reduced()
            }
        }
    };
    if let Some(b) = args.get("base") {
        // Re-anchor the sweep on another template: single-point axes come
        // from the base machine, mesh stays swept (override with --mesh).
        let base = parse_arch(b)?;
        spec.ce = vec![(base.tile.ce_m, base.tile.ce_n)];
        spec.spm_kib = vec![base.tile.l1_bytes / 1024];
        spec.hbm_channel_gbps = vec![base.hbm.channel_gbps];
        // Preserve the base machine's channel population relative to its
        // own shorter mesh edge — the inverse of the sweep's derivation
        // rule (`SweepSpec::hbm_channels_per_edge`), round-to-nearest.
        // Presets have channels_per_edge == rows == cols, i.e. 100%, but
        // a custom config may be sparser or rectangular.
        let edge = base.rows.min(base.cols).max(1);
        spec.hbm_channels_pct =
            vec![((base.hbm.channels_per_edge * 100 + edge / 2) / edge).max(1)];
        spec.dma_engines = vec![base.tile.dma_engines];
        spec.base = base;
    }
    // --mesh accepts a comma list mixing square sugar and explicit
    // geometries: `8` is 8x8, `16x4` is 16 rows x 4 columns. Zero
    // dimensions are rejected here (matching the spec-file parser) —
    // enumerate() silently drops validate() failures, so a `0x4` typo
    // would otherwise vanish from the sweep without a diagnostic.
    if let Some(list) = args.get("mesh") {
        let mut meshes = Vec::new();
        for tok in list.split(',') {
            let tok = tok.trim();
            let (rows, cols) = match tok.split_once('x') {
                Some((r, c)) => (
                    r.trim().parse::<usize>().with_context(|| format!("--mesh rows in {tok:?}"))?,
                    c.trim().parse::<usize>().with_context(|| format!("--mesh cols in {tok:?}"))?,
                ),
                None => {
                    let n = tok.parse::<usize>().with_context(|| format!("--mesh {tok:?}"))?;
                    (n, n)
                }
            };
            anyhow::ensure!(rows > 0 && cols > 0, "--mesh {tok:?}: dimensions must be positive");
            meshes.push((rows, cols));
        }
        spec.meshes = meshes;
    }
    if let Some(list) = args.get("spm") {
        spec.spm_kib = list
            .split(',')
            .map(|s| s.trim().parse::<usize>().context("--spm"))
            .collect::<Result<Vec<usize>>>()?;
    }

    let suite_name = args.get_or("workload", "serving");
    let workload = crate::dse::suite(suite_name).with_context(|| {
        format!("unknown DSE workload {suite_name:?}; available: {:?}", crate::dse::suite_names())
    })?;

    let mut opts = DseOptions::default();
    if let Some(n) = args.get("workers") {
        opts.workers = n.parse().context("--workers")?;
    }
    if let Some(n) = args.get("wave") {
        opts.config_parallelism = n.parse().context("--wave")?;
    }
    if let Some(v) = args.get("prune") {
        opts.prune = v.parse().context("--prune")?;
    }
    if let Some(v) = args.get("prune-slack") {
        opts.prune_slack = v.parse().context("--prune-slack")?;
    }
    if let Some(v) = args.get("static-precheck") {
        opts.static_precheck = v.parse().context("--static-precheck")?;
    }
    opts.policy = parse_policy(args)?;
    if let Some(path) = args.get("cache") {
        opts.cache_path = Some(path.into());
    }
    if let Some(list) = args.get("objectives") {
        opts.objectives = Objective::parse_list(list).context("--objectives")?;
    }
    if let Some(path) = args.get("energy-coeffs") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("unreadable energy coefficient file {path:?}"))?;
        opts.energy = crate::perfmodel::EnergyModel::from_text(&text)
            .with_context(|| format!("invalid energy coefficient file {path:?}"))?;
    }
    let weights: Option<Vec<f64>> = match args.get("weights") {
        None => None,
        Some(list) => Some(
            list.split(',')
                .map(|s| s.trim().parse::<f64>().context("--weights"))
                .collect::<Result<Vec<f64>>>()?,
        ),
    };
    if let Some(w) = &weights {
        // Validate fully before the sweep runs — a malformed weight must
        // not waste a multi-minute --full sweep only to fail at ranking.
        Objective::validate_weights(&opts.objectives, w).context("--weights")?;
    }
    let csv: bool = match args.get("csv") {
        Some(v) => v.parse().context("--csv")?,
        None => false,
    };

    let three_axis = opts.objectives.contains(&Objective::Energy);
    let res = crate::dse::run_sweep(&spec, &workload, &opts)?;
    let table = crate::report::dse_summary(&res);
    if csv {
        print!("{}", table.csv());
    } else {
        print!("{}", table.markdown());
        if three_axis {
            for plot in crate::report::dse_plot_projections(&res) {
                print!("{}", plot.render());
            }
        } else {
            print!("{}", crate::report::dse_plot(&res).render());
        }
    }
    println!(
        "frontier   : {} non-dominated of {} evaluated ({} pruned by roofline, {} infeasible)",
        res.frontier().len(),
        res.points.len(),
        res.pruned.len(),
        res.infeasible.len()
    );
    if three_axis {
        println!(
            "3-axis     : {} non-dominated over (cost, TFLOP/s, energy); roofline prune disabled for energy soundness",
            res.frontier3().len()
        );
    }
    if let Some(w) = &weights {
        if let Some((p, score)) = res.best_scalarized(&opts.objectives, w)? {
            let axes: Vec<String> = opts
                .objectives
                .iter()
                .zip(w)
                .map(|(o, wt)| format!("{}={wt}", o.name()))
                .collect();
            println!(
                "scalarized : {} wins at score {score:.3} ({}; {:.1} TFLOP/s, cost {:.0}, {:.2} mJ/pass, {:.2} TFLOP/s/W)",
                p.arch.name,
                axes.join(", "),
                p.tflops,
                p.cost,
                p.energy_j * 1e3,
                p.tflops_per_w
            );
        }
    }
    // Read the Table 1-class instance against the frontier.
    if let Some(p) = res.best_at_square(32) {
        println!(
            "32x32 class: {} achieves {:.1} TFLOP/s at cost {:.0}; frontier interpolation there is {:.1} -> {}",
            p.arch.name,
            p.tflops,
            p.cost,
            res.interpolation_at(p.cost),
            if res.on_or_above_frontier(p) { "on/above the frontier" } else { "below the frontier" }
        );
    }
    println!("{}", crate::report::dse_counters(&res));
    if let Some(path) = args.get("json") {
        std::fs::write(path, res.to_json().pretty())
            .with_context(|| format!("writing {path:?}"))?;
        println!("wrote      : {path}");
    }
    Ok(())
}

/// Statically lint architecture configs, sweep specs, presets, GEMM
/// suites and request traces through [`crate::analysis`] — the CI lint
/// gate. Runs zero simulations: every subject is checked closed-form
/// and reported as structured `DIT-Exxx` diagnostics. Exits non-zero
/// iff any subject has error-severity diagnostics; warnings alone stay
/// green so advisory lints never block a pipeline.
fn cmd_check(args: &Args) -> Result<()> {
    use crate::analysis::{check_arch, check_workload, CheckReport};
    use crate::util::json::Json;

    let mut reports: Vec<CheckReport> = Vec::new();
    for path in flag_paths(args, "config") {
        reports.push(check_config_file(&path));
    }
    for path in flag_paths(args, "spec") {
        reports.push(check_spec_file(&path));
    }

    // Workload-level subjects (--shapes/--suite/--trace/--graph) are
    // checked against the --preset architecture; a bare `dit check
    // --preset P` (or no flags at all) lints just the architecture.
    let graph_specs = flag_paths(args, "graph");
    let wants_workload =
        args.get("shapes").is_some() || args.get("suite").is_some() || args.get("trace").is_some();
    if wants_workload
        || !graph_specs.is_empty()
        || args.get("preset").is_some()
        || reports.is_empty()
    {
        let arch = parse_arch(args.get_or("preset", "gh200"))?;
        for spec in &graph_specs {
            reports.push(check_graph_subject(&arch, spec));
        }
        if wants_workload {
            let mut w = Workload::new(format!("workload on {}", arch.name));
            if let Some(list) = args.get("shapes") {
                for (i, spec) in list.split(',').enumerate() {
                    w.push(format!("gemm{i}"), parse_shape(spec.trim())?, 1);
                }
            }
            if let Some(name) = args.get("suite") {
                let suite = Workload::builtin(name).with_context(|| {
                    format!("unknown suite {name:?}; available: {:?}", Workload::builtin_names())
                })?;
                for item in suite.items {
                    w.push(item.label, item.shape, item.count);
                }
            }
            if let Some(path) = args.get("trace") {
                for (i, shape) in
                    crate::coordinator::shapedb::load_trace(path)?.into_iter().enumerate()
                {
                    w.push(format!("req{i}"), shape, 1);
                }
            }
            reports.push(check_workload(&arch, &w));
        } else if graph_specs.is_empty() {
            reports.push(check_arch(&arch));
        }
    }

    let errors: usize = reports.iter().map(|r| r.errors()).sum();
    let warnings: usize = reports.iter().map(|r| r.warnings()).sum();
    let json: bool = match args.get("json") {
        Some(v) => v.parse().context("--json")?,
        None => false,
    };
    if json {
        let mut subjects = Json::arr();
        for r in &reports {
            subjects = subjects.push(r.to_json());
        }
        let out = Json::obj()
            .field("subjects", subjects)
            .field("errors", errors)
            .field("warnings", warnings);
        println!("{}", out.pretty());
    } else {
        for r in &reports {
            print!("{}", r.render());
        }
        println!(
            "checked    : {} subject{}, {errors} error{}, {warnings} warning{} (0 simulations)",
            reports.len(),
            if reports.len() == 1 { "" } else { "s" },
            if errors == 1 { "" } else { "s" },
            if warnings == 1 { "" } else { "s" },
        );
    }
    anyhow::ensure!(errors == 0, "dit check found {errors} error(s)");
    Ok(())
}

/// Split a comma-separated `--flag a,b,c` into its non-empty entries.
fn flag_paths(args: &Args, key: &str) -> Vec<String> {
    args.get(key)
        .map(|list| {
            list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
        })
        .unwrap_or_default()
}

/// Lint one architecture config file. Unreadable files and syntax
/// errors become a `DIT-E071` diagnostic; a file that parses gets the
/// full [`crate::analysis::check_arch`] pass stack. That is why this
/// goes through [`ArchConfig::from_text_unchecked`] — `from_text`'s
/// trailing validate would collapse every semantic problem into one
/// opaque parse error.
fn check_config_file(path: &str) -> crate::analysis::CheckReport {
    use crate::analysis::{check_arch, codes, CheckReport, Loc};
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            let mut rep = CheckReport::new(path);
            rep.error(codes::E071, Loc::none(), format!("unreadable config: {e}"));
            return rep;
        }
    };
    match ArchConfig::from_text_unchecked(&text) {
        Ok(arch) => {
            let mut rep = check_arch(&arch);
            rep.subject = format!("{path} ({})", arch.name);
            rep
        }
        Err(e) => {
            let mut rep = CheckReport::new(path);
            rep.error(codes::E071, Loc::none(), format!("config does not parse: {e:#}"));
            rep
        }
    }
}

/// Lint one workload-graph subject — a builtin graph name or a `.graph`
/// text file. Unreadable/unparseable files become a `DIT-E071`
/// diagnostic (the text parser validates, so a malformed graph is a
/// parse error here; graphs built through the API get the structured
/// `DIT-E09x` codes from [`crate::analysis::check_graph`]).
fn check_graph_subject(arch: &ArchConfig, spec: &str) -> crate::analysis::CheckReport {
    use crate::analysis::{check_graph, codes, CheckReport, Loc};
    use crate::graph::WorkloadGraph;
    if let Some(g) = WorkloadGraph::builtin(spec) {
        return check_graph(arch, &g);
    }
    let text = match std::fs::read_to_string(spec) {
        Ok(t) => t,
        Err(e) => {
            let mut rep = CheckReport::new(spec);
            rep.error(
                codes::E071,
                Loc::none(),
                format!("unknown builtin graph and unreadable file: {e}"),
            );
            return rep;
        }
    };
    match WorkloadGraph::from_text(&text) {
        Ok(g) => {
            let mut rep = check_graph(arch, &g);
            rep.subject = format!("{spec} ({})", g.name);
            rep
        }
        Err(e) => {
            let mut rep = CheckReport::new(spec);
            rep.error(codes::E071, Loc::none(), format!("graph does not parse: {e:#}"));
            rep
        }
    }
}

/// Lint a sweep spec file: syntax errors are `DIT-E071`; every
/// enumerated design point runs through the architecture pass stack;
/// points the enumeration silently drops (validate failures) surface
/// as one `DIT-W082` warning, so a typo'd axis cannot quietly shrink
/// a sweep.
fn check_spec_file(path: &str) -> crate::analysis::CheckReport {
    use crate::analysis::{check_arch, codes, CheckReport, Loc};
    let mut rep = CheckReport::new(path);
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            rep.error(codes::E071, Loc::none(), format!("unreadable sweep spec: {e}"));
            return rep;
        }
    };
    let spec = match SweepSpec::from_text(&text) {
        Ok(s) => s,
        Err(e) => {
            rep.error(codes::E071, Loc::none(), format!("sweep spec does not parse: {e:#}"));
            return rep;
        }
    };
    let raw = spec.meshes.len()
        * spec.ce.len()
        * spec.spm_kib.len()
        * spec.hbm_channel_gbps.len()
        * spec.hbm_channels_pct.len()
        * spec.dma_engines.len();
    let configs = spec.enumerate();
    rep.subject = format!("{path} ({}, {} design points)", spec.name, configs.len());
    if configs.len() < raw {
        rep.warn(
            codes::W082,
            Loc::none(),
            format!(
                "{} of {raw} swept design points fail validation and are silently \
                 dropped from the sweep",
                raw - configs.len()
            ),
        );
    }
    for a in &configs {
        for mut d in check_arch(a).diags {
            d.message = format!("{}: {}", a.name, d.message);
            rep.diags.push(d);
        }
    }
    rep
}

fn cmd_verify(args: &Args) -> Result<()> {
    let grid: usize = args.get_or("grid", "4").parse().context("--grid")?;
    let arch = ArchConfig::tiny(grid, grid);
    arch.validate()
        .with_context(|| format!("invalid verification grid --grid {grid}"))?;
    let shape = parse_shape(args.get("shape").context("--shape required")?)?;
    let sched = parse_schedule(args, &arch, shape)?;
    let mut oracle = match args.get("artifacts") {
        Some(dir) => crate::runtime::Oracle::open(dir)?,
        None => match crate::runtime::Oracle::open_default() {
            Ok(o) => o,
            Err(e) => {
                println!("note: PJRT oracle unavailable ({e:#})");
                println!("      falling back to the f64-accumulation CPU reference oracle");
                crate::runtime::Oracle::cpu_reference()
            }
        },
    };
    anyhow::ensure!(
        oracle.has("gemm", shape.m, shape.n, shape.k),
        "no artifact for {shape}; available: {:?}",
        oracle.shapes("gemm")
    );
    let seed: u64 = args.get_or("seed", "7").parse().context("--seed")?;
    let report = coordinator::verify(&arch, shape, &sched, &mut oracle, seed)?;
    println!(
        "verify {} via {} on {}x{} grid: max|diff| = {:.3e} (tol {:.3e}) -> {}",
        report.shape,
        report.schedule,
        grid,
        grid,
        report.max_abs_diff,
        report.tolerance,
        if report.passed() { "PASS" } else { "FAIL" }
    );
    anyhow::ensure!(report.passed(), "verification failed");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_shape_ok() {
        let s = parse_shape("4096x2112x7168").unwrap();
        assert_eq!((s.m, s.n, s.k), (4096, 2112, 7168));
        assert!(parse_shape("12x34").is_err());
        assert!(parse_shape("axbxc").is_err());
        assert!(parse_shape("0x64x64").is_err(), "zero dims rejected at the boundary");
        assert!(parse_shape("64x64x").is_err());
    }

    #[test]
    fn parse_args_flags() {
        let a = Args::parse(&argv("simulate --shape 1x2x3 --preset gh200")).unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("shape"), Some("1x2x3"));
        assert!(Args::parse(&argv("x --oops")).is_err());
        assert!(Args::parse(&argv("x stray")).is_err());
    }

    #[test]
    fn parse_arch_presets() {
        assert_eq!(parse_arch("gh200").unwrap().rows, 32);
        assert_eq!(parse_arch("a100").unwrap().rows, 16);
        assert_eq!(parse_arch("tiny8").unwrap().rows, 8);
        assert!(parse_arch("/no/such/file").is_err());
    }

    #[test]
    fn parse_schedule_flags() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(64, 64, 64);
        let a = Args::parse(&argv("simulate --schedule splitk --splits 2 --tk 32")).unwrap();
        let s = parse_schedule(&a, &arch, shape).unwrap();
        assert_eq!(s.splits(), 2);
        assert_eq!(s.tk, 32);
        let a = Args::parse(&argv("simulate --schedule nope")).unwrap();
        assert!(parse_schedule(&a, &arch, shape).is_err());
    }

    #[test]
    fn run_simulate_smoke() {
        run(&argv("simulate --preset tiny4 --shape 64x64x64")).unwrap();
        run(&argv("candidates --preset tiny4 --shape 64x64x64")).unwrap();
        run(&argv("arch --preset a100")).unwrap();
        assert!(run(&argv("bogus")).is_err());
    }

    #[test]
    fn cli_supplied_configs_are_validated() {
        // tinyN with a degenerate grid must error cleanly, not panic later.
        let err = parse_arch("tiny0").unwrap_err();
        assert!(format!("{err:#}").contains("invalid tiny grid"), "{err:#}");
        // The verify path validates its --grid before deploying.
        let err = run(&argv("verify --shape 8x8x8 --grid 0")).unwrap_err();
        assert!(format!("{err:#}").contains("invalid verification grid"), "{err:#}");
    }

    #[test]
    fn run_dse_smoke() {
        // A tiny-grid sweep: two meshes of the tiny template, tiny suite.
        run(&argv("dse --base tiny4 --mesh 2,4 --workload tiny --wave 2 --workers 2")).unwrap();
        run(&argv("dse --base tiny4 --mesh 2 --workload tiny --csv true --prune false")).unwrap();
        run(&argv("dse --base tiny4 --mesh 2 --workload tiny --static-precheck false")).unwrap();
        assert!(run(&argv("dse --base tiny4 --mesh 2 --workload tiny --static-precheck maybe"))
            .is_err());
        assert!(run(&argv("dse --workload nope")).is_err());
        assert!(run(&argv("dse --base tiny4 --mesh 0 --workload tiny")).is_err());
        assert!(run(&argv("dse --spec /no/such/file")).is_err());
        assert!(run(&argv("dse --base tiny4 --mesh x")).is_err());
    }

    #[test]
    fn run_dse_rectangular_mesh_smoke() {
        // RxC entries mix freely with square sugar in one --mesh list.
        run(&argv("dse --base tiny4 --mesh 2x4,4x2,2 --workload tiny --wave 2 --workers 2"))
            .unwrap();
        run(&argv("dse --base tiny4 --mesh 2x4 --workload tiny --prune false")).unwrap();
        // Malformed geometries error before any sweep runs.
        assert!(run(&argv("dse --base tiny4 --mesh 4x --workload tiny")).is_err());
        assert!(run(&argv("dse --base tiny4 --mesh x4 --workload tiny")).is_err());
        assert!(run(&argv("dse --base tiny4 --mesh 2x2x2 --workload tiny")).is_err());
        assert!(run(&argv("dse --base tiny4 --mesh 0x4 --workload tiny")).is_err());
        // A zero-dimension typo must error even when mixed with valid
        // entries — not silently shrink the sweep.
        assert!(run(&argv("dse --base tiny4 --mesh 0x4,2 --workload tiny")).is_err());
    }

    #[test]
    fn run_dse_energy_objectives_smoke() {
        // 3-axis sweep with a scalarized winner, on a tiny grid.
        run(&argv(
            "dse --base tiny4 --mesh 2,4 --workload tiny --workers 2 \
             --objectives perf,cost,energy --weights 0.5,0.2,0.3",
        ))
        .unwrap();
        // Weights without energy in the objectives still scalarize.
        run(&argv(
            "dse --base tiny4 --mesh 2 --workload tiny --objectives perf,cost --weights 1,1",
        ))
        .unwrap();
        assert!(
            run(&argv("dse --base tiny4 --mesh 2 --workload tiny --objectives perf,watts"))
                .is_err(),
            "unknown objective"
        );
        assert!(
            run(&argv(
                "dse --base tiny4 --mesh 2 --workload tiny --objectives perf,cost --weights 1"
            ))
            .is_err(),
            "ragged weights"
        );
        assert!(
            run(&argv(
                "dse --base tiny4 --mesh 2 --workload tiny --objectives perf,cost --weights 0,0"
            ))
            .is_err(),
            "all-zero weights rejected before the sweep"
        );
        assert!(
            run(&argv("dse --base tiny4 --mesh 2 --workload tiny --energy-coeffs /no/file"))
                .is_err(),
            "unreadable coefficient file"
        );
    }

    #[test]
    fn run_tune_graph_smoke() {
        run(&argv("tune-workload --preset tiny4 --graph attn-decode")).unwrap();
        // Unknown graph names error with the builtin list, like suites do.
        let err = run(&argv("tune-workload --preset tiny4 --graph nope")).unwrap_err();
        assert!(format!("{err:#}").contains("attn-prefill"), "{err:#}");
        // --graph and --suite/--shapes are mutually exclusive.
        assert!(run(&argv("tune-workload --preset tiny4 --graph attn-decode --suite tiny"))
            .is_err());
        assert!(
            run(&argv("tune-workload --preset tiny4 --graph attn-decode --shapes 64x64x64"))
                .is_err()
        );
    }

    #[test]
    fn run_check_graph_smoke() {
        let path =
            std::env::temp_dir().join(format!("dit-cli-graph-{}.graph", std::process::id()));
        let p = path.to_string_lossy().into_owned();
        // Builtin graph names and graph files are both accepted subjects.
        run(&argv("check --preset tiny4 --graph attn-decode")).unwrap();
        let g = crate::graph::WorkloadGraph::builtin("attn-decode").unwrap();
        std::fs::write(&path, g.to_text()).unwrap();
        run(&argv(&format!("check --preset tiny4 --graph {p}"))).unwrap();
        run(&argv(&format!("tune-workload --preset tiny4 --graph {p}"))).unwrap();
        // Missing and unparseable files are structured diagnostics that
        // exit non-zero, not panics.
        assert!(run(&argv("check --preset tiny4 --graph /no/such/file.graph")).is_err());
        std::fs::write(&path, "graph broken\nop q gemm nope x1\n").unwrap();
        assert!(run(&argv(&format!("check --preset tiny4 --graph {p}"))).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_cache_cli_smoke() {
        let path =
            std::env::temp_dir().join(format!("dit-cli-cache-{}.jsonl", std::process::id()));
        let p = path.to_string_lossy().into_owned();
        let _ = std::fs::remove_file(&path);
        // Cold run writes the cache; the same command again resumes from
        // it; stats and clear round the lifecycle off.
        run(&argv(&format!("tune-workload --preset tiny4 --shapes 64x64x64 --cache {p}")))
            .unwrap();
        assert!(path.exists(), "tuning with --cache persists");
        run(&argv(&format!("tune-workload --preset tiny4 --shapes 64x64x64 --cache {p}")))
            .unwrap();
        run(&argv(&format!("dse --base tiny4 --mesh 2 --workload tiny --cache {p}"))).unwrap();
        run(&argv(&format!("cache stats --cache {p}"))).unwrap();
        run(&argv(&format!("cache clear --cache {p}"))).unwrap();
        assert!(!path.exists(), "clear removes the file");
        run(&argv(&format!("cache clear --cache {p}"))).unwrap();
        // Bad usages error cleanly.
        assert!(run(&argv("cache")).is_err(), "stats without --cache");
        assert!(run(&argv("cache nuke --cache x")).is_err(), "unknown action");
        assert!(run(&argv("cache --cache x")).is_err(), "missing action");
    }

    #[test]
    fn parse_arch_tiny_suffix_is_strict() {
        // Bare `tiny` keeps the 4x4 default; a garbage suffix used to
        // silently alias to it.
        assert_eq!(parse_arch("tiny").unwrap().rows, 4);
        let err = parse_arch("tinyzzz").unwrap_err();
        assert!(format!("{err:#}").contains("tinyzzz"), "{err:#}");
    }

    #[test]
    fn run_check_smoke() {
        // Presets, ad-hoc shapes and built-in suites all lint clean
        // (simulation-freedom is pinned by the `check` bench, where no
        // concurrent test can race the global sim counter).
        run(&argv("check")).unwrap();
        run(&argv("check --preset tiny8")).unwrap();
        run(&argv("check --preset tiny4 --shapes 64x64x64,128x96x256")).unwrap();
        run(&argv("check --preset gh200 --suite transformer --json true")).unwrap();
        assert!(run(&argv("check --preset nope")).is_err());
        assert!(run(&argv("check --preset tiny4 --suite nope")).is_err());
        assert!(run(&argv("check --preset tiny4 --trace /no/such/trace")).is_err());
        assert!(run(&argv("check --json maybe")).is_err());
        // A missing config is an E071 diagnostic and a non-zero exit.
        assert!(run(&argv("check --config /no/such/config.dit")).is_err());
    }

    #[test]
    fn check_config_file_reports_specific_codes() {
        use crate::analysis::codes;
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let good = dir.join(format!("dit-check-good-{pid}.dit"));
        let broken = dir.join(format!("dit-check-broken-{pid}.dit"));
        let garbled = dir.join(format!("dit-check-garbled-{pid}.dit"));
        let text = ArchConfig::tiny(4, 4).to_text();
        std::fs::write(&good, &text).unwrap();
        std::fs::write(&broken, text.replace("rows = 4", "rows = 0")).unwrap();
        std::fs::write(&garbled, "[grid\nrows = ]\n").unwrap();

        run(&argv(&format!("check --config {}", good.display()))).unwrap();
        // A semantically broken config earns its specific code — the
        // whole point of parsing with `from_text_unchecked`.
        let rep = check_config_file(&broken.display().to_string());
        assert!(rep.has_code(codes::E001), "{}", rep.render());
        assert!(run(&argv(&format!("check --config {}", broken.display()))).is_err());
        // Syntax errors and missing files are E071.
        let rep = check_config_file(&garbled.display().to_string());
        assert!(rep.has_code(codes::E071), "{}", rep.render());
        let rep = check_config_file("/no/such/config.dit");
        assert!(rep.has_code(codes::E071), "{}", rep.render());
        for p in [&good, &broken, &garbled] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn check_spec_file_flags_dropped_points() {
        use crate::analysis::codes;
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let spec = dir.join(format!("dit-check-spec-{pid}.dit"));
        // The 2 KiB SPM point fails validation (L1 floor is 4 KiB):
        // enumerate() silently drops it, the checker warns W082.
        std::fs::write(
            &spec,
            "[sweep]\nname = \"smoke\"\nmesh = [2]\nce_m = [16]\nce_n = [8]\nspm_kib = [2, 256]\n",
        )
        .unwrap();
        let rep = check_spec_file(&spec.display().to_string());
        assert!(rep.has_code(codes::W082), "{}", rep.render());
        assert_eq!(rep.errors(), 0, "{}", rep.render());
        // Warnings alone keep the gate green.
        run(&argv(&format!("check --spec {}", spec.display()))).unwrap();
        // A spec with no invalid points has nothing to warn about.
        std::fs::write(&spec, "[sweep]\nname = \"smoke\"\nmesh = [2]\nspm_kib = 256\n").unwrap();
        let rep = check_spec_file(&spec.display().to_string());
        assert!(!rep.has_code(codes::W082), "{}", rep.render());
        // Unparseable specs are E071.
        std::fs::write(&spec, "[sweep]\nmesh = [0]\n").unwrap();
        let rep = check_spec_file(&spec.display().to_string());
        assert!(rep.has_code(codes::E071), "{}", rep.render());
        let _ = std::fs::remove_file(&spec);
    }

    #[test]
    fn run_cache_stats_rejects_foreign_files() {
        // `cache stats` on something that is not a cache is a DIT-E072
        // diagnostic, not zero-entry stats for a typo'd path.
        let p = std::env::temp_dir().join(format!("dit-e072-{}.txt", std::process::id()));
        std::fs::write(&p, "hello, not a cache\n").unwrap();
        let err = run(&argv(&format!("cache stats --cache {}", p.display()))).unwrap_err();
        assert!(format!("{err:#}").contains("DIT-E072"), "{err:#}");
        let err = run(&argv("cache stats --cache /no/such/cache.jsonl")).unwrap_err();
        assert!(format!("{err:#}").contains("DIT-E072"), "{err:#}");
        // `clear` on a missing path stays a polite no-op.
        run(&argv("cache clear --cache /no/such/cache.jsonl")).unwrap();
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn run_serve_cli_smoke() {
        let dir = std::env::temp_dir().join(format!("dit-cli-serve-{}", std::process::id()));
        let d = dir.to_string_lossy().into_owned();
        let trace =
            std::env::temp_dir().join(format!("dit-cli-serve-{}.trace", std::process::id()));
        let t = trace.to_string_lossy().into_owned();
        let _ = crate::coordinator::cache::ShardedDiskCache::clear(&dir);
        let _ = std::fs::remove_file(&trace);
        // Generate a small deterministic trace, then replay it twice
        // against one sharded cache path: cold tunes, warm resumes.
        run(&argv(&format!("serve --gen-trace {t} --seed 3 --len 24"))).unwrap();
        run(&argv(&format!(
            "serve --preset tiny4 --trace {t} --cache {d} --shards 2 --drain 2"
        )))
        .unwrap();
        run(&argv(&format!("serve --preset tiny4 --trace {t} --cache {d} --shards 2")))
            .unwrap();
        // In-memory replay; knob validation errors cleanly.
        run(&argv(&format!("serve --preset tiny4 --trace {t} --epsilon 0.5"))).unwrap();
        assert!(run(&argv(&format!("serve --preset tiny4 --trace {t} --epsilon -1"))).is_err());
        assert!(run(&argv(&format!("serve --preset tiny4 --trace {t} --shards 0"))).is_err());
        assert!(run(&argv("serve --preset tiny4")).is_err(), "--trace required");
        assert!(run(&argv("serve --gen-trace /no/such/dir/x --len 4")).is_err());
        // The sharded directory is a first-class `cache` citizen.
        run(&argv(&format!("cache stats --cache {d}"))).unwrap();
        run(&argv(&format!("cache clear --cache {d}"))).unwrap();
        assert!(!dir.exists(), "clear removes the shard directory");
        let _ = std::fs::remove_file(&trace);
    }

    #[test]
    fn run_tiered_smoke() {
        // Tiered tuning end to end on tiny grids, via both commands.
        run(&argv(
            "tune-workload --preset tiny4 --shapes 128x128x256 --tiered true --top-k 2 \
             --explore 1 --workers 2",
        ))
        .unwrap();
        run(&argv("dse --base tiny4 --mesh 2,4 --workload tiny --tiered true --wave 2"))
            .unwrap();
        run(&argv("dse --base tiny4 --mesh 2 --workload tiny --prune-slack 0.1")).unwrap();
        // Knob validation: bad values and orphaned knobs error cleanly.
        assert!(run(&argv("tune-workload --preset tiny4 --shapes 8x8x8 --tiered maybe")).is_err());
        assert!(
            run(&argv("tune-workload --preset tiny4 --shapes 8x8x8 --top-k 2")).is_err(),
            "--top-k without --tiered true is a likely typo"
        );
        assert!(run(&argv(
            "tune-workload --preset tiny4 --shapes 8x8x8 --tiered true --top-k 0"
        ))
        .is_err());
        assert!(run(&argv("dse --base tiny4 --mesh 2 --workload tiny --prune-slack 0.9"))
            .is_err());
        assert!(run(&argv("dse --base tiny4 --mesh 2 --workload tiny --prune-slack nan"))
            .is_err());
    }

    #[test]
    fn run_tune_workload_smoke() {
        // Ad-hoc shape list with a repeat (exercises the memo-cache), on a
        // tiny grid so the test is fast.
        run(&argv("tune-workload --preset tiny4 --shapes 64x64x64,96x96x96,64x64x64 --workers 2"))
            .unwrap();
        run(&argv("tune-workload --preset tiny4 --shapes 64x64x64 --csv true")).unwrap();
        assert!(run(&argv("tune-workload --preset tiny4 --suite nope")).is_err());
        assert!(run(&argv("tune-workload --preset tiny4 --shapes 12x34")).is_err());
        assert!(run(&argv("tune-workload --preset tiny4 --shapes 8x8x8 --csv maybe")).is_err());
    }
}

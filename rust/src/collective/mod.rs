//! Mask-based NoC collective group calculus (paper §2.1, Eq. 1).
//!
//! SoftHier's hardware collectives address a *group* of tiles with selector
//! coordinates and masks carried in the packet header:
//!
//! ```text
//! Tile_group = { Tile(i,j) ∈ P | (i & M_row) = S_row  ∧  (j & M_col) = S_col }
//! ```
//!
//! A broadcast delivers one payload to every member; a reduction combines
//! one contribution per member at a root. This module implements the
//! calculus itself plus *mask synthesis*: turning the groups the deployment
//! schedules need (rows, columns, power-of-two aligned rectangles, strided
//! subsets, logical-grid rows after a cluster-index remap) into
//! `(S, M)` pairs, and verifying exact coverage.

use crate::util::is_pow2;

/// A tile coordinate on the physical grid (row-major).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TileCoord {
    pub row: usize,
    pub col: usize,
}

impl TileCoord {
    pub fn new(row: usize, col: usize) -> Self {
        TileCoord { row, col }
    }

    /// Linear (row-major) index on a grid with `cols` columns.
    pub fn linear(&self, cols: usize) -> usize {
        self.row * cols + self.col
    }

    /// Inverse of [`TileCoord::linear`].
    pub fn from_linear(lin: usize, cols: usize) -> Self {
        TileCoord::new(lin / cols, lin % cols)
    }

    /// Manhattan (mesh-hop) distance.
    pub fn hops_to(&self, other: TileCoord) -> usize {
        self.row.abs_diff(other.row) + self.col.abs_diff(other.col)
    }

    /// Dense id of the directed mesh link `self -> to`, where `to` is one
    /// of the four mesh neighbours: `4 * linear + direction`, direction
    /// 0 = north (row−1), 1 = south (row+1), 2 = west (col−1),
    /// 3 = east (col+1). With [`num_links`] slots every directed link of
    /// a `rows × cols` mesh owns a unique index — the flat-array resource
    /// model in [`crate::sim`] indexes its busy-horizon table with this
    /// (edge tiles simply own a few slots no route ever touches).
    #[inline]
    pub fn link_to(&self, to: TileCoord, cols: usize) -> usize {
        let dir = if to.col == self.col && to.row + 1 == self.row {
            0
        } else if to.col == self.col && to.row == self.row + 1 {
            1
        } else if to.row == self.row && to.col + 1 == self.col {
            2
        } else if to.row == self.row && to.col == self.col + 1 {
            3
        } else {
            panic!("link_to: {self} -> {to} is not a unit mesh step")
        };
        4 * self.linear(cols) + dir
    }
}

/// Number of dense directed-link slots ([`TileCoord::link_to`]) a
/// `rows × cols` mesh needs: four outgoing directions per tile.
#[inline]
pub fn num_links(rows: usize, cols: usize) -> usize {
    4 * rows * cols
}

impl std::fmt::Display for TileCoord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{})", self.row, self.col)
    }
}

/// A collective addressing mask: the packet-header `(S, M)` pairs.
///
/// Tile `(i, j)` is a member iff `(i & m_row) == s_row && (j & m_col) == s_col`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mask {
    pub s_row: usize,
    pub m_row: usize,
    pub s_col: usize,
    pub m_col: usize,
}

impl Mask {
    /// Hardware membership test — Eq. (1) verbatim.
    #[inline]
    pub fn contains(&self, t: TileCoord) -> bool {
        (t.row & self.m_row) == self.s_row && (t.col & self.m_col) == self.s_col
    }

    /// Enumerate members on a `rows × cols` grid, row-major order.
    pub fn members(&self, rows: usize, cols: usize) -> Vec<TileCoord> {
        let mut out = Vec::new();
        self.members_into(rows, cols, &mut out);
        out
    }

    /// [`Mask::members`] into a caller-provided buffer (cleared first,
    /// same row-major order) — the allocation-free form the simulator's
    /// per-collective-op hot path uses with its arena scratch.
    pub fn members_into(&self, rows: usize, cols: usize, out: &mut Vec<TileCoord>) {
        out.clear();
        for i in 0..rows {
            if (i & self.m_row) != self.s_row {
                continue;
            }
            for j in 0..cols {
                if (j & self.m_col) == self.s_col {
                    out.push(TileCoord::new(i, j));
                }
            }
        }
    }

    /// Member count on a grid without materializing the member list.
    pub fn count(&self, rows: usize, cols: usize) -> usize {
        let r = (0..rows).filter(|i| (i & self.m_row) == self.s_row).count();
        let c = (0..cols).filter(|j| (j & self.m_col) == self.s_col).count();
        r * c
    }

    /// All tiles of the grid. (`M = 0` matches everything when `S = 0`.)
    pub fn all() -> Mask {
        Mask { s_row: 0, m_row: 0, s_col: 0, m_col: 0 }
    }

    /// The single tile `(i, j)` on a grid no larger than `rows × cols`
    /// (masks select all coordinate bits).
    pub fn single(t: TileCoord, rows: usize, cols: usize) -> Mask {
        Mask {
            s_row: t.row,
            m_row: full_mask(rows),
            s_col: t.col,
            m_col: full_mask(cols),
        }
    }

    /// Physical row `i` (all columns).
    pub fn row(i: usize, rows: usize) -> Mask {
        Mask { s_row: i, m_row: full_mask(rows), s_col: 0, m_col: 0 }
    }

    /// Physical column `j` (all rows).
    pub fn col(j: usize, cols: usize) -> Mask {
        Mask { s_row: 0, m_row: 0, s_col: j, m_col: full_mask(cols) }
    }

    /// A power-of-two aligned rectangle: rows `[r0, r0+h)`, cols
    /// `[c0, c0+w)` where `h`/`w` are powers of two and `r0`/`c0` are
    /// aligned to them — the constraint the AND-mask hardware imposes.
    pub fn rect(r0: usize, c0: usize, h: usize, w: usize, rows: usize, cols: usize) -> Option<Mask> {
        if !is_pow2(h) || !is_pow2(w) || r0 % h != 0 || c0 % w != 0 {
            return None;
        }
        Some(Mask {
            s_row: r0,
            m_row: full_mask(rows) & !(h - 1),
            s_col: c0,
            m_col: full_mask(cols) & !(w - 1),
        })
    }

    /// A strided row subset: rows ≡ `phase (mod stride)` (power-of-two
    /// stride), all columns — the "strided broadcast" used by split-K
    /// (§3.3.2).
    pub fn row_stride(phase: usize, stride: usize) -> Option<Mask> {
        if !is_pow2(stride) || phase >= stride {
            return None;
        }
        Some(Mask { s_row: phase, m_row: stride - 1, s_col: 0, m_col: 0 })
    }

    /// A strided column subset: cols ≡ `phase (mod stride)`.
    pub fn col_stride(phase: usize, stride: usize) -> Option<Mask> {
        if !is_pow2(stride) || phase >= stride {
            return None;
        }
        Some(Mask { s_row: 0, m_row: 0, s_col: phase, m_col: stride - 1 })
    }

    /// Does this mask cover *exactly* the given tile set on the grid?
    pub fn covers_exactly(&self, tiles: &[TileCoord], rows: usize, cols: usize) -> bool {
        let mut want: Vec<TileCoord> = tiles.to_vec();
        want.sort();
        want.dedup();
        self.members(rows, cols) == want
    }
}

/// All-ones mask wide enough for coordinates `0..extent`.
pub fn full_mask(extent: usize) -> usize {
    if extent <= 1 {
        // A 1-wide dimension still needs its (only) coordinate bit checked;
        // use mask 1 so selector 0 matches only coordinate 0.
        1
    } else {
        (1usize << (usize::BITS - (extent - 1).leading_zeros())) - 1
    }
}

/// Synthesize a mask covering an arbitrary tile set, if the AND-mask
/// hardware can express it (the set must be a Cartesian product of
/// mask-expressible row and column sets). Returns `None` otherwise —
/// callers then fall back to iterated unicast (which the simulator charges
/// accordingly, making the cost of non-collective-friendly mappings
/// visible, as the paper's Insight 2 demands).
pub fn synthesize(tiles: &[TileCoord], rows: usize, cols: usize) -> Option<Mask> {
    if tiles.is_empty() {
        return None;
    }
    let mut rset: Vec<usize> = tiles.iter().map(|t| t.row).collect();
    let mut cset: Vec<usize> = tiles.iter().map(|t| t.col).collect();
    rset.sort_unstable();
    rset.dedup();
    cset.sort_unstable();
    cset.dedup();
    // Must be a full Cartesian product.
    if tiles.len() != rset.len() * cset.len() {
        let mut uniq = tiles.to_vec();
        uniq.sort();
        uniq.dedup();
        if uniq.len() != rset.len() * cset.len() {
            return None;
        }
    }
    let (s_row, m_row) = synthesize_1d(&rset, rows)?;
    let (s_col, m_col) = synthesize_1d(&cset, cols)?;
    let mask = Mask { s_row, m_row, s_col, m_col };
    mask.covers_exactly(tiles, rows, cols).then_some(mask)
}

/// 1-D synthesis: find `(s, m)` with `{ x < extent | x & m == s }  == set`.
fn synthesize_1d(set: &[usize], extent: usize) -> Option<(usize, usize)> {
    assert!(!set.is_empty(), "synthesize_1d on an empty coordinate set");
    let full = full_mask(extent);
    // Bits that vary across the set must be 0 in the mask; bits constant
    // across the set should be 1 (checked) with selector = the constant.
    let first = set[0];
    let varying = set.iter().fold(0usize, |acc, &x| acc | (x ^ first));
    let m = full & !varying;
    let s = first & m;
    // Verify: the candidate is the *unique* maximal mask; if the set is not
    // exactly the matched set, no AND-mask expresses it.
    let matched: Vec<usize> = (0..extent).filter(|&x| x & m == s).collect();
    (matched == set).then_some((s, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::check;

    #[test]
    fn full_mask_widths() {
        assert_eq!(full_mask(1), 1);
        assert_eq!(full_mask(2), 1);
        assert_eq!(full_mask(32), 31);
        assert_eq!(full_mask(33), 63);
    }

    #[test]
    fn row_and_col_groups() {
        let m = Mask::row(3, 32);
        assert_eq!(m.count(32, 32), 32);
        assert!(m.contains(TileCoord::new(3, 17)));
        assert!(!m.contains(TileCoord::new(4, 17)));

        let m = Mask::col(5, 32);
        assert_eq!(m.count(32, 32), 32);
        assert!(m.contains(TileCoord::new(9, 5)));
        assert!(!m.contains(TileCoord::new(9, 6)));
    }

    #[test]
    fn single_tile_group() {
        let m = Mask::single(TileCoord::new(7, 9), 32, 32);
        assert_eq!(m.members(32, 32), vec![TileCoord::new(7, 9)]);
    }

    #[test]
    fn rect_groups() {
        // 2x2-aligned rectangle inside a 4x4 grid (paper Fig. 6c inner groups).
        let m = Mask::rect(2, 0, 2, 2, 4, 4).unwrap();
        assert_eq!(
            m.members(4, 4),
            vec![
                TileCoord::new(2, 0),
                TileCoord::new(2, 1),
                TileCoord::new(3, 0),
                TileCoord::new(3, 1)
            ]
        );
        // Misaligned or non-pow2 rectangles are not expressible.
        assert!(Mask::rect(1, 0, 2, 2, 4, 4).is_none());
        assert!(Mask::rect(0, 0, 3, 2, 4, 4).is_none());
    }

    #[test]
    fn strided_groups() {
        // Every second row, phase 1 (split-K strided broadcast).
        let m = Mask::row_stride(1, 2).unwrap();
        let members = m.members(4, 2);
        assert_eq!(
            members,
            vec![
                TileCoord::new(1, 0),
                TileCoord::new(1, 1),
                TileCoord::new(3, 0),
                TileCoord::new(3, 1)
            ]
        );
        assert!(Mask::row_stride(2, 2).is_none());
        assert!(Mask::row_stride(0, 3).is_none());
    }

    #[test]
    fn synthesis_recovers_standard_groups() {
        for grid in [(4usize, 4usize), (8, 8), (32, 32)] {
            let (rows, cols) = grid;
            let row_set = Mask::row(rows / 2, rows).members(rows, cols);
            let got = synthesize(&row_set, rows, cols).unwrap();
            assert!(got.covers_exactly(&row_set, rows, cols));

            let col_set = Mask::col(cols - 1, cols).members(rows, cols);
            let got = synthesize(&col_set, rows, cols).unwrap();
            assert!(got.covers_exactly(&col_set, rows, cols));
        }
    }

    #[test]
    fn synthesis_rejects_non_product_sets() {
        // An L-shape is not a Cartesian product -> not mask-expressible.
        let l = vec![TileCoord::new(0, 0), TileCoord::new(0, 1), TileCoord::new(1, 0)];
        assert!(synthesize(&l, 4, 4).is_none());
    }

    #[test]
    fn synthesis_rejects_unaligned_ranges() {
        // Rows {1, 2} share no AND-mask (1 = 0b01, 2 = 0b10).
        let set: Vec<TileCoord> = (0..4).map(|j| TileCoord::new(1, j)).collect::<Vec<_>>()
            .into_iter()
            .chain((0..4).map(|j| TileCoord::new(2, j)))
            .collect();
        assert!(synthesize(&set, 4, 4).is_none());
    }

    #[test]
    fn prop_synthesis_roundtrips_every_mask() {
        // Any (S, M) pair's member set must synthesize back to an
        // equivalent mask — the calculus is closed under synthesis.
        check("mask synthesis roundtrip", 200, |rng| {
            let rows = *rng.choose(&[2usize, 4, 8, 16, 32]);
            let cols = *rng.choose(&[2usize, 4, 8, 16, 32]);
            let mask = Mask {
                s_row: rng.below(rows as u64) as usize,
                m_row: rng.below(full_mask(rows) as u64 + 1) as usize,
                s_col: rng.below(cols as u64) as usize,
                m_col: rng.below(full_mask(cols) as u64 + 1) as usize,
            };
            let members = mask.members(rows, cols);
            if members.is_empty() {
                return; // selector outside masked space: legal, empty
            }
            let again = synthesize(&members, rows, cols)
                .unwrap_or_else(|| panic!("unsynthesizable mask {mask:?} -> {members:?}"));
            assert!(again.covers_exactly(&members, rows, cols));
        });
    }

    #[test]
    fn prop_count_matches_members() {
        check("count == members.len()", 100, |rng| {
            let rows = rng.range(1, 16);
            let cols = rng.range(1, 16);
            let mask = Mask {
                s_row: rng.below(16) as usize,
                m_row: rng.below(16) as usize,
                s_col: rng.below(16) as usize,
                m_col: rng.below(16) as usize,
            };
            assert_eq!(mask.count(rows, cols), mask.members(rows, cols).len());
        });
    }

    #[test]
    fn link_ids_are_dense_and_injective() {
        // Every directed unit step on a rectangular mesh gets a distinct
        // id inside the `num_links` range (the flat resource table's
        // soundness condition).
        let (rows, cols) = (3usize, 5usize);
        let mut seen = vec![false; num_links(rows, cols)];
        for r in 0..rows {
            for c in 0..cols {
                let t = TileCoord::new(r, c);
                let mut claim = |n: TileCoord| {
                    let id = t.link_to(n, cols);
                    assert!(id < num_links(rows, cols), "{t} -> {n} id {id} out of range");
                    assert!(!seen[id], "{t} -> {n} reuses id {id}");
                    seen[id] = true;
                };
                if r > 0 {
                    claim(TileCoord::new(r - 1, c));
                }
                if r + 1 < rows {
                    claim(TileCoord::new(r + 1, c));
                }
                if c > 0 {
                    claim(TileCoord::new(r, c - 1));
                }
                if c + 1 < cols {
                    claim(TileCoord::new(r, c + 1));
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "not a unit mesh step")]
    fn link_to_rejects_non_neighbours() {
        TileCoord::new(0, 0).link_to(TileCoord::new(2, 0), 4);
    }

    #[test]
    #[should_panic(expected = "not a unit mesh step")]
    fn link_to_rejects_diagonals() {
        TileCoord::new(1, 1).link_to(TileCoord::new(2, 2), 4);
    }

    #[test]
    fn members_into_matches_members() {
        check("members_into == members", 100, |rng| {
            let rows = rng.range(1, 16);
            let cols = rng.range(1, 16);
            let mask = Mask {
                s_row: rng.below(16) as usize,
                m_row: rng.below(16) as usize,
                s_col: rng.below(16) as usize,
                m_col: rng.below(16) as usize,
            };
            // A dirty reused buffer must come back identical to a fresh
            // allocation (the simulator reuses one across ops).
            let mut buf = vec![TileCoord::new(9, 9); 3];
            mask.members_into(rows, cols, &mut buf);
            assert_eq!(buf, mask.members(rows, cols));
        });
    }

    #[test]
    fn linear_roundtrip() {
        check("linear index roundtrip", 100, |rng| {
            let cols = rng.range(1, 64);
            let t = TileCoord::new(rng.range(0, 63), rng.range(0, cols - 1));
            assert_eq!(TileCoord::from_linear(t.linear(cols), cols), t);
        });
    }
}

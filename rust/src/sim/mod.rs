//! The SoftHier performance model: a deterministic, event-driven
//! resource-occupancy simulator (the GVSoC substitution — see DESIGN.md).
//!
//! Execution follows the IR's BSP semantics: per superstep, every tile's
//! compute phase (matrix-engine MMADs, serialized per tile) runs
//! concurrently with its communication phase (DMA + NoC transfers), and a
//! barrier closes the step. Contention is modelled by *resource
//! reservation*:
//!
//! * every directed mesh link has a `busy_until` horizon; a transfer
//!   reserves all links on its XY route (multicast: the union tree; it
//!   charges each tree link **once** — the hardware-collective advantage),
//! * every HBM channel is a serving resource with per-request overhead and
//!   a stream-efficiency factor, so many small strided bursts (the base
//!   layout) saturate a channel long before its peak bandwidth,
//! * every tile has `dma_engines` DMA queues and one matrix engine whose
//!   throughput follows the calibrated efficiency model
//!   (`engine_time_ns`): CE-array quantization × pipeline fill × ragged-
//!   edge stall — a TN=66 tile lands at ≈50% utilization as in §4.1.3.
//!
//! The simulator is deterministic (tiles processed row-major, ops in
//! program order) and produces [`RunStats`]: makespan, TFLOP/s,
//! utilization, HBM/NoC traffic, and per-superstep timing for the
//! pipeline-stage analyses of Fig. 8.
//!
//! # Hot-path design (flat indexed resources + arenas)
//!
//! `simulate` is the inner loop under every autotune and DSE sweep, so its
//! resource model is built on flat arrays instead of hashed collections:
//!
//! * directed links live in a `Vec<f64>` indexed by the dense link id
//!   [`TileCoord::link_to`] (`4 * tile_linear + direction`), sized once
//!   per mesh — no `HashMap<LinkId, f64>` churn per reservation;
//! * multicast/reduce tree dedup uses an epoch-stamped bitset
//!   (`seen[link] == epoch`), cleared in O(0) by bumping the epoch;
//! * per-`hbm_transfer` channel grouping accumulates into per-channel
//!   arrays reset via a touched list — no per-op `HashMap` + sort;
//! * route/tree/member scratch `Vec`s live in a [`SimArena`] the caller
//!   owns, so back-to-back simulations ([`simulate_in`]) reuse every
//!   buffer. The autotuners hold one arena per worker thread.
//!
//! The rewrite is bit-identical to the original hashed model — the frozen
//! [`reference`] twin and `tests/properties.rs` pin `RunStats` equality
//! `to_bits`-exact across meshes and schedules. Process-wide throughput
//! counters ([`sim_counters`]) feed the gated `sims_per_sec` bench metric.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::arch::ArchConfig;
use crate::collective::{num_links, Mask, TileCoord};
use crate::ir::{Deployment, Op};
use crate::layout::Run;
use crate::util::json::Json;

#[doc(hidden)]
pub mod reference;

/// Matrix-engine execution time for one `m×n×k` MMAD, in ns.
///
/// Efficiency model (calibrated to the paper's §4.1.3 observation that a
/// ragged TN=66 tile reaches ~50% utilization — mirrored in
/// `python/compile/kernels/mmad.py::mxu_utilization_estimate`):
///
/// * quantization: the CE array processes `ce_m × ce_n` sub-tiles;
/// * fill: each K-pass pays a pipeline fill of ~`ce_n` cycles;
/// * ragged: a sub-tile edge that does not fill the array breaks the
///   systolic wavefront (0.7 stall factor).
pub fn engine_time_ns(arch: &ArchConfig, m: usize, n: usize, k: usize) -> f64 {
    let ce_m = arch.tile.ce_m as f64;
    let ce_n = arch.tile.ce_n as f64;
    let sub_m = (m as f64 / ce_m).ceil();
    let sub_n = (n as f64 / ce_n).ceil();
    let quant = (m * n) as f64 / (sub_m * ce_m * sub_n * ce_n);
    let fill = k as f64 / (k as f64 + ce_n);
    let ragged = if m % arch.tile.ce_m != 0 || n % arch.tile.ce_n != 0 { 0.7 } else { 1.0 };
    let eff = (quant * fill * ragged).min(1.0);
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let peak_flops_per_ns = arch.tile.peak_tflops() * 1e3; // TFLOP/s = kflop/ns
    flops / (peak_flops_per_ns * eff)
}

// ---- simulator throughput instrumentation --------------------------------

static SIM_CALLS: AtomicU64 = AtomicU64::new(0);
static SIM_NANOS: AtomicU64 = AtomicU64::new(0);

/// Process-wide simulator throughput counters: completed [`simulate`] /
/// [`simulate_in`] calls and accumulated in-simulator wall nanoseconds
/// (summed across threads, so the quotient is the *mean per-call latency*,
/// not end-to-end wall throughput). The bench harness samples this around
/// a tuning run to report the gated `sims_per_sec` metric without counting
/// codegen, planning, or ranking time.
pub fn sim_counters() -> (u64, u64) {
    (SIM_CALLS.load(Ordering::Relaxed), SIM_NANOS.load(Ordering::Relaxed))
}

/// `DIT_SIM_DEBUG` probe, latched on first use: the per-superstep trace
/// used to re-read the environment on every `simulate` call, and the DMA
/// variant below on every DMA *leg* — a getenv syscall inside the hottest
/// loop of the whole tuner.
fn debug_enabled() -> bool {
    static FLAG: OnceLock<bool> = OnceLock::new();
    *FLAG.get_or_init(|| std::env::var("DIT_SIM_DEBUG").is_ok())
}

/// `DIT_SIM_DEBUG_DMA` probe, latched on first use (see
/// [`debug_enabled`]).
fn debug_dma_enabled() -> bool {
    static FLAG: OnceLock<bool> = OnceLock::new();
    *FLAG.get_or_init(|| std::env::var("DIT_SIM_DEBUG_DMA").is_ok())
}

// ---- flat resource model -------------------------------------------------

/// Mutable resource state for one run, all flat arrays indexed by dense
/// ids so the hot path never hashes.
#[derive(Default)]
struct Resources {
    /// Dense directed-link id ([`TileCoord::link_to`]) -> busy horizon
    /// (ns). Sized to [`num_links`] once per mesh.
    links: Vec<f64>,
    /// HBM channel -> busy horizon.
    channels: Vec<f64>,
    /// `tile_linear * dma_engines + engine` -> DMA queue horizon.
    dma: Vec<f64>,
    dma_engines: usize,
    cols: usize,
    link_gbps: f64,
    hop_ns: f64,
}

impl Resources {
    /// Size (or re-size) for `arch` and zero every horizon.
    fn reset(&mut self, arch: &ArchConfig) {
        self.links.clear();
        self.links.resize(num_links(arch.rows, arch.cols), 0.0);
        self.channels.clear();
        self.channels.resize(arch.hbm.num_channels(), 0.0);
        self.dma.clear();
        self.dma.resize(arch.num_tiles() * arch.tile.dma_engines, 0.0);
        self.dma_engines = arch.tile.dma_engines;
        self.cols = arch.cols;
        self.link_gbps = arch.noc.link_gbps();
        self.hop_ns = arch.noc.hop_ns;
    }

    /// Write the dimension-ordered route `from -> to` into `out` as dense
    /// link ids (cleared first; same step order as the pre-flat model:
    /// column-coordinate first when `col_first`).
    fn route_into(&self, out: &mut Vec<usize>, from: TileCoord, to: TileCoord, col_first: bool) {
        out.clear();
        let cols = self.cols;
        let mut cur = from;
        let step_col = |cur: TileCoord| {
            TileCoord::new(cur.row, if to.col > cur.col { cur.col + 1 } else { cur.col - 1 })
        };
        let step_row = |cur: TileCoord| {
            TileCoord::new(if to.row > cur.row { cur.row + 1 } else { cur.row - 1 }, cur.col)
        };
        if col_first {
            while cur.col != to.col {
                let next = step_col(cur);
                out.push(cur.link_to(next, cols));
                cur = next;
            }
        }
        while cur.row != to.row {
            let next = step_row(cur);
            out.push(cur.link_to(next, cols));
            cur = next;
        }
        while cur.col != to.col {
            let next = step_col(cur);
            out.push(cur.link_to(next, cols));
            cur = next;
        }
    }

    /// Reserve a set of links for a transfer of `bytes` starting no earlier
    /// than `t0`; returns (start, arrival at the farthest endpoint given
    /// `max_hops`).
    ///
    /// Virtual-cut-through approximation with *decoupled* link horizons:
    /// each link only delays the flit stream by its own backlog (wormhole
    /// packets pipeline through partially-busy paths), so the arrival is
    /// governed by the most-backlogged link plus hop latency plus the
    /// serialization of the payload — not by a whole-path mutual lock.
    fn reserve(&mut self, links: &[usize], max_hops: usize, bytes: u64, t0: f64) -> (f64, f64) {
        let serial = bytes as f64 / self.link_gbps;
        let mut worst = t0;
        for &l in links {
            let busy = &mut self.links[l];
            let start = busy.max(t0);
            worst = worst.max(start);
            *busy = start + serial;
        }
        let arrival = worst + max_hops as f64 * self.hop_ns + serial;
        (worst, arrival)
    }
}

/// Reusable scratch buffers: route/tree/member vectors, the epoch-stamped
/// link set for collective-tree dedup, and the per-channel DMA-leg
/// accumulators.
#[derive(Default)]
struct Scratch {
    /// One XY route, as dense link ids.
    route: Vec<usize>,
    /// Union tree of a multicast/reduction, each link exactly once.
    tree: Vec<usize>,
    /// Collective group member list.
    members: Vec<TileCoord>,
    /// `seen[link] == epoch` marks membership in the current tree; the
    /// epoch bump at every collective op clears the whole set in O(0).
    seen: Vec<u64>,
    epoch: u64,
    /// Per-channel (bytes, run-count) accumulators for one `hbm_transfer`,
    /// zeroed back via `chan_touched` after each op.
    chan_bytes: Vec<u64>,
    chan_runs: Vec<u64>,
    chan_touched: Vec<usize>,
}

impl Scratch {
    /// Grow (never shrink) to `arch`'s mesh and channel count. Epoch
    /// stamps survive across runs — the epoch only ever increases, so a
    /// stale stamp can never alias the current tree.
    fn reset(&mut self, arch: &ArchConfig) {
        let nl = num_links(arch.rows, arch.cols);
        if self.seen.len() < nl {
            self.seen.resize(nl, 0);
        }
        let nc = arch.hbm.num_channels();
        if self.chan_bytes.len() < nc {
            self.chan_bytes.resize(nc, 0);
            self.chan_runs.resize(nc, 0);
        }
        self.chan_touched.clear();
    }
}

/// Reusable simulation arena: the flat resource tables plus scratch
/// buffers, reset (not reallocated) by every [`simulate_in`] call.
///
/// Hold one per thread that simulates in a loop — the serial autotuner
/// keeps one for its whole candidate scan and the parallel engine keeps
/// one per worker — so the hot path stays allocation-free after the first
/// call. A fresh arena per call ([`simulate`]) is always correct, just
/// slower.
#[derive(Default)]
pub struct SimArena {
    res: Resources,
    scratch: Scratch,
}

impl SimArena {
    pub fn new() -> SimArena {
        SimArena::default()
    }
}

/// Aggregate statistics of one simulated run.
#[derive(Debug, Clone)]
pub struct RunStats {
    pub makespan_ns: f64,
    /// FLOPs of the original (unpadded) problem.
    pub useful_flops: f64,
    /// FLOPs actually executed (padding included).
    pub total_flops: f64,
    pub hbm_read_bytes: u64,
    pub hbm_write_bytes: u64,
    /// Bytes × links traversed on the NoC.
    pub noc_link_bytes: u64,
    /// Bytes read from / written to tile L1 SPMs: matrix-engine operand
    /// and accumulator traffic plus one endpoint access per transferred
    /// byte of DMA/NoC payload (the energy model's SPM term).
    pub spm_bytes: u64,
    pub peak_tflops: f64,
    pub hbm_peak_gbps: f64,
    pub supersteps: usize,
    /// Σ per-tile matrix-engine busy time.
    pub compute_busy_ns: f64,
    pub num_tiles: usize,
    /// End time of each superstep (for pipeline/stagger analysis).
    pub step_end_ns: Vec<f64>,
}

impl RunStats {
    /// Achieved useful throughput in TFLOP/s.
    pub fn tflops(&self) -> f64 {
        self.useful_flops / self.makespan_ns / 1e3
    }

    /// Utilization vs system peak (the paper's headline metric).
    pub fn utilization(&self) -> f64 {
        self.tflops() / self.peak_tflops
    }

    /// Achieved HBM bandwidth (GB/s) averaged over the run. Always
    /// finite: a run with no HBM traffic reports 0 GB/s (and a
    /// non-positive makespan — impossible for simulator output, which
    /// clamps to ≥ 1e-9 ns, but reachable on hand-built stats — reports
    /// 0 rather than ±inf/NaN).
    pub fn hbm_gbps(&self) -> f64 {
        if self.makespan_ns <= 0.0 {
            return 0.0;
        }
        (self.hbm_read_bytes + self.hbm_write_bytes) as f64 / self.makespan_ns
    }

    /// HBM bandwidth utilization (Fig. 11's metric).
    pub fn hbm_utilization(&self) -> f64 {
        self.hbm_gbps() / self.hbm_peak_gbps
    }

    /// Operational intensity actually achieved (FLOP per HBM byte).
    /// Always finite: an SPM-resident run with zero HBM bytes reports
    /// FLOPs-per-single-byte — a huge but finite stand-in for "infinite
    /// intensity" that keeps roofline plots, report tables, and Pareto
    /// scalarization NaN-free (0/0 used to poison all three).
    pub fn intensity(&self) -> f64 {
        self.useful_flops / (self.hbm_read_bytes + self.hbm_write_bytes).max(1) as f64
    }

    /// Multiply-accumulates executed (padding included): one MAC is two
    /// FLOPs — the energy model's compute term.
    pub fn macs(&self) -> f64 {
        self.total_flops / 2.0
    }

    /// Serialize for the persistent simulation cache
    /// ([`crate::coordinator::cache`]). The rendering is **lossless**:
    /// f64 fields go through the shortest-roundtrip float formatter and
    /// the `u64` byte counters through the exact integer representation
    /// ([`crate::util::json::Json::Int`]), so
    /// [`RunStats::from_json`] reproduces this value bit for bit — the
    /// property that makes a resumed sweep identical to a cold one.
    pub fn to_json(&self) -> Json {
        let mut steps = Json::arr();
        for s in &self.step_end_ns {
            steps = steps.push(*s);
        }
        Json::obj()
            .field("makespan_ns", self.makespan_ns)
            .field("useful_flops", self.useful_flops)
            .field("total_flops", self.total_flops)
            .field("hbm_read_bytes", self.hbm_read_bytes)
            .field("hbm_write_bytes", self.hbm_write_bytes)
            .field("noc_link_bytes", self.noc_link_bytes)
            .field("spm_bytes", self.spm_bytes)
            .field("peak_tflops", self.peak_tflops)
            .field("hbm_peak_gbps", self.hbm_peak_gbps)
            .field("supersteps", self.supersteps)
            .field("compute_busy_ns", self.compute_busy_ns)
            .field("num_tiles", self.num_tiles)
            .field("step_end_ns", steps)
    }

    /// Inverse of [`RunStats::to_json`]. Any missing or mistyped field is
    /// an error (callers degrade to a cache miss) — never a panic and
    /// never a silently defaulted value.
    pub fn from_json(j: &Json) -> anyhow::Result<RunStats> {
        let f64_field = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("RunStats field {key:?} missing or not a number"))
        };
        let u64_field = |key: &str| -> anyhow::Result<u64> {
            j.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("RunStats field {key:?} missing or not exact u64"))
        };
        let usize_field = |key: &str| -> anyhow::Result<usize> {
            let v = u64_field(key)?;
            usize::try_from(v)
                .map_err(|_| anyhow::anyhow!("RunStats field {key:?} out of usize range"))
        };
        let steps = j
            .get("step_end_ns")
            .and_then(Json::items)
            .ok_or_else(|| anyhow::anyhow!("RunStats field \"step_end_ns\" missing"))?;
        let mut step_end_ns = Vec::with_capacity(steps.len());
        for s in steps {
            step_end_ns.push(
                s.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric step_end_ns entry"))?,
            );
        }
        Ok(RunStats {
            makespan_ns: f64_field("makespan_ns")?,
            useful_flops: f64_field("useful_flops")?,
            total_flops: f64_field("total_flops")?,
            hbm_read_bytes: u64_field("hbm_read_bytes")?,
            hbm_write_bytes: u64_field("hbm_write_bytes")?,
            noc_link_bytes: u64_field("noc_link_bytes")?,
            spm_bytes: u64_field("spm_bytes")?,
            peak_tflops: f64_field("peak_tflops")?,
            hbm_peak_gbps: f64_field("hbm_peak_gbps")?,
            supersteps: usize_field("supersteps")?,
            compute_busy_ns: f64_field("compute_busy_ns")?,
            num_tiles: usize_field("num_tiles")?,
            step_end_ns,
        })
    }
}

/// Simulate a deployment on an architecture with a private, throwaway
/// arena. Correct everywhere; callers that simulate in a loop should hold
/// a [`SimArena`] and use [`simulate_in`] instead.
pub fn simulate(arch: &ArchConfig, dep: &Deployment) -> anyhow::Result<RunStats> {
    simulate_in(arch, dep, &mut SimArena::new())
}

/// Simulate a deployment reusing the caller's [`SimArena`]: identical
/// output to [`simulate`] (the arena is fully reset, and mesh/channel
/// resizes are handled), but the route/tree/resource buffers are reused
/// across calls — the allocation-free hot path under autotuning and DSE.
pub fn simulate_in(
    arch: &ArchConfig,
    dep: &Deployment,
    arena: &mut SimArena,
) -> anyhow::Result<RunStats> {
    let t_wall = std::time::Instant::now();
    arena.res.reset(arch);
    arena.scratch.reset(arch);
    let SimArena { res, scratch } = arena;
    let mut stats = RunStats {
        makespan_ns: 0.0,
        useful_flops: dep.useful_flops(),
        total_flops: 0.0,
        hbm_read_bytes: 0,
        hbm_write_bytes: 0,
        noc_link_bytes: 0,
        spm_bytes: 0,
        peak_tflops: arch.peak_tflops(),
        hbm_peak_gbps: arch.hbm.total_gbps(),
        supersteps: dep.supersteps(),
        compute_busy_ns: 0.0,
        num_tiles: arch.num_tiles(),
        step_end_ns: Vec::with_capacity(dep.supersteps()),
    };

    // Barrier cost: a single-phase hardware barrier over the collective
    // network (mask-based reduction to a corner), ~(rows+cols) hops.
    let barrier_ns = (arch.rows + arch.cols) as f64 * arch.noc.hop_ns;

    let n_steps = dep.supersteps();
    let mut t_step = 0.0f64; // global superstep start
    let mut t_prev = 0.0f64; // previous superstep start (DMA prefetch window)
    let debug = debug_enabled();

    // Multicast groups resolved once per op via mask membership.
    for step in 0..n_steps {
        let mut step_end = t_step;
        let mut slowest: (f64, String) = (t_step, String::new());

        for prog in &dep.programs {
            let Some(ss) = prog.steps.get(step) else { continue };
            let tile = prog.tile;
            let tile_lin = tile.linear(arch.cols);

            // --- Compute phase: MMADs serialize on the matrix engine.
            let mut engine_t = t_step;
            for op in &ss.ops {
                if let Op::Mmad { m, n, k, .. } = op {
                    let dt = engine_time_ns(arch, *m, *n, *k);
                    engine_t += dt;
                    stats.compute_busy_ns += dt;
                    stats.total_flops += 2.0 * (*m as f64) * (*n as f64) * (*k as f64);
                    // SPM operand traffic: read the A and B panels, and
                    // read-modify-write the C accumulator tile.
                    stats.spm_bytes += ((m * k + k * n + 2 * m * n) * arch.elem_bytes) as u64;
                }
            }
            step_end = step_end.max(engine_t);
            if debug && engine_t > slowest.0 {
                slowest = (engine_t, format!("mmad@{tile}"));
            }

            // --- Communication phase.
            for op in &ss.ops {
                let end = match op {
                    Op::DmaIn { runs, .. } => {
                        let bytes = runs.iter().map(|r| r.bytes).sum::<u64>();
                        stats.hbm_read_bytes += bytes;
                        stats.spm_bytes += bytes; // written into the tile's L1
                        // Input fetches are posted one superstep ahead
                        // (double-buffered DMA descriptor queues): the
                        // channel may start serving during the previous
                        // step; delivery is still barrier-synchronized.
                        hbm_transfer(
                            arch, res, scratch, &mut stats, tile, tile_lin, runs, t_prev, true,
                        )
                    }
                    Op::DmaOut { runs, .. } => {
                        let bytes = runs.iter().map(|r| r.bytes).sum::<u64>();
                        stats.hbm_write_bytes += bytes;
                        stats.spm_bytes += bytes; // read out of the tile's L1
                        hbm_transfer(
                            arch, res, scratch, &mut stats, tile, tile_lin, runs, t_step, false,
                        )
                    }
                    Op::Multicast { group, bytes, .. } => {
                        multicast_transfer(arch, res, scratch, &mut stats, tile, group, *bytes, t_step)
                    }
                    Op::Send { to, bytes, .. } => {
                        res.route_into(&mut scratch.route, tile, *to, true);
                        let hops = scratch.route.len();
                        stats.noc_link_bytes += *bytes * hops as u64;
                        stats.spm_bytes += *bytes * 2; // read at source, write at sink
                        let (_, end) = res.reserve(&scratch.route, hops, *bytes, t_step);
                        end
                    }
                    Op::Reduce { group, root, bytes, .. } => {
                        // Emitted by every member; charge the tree once,
                        // from the member that *is* the root.
                        if tile == *root {
                            reduce_transfer(arch, res, scratch, &mut stats, group, *root, *bytes, t_step)
                        } else {
                            t_step
                        }
                    }
                    // Receives complete when the matching send completes;
                    // their cost is carried by the sender's reservation.
                    Op::RecvMulticast { .. } | Op::Recv { .. } => t_step,
                    Op::Mmad { .. } => continue,
                };
                step_end = step_end.max(end);
                if debug && end > slowest.0 {
                    slowest = (end, format!("{} @{tile}", op_kind(op)));
                }
            }
        }

        if debug {
            eprintln!(
                "step {step}: dur {} slowest {} ({})",
                crate::util::human_time_ns(step_end - t_step),
                slowest.1,
                crate::util::human_time_ns(slowest.0 - t_step)
            );
        }
        t_prev = t_step;
        t_step = step_end + barrier_ns;
        stats.step_end_ns.push(t_step);
    }

    stats.makespan_ns = t_step.max(1e-9);
    SIM_CALLS.fetch_add(1, Ordering::Relaxed);
    SIM_NANOS.fetch_add(t_wall.elapsed().as_nanos() as u64, Ordering::Relaxed);
    Ok(stats)
}

fn op_kind(op: &Op) -> &'static str {
    match op {
        Op::DmaIn { .. } => "dma_in",
        Op::DmaOut { .. } => "dma_out",
        Op::Multicast { .. } => "mcast",
        Op::RecvMulticast { .. } => "recv_mcast",
        Op::Send { .. } => "send",
        Op::Recv { .. } => "recv",
        Op::Reduce { .. } => "reduce",
        Op::Mmad { .. } => "mmad",
    }
}

/// DMA transfer between HBM channels and a tile's L1.
///
/// Per channel: queue behind the channel's horizon, pay per-request
/// overhead per burst (strided layouts bleed here) and stream the bytes at
/// channel bandwidth × efficiency; then traverse the mesh from the
/// channel's edge router (read) or to it (write) — a write's channel
/// service starts only once the payload has arrived at the router. The op
/// completes when the slowest channel leg completes. The tile's DMA
/// engines round-robin over the channel legs.
#[allow(clippy::too_many_arguments)]
fn hbm_transfer(
    arch: &ArchConfig,
    res: &mut Resources,
    scratch: &mut Scratch,
    stats: &mut RunStats,
    tile: TileCoord,
    tile_lin: usize,
    runs: &[Run],
    t0: f64,
    is_read: bool,
) -> f64 {
    // Group runs by channel in the reusable accumulators. Legs are
    // processed in ascending channel order: the leg → DMA-engine
    // round-robin below is order-sensitive, and simulate() must be a pure
    // function of its inputs (the parallel autotuning engine requires two
    // simulations of the same deployment to agree bit for bit).
    let Scratch { route, chan_bytes, chan_runs, chan_touched, .. } = scratch;
    for r in runs {
        if chan_runs[r.channel] == 0 {
            chan_touched.push(r.channel);
        }
        chan_bytes[r.channel] += r.bytes;
        chan_runs[r.channel] += 1;
    }
    chan_touched.sort_unstable();
    let debug_dma = debug_dma_enabled();
    let mut op_end = t0;
    let n_engines = res.dma_engines;
    for (idx, &ch) in chan_touched.iter().enumerate() {
        let bytes = chan_bytes[ch];
        let nruns = chan_runs[ch];
        // DMA engine availability.
        let engine = idx % n_engines;
        let t_engine = res.dma[tile_lin * n_engines + engine].max(t0);
        // Channel service.
        let service = nruns as f64 * arch.hbm.request_overhead_ns
            + bytes as f64 / (arch.hbm.channel_gbps * arch.hbm.stream_efficiency);
        // Mesh leg between the channel's router and the tile. Memory
        // traffic is dimension-ordered so it travels the channel's own
        // dedicated lane (its row for west channels, its column for south
        // channels) and never funnels along the die edge: west reads /
        // south writes go column-first, west writes / south reads go
        // row-first. (Edge funneling otherwise serializes the entire
        // store burst of a superstep through column 0 / row N-1.)
        let router = arch.hbm_router(ch);
        let is_west = ch < arch.hbm.channels_per_edge;
        let (from, to) = if is_read { (router, tile) } else { (tile, router) };
        let col_first = is_west == is_read;
        res.route_into(route, from, to, col_first);
        let hops = route.len();
        stats.noc_link_bytes += bytes * hops as u64;
        let (leg_end, ch_start, ch_end) = if is_read {
            // Read: the channel serves first, then the payload crosses
            // the mesh from the edge router to the tile.
            let ch_start = res.channels[ch].max(t_engine);
            let ch_end = ch_start + service;
            res.channels[ch] = ch_end;
            let (_, arr) = res.reserve(route, hops, bytes, ch_end);
            (arr, ch_start, ch_end)
        } else {
            // Write: the payload must reach the edge router before the
            // channel can serve a single byte, so channel service queues
            // behind the NoC arrival. (It used to start at DMA-engine
            // availability, letting a congested store path overlap its
            // own mesh traversal with channel service — bytes served
            // before they could exist at the router.)
            let (_, arr) = res.reserve(route, hops, bytes, t_engine);
            let ch_start = res.channels[ch].max(arr);
            let ch_end = ch_start + service;
            res.channels[ch] = ch_end;
            (ch_end, ch_start, ch_end)
        };
        if debug_dma && leg_end - t0 > 3000.0 {
            eprintln!(
                "  dma {} ch{ch} {bytes}B x{nruns}: tile {tile} queue {:.0} service {service:.0} noc {:.0} total {:.0}",
                if is_read { "r" } else { "w" },
                ch_start - t0,
                leg_end - ch_end,
                leg_end - t0,
            );
        }
        res.dma[tile_lin * n_engines + engine] = leg_end;
        op_end = op_end.max(leg_end);
    }
    // Leave the accumulators zeroed for the next transfer.
    for &ch in chan_touched.iter() {
        chan_bytes[ch] = 0;
        chan_runs[ch] = 0;
    }
    chan_touched.clear();
    op_end
}

/// Hardware multicast: build the XY tree root→members, charge every tree
/// link exactly once (this is the collective advantage over unicast).
#[allow(clippy::too_many_arguments)]
fn multicast_transfer(
    arch: &ArchConfig,
    res: &mut Resources,
    scratch: &mut Scratch,
    stats: &mut RunStats,
    root: TileCoord,
    group: &Mask,
    bytes: u64,
    t0: f64,
) -> f64 {
    group.members_into(arch.rows, arch.cols, &mut scratch.members);
    scratch.epoch += 1;
    let epoch = scratch.epoch;
    let Scratch { route, tree, members, seen, .. } = scratch;
    tree.clear();
    let mut max_hops = 0usize;
    for &m in members.iter() {
        if m == root {
            continue;
        }
        res.route_into(route, root, m, true);
        for &l in route.iter() {
            if seen[l] != epoch {
                seen[l] = epoch;
                tree.push(l);
            }
        }
        max_hops = max_hops.max(root.hops_to(m));
    }
    if tree.is_empty() {
        return t0; // self-only group
    }
    stats.noc_link_bytes += bytes * tree.len() as u64;
    // SPM endpoints: one read at the root, one write per other member.
    stats.spm_bytes += bytes * members.len() as u64;
    let (_, end) = res.reserve(tree, max_hops, bytes, t0);
    end
}

/// Hardware reduction: the reversed tree members→root with in-network
/// combining; each link carries the payload once.
#[allow(clippy::too_many_arguments)]
fn reduce_transfer(
    arch: &ArchConfig,
    res: &mut Resources,
    scratch: &mut Scratch,
    stats: &mut RunStats,
    group: &Mask,
    root: TileCoord,
    bytes: u64,
    t0: f64,
) -> f64 {
    group.members_into(arch.rows, arch.cols, &mut scratch.members);
    scratch.epoch += 1;
    let epoch = scratch.epoch;
    let Scratch { route, tree, members, seen, .. } = scratch;
    tree.clear();
    let mut max_hops = 0usize;
    for &m in members.iter() {
        if m == root {
            continue;
        }
        res.route_into(route, m, root, true);
        for &l in route.iter() {
            if seen[l] != epoch {
                seen[l] = epoch;
                tree.push(l);
            }
        }
        max_hops = max_hops.max(m.hops_to(root));
    }
    if tree.is_empty() {
        return t0;
    }
    stats.noc_link_bytes += bytes * tree.len() as u64;
    // SPM endpoints: one read per contributing member, one result write
    // at the root (in-network combining touches no intermediate SPM).
    stats.spm_bytes += bytes * (members.len() as u64 + 1);
    let (_, end) = res.reserve(tree, max_hops, bytes, t0);
    end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, GemmShape};
    use crate::codegen::generate;
    use crate::schedule::Schedule;

    fn run(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> RunStats {
        let dep = generate(arch, shape, sched, arch.elem_bytes).unwrap();
        simulate(arch, &dep).unwrap()
    }

    fn blank_stats() -> RunStats {
        RunStats {
            makespan_ns: 0.0,
            useful_flops: 0.0,
            total_flops: 0.0,
            hbm_read_bytes: 0,
            hbm_write_bytes: 0,
            noc_link_bytes: 0,
            spm_bytes: 0,
            peak_tflops: 1.0,
            hbm_peak_gbps: 1.0,
            supersteps: 0,
            compute_busy_ns: 0.0,
            num_tiles: 0,
            step_end_ns: Vec::new(),
        }
    }

    #[test]
    fn engine_model_matches_paper_calibration() {
        let arch = ArchConfig::gh200_like();
        // Ragged TN=66 (the 2112/32 case): ~50% utilization.
        let t = engine_time_ns(&arch, 128, 66, 128);
        let ideal = 2.0 * 128.0 * 66.0 * 128.0 / (arch.tile.peak_tflops() * 1e3);
        let eff = ideal / t;
        assert!((0.40..=0.60).contains(&eff), "ragged eff {eff}");
        // Wide aligned tile: high utilization.
        let t = engine_time_ns(&arch, 128, 528, 512);
        let ideal = 2.0 * 128.0 * 528.0 * 512.0 / (arch.tile.peak_tflops() * 1e3);
        let eff = ideal / t;
        assert!(eff >= 0.85, "wide eff {eff}");
    }

    #[test]
    fn simulation_is_deterministic() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let s = Schedule::summa(&arch, shape);
        let a = run(&arch, shape, &s);
        let b = run(&arch, shape, &s);
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.hbm_read_bytes, b.hbm_read_bytes);
        assert_eq!(a.noc_link_bytes, b.noc_link_bytes);
        assert_eq!(a.spm_bytes, b.spm_bytes);
    }

    #[test]
    fn arena_reuse_is_bit_identical_across_meshes() {
        // One arena reused across different mesh geometries and schedules
        // (exercising every resize path) must match a fresh arena per
        // call bit for bit — reuse may never leak horizons, stale epochs,
        // or channel accumulators between runs.
        let mut arena = SimArena::new();
        let shape = GemmShape::new(128, 96, 256);
        for (rows, cols) in [(4usize, 4usize), (2, 4), (4, 2), (4, 4)] {
            let arch = ArchConfig::tiny(rows, cols);
            for sched in [Schedule::summa(&arch, shape), Schedule::baseline(&arch, shape)] {
                let dep = generate(&arch, shape, &sched, arch.elem_bytes).unwrap();
                let fresh = simulate(&arch, &dep).unwrap();
                let reused = simulate_in(&arch, &dep, &mut arena).unwrap();
                assert_eq!(
                    fresh.makespan_ns.to_bits(),
                    reused.makespan_ns.to_bits(),
                    "{rows}x{cols} {}",
                    sched.name()
                );
                assert_eq!(fresh.noc_link_bytes, reused.noc_link_bytes);
                assert_eq!(fresh.spm_bytes, reused.spm_bytes);
                assert_eq!(fresh.compute_busy_ns.to_bits(), reused.compute_busy_ns.to_bits());
            }
        }
    }

    #[test]
    fn sim_counters_accumulate() {
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(64, 64, 64);
        let (calls0, _) = sim_counters();
        run(&arch, shape, &Schedule::summa(&arch, shape));
        let (calls1, nanos1) = sim_counters();
        assert!(calls1 > calls0, "simulate must count itself");
        assert!(nanos1 > 0);
    }

    #[test]
    fn debug_probes_latch_once() {
        // The env probes are read exactly once per process (they used to
        // be a getenv per simulate call / per DMA leg); flipping the
        // variable afterwards must change neither the probe nor the
        // simulated output.
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(64, 64, 64);
        let before_stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        let before = (debug_enabled(), debug_dma_enabled());
        std::env::set_var("DIT_SIM_DEBUG", "1");
        std::env::set_var("DIT_SIM_DEBUG_DMA", "1");
        assert_eq!((debug_enabled(), debug_dma_enabled()), before, "probes must latch");
        let after_stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        std::env::remove_var("DIT_SIM_DEBUG");
        std::env::remove_var("DIT_SIM_DEBUG_DMA");
        assert_eq!((debug_enabled(), debug_dma_enabled()), before, "probes must stay latched");
        assert_eq!(before_stats.makespan_ns.to_bits(), after_stats.makespan_ns.to_bits());
        assert_eq!(before_stats.spm_bytes, after_stats.spm_bytes);
    }

    #[test]
    fn write_channel_queues_behind_noc_arrival() {
        // Regression for the DmaOut ordering bug: channel service used to
        // start at DMA-engine availability — *before* the payload could
        // have crossed the mesh to the edge router — so a congested store
        // path never delayed channel occupancy.
        let arch = ArchConfig::tiny(4, 4);
        let bytes = 1u64 << 16;
        let runs = [Run { channel: 0, offset: 0, bytes }];
        let tile = TileCoord::new(0, 3); // 3 hops east of channel 0's router (0,0)
        let write = |congest: bool| {
            let mut arena = SimArena::new();
            arena.res.reset(&arch);
            arena.scratch.reset(&arch);
            let SimArena { res, scratch } = &mut arena;
            if congest {
                // Pre-load the exact store route (west writes go
                // row-first) with a large earlier transfer.
                res.route_into(&mut scratch.route, tile, TileCoord::new(0, 0), false);
                let hops = scratch.route.len();
                res.reserve(&scratch.route, hops, 1 << 22, 0.0);
            }
            let mut stats = blank_stats();
            let end = hbm_transfer(
                &arch,
                res,
                scratch,
                &mut stats,
                tile,
                tile.linear(arch.cols),
                &runs,
                0.0,
                false,
            );
            (end, res.channels[0])
        };
        let (free_end, free_ch) = write(false);
        let (cong_end, cong_ch) = write(true);
        // Even uncongested, the channel cannot finish before NoC arrival
        // plus its own service time.
        let serial = bytes as f64 / arch.noc.link_gbps();
        let noc_arrival = 3.0 * arch.noc.hop_ns + serial;
        let service = arch.hbm.request_overhead_ns
            + bytes as f64 / (arch.hbm.channel_gbps * arch.hbm.stream_efficiency);
        assert!(
            (free_ch - (noc_arrival + service)).abs() < 1e-6,
            "channel horizon {free_ch} != arrival {noc_arrival} + service {service}"
        );
        assert_eq!(free_end, free_ch, "a write completes when its channel service does");
        // A congested store path delays when the channel starts serving.
        assert!(
            cong_ch > free_ch + 1.0,
            "congestion must delay channel occupancy: {cong_ch} vs {free_ch}"
        );
        assert!(cong_end > free_end);
    }

    #[test]
    fn zero_hbm_stats_are_finite() {
        // SPM-resident deployments produce zero HBM bytes; intensity and
        // bandwidth must stay finite (0/0 used to propagate NaN into
        // report tables and Pareto scalarization).
        let mut s = blank_stats();
        s.makespan_ns = 1000.0;
        s.useful_flops = 1e9;
        assert!(s.intensity().is_finite());
        assert_eq!(s.intensity(), 1e9, "zero HBM bytes read as FLOPs per single byte");
        assert_eq!(s.hbm_gbps(), 0.0);
        assert!(s.hbm_utilization().is_finite());
        // Hand-built stats with a zero makespan must not divide by zero
        // either.
        s.makespan_ns = 0.0;
        assert_eq!(s.hbm_gbps(), 0.0);
        // Simulator output is never zero-makespan, and stays finite even
        // for an empty deployment.
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(64, 64, 64);
        let stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        assert!(stats.intensity().is_finite() && stats.hbm_gbps().is_finite());
    }

    #[test]
    fn spm_traffic_covers_engine_operands() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        // At minimum the matrix engines read every A/B operand byte and
        // read-modify-write every C byte once per MMAD; with K-panel
        // staging and communication endpoints the SPM sees strictly more
        // traffic than the compulsory HBM bytes.
        assert!(stats.spm_bytes > 0);
        assert!(
            stats.spm_bytes > stats.hbm_read_bytes + stats.hbm_write_bytes,
            "spm {} vs hbm {}",
            stats.spm_bytes,
            stats.hbm_read_bytes + stats.hbm_write_bytes
        );
        assert!((stats.macs() - stats.total_flops / 2.0).abs() < 1.0);
    }

    #[test]
    fn runstats_json_roundtrip_is_bit_identical() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 96, 256);
        let stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        let text = stats.to_json().render();
        let back = RunStats::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.makespan_ns.to_bits(), stats.makespan_ns.to_bits());
        assert_eq!(back.useful_flops.to_bits(), stats.useful_flops.to_bits());
        assert_eq!(back.total_flops.to_bits(), stats.total_flops.to_bits());
        assert_eq!(back.hbm_read_bytes, stats.hbm_read_bytes);
        assert_eq!(back.hbm_write_bytes, stats.hbm_write_bytes);
        assert_eq!(back.noc_link_bytes, stats.noc_link_bytes);
        assert_eq!(back.spm_bytes, stats.spm_bytes);
        assert_eq!(back.peak_tflops.to_bits(), stats.peak_tflops.to_bits());
        assert_eq!(back.hbm_peak_gbps.to_bits(), stats.hbm_peak_gbps.to_bits());
        assert_eq!(back.supersteps, stats.supersteps);
        assert_eq!(back.compute_busy_ns.to_bits(), stats.compute_busy_ns.to_bits());
        assert_eq!(back.num_tiles, stats.num_tiles);
        assert_eq!(back.step_end_ns.len(), stats.step_end_ns.len());
        for (a, b) in back.step_end_ns.iter().zip(&stats.step_end_ns) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Counters above 2^53 survive exactly (the util::json Int path).
        let mut big = stats.clone();
        big.spm_bytes = (1 << 53) + 1;
        big.hbm_read_bytes = u64::MAX;
        let back = RunStats::from_json(&Json::parse(&big.to_json().render()).unwrap()).unwrap();
        assert_eq!(back.spm_bytes, (1 << 53) + 1);
        assert_eq!(back.hbm_read_bytes, u64::MAX);
    }

    #[test]
    fn runstats_from_json_rejects_malformed_documents() {
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(64, 64, 64);
        let stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        let good = stats.to_json();
        assert!(RunStats::from_json(&good).is_ok());
        assert!(RunStats::from_json(&Json::Null).is_err(), "not an object");
        assert!(RunStats::from_json(&Json::obj()).is_err(), "missing fields");
        // A counter stored as a non-integer is rejected, not truncated.
        let bad = Json::parse(&good.render().replace("\"spm_bytes\":", "\"spm_bytes\":0.5,\"x\":"))
            .unwrap();
        assert!(RunStats::from_json(&bad).is_err(), "non-integer counter");
    }

    #[test]
    fn utilization_is_sane() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(256, 256, 1024);
        let stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        assert!(stats.makespan_ns > 0.0);
        assert!(stats.utilization() > 0.0 && stats.utilization() <= 1.0,
            "util {}", stats.utilization());
        assert!(stats.hbm_utilization() <= 1.0 + 1e-9);
    }

    #[test]
    fn summa_beats_baseline() {
        // Fig. 7a: collective dataflow + layout beats the naive baseline.
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(256, 256, 512);
        let summa = run(&arch, shape, &Schedule::summa(&arch, shape));
        let base = run(&arch, shape, &Schedule::baseline(&arch, shape));
        assert!(
            summa.makespan_ns < base.makespan_ns,
            "summa {} vs baseline {}",
            summa.makespan_ns,
            base.makespan_ns
        );
        // And achieves higher operational intensity (less HBM traffic).
        assert!(summa.intensity() > base.intensity());
    }

    #[test]
    fn optimal_layout_beats_base_layout() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(256, 256, 512);
        let opt = run(&arch, shape, &Schedule::summa(&arch, shape));
        let mut s = Schedule::summa(&arch, shape);
        s.opt_layout = false;
        let base = run(&arch, shape, &s);
        assert!(opt.makespan_ns < base.makespan_ns,
            "opt {} vs base {}", opt.makespan_ns, base.makespan_ns);
    }

    #[test]
    fn double_buffering_helps() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(256, 256, 1024);
        let db = run(&arch, shape, &Schedule::summa(&arch, shape));
        let mut s = Schedule::summa(&arch, shape);
        s.double_buffer = false;
        let nodb = run(&arch, shape, &s);
        assert!(db.makespan_ns < nodb.makespan_ns,
            "db {} vs nodb {}", db.makespan_ns, nodb.makespan_ns);
    }

    #[test]
    fn step_timeline_is_monotone() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let stats = run(&arch, shape, &Schedule::summa(&arch, shape));
        for w in stats.step_end_ns.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(stats.step_end_ns.len(), stats.supersteps);
    }

    #[test]
    fn total_flops_cover_padded_problem() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(100, 100, 100); // ragged everything
        let dep = generate(&arch, shape, &Schedule::summa(&arch, shape), arch.elem_bytes).unwrap();
        let stats = simulate(&arch, &dep).unwrap();
        assert!((stats.total_flops - dep.padded.flops()).abs() < 1.0);
        assert!(stats.total_flops >= stats.useful_flops);
    }
}

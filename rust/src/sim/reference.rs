//! Frozen reference implementation of the simulator resource model.
//!
//! This is the pre-flat-index model (hashed directed-link map, per-op
//! `HashSet` tree dedup, per-transfer channel `HashMap`) kept as an
//! *executable golden*: `tests/properties.rs` asserts that the optimized
//! arena simulator in the parent module produces bit-identical `RunStats`
//! against this twin across meshes, shapes, and schedules. A dual
//! implementation is a stronger pin than committed constants — it holds
//! on any machine and any future schedule, not just the tuples someone
//! happened to record.
//!
//! Two deliberate differences from the historical code it snapshots:
//! the `DmaOut` ordering bug is fixed here too (write-channel service
//! queues behind NoC arrival — both models pin the *corrected* physics,
//! and the fix itself has its own regression test in the parent module),
//! and the debug `eprintln!` traces are stripped (they never affected the
//! returned stats).
//!
//! Do not optimize or refactor this module; it exists to stay still.

use std::collections::{HashMap, HashSet};

use crate::arch::ArchConfig;
use crate::collective::{Mask, TileCoord};
use crate::ir::{Deployment, Op};
use crate::layout::Run;

use super::{engine_time_ns, RunStats};

/// Directed mesh link identifier (the hashed pre-flat form).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct LinkId {
    from: TileCoord,
    to: TileCoord,
}

struct Resources {
    /// Directed link -> busy horizon (ns).
    links: HashMap<LinkId, f64>,
    /// HBM channel -> busy horizon.
    channels: Vec<f64>,
    /// (tile linear, engine) -> DMA queue horizon.
    dma: Vec<Vec<f64>>,
    link_gbps: f64,
    hop_ns: f64,
}

impl Resources {
    fn new(arch: &ArchConfig) -> Resources {
        Resources {
            links: HashMap::new(),
            channels: vec![0.0; arch.hbm.num_channels()],
            dma: vec![vec![0.0; arch.tile.dma_engines]; arch.num_tiles()],
            link_gbps: arch.noc.link_gbps(),
            hop_ns: arch.noc.hop_ns,
        }
    }

    /// X-first (column-coordinate first) dimension-ordered route.
    fn route(from: TileCoord, to: TileCoord) -> Vec<LinkId> {
        Self::route_ordered(from, to, true)
    }

    fn route_ordered(from: TileCoord, to: TileCoord, col_first: bool) -> Vec<LinkId> {
        let mut path = Vec::with_capacity(from.hops_to(to));
        let mut cur = from;
        let step_col = |cur: TileCoord| {
            TileCoord::new(cur.row, if to.col > cur.col { cur.col + 1 } else { cur.col - 1 })
        };
        let step_row = |cur: TileCoord| {
            TileCoord::new(if to.row > cur.row { cur.row + 1 } else { cur.row - 1 }, cur.col)
        };
        if col_first {
            while cur.col != to.col {
                let next = step_col(cur);
                path.push(LinkId { from: cur, to: next });
                cur = next;
            }
        }
        while cur.row != to.row {
            let next = step_row(cur);
            path.push(LinkId { from: cur, to: next });
            cur = next;
        }
        while cur.col != to.col {
            let next = step_col(cur);
            path.push(LinkId { from: cur, to: next });
            cur = next;
        }
        path
    }

    fn reserve(&mut self, links: &[LinkId], max_hops: usize, bytes: u64, t0: f64) -> (f64, f64) {
        let serial = bytes as f64 / self.link_gbps;
        let mut worst = t0;
        for l in links {
            let busy = self.links.entry(*l).or_insert(0.0);
            let start = busy.max(t0);
            worst = worst.max(start);
            *busy = start + serial;
        }
        let arrival = worst + max_hops as f64 * self.hop_ns + serial;
        (worst, arrival)
    }
}

/// Simulate a deployment with the frozen hashed resource model. Same
/// contract as [`super::simulate`]; exists only for the golden
/// bit-identity tests (and is therefore excluded from the throughput
/// counters).
pub fn simulate(arch: &ArchConfig, dep: &Deployment) -> anyhow::Result<RunStats> {
    let mut res = Resources::new(arch);
    let mut stats = RunStats {
        makespan_ns: 0.0,
        useful_flops: dep.useful_flops(),
        total_flops: 0.0,
        hbm_read_bytes: 0,
        hbm_write_bytes: 0,
        noc_link_bytes: 0,
        spm_bytes: 0,
        peak_tflops: arch.peak_tflops(),
        hbm_peak_gbps: arch.hbm.total_gbps(),
        supersteps: dep.supersteps(),
        compute_busy_ns: 0.0,
        num_tiles: arch.num_tiles(),
        step_end_ns: Vec::with_capacity(dep.supersteps()),
    };

    let barrier_ns = (arch.rows + arch.cols) as f64 * arch.noc.hop_ns;

    let n_steps = dep.supersteps();
    let mut t_step = 0.0f64;
    let mut t_prev = 0.0f64;

    for step in 0..n_steps {
        let mut step_end = t_step;

        for prog in &dep.programs {
            let Some(ss) = prog.steps.get(step) else { continue };
            let tile = prog.tile;
            let tile_lin = tile.linear(arch.cols);

            let mut engine_t = t_step;
            for op in &ss.ops {
                if let Op::Mmad { m, n, k, .. } = op {
                    let dt = engine_time_ns(arch, *m, *n, *k);
                    engine_t += dt;
                    stats.compute_busy_ns += dt;
                    stats.total_flops += 2.0 * (*m as f64) * (*n as f64) * (*k as f64);
                    stats.spm_bytes += ((m * k + k * n + 2 * m * n) * arch.elem_bytes) as u64;
                }
            }
            step_end = step_end.max(engine_t);

            for op in &ss.ops {
                let end = match op {
                    Op::DmaIn { runs, .. } => {
                        let bytes = runs.iter().map(|r| r.bytes).sum::<u64>();
                        stats.hbm_read_bytes += bytes;
                        stats.spm_bytes += bytes;
                        hbm_transfer(arch, &mut res, &mut stats, tile, tile_lin, runs, t_prev, true)
                    }
                    Op::DmaOut { runs, .. } => {
                        let bytes = runs.iter().map(|r| r.bytes).sum::<u64>();
                        stats.hbm_write_bytes += bytes;
                        stats.spm_bytes += bytes;
                        hbm_transfer(arch, &mut res, &mut stats, tile, tile_lin, runs, t_step, false)
                    }
                    Op::Multicast { group, bytes, .. } => {
                        multicast_transfer(arch, &mut res, &mut stats, tile, group, *bytes, t_step)
                    }
                    Op::Send { to, bytes, .. } => {
                        let path = Resources::route(tile, *to);
                        let hops = path.len();
                        stats.noc_link_bytes += *bytes * hops as u64;
                        stats.spm_bytes += *bytes * 2;
                        let (_, end) = res.reserve(&path, hops, *bytes, t_step);
                        end
                    }
                    Op::Reduce { group, root, bytes, .. } => {
                        if tile == *root {
                            reduce_transfer(arch, &mut res, &mut stats, group, *root, *bytes, t_step)
                        } else {
                            t_step
                        }
                    }
                    Op::RecvMulticast { .. } | Op::Recv { .. } => t_step,
                    Op::Mmad { .. } => continue,
                };
                step_end = step_end.max(end);
            }
        }

        t_prev = t_step;
        t_step = step_end + barrier_ns;
        stats.step_end_ns.push(t_step);
    }

    stats.makespan_ns = t_step.max(1e-9);
    Ok(stats)
}

#[allow(clippy::too_many_arguments)]
fn hbm_transfer(
    arch: &ArchConfig,
    res: &mut Resources,
    stats: &mut RunStats,
    tile: TileCoord,
    tile_lin: usize,
    runs: &[Run],
    t0: f64,
    is_read: bool,
) -> f64 {
    // Ascending channel order: the leg → engine round-robin is
    // order-sensitive and HashMap iteration order is not deterministic.
    let mut per_chan: HashMap<usize, (u64, u64)> = HashMap::new(); // ch -> (bytes, nruns)
    for r in runs {
        let e = per_chan.entry(r.channel).or_insert((0, 0));
        e.0 += r.bytes;
        e.1 += 1;
    }
    let mut legs: Vec<(usize, (u64, u64))> = per_chan.into_iter().collect();
    legs.sort_unstable_by_key(|(ch, _)| *ch);
    let mut op_end = t0;
    let n_engines = res.dma[tile_lin].len();
    for (idx, (ch, (bytes, nruns))) in legs.into_iter().enumerate() {
        let engine = idx % n_engines;
        let t_engine = res.dma[tile_lin][engine].max(t0);
        let service = nruns as f64 * arch.hbm.request_overhead_ns
            + bytes as f64 / (arch.hbm.channel_gbps * arch.hbm.stream_efficiency);
        let router = arch.hbm_router(ch);
        let is_west = ch < arch.hbm.channels_per_edge;
        let (from, to) = if is_read { (router, tile) } else { (tile, router) };
        let col_first = is_west == is_read;
        let path = Resources::route_ordered(from, to, col_first);
        let hops = path.len();
        stats.noc_link_bytes += bytes * hops as u64;
        let leg_end = if is_read {
            let ch_start = res.channels[ch].max(t_engine);
            let ch_end = ch_start + service;
            res.channels[ch] = ch_end;
            let (_, arr) = res.reserve(&path, hops, bytes, ch_end);
            arr
        } else {
            // Write-channel service queues behind NoC arrival (the
            // DmaOut ordering fix, mirrored in the optimized model).
            let (_, arr) = res.reserve(&path, hops, bytes, t_engine);
            let ch_start = res.channels[ch].max(arr);
            let ch_end = ch_start + service;
            res.channels[ch] = ch_end;
            ch_end
        };
        res.dma[tile_lin][engine] = leg_end;
        op_end = op_end.max(leg_end);
    }
    op_end
}

fn multicast_transfer(
    arch: &ArchConfig,
    res: &mut Resources,
    stats: &mut RunStats,
    root: TileCoord,
    group: &Mask,
    bytes: u64,
    t0: f64,
) -> f64 {
    let members = group.members(arch.rows, arch.cols);
    let mut seen: HashSet<LinkId> = HashSet::new();
    let mut tree: Vec<LinkId> = Vec::new();
    let mut max_hops = 0usize;
    for m in &members {
        if *m == root {
            continue;
        }
        for l in Resources::route(root, *m) {
            if seen.insert(l) {
                tree.push(l);
            }
        }
        max_hops = max_hops.max(root.hops_to(*m));
    }
    if tree.is_empty() {
        return t0; // self-only group
    }
    stats.noc_link_bytes += bytes * tree.len() as u64;
    stats.spm_bytes += bytes * members.len() as u64;
    let (_, end) = res.reserve(&tree, max_hops, bytes, t0);
    end
}

fn reduce_transfer(
    arch: &ArchConfig,
    res: &mut Resources,
    stats: &mut RunStats,
    group: &Mask,
    root: TileCoord,
    bytes: u64,
    t0: f64,
) -> f64 {
    let members = group.members(arch.rows, arch.cols);
    let mut seen: HashSet<LinkId> = HashSet::new();
    let mut tree: Vec<LinkId> = Vec::new();
    let mut max_hops = 0usize;
    for m in &members {
        if *m == root {
            continue;
        }
        for l in Resources::route(*m, root) {
            if seen.insert(l) {
                tree.push(l);
            }
        }
        max_hops = max_hops.max(m.hops_to(root));
    }
    if tree.is_empty() {
        return t0;
    }
    stats.noc_link_bytes += bytes * tree.len() as u64;
    stats.spm_bytes += bytes * (members.len() as u64 + 1);
    let (_, end) = res.reserve(&tree, max_hops, bytes, t0);
    end
}

//! `dit` binary entry point. All logic lives in [`dit::cli`].

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dit::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

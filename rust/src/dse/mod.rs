//! Hardware design-space exploration (DSE).
//!
//! The paper's thesis is that GEMM deployment must be *co-designed* with
//! the hardware instance: SoftHier is "fully configurable through
//! architecture configuration files", and the deployment toolchain is the
//! evaluator that tells you what a configuration is worth. This module
//! closes that loop. A [`SweepSpec`] spans the hardware side of the design
//! space — mesh dimensions, CE-array shape, SPM capacity, HBM channel
//! count/bandwidth, DMA engines — and [`run_sweep`] co-tunes every
//! candidate instance with the parallel batched autotuner
//! ([`Engine::tune_workload_on`]) over a named GEMM workload, reporting
//! the Pareto frontier of achieved TFLOP/s vs. a silicon-cost proxy —
//! and, since energy is the binding constraint for GH200-class machines,
//! the 3-axis frontier over perf/cost/energy, where the energy of a pass
//! comes from the deterministic [`EnergyModel`] over the simulator's
//! traffic counters. A weighted [scalarization](pareto::scalarize) mode
//! collapses the multi-objective result into one ranked winner
//! ([`DseResult::best_scalarized`]).
//!
//! Sweep mechanics:
//!
//! * **one engine, one memo-cache** — the simulation cache is keyed by
//!   architecture fingerprint, so every config shares one engine and
//!   repeated shapes/schedules across sweep waves never re-simulate;
//! * **persistent checkpointing** — with [`DseOptions::cache_path`] set,
//!   that cache is backed by the on-disk store
//!   ([`crate::coordinator::cache`]), checkpointed atomically after
//!   every evaluated config: a sweep killed mid-run resumes for free and
//!   produces a bit-identical [`DseResult`], and a refined spec around
//!   the frontier reuses every overlapping point;
//! * **config-level parallelism** — candidate configs are evaluated in
//!   deterministic cost-ordered waves, the configs of a wave concurrently;
//! * **roofline early-prune** — before simulating a config, its workload
//!   roofline upper bound ([`crate::perfmodel::workload_roofline_tflops`])
//!   is compared against the already-measured frontier: a config whose
//!   *ceiling* cannot beat a cheaper measured point can never be Pareto-
//!   optimal and is skipped. Pruning only consults completed waves, so the
//!   sweep output is independent of thread scheduling. The prune argument
//!   is only sound for the perf/cost axes — a slow-but-frugal config can
//!   still be energy-optimal — so whenever [`DseOptions::objectives`]
//!   includes [`Objective::Energy`] the sweep evaluates exhaustively.

pub mod pareto;

use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::arch::workload::Workload;
use crate::arch::ArchConfig;
use crate::coordinator::engine::{Engine, TunePolicy, WorkloadReport};
use crate::dse::pareto::Sense;
use crate::perfmodel::{workload_roofline_tflops, EnergyModel};
use crate::util::cfgtext::{Doc, Value};
use crate::util::json::Json;

/// Default safety slack applied to the roofline bound before pruning, as
/// a fraction: a config is only discarded when even `(1 + slack) × bound`
/// cannot reach the measured frontier, so modest model error cannot prune
/// a truly optimal config. Overridable per sweep via
/// [`DseOptions::prune_slack`].
pub const DEFAULT_PRUNE_SLACK: f64 = 0.05;

/// One axis of the multi-objective search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Achieved count-weighted aggregate TFLOP/s (maximized).
    Perf,
    /// Silicon-cost proxy units (minimized).
    Cost,
    /// Energy per workload pass, Joules (minimized).
    Energy,
}

/// The canonical 3-axis frontier order: (cost, perf, energy) — matching
/// the coordinates [`DseResult::frontier3`] is computed over.
pub const FRONTIER3: [Objective; 3] = [Objective::Cost, Objective::Perf, Objective::Energy];

impl Objective {
    pub fn name(self) -> &'static str {
        match self {
            Objective::Perf => "perf",
            Objective::Cost => "cost",
            Objective::Energy => "energy",
        }
    }

    /// Optimization direction of this axis.
    pub fn sense(self) -> Sense {
        match self {
            Objective::Perf => Sense::Max,
            Objective::Cost | Objective::Energy => Sense::Min,
        }
    }

    /// This axis's value for an evaluated point.
    pub fn value(self, p: &DsePoint) -> f64 {
        match self {
            Objective::Perf => p.tflops,
            Objective::Cost => p.cost,
            Objective::Energy => p.energy_j,
        }
    }

    /// Validate a weight vector against an objective list: one finite,
    /// non-negative weight per objective, not all zero. Shared by the CLI
    /// (which must reject bad weights *before* a long sweep runs) and
    /// [`DseResult::scalarized_scores`].
    pub fn validate_weights(objectives: &[Objective], weights: &[f64]) -> Result<()> {
        anyhow::ensure!(!objectives.is_empty(), "no objectives to scalarize");
        anyhow::ensure!(
            objectives.len() == weights.len(),
            "{} objectives but {} weights",
            objectives.len(),
            weights.len()
        );
        anyhow::ensure!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0)
                && weights.iter().sum::<f64>() > 0.0,
            "weights must be finite, non-negative, and not all zero"
        );
        Ok(())
    }

    /// Parse a comma-separated objective list (`perf,cost,energy`).
    /// Duplicates and empty lists are rejected.
    pub fn parse_list(s: &str) -> Result<Vec<Objective>> {
        let mut out = Vec::new();
        for part in s.split(',') {
            let o = match part.trim() {
                "perf" => Objective::Perf,
                "cost" => Objective::Cost,
                "energy" => Objective::Energy,
                other => anyhow::bail!(
                    "unknown objective {other:?}; available: perf, cost, energy"
                ),
            };
            anyhow::ensure!(!out.contains(&o), "objective {:?} listed twice", o.name());
            out.push(o);
        }
        anyhow::ensure!(!out.is_empty(), "objective list is empty");
        Ok(out)
    }
}

/// Silicon-cost proxy weights. The absolute scale is arbitrary (it only
/// ranks configurations); the defaults weigh a tile's MAC array, its SPM,
/// and system HBM bandwidth in roughly the area/cost proportions of a
/// modern accelerator die.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Cost per 1024 MAC units (PE count × CE-array area).
    pub per_kmac: f64,
    /// Cost per KiB of on-chip SPM, summed over all tiles.
    pub per_spm_kib: f64,
    /// Cost per GB/s of aggregate HBM bandwidth.
    pub per_hbm_gbps: f64,
}

impl CostModel {
    pub fn default_proxy() -> CostModel {
        CostModel { per_kmac: 1.0, per_spm_kib: 0.002, per_hbm_gbps: 0.05 }
    }

    /// Cost units for one architecture instance.
    pub fn cost(&self, arch: &ArchConfig) -> f64 {
        let kmacs = (arch.num_tiles() * arch.tile.ce_m * arch.tile.ce_n) as f64 / 1024.0;
        let spm_kib = (arch.num_tiles() * arch.tile.l1_bytes) as f64 / 1024.0;
        kmacs * self.per_kmac
            + spm_kib * self.per_spm_kib
            + arch.hbm.total_gbps() * self.per_hbm_gbps
    }
}

/// The swept hardware axes. Configurations are the cross product of all
/// axes applied to `base` (every non-swept parameter comes from `base`);
/// combinations that fail [`ArchConfig::validate`] are silently skipped.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    /// The swept mesh geometries as explicit `(rows, cols)` pairs.
    /// Square points come from the `mesh` spec axis
    /// ([`SweepSpec::square_meshes`] — `n` is sugar for `n × n`);
    /// rectangular points from the `mesh_rows × mesh_cols` cross
    /// product ([`SweepSpec::mesh_grid`], optionally filtered by a
    /// maximum `aspect` ratio) or from explicit `RxC` CLI entries.
    /// Duplicate pairs are kept — they tune from cache.
    pub meshes: Vec<(usize, usize)>,
    /// CE-array shapes `(ce_m, ce_n)`.
    pub ce: Vec<(usize, usize)>,
    /// Per-tile SPM capacities, KiB.
    pub spm_kib: Vec<usize>,
    /// Per-channel HBM bandwidths, GB/s.
    pub hbm_channel_gbps: Vec<f64>,
    /// HBM channel population as a percentage of the mesh edge. The
    /// derived per-edge count ([`SweepSpec::hbm_channels_per_edge`]) is
    /// `pct`% of the **shorter** mesh edge, rounded to nearest (ties
    /// up, minimum 1), and the same count populates *both* HBM edges —
    /// west (column 0, one router per row, top to bottom) and south
    /// (bottom row, one router per column), matching
    /// [`ArchConfig::hbm_router`] — so at `pct <= 100` every channel
    /// has a dedicated edge router even on rectangular grids. Counts
    /// beyond an edge's length wrap onto its routers.
    pub hbm_channels_pct: Vec<usize>,
    /// DMA engines per tile.
    pub dma_engines: Vec<usize>,
    /// Template for everything not swept.
    pub base: ArchConfig,
}

impl SweepSpec {
    /// The square `mesh` axis sugar: each `n` expands into the `n × n`
    /// point — the diagonal, *not* a cross product, so a square-only
    /// spec enumerates exactly the geometry points it always did. (The
    /// per-point HBM channel count is bit-identical too except where
    /// the round-to-nearest bugfix in
    /// [`SweepSpec::hbm_channels_per_edge`] deliberately corrects the
    /// old truncation — every built-in spec's `pct × edge` is an exact
    /// multiple of 100, so the built-ins are unchanged.)
    /// Use [`SweepSpec::mesh_grid`] for rectangular geometries.
    pub fn square_meshes(ns: &[usize]) -> Vec<(usize, usize)> {
        ns.iter().map(|&n| (n, n)).collect()
    }

    /// The `mesh_rows × mesh_cols` cross product in axis order, keeping
    /// only the pairs whose long/short edge ratio is at most `aspect`
    /// (`None` keeps everything; `Some(1.0)` reduces the cross product
    /// to its square diagonal).
    pub fn mesh_grid(rows: &[usize], cols: &[usize], aspect: Option<f64>) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for &r in rows {
            for &c in cols {
                let keep = match aspect {
                    None => true,
                    Some(a) => r.max(c) as f64 <= a * r.min(c) as f64,
                };
                if keep {
                    out.push((r, c));
                }
            }
        }
        out
    }

    /// HBM channels per edge for a mesh geometry at a population
    /// percentage: `pct`% of the **shorter** mesh edge, rounded to
    /// nearest (ties round up), never below 1. Deriving from the
    /// shorter edge means the count — which populates both the west and
    /// the south edge — never oversubscribes either edge at
    /// `pct <= 100`. (The predecessor truncated toward zero, so e.g. a
    /// 70%-populated 8-edge got 5 channels instead of the nearest 6.)
    pub fn hbm_channels_per_edge(rows: usize, cols: usize, pct: usize) -> usize {
        ((rows.min(cols) * pct + 50) / 100).max(1)
    }

    /// The fast default sweep: five mesh sizes (8×8 → 32×32) at two SPM
    /// capacities around the GH200-like template. The 192 KiB point forces
    /// a shallower K-panel ladder than 384 KiB, so each mesh contributes a
    /// real cheaper-but-slower / costlier-but-faster trade-off pair.
    /// Completes in seconds and includes the 32×32 GH200-class instance
    /// itself, so the frontier can be read against the paper's Table 1
    /// machine.
    pub fn reduced() -> SweepSpec {
        SweepSpec {
            name: "reduced".into(),
            meshes: SweepSpec::square_meshes(&[8, 12, 16, 24, 32]),
            ce: vec![(64, 16)],
            spm_kib: vec![192, 384],
            hbm_channel_gbps: vec![64.0],
            hbm_channels_pct: vec![100],
            dma_engines: vec![2],
            base: ArchConfig::gh200_like(),
        }
    }

    /// The broad sweep: adds CE-array shape, per-channel bandwidth, and
    /// channel-population axes (120 raw configurations before pruning).
    pub fn full() -> SweepSpec {
        SweepSpec {
            name: "full".into(),
            meshes: SweepSpec::square_meshes(&[8, 12, 16, 24, 32]),
            ce: vec![(32, 16), (64, 16)],
            spm_kib: vec![256, 384, 512],
            hbm_channel_gbps: vec![48.0, 64.0],
            hbm_channels_pct: vec![50, 100],
            dma_engines: vec![2],
            base: ArchConfig::gh200_like(),
        }
    }

    /// Parse a sweep spec from config text (`util::cfgtext` grammar). All
    /// keys are optional and default to [`SweepSpec::reduced`]; the base
    /// architecture is read from the same document's `[grid]`/`[tile]`/
    /// `[noc]`/`[hbm]` sections exactly like an architecture file, and the
    /// sweep axes live in a `[sweep]` section:
    ///
    /// ```text
    /// [sweep]
    /// name = "mine"
    /// mesh = [8, 16, 32]        # square sugar: n expands into n x n
    /// mesh_rows = [4, 8, 16]    # rectangular axes: the rows x cols
    /// mesh_cols = [8, 16, 32]   # cross product joins the mesh points
    /// aspect = 4                # optional: keep long/short edge <= 4
    /// ce_m = [64]
    /// ce_n = [16]
    /// spm_kib = [256, 384]
    /// hbm_channel_gbps = [64]
    /// hbm_channels_pct = [50, 100]
    /// dma_engines = [2]
    /// ```
    pub fn from_text(text: &str) -> Result<SweepSpec> {
        let doc = Doc::parse(text).context("sweep spec")?;
        let base = ArchConfig::from_text(text).context("sweep spec base architecture")?;
        let mut spec = SweepSpec { base, ..SweepSpec::reduced() };
        if let Some(name) = doc.get_str("sweep", "name") {
            spec.name = name.to_string();
        }
        let opt_usize_list = |key: &str| -> Result<Option<Vec<usize>>> {
            match doc.get("sweep", key) {
                None => Ok(None),
                Some(Value::Int(v)) if *v > 0 => Ok(Some(vec![*v as usize])),
                Some(Value::IntList(vs)) if !vs.is_empty() && vs.iter().all(|v| *v > 0) => {
                    Ok(Some(vs.iter().map(|v| *v as usize).collect()))
                }
                Some(other) => {
                    anyhow::bail!("sweep.{key} must be a positive int or int list, got {other}")
                }
            }
        };
        let usize_list = |key: &str, dflt: &[usize]| -> Result<Vec<usize>> {
            Ok(opt_usize_list(key)?.unwrap_or_else(|| dflt.to_vec()))
        };
        // Mesh geometry: the square `mesh` axis expands each n into the
        // n x n point; `mesh_rows`/`mesh_cols` span their cross product,
        // optionally filtered by `aspect` (max long/short edge ratio).
        // Any mesh key present replaces the default square ladder.
        let mesh_sq = opt_usize_list("mesh")?;
        let mesh_rows = opt_usize_list("mesh_rows")?;
        let mesh_cols = opt_usize_list("mesh_cols")?;
        anyhow::ensure!(
            mesh_rows.is_some() == mesh_cols.is_some(),
            "sweep.mesh_rows and sweep.mesh_cols must be given together"
        );
        let aspect = match doc.get("sweep", "aspect") {
            None => None,
            Some(Value::Float(v)) if *v >= 1.0 => Some(*v),
            Some(Value::Int(v)) if *v >= 1 => Some(*v as f64),
            Some(other) => anyhow::bail!("sweep.aspect must be a number >= 1, got {other}"),
        };
        anyhow::ensure!(
            aspect.is_none() || mesh_rows.is_some(),
            "sweep.aspect only filters the mesh_rows x mesh_cols cross product"
        );
        if mesh_sq.is_some() || mesh_rows.is_some() {
            let mut meshes = SweepSpec::square_meshes(mesh_sq.as_deref().unwrap_or(&[]));
            if let (Some(rows), Some(cols)) = (&mesh_rows, &mesh_cols) {
                meshes.extend(SweepSpec::mesh_grid(rows, cols, aspect));
            }
            anyhow::ensure!(
                !meshes.is_empty(),
                "sweep mesh axes enumerate no geometry (aspect filter too strict?)"
            );
            spec.meshes = meshes;
        }
        spec.spm_kib = usize_list("spm_kib", &spec.spm_kib.clone())?;
        spec.hbm_channels_pct = usize_list("hbm_channels_pct", &spec.hbm_channels_pct.clone())?;
        spec.dma_engines = usize_list("dma_engines", &spec.dma_engines.clone())?;
        // The bandwidth axis is f64 (presets use fractional GB/s, e.g. the
        // A100-like 48.6): accept a float or int scalar, or an int list
        // (the cfgtext grammar has no float lists).
        spec.hbm_channel_gbps = match doc.get("sweep", "hbm_channel_gbps") {
            None => spec.hbm_channel_gbps.clone(),
            Some(Value::Float(v)) if *v > 0.0 => vec![*v],
            Some(Value::Int(v)) if *v > 0 => vec![*v as f64],
            Some(Value::IntList(vs)) if !vs.is_empty() && vs.iter().all(|v| *v > 0) => {
                vs.iter().map(|v| *v as f64).collect()
            }
            Some(other) => anyhow::bail!(
                "sweep.hbm_channel_gbps must be a positive number or int list, got {other}"
            ),
        };
        let default_ce: (Vec<usize>, Vec<usize>) = spec.ce.iter().copied().unzip();
        let ce_m = usize_list("ce_m", &default_ce.0)?;
        let ce_n = usize_list("ce_n", &default_ce.1)?;
        anyhow::ensure!(
            ce_m.len() == ce_n.len(),
            "sweep.ce_m and sweep.ce_n must have the same length ({} vs {})",
            ce_m.len(),
            ce_n.len()
        );
        spec.ce = ce_m.into_iter().zip(ce_n).collect();
        Ok(spec)
    }

    /// All valid architecture instances this spec spans, in axis order.
    pub fn enumerate(&self) -> Vec<ArchConfig> {
        let mut out = Vec::new();
        for &(rows, cols) in &self.meshes {
            for &(ce_m, ce_n) in &self.ce {
                for &spm in &self.spm_kib {
                    for &gbps in &self.hbm_channel_gbps {
                        for &pct in &self.hbm_channels_pct {
                            for &dma in &self.dma_engines {
                                let mut a = self.base.clone();
                                a.rows = rows;
                                a.cols = cols;
                                a.tile.ce_m = ce_m;
                                a.tile.ce_n = ce_n;
                                a.tile.l1_bytes = spm * 1024;
                                a.tile.dma_engines = dma;
                                a.hbm.channel_gbps = gbps;
                                a.hbm.channels_per_edge =
                                    SweepSpec::hbm_channels_per_edge(rows, cols, pct);
                                a.name = format!(
                                    "dse-{rows}x{cols}-ce{ce_m}x{ce_n}-spm{spm}k-hbm{}x{:.0}-dma{dma}",
                                    a.hbm.num_channels(),
                                    gbps
                                );
                                if a.validate().is_ok() {
                                    out.push(a);
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// The GEMM suites a DSE sweep co-tunes against. These are deliberately
/// smaller than the `tune-workload` serving suites (d_model 2048 instead
/// of 7168, a handful of layers) so a whole sweep stays interactive while
/// still mixing compute-bound prefill with flat decode traffic.
pub fn suite(name: &str) -> Option<Workload> {
    let mut w = match name {
        "serving" => Workload::transformer_serving(512, 32, 2, 2048, 1024, 4),
        "prefill" => Workload::transformer_prefill("prefill", 512, 2048, 1024, 4),
        "decode" => Workload::transformer_decode("decode", 32, 2048, 1024, 4),
        "tiny" => Workload::builtin("tiny")?,
        _ => return None,
    };
    w.name = format!("dse-{name}");
    Some(w)
}

/// Names accepted by [`suite`].
pub fn suite_names() -> &'static [&'static str] {
    &["serving", "prefill", "decode", "tiny"]
}

/// Sweep execution knobs.
#[derive(Debug, Clone)]
pub struct DseOptions {
    /// Worker threads per tuning engine (0 = engine default).
    pub workers: usize,
    /// Configs evaluated concurrently per wave (config-level parallelism).
    pub config_parallelism: usize,
    /// Enable the roofline early-prune. Ignored (forced off) when
    /// `objectives` includes [`Objective::Energy`]: the roofline argument
    /// only bounds throughput, so pruning could drop an energy-optimal
    /// config.
    pub prune: bool,
    /// Safety slack on the roofline prune bound, as a fraction in
    /// `[0, 0.5]` (default [`DEFAULT_PRUNE_SLACK`]): a config is pruned
    /// only when even `(1 + prune_slack) × roofline` cannot reach the
    /// measured frontier. Was hard-coded at 5% before this knob existed.
    pub prune_slack: f64,
    /// Per-shape tuning policy for the sweep's engine
    /// ([`TunePolicy::Exhaustive`] by default): the tiered policy ranks
    /// each config's candidate schedules with the closed-form model and
    /// simulates only the analytic head + exploration band, which is what
    /// makes paper-scale meshes tractable in the inner loop.
    pub policy: TunePolicy,
    /// Cost-model weights.
    pub cost: CostModel,
    /// Energy coefficient table (every point gets energy metrics from it).
    pub energy: EnergyModel,
    /// Statically pre-prune configurations the deployment checker
    /// ([`crate::analysis::check_workload`]) proves undeployable — a
    /// shape with zero checker-accepted schedule candidates — before any
    /// simulation. Rejected configs are recorded under
    /// [`DseResult::infeasible`] with their first diagnostic and counted
    /// in [`DseResult::statically_rejected`]. Sound by the checker's
    /// lockstep contract: exactly these configs would have failed their
    /// tuning call anyway, so evaluated points and winners are
    /// bit-identical with the precheck off. On by default.
    pub static_precheck: bool,
    /// The axes the caller cares about; governs prune soundness (above)
    /// and is echoed into [`DseResult::objectives`] for reporting.
    pub objectives: Vec<Objective>,
    /// Persistent simulation cache path ([`crate::coordinator::cache`]).
    /// When set, the sweep's engine loads it on open and checkpoints
    /// after every evaluated config, so an interrupted sweep resumes for
    /// free and a refined sweep (finer axes around the frontier) reuses
    /// every overlapping point.
    pub cache_path: Option<std::path::PathBuf>,
}

impl Default for DseOptions {
    fn default() -> DseOptions {
        DseOptions {
            workers: 0,
            config_parallelism: 4,
            prune: true,
            prune_slack: DEFAULT_PRUNE_SLACK,
            static_precheck: true,
            cost: CostModel::default_proxy(),
            energy: EnergyModel::default_table(),
            objectives: vec![Objective::Perf, Objective::Cost],
            cache_path: None,
            policy: TunePolicy::Exhaustive,
        }
    }
}

impl DseOptions {
    /// Is the roofline prune sound for the requested objectives?
    fn prune_effective(&self) -> bool {
        self.prune && !self.objectives.contains(&Objective::Energy)
    }

    /// Reject nonsensical knob values before a long sweep runs. Called by
    /// [`run_sweep`]; exposed so the CLI can fail fast on bad flags.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.prune_slack.is_finite() && (0.0..=0.5).contains(&self.prune_slack),
            "prune slack must be a fraction in [0, 0.5], got {}",
            self.prune_slack
        );
        Ok(())
    }
}

/// One evaluated configuration.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub arch: ArchConfig,
    /// Cost-proxy units ([`CostModel`]).
    pub cost: f64,
    /// Achieved count-weighted aggregate TFLOP/s (best schedules).
    pub tflops: f64,
    /// Roofline upper bound for the same workload.
    pub roofline_tflops: f64,
    /// Energy of one workload pass under the sweep's [`EnergyModel`], J.
    pub energy_j: f64,
    /// Count-weighted useful throughput per Watt, TFLOP/s/W.
    pub tflops_per_w: f64,
    /// On the Pareto frontier of (cost, tflops)?
    pub on_frontier: bool,
    /// On the 3-axis Pareto frontier of (cost, tflops, energy)?
    pub on_frontier3: bool,
    /// Full per-shape tuning report for this config.
    pub report: WorkloadReport,
}

impl DsePoint {
    /// Achieved fraction of this instance's peak.
    pub fn utilization(&self) -> f64 {
        let peak = self.arch.peak_tflops();
        if peak <= 0.0 {
            0.0
        } else {
            self.tflops / peak
        }
    }

    /// Energy-delay product of one workload pass, J·s.
    pub fn edp_js(&self) -> f64 {
        self.energy_j * self.report.total_time_ns() * 1e-9
    }
}

/// A configuration skipped by the roofline prune.
#[derive(Debug, Clone)]
pub struct PrunedPoint {
    pub name: String,
    pub cost: f64,
    pub roofline_tflops: f64,
}

/// Outcome of one [`run_sweep`] call.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub spec_name: String,
    pub workload: String,
    /// The objective axes this sweep was run for (echo of the options).
    pub objectives: Vec<Objective>,
    /// Evaluated points, sorted by ascending cost (name-tie-broken).
    pub points: Vec<DsePoint>,
    /// Configs the roofline prune skipped.
    pub pruned: Vec<PrunedPoint>,
    /// Configs the tuner could not deploy at all (name, error).
    pub infeasible: Vec<(String, String)>,
    /// Configs the static checker rejected before simulating
    /// ([`DseOptions::static_precheck`]); each also appears in
    /// `infeasible` with its first diagnostic.
    pub statically_rejected: usize,
    /// Simulations actually executed across the sweep.
    pub sim_calls: usize,
    /// In-memory memo-cache hits across the sweep.
    pub cache_hits: usize,
    /// Persistent-cache hits across the sweep (0 without
    /// [`DseOptions::cache_path`]).
    pub disk_hits: usize,
    /// Entries the persistent cache held when the sweep opened it.
    pub disk_loaded: usize,
    /// Candidate simulations skipped by the tiered tuning policy across
    /// the sweep (0 under [`TunePolicy::Exhaustive`]).
    pub sims_saved: usize,
    /// Closed-form ranking estimates computed across the sweep (0 under
    /// [`TunePolicy::Exhaustive`]).
    pub analytic_rank_calls: usize,
    pub elapsed_ms: f64,
}

impl DseResult {
    /// Frontier points in ascending-cost order.
    pub fn frontier(&self) -> Vec<&DsePoint> {
        self.points.iter().filter(|p| p.on_frontier).collect()
    }

    /// 3-axis (cost, tflops, energy) frontier points in ascending-cost
    /// order. A superset of [`DseResult::frontier`] on tie-free data: an
    /// extra axis can only keep more trade-offs alive. Complete only when
    /// the sweep ran with [`Objective::Energy`] requested (otherwise the
    /// roofline prune may have skipped energy-optimal configs).
    pub fn frontier3(&self) -> Vec<&DsePoint> {
        self.points.iter().filter(|p| p.on_frontier3).collect()
    }

    /// Scalarized score per evaluated point (input order): weighted sum
    /// over min–max-normalized objectives, higher is better. `weights`
    /// pairs positionally with `objectives`.
    pub fn scalarized_scores(
        &self,
        objectives: &[Objective],
        weights: &[f64],
    ) -> Result<Vec<f64>> {
        Objective::validate_weights(objectives, weights)?;
        let senses: Vec<Sense> = objectives.iter().map(|o| o.sense()).collect();
        let pts: Vec<Vec<f64>> = self
            .points
            .iter()
            .map(|p| objectives.iter().map(|o| o.value(p)).collect())
            .collect();
        // A NaN objective would silently poison the min-max normalization
        // inside scalarize (every comparison involving it is false), so a
        // design whose stats go non-finite must fail loudly here. The
        // simulator guarantees finite RunStats (zero-HBM runs included),
        // making this unreachable unless that contract breaks.
        for (p, pt) in self.points.iter().zip(&pts) {
            for (o, v) in objectives.iter().zip(pt) {
                anyhow::ensure!(
                    !v.is_nan(),
                    "NaN {o:?} objective for {} — simulator stats must stay finite",
                    p.arch.name
                );
            }
        }
        Ok(pareto::scalarize(&pts, &senses, weights))
    }

    /// The single ranked winner of the weighted scalarization: the
    /// highest-scoring evaluated point (score ties broken by input order,
    /// i.e. ascending cost then name — deterministic).
    pub fn best_scalarized(
        &self,
        objectives: &[Objective],
        weights: &[f64],
    ) -> Result<Option<(&DsePoint, f64)>> {
        let scores = self.scalarized_scores(objectives, weights)?;
        let mut best: Option<(usize, f64)> = None;
        for (i, s) in scores.iter().enumerate() {
            if best.map(|(_, b)| *s > b).unwrap_or(true) {
                best = Some((i, *s));
            }
        }
        Ok(best.map(|(i, s)| (&self.points[i], s)))
    }

    /// The highest-throughput evaluated point.
    pub fn best(&self) -> Option<&DsePoint> {
        self.points
            .iter()
            .reduce(|a, b| if b.tflops > a.tflops { b } else { a })
    }

    /// The most energy-efficient evaluated point (highest TFLOP/s/W).
    pub fn most_efficient(&self) -> Option<&DsePoint> {
        self.points
            .iter()
            .reduce(|a, b| if b.tflops_per_w > a.tflops_per_w { b } else { a })
    }

    /// The frontier as a (cost, tflops) polyline.
    pub fn frontier_curve(&self) -> Vec<(f64, f64)> {
        self.frontier().iter().map(|p| (p.cost, p.tflops)).collect()
    }

    /// Frontier interpolation at an arbitrary cost (clamped outside the
    /// covered range) — the "is this point on or above the frontier?"
    /// reference line.
    pub fn interpolation_at(&self, cost: f64) -> f64 {
        pareto::interpolate(&self.frontier_curve(), cost)
    }

    /// The fastest evaluated point on a `rows × cols` mesh, if any.
    ///
    /// Filters on the exact geometry: a 16×4 point never answers for
    /// 4×16 or 8×8 (same tile count, different machine). The square-only
    /// predecessor of this method compared both dimensions against one
    /// `n`, silently returning `None` for every rectangular point;
    /// [`DseResult::best_at_square`] keeps the old call shape.
    pub fn best_at_mesh(&self, rows: usize, cols: usize) -> Option<&DsePoint> {
        self.points
            .iter()
            .filter(|p| p.arch.rows == rows && p.arch.cols == cols)
            .reduce(|a, b| if b.tflops > a.tflops { b } else { a })
    }

    /// Square convenience wrapper around [`DseResult::best_at_mesh`]:
    /// the fastest point on an `n × n` mesh — e.g. the Table 1-class
    /// 32×32 instance the reduced sweep includes.
    pub fn best_at_square(&self, n: usize) -> Option<&DsePoint> {
        self.best_at_mesh(n, n)
    }

    /// Does `p` sit on or above the frontier's interpolation at its cost?
    pub fn on_or_above_frontier(&self, p: &DsePoint) -> bool {
        p.tflops + 1e-9 >= self.interpolation_at(p.cost)
    }

    /// Machine-readable rendering (the `dse --json` artifact).
    pub fn to_json(&self) -> Json {
        let mut pts = Json::arr();
        for p in &self.points {
            pts = pts.push(
                Json::obj()
                    .field("config", p.arch.name.as_str())
                    .field("rows", p.arch.rows)
                    .field("cols", p.arch.cols)
                    .field("peak_tflops", p.arch.peak_tflops())
                    .field("hbm_gbps", p.arch.hbm.total_gbps())
                    .field("cost", p.cost)
                    .field("tflops", p.tflops)
                    .field("utilization", p.utilization())
                    .field("roofline_tflops", p.roofline_tflops)
                    .field("energy_j", p.energy_j)
                    .field("tflops_per_w", p.tflops_per_w)
                    .field("edp_js", p.edp_js())
                    .field("on_frontier", p.on_frontier)
                    .field("on_frontier3", p.on_frontier3),
            );
        }
        let mut pruned = Json::arr();
        for p in &self.pruned {
            pruned = pruned.push(
                Json::obj()
                    .field("config", p.name.as_str())
                    .field("cost", p.cost)
                    .field("roofline_tflops", p.roofline_tflops),
            );
        }
        let mut infeasible = Json::arr();
        for (name, err) in &self.infeasible {
            let entry = Json::obj().field("config", name.as_str()).field("error", err.as_str());
            infeasible = infeasible.push(entry);
        }
        let mut objectives = Json::arr();
        for o in &self.objectives {
            objectives = objectives.push(o.name());
        }
        Json::obj()
            .field("spec", self.spec_name.as_str())
            .field("workload", self.workload.as_str())
            .field("objectives", objectives)
            .field("evaluated", self.points.len())
            .field("frontier_size", self.frontier().len())
            .field("frontier3_size", self.frontier3().len())
            .field("statically_rejected", self.statically_rejected)
            .field("sim_calls", self.sim_calls)
            .field("cache_hits", self.cache_hits)
            .field("disk_hits", self.disk_hits)
            .field("disk_loaded", self.disk_loaded)
            .field("sims_saved", self.sims_saved)
            .field("analytic_rank_calls", self.analytic_rank_calls)
            .field("points", pts)
            .field("pruned", pruned)
            .field("infeasible", infeasible)
    }
}

/// Sweep the spec's design space over a workload: enumerate configs, prune
/// by roofline bound (perf/cost objectives only — see
/// [`DseOptions::prune`]), co-tune the survivors (sharing one
/// engine/cache), attach energy metrics to every point, and mark both the
/// 2-axis (cost, tflops) and 3-axis (cost, tflops, energy) Pareto
/// frontiers.
pub fn run_sweep(spec: &SweepSpec, w: &Workload, opts: &DseOptions) -> Result<DseResult> {
    anyhow::ensure!(!w.items.is_empty(), "DSE workload is empty");
    opts.validate()?;
    let prune = opts.prune_effective();
    let t0 = Instant::now();

    // Candidate list: (arch, cost, roofline bound), cost-ascending so the
    // prune sees cheap configs first and waves are deterministic.
    let mut cands: Vec<(ArchConfig, f64, f64)> = spec
        .enumerate()
        .into_iter()
        .map(|a| {
            let cost = opts.cost.cost(&a);
            let ub = workload_roofline_tflops(&a, w);
            (a, cost, ub)
        })
        .collect();
    anyhow::ensure!(
        !cands.is_empty(),
        "sweep spec '{}' enumerates no valid configuration",
        spec.name
    );
    cands.sort_by(|x, y| x.1.total_cmp(&y.1).then_with(|| x.0.name.cmp(&y.0.name)));

    // Static pre-prune: configs the checker proves undeployable skip the
    // tuning waves entirely. Sound with the roofline prune too — pruning
    // decisions only consult *measured* points, and a statically rejected
    // config could never have produced one (its tuning call would have
    // failed into `infeasible`).
    let mut statically_rejected = 0usize;
    let mut infeasible: Vec<(String, String)> = Vec::new();
    if opts.static_precheck {
        cands.retain(|(a, _, _)| {
            let rep = crate::analysis::check_workload(a, w);
            if !rep.rejected() {
                return true;
            }
            statically_rejected += 1;
            let first = rep
                .diags
                .iter()
                .find(|d| d.severity == crate::analysis::Severity::Error)
                .map(|d| d.to_string())
                .unwrap_or_else(|| "statically rejected".into());
            infeasible.push((a.name.clone(), first));
            false
        });
    }

    let mut engine = Engine::new(&spec.base).with_policy(opts.policy);
    if opts.workers > 0 {
        engine = engine.with_workers(opts.workers);
    }
    if let Some(path) = &opts.cache_path {
        engine = engine.with_cache(path);
    }
    let disk_loaded = engine.disk_loaded();
    let sim0 = engine.sim_calls();
    let hits0 = engine.cache_hits();
    let disk0 = engine.disk_hits();
    let saved0 = engine.sims_saved();
    let rank0 = engine.analytic_rank_calls();

    let mut points: Vec<DsePoint> = Vec::new();
    let mut pruned: Vec<PrunedPoint> = Vec::new();
    let wave = opts.config_parallelism.max(1);

    let mut idx = 0usize;
    while idx < cands.len() {
        // Assemble the next wave, pruning against completed points only —
        // a config whose (slack-inflated) ceiling cannot strictly beat an
        // already-measured cheaper-or-equal point can never join the
        // frontier.
        let mut batch: Vec<usize> = Vec::new();
        while idx < cands.len() && batch.len() < wave {
            let (a, cost, ub) = &cands[idx];
            let bound = ub * (1.0 + opts.prune_slack);
            let hopeless = prune
                && points.iter().any(|p| {
                    (p.tflops > bound && p.cost <= *cost) || (p.tflops >= bound && p.cost < *cost)
                });
            if hopeless {
                pruned.push(PrunedPoint {
                    name: a.name.clone(),
                    cost: *cost,
                    roofline_tflops: *ub,
                });
            } else {
                batch.push(idx);
            }
            idx += 1;
        }

        // Evaluate the wave concurrently; merge results in wave order so
        // thread completion order never reaches the output.
        let slots: Vec<Mutex<Option<Result<WorkloadReport>>>> =
            batch.iter().map(|_| Mutex::new(None)).collect();
        let eng = &engine;
        std::thread::scope(|scope| {
            for (slot, &ci) in slots.iter().zip(&batch) {
                let arch = &cands[ci].0;
                scope.spawn(move || {
                    let r = eng.tune_workload_on(arch, w);
                    *slot.lock().unwrap() = Some(r);
                });
            }
        });
        for (slot, &ci) in slots.iter().zip(&batch) {
            let (a, cost, ub) = &cands[ci];
            match slot.lock().unwrap().take().expect("wave evaluated every slot") {
                Ok(report) => {
                    let energy_j = opts.energy.workload_energy_j(&report);
                    let tflops_per_w = opts.energy.workload_tflops_per_w(&report);
                    points.push(DsePoint {
                        arch: a.clone(),
                        cost: *cost,
                        tflops: report.aggregate_tflops(),
                        roofline_tflops: *ub,
                        energy_j,
                        tflops_per_w,
                        on_frontier: false,
                        on_frontier3: false,
                        report,
                    })
                }
                Err(e) => infeasible.push((a.name.clone(), format!("{e:#}"))),
            }
        }
    }

    anyhow::ensure!(
        !points.is_empty(),
        "no sweep configuration could deploy workload '{}' (first error: {})",
        w.name,
        infeasible.first().map(|(n, e)| format!("{n}: {e}")).unwrap_or_default()
    );

    let curve: Vec<(f64, f64)> = points.iter().map(|p| (p.cost, p.tflops)).collect();
    for i in pareto::frontier_indices(&curve) {
        points[i].on_frontier = true;
    }
    let senses: Vec<Sense> = FRONTIER3.iter().map(|o| o.sense()).collect();
    let pts3: Vec<Vec<f64>> = points.iter().map(|p| vec![p.cost, p.tflops, p.energy_j]).collect();
    for i in pareto::frontier_indices_nd(&pts3, &senses) {
        points[i].on_frontier3 = true;
    }

    // Final checkpoint (the engine also flushed after every config); a
    // persistence failure degrades durability, not the sweep result.
    if let Err(e) = engine.flush_cache() {
        eprintln!("warning: simulation cache: {e:#}");
    }

    Ok(DseResult {
        spec_name: spec.name.clone(),
        workload: w.name.clone(),
        objectives: opts.objectives.clone(),
        points,
        pruned,
        infeasible,
        statically_rejected,
        sim_calls: engine.sim_calls() - sim0,
        cache_hits: engine.cache_hits() - hits0,
        disk_hits: engine.disk_hits() - disk0,
        disk_loaded,
        sims_saved: engine.sims_saved() - saved0,
        analytic_rank_calls: engine.analytic_rank_calls() - rank0,
        elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_orders_machines_sanely() {
        let c = CostModel::default_proxy();
        let small = ArchConfig::tiny(2, 2);
        let big = ArchConfig::tiny(4, 4);
        assert!(c.cost(&small) < c.cost(&big));
        assert!(c.cost(&ArchConfig::a100_like()) < c.cost(&ArchConfig::gh200_like()));
        assert!(c.cost(&small) > 0.0);
    }

    #[test]
    fn reduced_spec_contains_gh200_class_point() {
        let spec = SweepSpec::reduced();
        let configs = spec.enumerate();
        assert!(configs.len() >= 5, "{}", configs.len());
        let gh = ArchConfig::gh200_like();
        let class = configs.iter().find(|a| {
            a.rows == 32
                && a.cols == 32
                && a.tile == gh.tile
                && a.hbm == gh.hbm
                && a.noc == gh.noc
                && a.elem_bytes == gh.elem_bytes
        });
        assert!(class.is_some(), "reduced sweep must include the Table 1 instance");
        for a in &configs {
            a.validate().unwrap();
        }
    }

    #[test]
    fn spec_text_roundtrip_and_defaults() {
        let text = "\
[sweep]\nname = \"mine\"\nmesh = [2, 4]\nce_m = [16]\nce_n = [8]\nspm_kib = 128\n\
[tile]\nclock_ghz = 1.0\n";
        let spec = SweepSpec::from_text(text).unwrap();
        assert_eq!(spec.name, "mine");
        assert_eq!(spec.meshes, vec![(2, 2), (4, 4)], "square sugar expands the diagonal");
        assert_eq!(spec.ce, vec![(16, 8)]);
        assert_eq!(spec.spm_kib, vec![128], "scalar promotes to one-element list");
        // Unset axes fall back to the reduced defaults.
        assert_eq!(spec.hbm_channels_pct, SweepSpec::reduced().hbm_channels_pct);
        assert_eq!(spec.base.tile.clock_ghz, 1.0, "base arch read from same doc");
        assert_eq!(spec.enumerate().len(), 2);
    }

    #[test]
    fn spec_text_accepts_fractional_bandwidth() {
        // Presets use fractional GB/s (A100-like: 48.6); a float scalar
        // must parse even though the list grammar is int-only.
        let spec = SweepSpec::from_text("[sweep]\nhbm_channel_gbps = 48.6\n").unwrap();
        assert_eq!(spec.hbm_channel_gbps, vec![48.6]);
        let spec = SweepSpec::from_text("[sweep]\nhbm_channel_gbps = [48, 64]\n").unwrap();
        assert_eq!(spec.hbm_channel_gbps, vec![48.0, 64.0]);
        assert!(SweepSpec::from_text("[sweep]\nhbm_channel_gbps = -3\n").is_err());
    }

    #[test]
    fn spec_text_rejects_nonsense() {
        assert!(SweepSpec::from_text("[sweep]\nmesh = [0]\n").is_err(), "zero mesh");
        assert!(SweepSpec::from_text("[sweep]\nmesh = \"big\"\n").is_err(), "wrong type");
        assert!(
            SweepSpec::from_text("[sweep]\nce_m = [16, 32]\nce_n = [8]\n").is_err(),
            "ragged ce lists"
        );
        assert!(SweepSpec::from_text("[grid\n").is_err(), "cfgtext error propagates");
        assert!(
            SweepSpec::from_text("elem_bytes = 99\n").is_err(),
            "invalid base architecture rejected via ArchConfig::validate"
        );
    }

    #[test]
    fn spec_text_rectangular_mesh_axes() {
        let p = SweepSpec::from_text;
        let spec = p("[sweep]\nmesh_rows = [8, 16]\nmesh_cols = [4, 8]\naspect = 2.0\n").unwrap();
        assert_eq!(spec.meshes, vec![(8, 4), (8, 8), (16, 8)], "16x4 filtered by aspect 2");
        // Square sugar and the cross product compose, sugar first.
        let spec = p("[sweep]\nmesh = [32]\nmesh_rows = [4]\nmesh_cols = [16]\n").unwrap();
        assert_eq!(spec.meshes, vec![(32, 32), (4, 16)]);
        // An integer aspect parses too.
        let spec = p("[sweep]\nmesh_rows = [4, 16]\nmesh_cols = [4, 16]\naspect = 1\n").unwrap();
        assert_eq!(spec.meshes, vec![(4, 4), (16, 16)], "aspect 1 keeps the diagonal");
        // One-sided axes, sub-1 aspect, aspect without the axes it
        // filters, and a filter that empties the axis are all rejected.
        assert!(p("[sweep]\nmesh_rows = [8]\n").is_err());
        assert!(p("[sweep]\nmesh_cols = [8]\n").is_err());
        assert!(p("[sweep]\nmesh_rows = [8]\nmesh_cols = [8]\naspect = 0.5\n").is_err());
        assert!(p("[sweep]\naspect = 2.0\n").is_err());
        assert!(p("[sweep]\nmesh_rows = [16]\nmesh_cols = [2]\naspect = 2.0\n").is_err());
    }

    #[test]
    fn mesh_grid_cross_product_and_aspect_filter() {
        assert_eq!(SweepSpec::square_meshes(&[2, 4]), vec![(2, 2), (4, 4)]);
        assert_eq!(
            SweepSpec::mesh_grid(&[8, 16], &[4, 8], None),
            vec![(8, 4), (8, 8), (16, 4), (16, 8)]
        );
        assert_eq!(
            SweepSpec::mesh_grid(&[8, 16], &[4, 8], Some(2.0)),
            vec![(8, 4), (8, 8), (16, 8)]
        );
        assert_eq!(SweepSpec::mesh_grid(&[8, 16], &[4, 8], Some(1.0)), vec![(8, 8)]);
    }

    #[test]
    fn hbm_channel_derivation_rounds_to_nearest() {
        // Truncation vs rounding disagree above the half mark: 3 x 50%
        // is 1.5 channels (was 1, now 2), 8 x 70% is 5.6 (was 5, now 6).
        assert_eq!(SweepSpec::hbm_channels_per_edge(3, 3, 50), 2);
        assert_eq!(SweepSpec::hbm_channels_per_edge(8, 8, 70), 6);
        // Below the half mark they agree: 8 x 30% = 2.4 -> 2.
        assert_eq!(SweepSpec::hbm_channels_per_edge(8, 8, 30), 2);
        // Exact multiples are untouched (built-in specs use 50/100 on
        // even meshes, so square sweeps reproduce pre-fix results).
        assert_eq!(SweepSpec::hbm_channels_per_edge(8, 8, 50), 4);
        assert_eq!(SweepSpec::hbm_channels_per_edge(32, 32, 100), 32);
        // Never zero, however small the percentage.
        assert_eq!(SweepSpec::hbm_channels_per_edge(4, 4, 1), 1);
        assert_eq!(SweepSpec::hbm_channels_per_edge(1, 1, 100), 1);
        // Rectangular grids derive from the shorter edge — both edges
        // get the same count, so neither oversubscribes at pct <= 100 —
        // and the rule is orientation-symmetric.
        assert_eq!(SweepSpec::hbm_channels_per_edge(16, 4, 100), 4);
        assert_eq!(SweepSpec::hbm_channels_per_edge(4, 16, 100), 4);
        assert_eq!(SweepSpec::hbm_channels_per_edge(16, 4, 50), 2);
    }

    #[test]
    fn rectangular_points_enumerate_with_geometry_names() {
        let spec = SweepSpec { meshes: vec![(16, 4), (4, 16)], ..SweepSpec::reduced() };
        let configs = spec.enumerate();
        assert_eq!(configs.len(), 4, "two geometries x two SPM capacities");
        for a in &configs {
            a.validate().unwrap();
            assert_eq!(a.hbm.channels_per_edge, 4, "pct 100 of the shorter edge");
            assert!(a.name.contains("-hbm8x64-"), "{}", a.name);
        }
        assert!(configs[0].name.starts_with("dse-16x4-"), "{}", configs[0].name);
        assert!(configs[2].name.starts_with("dse-4x16-"), "{}", configs[2].name);
        // Same tile count, different machines: the names must differ.
        assert_ne!(configs[0].name, configs[2].name);
        assert_eq!(configs[0].num_tiles(), configs[2].num_tiles());
    }

    #[test]
    fn objective_lists_parse() {
        assert_eq!(
            Objective::parse_list("perf,cost,energy").unwrap(),
            vec![Objective::Perf, Objective::Cost, Objective::Energy]
        );
        assert_eq!(
            Objective::parse_list(" perf , energy ").unwrap(),
            vec![Objective::Perf, Objective::Energy]
        );
        assert!(Objective::parse_list("perf,watts").is_err(), "unknown axis");
        assert!(Objective::parse_list("perf,perf").is_err(), "duplicate axis");
        assert!(Objective::parse_list("").is_err(), "empty list");
    }

    #[test]
    fn energy_objective_forces_exhaustive_sweep() {
        let mut o = DseOptions::default();
        assert!(o.prune_effective(), "default perf/cost sweep prunes");
        o.objectives = vec![Objective::Perf, Objective::Cost, Objective::Energy];
        assert!(!o.prune_effective(), "energy axis disables the roofline prune");
        o.objectives = vec![Objective::Perf];
        assert!(o.prune_effective(), "perf-only keeps the prune");
    }

    #[test]
    fn static_precheck_is_sound_and_counts() {
        // One config the checker proves undeployable (4 KiB SPM cannot
        // hold any candidate's accumulator panel) next to one that tunes
        // fine: the precheck must reject exactly the former, and the
        // evaluated points / winner must be bit-identical with it off.
        let spec = SweepSpec {
            name: "precheck".into(),
            meshes: vec![(2, 2)],
            ce: vec![(16, 8)],
            spm_kib: vec![4, 256],
            hbm_channel_gbps: vec![64.0],
            hbm_channels_pct: vec![100],
            dma_engines: vec![2],
            base: ArchConfig::tiny(2, 2),
        };
        let w = Workload::single("s", crate::arch::GemmShape::new(256, 256, 512));
        let on = DseOptions { prune: false, ..DseOptions::default() };
        let off = DseOptions { static_precheck: false, ..on.clone() };
        let a = run_sweep(&spec, &w, &on).unwrap();
        let b = run_sweep(&spec, &w, &off).unwrap();
        assert_eq!(a.statically_rejected, 1, "{:?}", a.infeasible);
        assert_eq!(b.statically_rejected, 0);
        assert!(a.infeasible[0].1.contains("DIT-E081"), "{}", a.infeasible[0].1);
        assert_eq!(a.infeasible.len(), b.infeasible.len(), "{:?} vs {:?}", a.infeasible, b.infeasible);
        assert_eq!(a.points.len(), b.points.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.arch.name, y.arch.name);
            assert_eq!(x.tflops.to_bits(), y.tflops.to_bits());
            assert_eq!(x.on_frontier, y.on_frontier);
        }
        assert_eq!(a.best().unwrap().arch.name, b.best().unwrap().arch.name);
        let j = a.to_json().render();
        assert!(j.contains("\"statically_rejected\":1"), "{j}");
    }

    #[test]
    fn suites_resolve_and_mix_regimes() {
        for name in suite_names() {
            let w = suite(name).unwrap();
            assert!(!w.items.is_empty(), "{name}");
            assert_eq!(w.name, format!("dse-{name}"));
        }
        assert!(suite("nope").is_none());
        let serving = suite("serving").unwrap();
        assert!(serving.items.iter().any(|i| i.shape.is_flat()), "decode side present");
        assert!(serving.items.iter().any(|i| !i.shape.is_flat()), "prefill side present");
    }
}

//! Pareto-dominance calculus for (cost, value) points.
//!
//! The DSE sweep reports configurations on the frontier of *achieved
//! TFLOP/s vs. hardware cost*: a config earns its place only if no other
//! config is at least as fast for strictly less cost (or strictly faster
//! for the same cost). Everything here is deterministic — ties between
//! bit-identical points are broken by input order, so two sweeps over the
//! same spec mark exactly the same frontier.

/// `a` dominates `b` in (cost, value) space: no worse on both axes
/// (cost minimized, value maximized) and strictly better on at least one.
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 <= b.0 && a.1 >= b.1 && (a.0 < b.0 || a.1 > b.1)
}

/// Indices of the Pareto-optimal points of `pts`, in input order.
///
/// Exact duplicates keep only their first occurrence (a copy of a frontier
/// point adds no information); NaN on either axis disqualifies a point.
pub fn frontier_indices(pts: &[(f64, f64)]) -> Vec<usize> {
    (0..pts.len())
        .filter(|&i| {
            let p = pts[i];
            if p.0.is_nan() || p.1.is_nan() {
                return false;
            }
            !pts.iter().enumerate().any(|(j, &q)| {
                j != i && (dominates(q, p) || (q == p && j < i))
            })
        })
        .collect()
}

/// Piecewise-linear interpolation of a frontier at `cost`.
///
/// `frontier` must be sorted by ascending cost (what
/// [`crate::dse::DseResult::frontier`] returns). Outside the covered cost
/// range the curve is clamped to the nearest endpoint's value; an empty
/// frontier interpolates to 0.
pub fn interpolate(frontier: &[(f64, f64)], cost: f64) -> f64 {
    match frontier {
        [] => 0.0,
        [only] => only.1,
        _ => {
            if cost <= frontier[0].0 {
                return frontier[0].1;
            }
            let last = frontier[frontier.len() - 1];
            if cost >= last.0 {
                return last.1;
            }
            for w in frontier.windows(2) {
                let (x0, y0) = w[0];
                let (x1, y1) = w[1];
                if cost >= x0 && cost <= x1 {
                    if x1 <= x0 {
                        return y0.max(y1);
                    }
                    let t = (cost - x0) / (x1 - x0);
                    return y0 + t * (y1 - y0);
                }
            }
            last.1 // unreachable for sorted input, but stay total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict_somewhere() {
        assert!(dominates((1.0, 10.0), (2.0, 9.0)));
        assert!(dominates((1.0, 10.0), (1.0, 9.0)));
        assert!(dominates((1.0, 10.0), (2.0, 10.0)));
        assert!(!dominates((1.0, 10.0), (1.0, 10.0)), "ties dominate nothing");
        assert!(!dominates((2.0, 11.0), (1.0, 10.0)), "trade-offs don't dominate");
        assert!(!dominates((1.0, 9.0), (2.0, 10.0)));
    }

    #[test]
    fn frontier_filters_dominated_points() {
        //  (cost, value): b dominated by a; d dominated by c; trade-offs stay.
        let pts = [
            (1.0, 10.0), // a: frontier
            (1.5, 9.0),  // b: dominated by a
            (2.0, 20.0), // c: frontier
            (2.0, 15.0), // d: dominated by c
            (3.0, 25.0), // e: frontier
        ];
        assert_eq!(frontier_indices(&pts), vec![0, 2, 4]);
    }

    #[test]
    fn frontier_no_mutual_domination_invariant() {
        let pts = [
            (5.0, 5.0),
            (1.0, 1.0),
            (3.0, 3.0),
            (3.0, 3.0), // exact duplicate: only the first survives
            (2.0, 0.5),
            (4.0, 4.5),
        ];
        let f = frontier_indices(&pts);
        assert_eq!(f, vec![0, 1, 2, 5]);
        for &i in &f {
            for &j in &f {
                if i != j {
                    assert!(!dominates(pts[i], pts[j]), "{i} dominates {j}");
                }
            }
        }
        // Every non-frontier point is dominated by (or duplicates) one on it.
        for i in 0..pts.len() {
            if !f.contains(&i) {
                assert!(
                    f.iter().any(|&j| dominates(pts[j], pts[i]) || pts[j] == pts[i]),
                    "point {i} excluded but not dominated"
                );
            }
        }
    }

    #[test]
    fn nan_points_are_excluded() {
        let pts = [(1.0, f64::NAN), (2.0, 5.0)];
        assert_eq!(frontier_indices(&pts), vec![1]);
    }

    #[test]
    fn interpolation_clamps_and_lerps() {
        let f = [(1.0, 10.0), (3.0, 30.0), (5.0, 40.0)];
        assert_eq!(interpolate(&f, 0.5), 10.0, "below range clamps left");
        assert_eq!(interpolate(&f, 9.0), 40.0, "above range clamps right");
        assert!((interpolate(&f, 2.0) - 20.0).abs() < 1e-12);
        assert!((interpolate(&f, 4.0) - 35.0).abs() < 1e-12);
        assert_eq!(interpolate(&[], 2.0), 0.0);
        assert_eq!(interpolate(&[(2.0, 7.0)], 99.0), 7.0);
    }
}

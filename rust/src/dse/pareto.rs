//! Pareto-dominance calculus, 2-D fast path and K-dimensional general
//! case.
//!
//! The DSE sweep reports configurations on the frontier of *achieved
//! TFLOP/s vs. hardware cost*: a config earns its place only if no other
//! config is at least as fast for strictly less cost (or strictly faster
//! for the same cost). Everything here is deterministic — ties between
//! bit-identical points are broken by input order, so two sweeps over the
//! same spec mark exactly the same frontier.
//!
//! The 2-D `(cost minimized, value maximized)` functions ([`dominates`],
//! [`frontier_indices`]) are the original fast path and stay as-is; the
//! `_nd` generalizations take an explicit per-axis [`Sense`] so the same
//! calculus covers the 3-axis perf/cost/energy frontier (and any K).
//! [`scalarize`] collapses K objectives into one ranking via a weighted
//! sum over min–max-normalized axes, for "give me a single winner" mode.

/// `a` dominates `b` in (cost, value) space: no worse on both axes
/// (cost minimized, value maximized) and strictly better on at least one.
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 <= b.0 && a.1 >= b.1 && (a.0 < b.0 || a.1 > b.1)
}

/// Indices of the Pareto-optimal points of `pts`, in input order.
///
/// Exact duplicates keep only their first occurrence (a copy of a frontier
/// point adds no information); NaN on either axis disqualifies a point.
pub fn frontier_indices(pts: &[(f64, f64)]) -> Vec<usize> {
    (0..pts.len())
        .filter(|&i| {
            let p = pts[i];
            if p.0.is_nan() || p.1.is_nan() {
                return false;
            }
            !pts.iter().enumerate().any(|(j, &q)| {
                j != i && (dominates(q, p) || (q == p && j < i))
            })
        })
        .collect()
}

/// Per-axis optimization sense for the K-dimensional calculus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    /// Smaller is better (cost, energy, makespan).
    Min,
    /// Larger is better (throughput, utilization).
    Max,
}

impl Sense {
    /// `a` is no worse than `b` on this axis.
    pub fn no_worse(self, a: f64, b: f64) -> bool {
        match self {
            Sense::Min => a <= b,
            Sense::Max => a >= b,
        }
    }

    /// `a` is strictly better than `b` on this axis.
    pub fn better(self, a: f64, b: f64) -> bool {
        match self {
            Sense::Min => a < b,
            Sense::Max => a > b,
        }
    }
}

/// `a` dominates `b` under `senses`: no worse on every axis and strictly
/// better on at least one. With `senses == [Min, Max]` this is exactly
/// [`dominates`]. Panics on length mismatch (a caller bug).
pub fn dominates_nd(a: &[f64], b: &[f64], senses: &[Sense]) -> bool {
    assert_eq!(a.len(), senses.len(), "point/sense arity mismatch");
    assert_eq!(b.len(), senses.len(), "point/sense arity mismatch");
    let mut strict = false;
    for ((x, y), s) in a.iter().zip(b).zip(senses) {
        if !s.no_worse(*x, *y) {
            return false;
        }
        if s.better(*x, *y) {
            strict = true;
        }
    }
    strict
}

/// Indices of the Pareto-optimal points of `pts` under `senses`, in input
/// order — the K-dimensional [`frontier_indices`]. Same tie rules: exact
/// duplicates keep only their first occurrence, NaN on any axis
/// disqualifies a point (and a NaN-bearing point dominates nothing, since
/// every comparison against NaN is false).
pub fn frontier_indices_nd(pts: &[Vec<f64>], senses: &[Sense]) -> Vec<usize> {
    for p in pts {
        assert_eq!(p.len(), senses.len(), "point/sense arity mismatch");
    }
    (0..pts.len())
        .filter(|&i| {
            let p = &pts[i];
            if p.iter().any(|v| v.is_nan()) {
                return false;
            }
            !pts.iter().enumerate().any(|(j, q)| {
                j != i && (dominates_nd(q, p, senses) || (q == p && j < i))
            })
        })
        .collect()
}

/// Weighted-sum scalarization: per point, `Σ wᵢ · normᵢ` where each axis
/// is min–max normalized so that 1 is the best observed value and 0 the
/// worst (direction folded in via `senses`). Weights are normalized to
/// sum to 1, so scores land in `[0, 1]`. Normalization is relative to the
/// observed range, so scores only rank points *within* one point set —
/// never compare them across sweeps.
/// A degenerate axis (all points equal) contributes a neutral 0.5; a
/// point with NaN on any axis scores `-inf` so it can never win. Panics
/// on arity mismatches; callers validate weights (non-negative, positive
/// sum) before calling.
pub fn scalarize(pts: &[Vec<f64>], senses: &[Sense], weights: &[f64]) -> Vec<f64> {
    assert_eq!(weights.len(), senses.len(), "weight/sense arity mismatch");
    for p in pts {
        assert_eq!(p.len(), senses.len(), "point/sense arity mismatch");
    }
    let wsum: f64 = weights.iter().sum();
    assert!(
        wsum > 0.0 && weights.iter().all(|w| w.is_finite() && *w >= 0.0),
        "weights must be non-negative with a positive sum"
    );
    // Per-axis observed range over NaN-free values.
    let mut lo = vec![f64::INFINITY; senses.len()];
    let mut hi = vec![f64::NEG_INFINITY; senses.len()];
    for p in pts {
        if p.iter().any(|v| v.is_nan()) {
            continue;
        }
        for (k, v) in p.iter().enumerate() {
            lo[k] = lo[k].min(*v);
            hi[k] = hi[k].max(*v);
        }
    }
    pts.iter()
        .map(|p| {
            if p.iter().any(|v| v.is_nan()) {
                return f64::NEG_INFINITY;
            }
            let mut score = 0.0;
            for (k, v) in p.iter().enumerate() {
                let norm = if hi[k] <= lo[k] {
                    0.5
                } else {
                    let t = (v - lo[k]) / (hi[k] - lo[k]);
                    match senses[k] {
                        Sense::Max => t,
                        Sense::Min => 1.0 - t,
                    }
                };
                score += weights[k] / wsum * norm;
            }
            score
        })
        .collect()
}

/// Piecewise-linear interpolation of a frontier at `cost`.
///
/// `frontier` must be sorted by ascending cost (what
/// [`crate::dse::DseResult::frontier`] returns). Outside the covered cost
/// range the curve is clamped to the nearest endpoint's value; an empty
/// frontier interpolates to 0.
pub fn interpolate(frontier: &[(f64, f64)], cost: f64) -> f64 {
    match frontier {
        [] => 0.0,
        [only] => only.1,
        _ => {
            if cost <= frontier[0].0 {
                return frontier[0].1;
            }
            let last = frontier[frontier.len() - 1];
            if cost >= last.0 {
                return last.1;
            }
            for w in frontier.windows(2) {
                let (x0, y0) = w[0];
                let (x1, y1) = w[1];
                if cost >= x0 && cost <= x1 {
                    if x1 <= x0 {
                        return y0.max(y1);
                    }
                    let t = (cost - x0) / (x1 - x0);
                    return y0 + t * (y1 - y0);
                }
            }
            last.1 // unreachable for sorted input, but stay total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_is_strict_somewhere() {
        assert!(dominates((1.0, 10.0), (2.0, 9.0)));
        assert!(dominates((1.0, 10.0), (1.0, 9.0)));
        assert!(dominates((1.0, 10.0), (2.0, 10.0)));
        assert!(!dominates((1.0, 10.0), (1.0, 10.0)), "ties dominate nothing");
        assert!(!dominates((2.0, 11.0), (1.0, 10.0)), "trade-offs don't dominate");
        assert!(!dominates((1.0, 9.0), (2.0, 10.0)));
    }

    #[test]
    fn frontier_filters_dominated_points() {
        //  (cost, value): b dominated by a; d dominated by c; trade-offs stay.
        let pts = [
            (1.0, 10.0), // a: frontier
            (1.5, 9.0),  // b: dominated by a
            (2.0, 20.0), // c: frontier
            (2.0, 15.0), // d: dominated by c
            (3.0, 25.0), // e: frontier
        ];
        assert_eq!(frontier_indices(&pts), vec![0, 2, 4]);
    }

    #[test]
    fn frontier_no_mutual_domination_invariant() {
        let pts = [
            (5.0, 5.0),
            (1.0, 1.0),
            (3.0, 3.0),
            (3.0, 3.0), // exact duplicate: only the first survives
            (2.0, 0.5),
            (4.0, 4.5),
        ];
        let f = frontier_indices(&pts);
        assert_eq!(f, vec![0, 1, 2, 5]);
        for &i in &f {
            for &j in &f {
                if i != j {
                    assert!(!dominates(pts[i], pts[j]), "{i} dominates {j}");
                }
            }
        }
        // Every non-frontier point is dominated by (or duplicates) one on it.
        for i in 0..pts.len() {
            if !f.contains(&i) {
                assert!(
                    f.iter().any(|&j| dominates(pts[j], pts[i]) || pts[j] == pts[i]),
                    "point {i} excluded but not dominated"
                );
            }
        }
    }

    #[test]
    fn nan_points_are_excluded() {
        let pts = [(1.0, f64::NAN), (2.0, 5.0)];
        assert_eq!(frontier_indices(&pts), vec![1]);
    }

    const MCME: [Sense; 3] = [Sense::Min, Sense::Max, Sense::Min]; // cost, perf, energy

    #[test]
    fn nd_dominance_matches_2d_on_min_max_axes() {
        let senses = [Sense::Min, Sense::Max];
        let cases = [
            ((1.0, 10.0), (2.0, 9.0)),
            ((1.0, 10.0), (1.0, 10.0)),
            ((2.0, 11.0), (1.0, 10.0)),
            ((1.0, 9.0), (2.0, 10.0)),
            ((1.0, 10.0), (1.0, 9.0)),
        ];
        for (a, b) in cases {
            assert_eq!(
                dominates_nd(&[a.0, a.1], &[b.0, b.1], &senses),
                dominates(a, b),
                "{a:?} vs {b:?}"
            );
        }
    }

    #[test]
    fn nd_dominance_three_axes() {
        // Better cost + energy, equal perf: dominates.
        assert!(dominates_nd(&[1.0, 5.0, 2.0], &[2.0, 5.0, 3.0], &MCME));
        // Trade-off on the third axis breaks domination.
        assert!(!dominates_nd(&[1.0, 5.0, 4.0], &[2.0, 5.0, 3.0], &MCME));
        assert!(!dominates_nd(&[1.0, 5.0, 2.0], &[1.0, 5.0, 2.0], &MCME), "ties");
    }

    #[test]
    fn nd_frontier_matches_2d_frontier() {
        let pts2 = [
            (1.0, 10.0),
            (1.5, 9.0),
            (2.0, 20.0),
            (2.0, 20.0), // duplicate: first occurrence only
            (2.0, 15.0),
            (3.0, f64::NAN),
            (3.0, 25.0),
        ];
        let ptsv: Vec<Vec<f64>> = pts2.iter().map(|p| vec![p.0, p.1]).collect();
        assert_eq!(frontier_indices_nd(&ptsv, &[Sense::Min, Sense::Max]), frontier_indices(&pts2));
    }

    #[test]
    fn nd_frontier_keeps_third_axis_tradeoffs() {
        // b is 2D-dominated by a on (cost, perf) but has lower energy, so
        // the 3-axis frontier keeps it; c is worse everywhere and drops.
        let pts = vec![
            vec![1.0, 10.0, 5.0], // a
            vec![2.0, 9.0, 1.0],  // b
            vec![3.0, 8.0, 6.0],  // c
        ];
        assert_eq!(frontier_indices_nd(&pts, &MCME), vec![0, 1]);
        // No mutual domination among frontier members.
        for &i in &[0usize, 1] {
            for &j in &[0usize, 1] {
                if i != j {
                    assert!(!dominates_nd(&pts[i], &pts[j], &MCME));
                }
            }
        }
    }

    #[test]
    fn scalarization_ranks_extremes() {
        let pts = vec![
            vec![1.0, 10.0, 5.0], // cheapest
            vec![5.0, 50.0, 9.0], // fastest
            vec![3.0, 20.0, 1.0], // most efficient
        ];
        // All weight on one axis selects that axis's best point.
        let perf_only = scalarize(&pts, &MCME, &[0.0, 1.0, 0.0]);
        assert!(perf_only[1] > perf_only[0] && perf_only[1] > perf_only[2]);
        assert_eq!(perf_only[1], 1.0, "best axis value normalizes to 1");
        let energy_only = scalarize(&pts, &MCME, &[0.0, 0.0, 1.0]);
        assert!(energy_only[2] > energy_only[0] && energy_only[2] > energy_only[1]);
        // Weight normalization: scaling all weights changes nothing.
        let a = scalarize(&pts, &MCME, &[1.0, 1.0, 1.0]);
        let b = scalarize(&pts, &MCME, &[2.0, 2.0, 2.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
        // Scores live in [0, 1].
        assert!(a.iter().all(|s| (0.0..=1.0).contains(s)), "{a:?}");
    }

    #[test]
    fn scalarization_handles_degenerate_axes_and_nan() {
        // Constant axis contributes 0.5 to everyone; NaN point never wins.
        let pts = vec![vec![2.0, 7.0], vec![2.0, 9.0], vec![2.0, f64::NAN]];
        let s = scalarize(&pts, &[Sense::Min, Sense::Max], &[0.5, 0.5]);
        assert!((s[0] - 0.25).abs() < 1e-12, "{s:?}"); // 0.5·0.5 + 0.5·0.0
        assert!((s[1] - 0.75).abs() < 1e-12, "{s:?}"); // 0.5·0.5 + 0.5·1.0
        assert_eq!(s[2], f64::NEG_INFINITY);
    }

    #[test]
    fn interpolation_clamps_and_lerps() {
        let f = [(1.0, 10.0), (3.0, 30.0), (5.0, 40.0)];
        assert_eq!(interpolate(&f, 0.5), 10.0, "below range clamps left");
        assert_eq!(interpolate(&f, 9.0), 40.0, "above range clamps right");
        assert!((interpolate(&f, 2.0) - 20.0).abs() < 1e-12);
        assert!((interpolate(&f, 4.0) - 35.0).abs() < 1e-12);
        assert_eq!(interpolate(&[], 2.0), 0.0);
        assert_eq!(interpolate(&[(2.0, 7.0)], 99.0), 7.0);
    }
}

//! The deployment-schedule abstraction (paper §3).
//!
//! A [`Schedule`] is the parameterizable, high-level description DiT lowers
//! to per-PE IR: how the GEMM is tiled and mapped onto (logical) compute
//! tiles (§3.1), whether the HBM layout is optimized (§3.2), and which
//! dataflow pattern moves the operands (§3.3). [`candidates`] enumerates
//! the schedule space the autotuner searches, pruned by the paper's
//! insights (L1 feasibility, collective-friendliness, 3D tiling for
//! irregular shapes, cluster remapping for flat GEMM).

pub mod remap;

use crate::arch::{ArchConfig, GemmShape};
use crate::util::{ceil_div, is_pow2};
use remap::Remap;

/// Dataflow pattern primitives (paper §3.3.2, Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// No on-chip sharing: every tile DMAs its own operands from HBM.
    Baseline,
    /// SUMMA: per-K-panel row broadcast of A, column broadcast of B.
    Summa,
    /// Systolic wavefront: A propagates east, B propagates south.
    Systolic,
    /// Hierarchical (Fig. 6c): outer systolic over `group × group` tile
    /// groups, inner SUMMA within each group.
    SystolicOverSumma { group: usize },
    /// Hierarchical (Fig. 6d): outer SUMMA across groups (strided
    /// multicast), inner Cannon-style systolic rotation within each group.
    SummaOverSystolic { group: usize },
    /// 3D tiling (Fig. 6e): `splits` disjoint K-slices, each running SUMMA
    /// on its own logical sub-grid, followed by a NoC reduction.
    SplitKSumma { splits: usize },
}

impl Dataflow {
    pub fn name(&self) -> String {
        match self {
            Dataflow::Baseline => "baseline".into(),
            Dataflow::Summa => "summa".into(),
            Dataflow::Systolic => "systolic".into(),
            Dataflow::SystolicOverSumma { group } => format!("systolic-over-summa/g{group}"),
            Dataflow::SummaOverSystolic { group } => format!("summa-over-systolic/g{group}"),
            Dataflow::SplitKSumma { splits } => format!("splitk-summa/s{splits}"),
        }
    }

    /// Does this pattern use NoC collectives? (Insight 2: prefer these.)
    pub fn uses_collectives(&self) -> bool {
        !matches!(self, Dataflow::Baseline | Dataflow::Systolic)
    }
}

/// Who reduces and commits split-K partial results (§3.1.1: "configurable
/// policies to determine which compute tiles are responsible for
/// performing the final reduction").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReducePolicy {
    /// K-group 0's tile always reduces + stores.
    FirstGroup,
    /// Rotate the root across K-groups by output index, spreading HBM
    /// store traffic over more NoC paths and channels.
    RoundRobin,
}

/// A complete deployment schedule: the tuple DiT's "Generate and Optimize"
/// stage consumes. `Eq + Hash` (all fields are discrete) so schedules can
/// key the engine's simulation memo-cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub dataflow: Dataflow,
    /// Logical grid `(P, Q)` the *compute* mapping uses. For split-K this
    /// is the per-K-group grid; `P·Q·splits` must equal the tile count.
    pub logical: (usize, usize),
    /// K-panel depth per superstep (elements).
    pub tk: usize,
    /// Pipeline staging (§4.1.3 / Fig. 8): the grid's logical rows are
    /// divided into this many stage groups whose execution is offset by
    /// one superstep each. 1 = everyone starts together.
    pub pipeline_stages: usize,
    /// Double buffering / communication-computation overlap (§3.3.1).
    pub double_buffer: bool,
    /// Optimized HBM data layout (§3.2) vs the row-major base layout.
    pub opt_layout: bool,
    pub reduce_policy: ReducePolicy,
}

impl Schedule {
    /// Default SUMMA schedule on the physical grid.
    pub fn summa(arch: &ArchConfig, shape: GemmShape) -> Schedule {
        let s = Schedule {
            dataflow: Dataflow::Summa,
            logical: (arch.rows, arch.cols),
            tk: 0,
            pipeline_stages: 1,
            double_buffer: true,
            opt_layout: true,
            reduce_policy: ReducePolicy::RoundRobin,
        };
        Schedule { tk: default_tk(arch, shape, &s), ..s }
    }

    /// The paper's reference baseline (no collectives, base layout).
    pub fn baseline(arch: &ArchConfig, shape: GemmShape) -> Schedule {
        let s = Schedule {
            dataflow: Dataflow::Baseline,
            logical: (arch.rows, arch.cols),
            tk: 0,
            pipeline_stages: 1,
            double_buffer: true,
            opt_layout: false,
            reduce_policy: ReducePolicy::RoundRobin,
        };
        Schedule { tk: default_tk(arch, shape, &s), ..s }
    }

    /// Systolic wavefront schedule on the physical grid.
    pub fn systolic(arch: &ArchConfig, shape: GemmShape) -> Schedule {
        Schedule { dataflow: Dataflow::Systolic, ..Schedule::summa(arch, shape) }
    }

    /// 3D split-K SUMMA: the grid is carved into `splits` K-groups, each a
    /// `rows × cols/splits` logical grid — per-tile output tiles get
    /// *wider* along N (Insight 3: TN = (2112/32)·8 = 528 in the paper's
    /// example), while each group reduces over a K-slice.
    pub fn splitk(arch: &ArchConfig, shape: GemmShape, splits: usize) -> Schedule {
        let s = Schedule {
            dataflow: Dataflow::SplitKSumma { splits },
            logical: (arch.rows, arch.cols / splits.min(arch.cols)),
            tk: 0,
            pipeline_stages: 1,
            double_buffer: true,
            opt_layout: true,
            reduce_policy: ReducePolicy::RoundRobin,
        };
        Schedule { tk: default_tk(arch, shape, &s), ..s }
    }

    /// Flat-GEMM schedule (§4.1.3 "Cluster Dimension Remap"): remap to a
    /// `1 × (tiles/splits)` logical grid with split-K.
    pub fn flat_remap(arch: &ArchConfig, shape: GemmShape, splits: usize) -> Schedule {
        let tiles = arch.num_tiles();
        let s = Schedule {
            dataflow: Dataflow::SplitKSumma { splits },
            logical: (1, tiles / splits),
            tk: 0,
            pipeline_stages: 1,
            double_buffer: true,
            opt_layout: true,
            reduce_policy: ReducePolicy::RoundRobin,
        };
        Schedule { tk: default_tk(arch, shape, &s), ..s }
    }

    /// K-groups in this schedule (1 unless split-K).
    pub fn splits(&self) -> usize {
        match self.dataflow {
            Dataflow::SplitKSumma { splits } => splits,
            _ => 1,
        }
    }

    /// Tiles used by the compute mapping.
    pub fn tiles_used(&self) -> usize {
        self.logical.0 * self.logical.1 * self.splits()
    }

    /// Human-readable name for reports/benches.
    pub fn name(&self) -> String {
        format!(
            "{}[{}x{}]tk{}{}{}{}",
            self.dataflow.name(),
            self.logical.0,
            self.logical.1,
            self.tk,
            if self.pipeline_stages > 1 {
                format!("/ps{}", self.pipeline_stages)
            } else {
                String::new()
            },
            if self.double_buffer { "" } else { "/nodb" },
            if self.opt_layout { "" } else { "/baselayout" },
        )
    }

    /// Canonical cache-key text for the persistent simulation cache
    /// ([`crate::coordinator::cache`]). Unlike [`Schedule::name`] (a
    /// human-readable label that elides default fields), this encodes
    /// **every** field — two schedules map to the same key iff they are
    /// equal — and its format is part of the on-disk cache contract:
    /// changing it orphans persisted entries (bump the cache FORMAT
    /// version if you must).
    pub fn cache_key(&self) -> String {
        format!(
            "{}|l{}x{}|tk{}|ps{}|db{}|ol{}|rp{}",
            self.dataflow.name(),
            self.logical.0,
            self.logical.1,
            self.tk,
            self.pipeline_stages,
            self.double_buffer as u8,
            self.opt_layout as u8,
            match self.reduce_policy {
                ReducePolicy::FirstGroup => "first",
                ReducePolicy::RoundRobin => "rr",
            },
        )
    }

    /// Structural validation against an architecture.
    pub fn validate(&self, arch: &ArchConfig) -> anyhow::Result<()> {
        anyhow::ensure!(self.tk > 0, "tk must be positive");
        anyhow::ensure!(self.logical.0 > 0 && self.logical.1 > 0, "empty logical grid");
        anyhow::ensure!(
            self.tiles_used() <= arch.num_tiles(),
            "schedule needs {} tiles, arch has {}",
            self.tiles_used(),
            arch.num_tiles()
        );
        anyhow::ensure!(self.pipeline_stages >= 1, "pipeline_stages >= 1");
        anyhow::ensure!(
            self.pipeline_stages <= self.logical.0.max(1),
            "more pipeline stages than logical rows"
        );
        match self.dataflow {
            Dataflow::Systolic => {
                anyhow::ensure!(
                    self.logical == (arch.rows, arch.cols),
                    "systolic runs on the physical grid"
                );
            }
            Dataflow::SystolicOverSumma { group } | Dataflow::SummaOverSystolic { group } => {
                anyhow::ensure!(is_pow2(group) && group >= 2, "group must be pow2 >= 2");
                anyhow::ensure!(
                    self.logical.0 % group == 0 && self.logical.1 % group == 0,
                    "group {} does not divide logical grid {}x{}",
                    group,
                    self.logical.0,
                    self.logical.1
                );
            }
            Dataflow::SplitKSumma { splits } => {
                anyhow::ensure!(splits >= 1, "splits >= 1");
                anyhow::ensure!(
                    self.tiles_used() == arch.num_tiles(),
                    "split-K mapping must cover the grid: {} != {}",
                    self.tiles_used(),
                    arch.num_tiles()
                );
                // The cross-K-group reduction is a hardware collective with
                // no unicast fallback, so every reduce group must be
                // AND-mask expressible on the physical grid. Grid/split
                // combinations that break this (e.g. a 12x12 mesh split in
                // 2: row stride 6 has no AND mask) are rejected here —
                // candidate enumeration then simply skips them — instead of
                // panicking inside codegen.
                if splits > 1 {
                    let (p_dim, q_dim) = self.logical;
                    let remap = Remap {
                        phys_rows: arch.rows,
                        phys_cols: arch.cols,
                        log_rows: p_dim * splits,
                        log_cols: q_dim,
                    };
                    for p in 0..p_dim {
                        for q in 0..q_dim {
                            let members: Vec<crate::collective::TileCoord> =
                                (0..splits).map(|ss| remap.to_phys(ss * p_dim + p, q)).collect();
                            anyhow::ensure!(
                                crate::collective::synthesize(&members, arch.rows, arch.cols)
                                    .is_some(),
                                "split-K reduce group (p={p}, q={q}) not mask-expressible on \
                                 the {}x{} grid (logical {p_dim}x{q_dim} x{splits})",
                                arch.rows,
                                arch.cols
                            );
                        }
                    }
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// The tiling plan this schedule induces for a problem.
    pub fn plan(&self, arch: &ArchConfig, shape: GemmShape) -> Plan {
        let (p, q) = self.logical;
        let splits = self.splits();
        let tm = ceil_div(shape.m, p);
        let tn = ceil_div(shape.n, q);
        let k_slice = ceil_div(shape.k, splits);
        let kp = ceil_div(k_slice, self.tk);
        let padded = GemmShape::new(p * tm, q * tn, splits * kp * self.tk);
        Plan {
            tm,
            tn,
            tk: self.tk,
            kp,
            splits,
            padded,
            remap: Remap {
                phys_rows: arch.rows,
                phys_cols: arch.cols,
                // Logical grid flattened over the physical tiles: K-groups
                // are consecutive bands of logical rows.
                log_rows: p * splits,
                log_cols: q,
            },
        }
    }
}

/// Concrete tiling plan derived from a schedule + problem.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// Output-tile height per logical tile.
    pub tm: usize,
    /// Output-tile width per logical tile.
    pub tn: usize,
    /// K-panel depth per superstep.
    pub tk: usize,
    /// K panels per K-slice.
    pub kp: usize,
    /// K-groups (split-K).
    pub splits: usize,
    /// Padded problem dimensions.
    pub padded: GemmShape,
    pub remap: Remap,
}

/// Estimated per-tile L1 requirement in bytes for a schedule (A/B panels
/// and the C accumulator at `arch.elem_bytes`, double-buffer factor, plus
/// the fetch staging buffer on owner tiles).
pub fn l1_estimate(arch: &ArchConfig, shape: GemmShape, s: &Schedule) -> u64 {
    let plan = s.plan(arch, shape);
    let e = arch.elem_bytes as u64;
    let db = if s.double_buffer { 2 } else { 1 };
    let a_panel = (plan.tm * plan.tk) as u64 * e;
    let b_panel = (plan.tk * plan.tn) as u64 * e;
    let c_acc = (plan.tm * plan.tn) as u64 * e;
    // Owner tiles stage the panel they fetch before multicasting it.
    // SUMMA/split-K single-buffer the staging (ownership rotates, see
    // codegen::summa); the hierarchical generators double-buffer it.
    let staging = match s.dataflow {
        Dataflow::SystolicOverSumma { .. } | Dataflow::SummaOverSystolic { .. } => {
            (a_panel + b_panel) * 2
        }
        d if d.uses_collectives() => a_panel + b_panel,
        _ => 0,
    };
    db * (a_panel + b_panel) + c_acc + staging
}

/// Pick the largest `tk` from a preferred ladder that fits L1, preferring
/// depths that leave at least 3 K-panels per slice so the fetch/broadcast/
/// compute software pipeline can actually overlap (§3.3.1) — with a single
/// panel the phases serialize and memory-bound shapes lose badly.
fn default_tk(arch: &ArchConfig, shape: GemmShape, s: &Schedule) -> usize {
    let fits = |tk: usize| {
        let cand = Schedule { tk, ..s.clone() };
        tk <= shape.k.max(32) && l1_estimate(arch, shape, &cand) <= arch.tile.l1_bytes as u64
    };
    let k_slice = shape.k.div_ceil(s.splits().max(1));
    let pipelined = |tk: usize| k_slice.div_ceil(tk) >= 3;
    for tk in [512, 256, 128, 64, 32] {
        if fits(tk) && pipelined(tk) {
            return tk;
        }
    }
    for tk in [512, 256, 128, 64, 32] {
        if fits(tk) {
            return tk;
        }
    }
    16
}

/// Re-derive `tk` after changing a schedule's dataflow (different
/// dataflows have different L1 footprints).
pub fn retune_tk(arch: &ArchConfig, shape: GemmShape, s: &Schedule) -> Schedule {
    Schedule { tk: default_tk(arch, shape, s), ..s.clone() }
}

/// Enumerate the candidate schedules the autotuner scores for a problem —
/// the paper's "predefined schedule candidates, guided by the insights".
pub fn candidates(arch: &ArchConfig, shape: GemmShape) -> Vec<Schedule> {
    let mut out = Vec::new();
    let (rows, cols) = (arch.rows, arch.cols);

    // 2D patterns on the physical grid.
    out.push(Schedule::baseline(arch, shape));
    out.push(Schedule { opt_layout: true, ..Schedule::baseline(arch, shape) });
    out.push(Schedule::summa(arch, shape));
    out.push(Schedule { opt_layout: false, ..Schedule::summa(arch, shape) });
    out.push(Schedule::systolic(arch, shape));
    for stages in [2, 4] {
        if stages <= rows {
            out.push(Schedule { pipeline_stages: stages, ..Schedule::summa(arch, shape) });
        }
    }

    // Hierarchical patterns (tk re-derived: they stage more in L1).
    for group in [2, 4] {
        if rows % group == 0 && cols % group == 0 && rows >= group * 2 {
            out.push(retune_tk(arch, shape, &Schedule {
                dataflow: Dataflow::SystolicOverSumma { group },
                ..Schedule::summa(arch, shape)
            }));
            out.push(retune_tk(arch, shape, &Schedule {
                dataflow: Dataflow::SummaOverSystolic { group },
                ..Schedule::summa(arch, shape)
            }));
        }
    }

    // 3D tiling (Insight 3): worthwhile when N or M tiles poorly.
    for splits in [2, 4, 8] {
        if cols % splits == 0 && shape.k >= splits * 64 {
            out.push(Schedule::splitk(arch, shape, splits));
        }
    }
    let _ = rows;

    // Cluster remap for flat GEMM (Insight 4).
    if shape.is_flat() {
        for splits in [4, 8, 16, 32] {
            let tiles = arch.num_tiles();
            if tiles % splits == 0 && shape.k >= splits * 64 {
                out.push(Schedule::flat_remap(arch, shape, splits));
            }
        }
    }

    out.retain(|s| s.validate(arch).is_ok());
    // Keep schedules that fit L1 directly, or that fit after the
    // coordinator's output chunking (deploy_chunked splits N by up to 64).
    out.retain(|s| {
        let l1 = arch.tile.l1_bytes as u64;
        if l1_estimate(arch, shape, s) <= l1 {
            return true;
        }
        let chunk = GemmShape::new(shape.m, shape.n.div_ceil(64), shape.k);
        l1_estimate(arch, chunk, s) <= l1
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gh200() -> ArchConfig {
        ArchConfig::gh200_like()
    }

    #[test]
    fn summa_defaults_fit_l1() {
        let arch = gh200();
        let shape = GemmShape::new(4096, 2112, 7168);
        let s = Schedule::summa(&arch, shape);
        s.validate(&arch).unwrap();
        assert!(l1_estimate(&arch, shape, &s) <= arch.tile.l1_bytes as u64);
        assert!(s.tk >= 64, "tk = {}", s.tk);
    }

    #[test]
    fn plan_pads_ragged_dimensions() {
        let arch = gh200();
        // N = 2112 over 32 columns -> TN = 66 (the paper's ragged case).
        let s = Schedule::summa(&arch, GemmShape::new(4096, 2112, 7168));
        let plan = s.plan(&arch, GemmShape::new(4096, 2112, 7168));
        assert_eq!(plan.tm, 128);
        assert_eq!(plan.tn, 66);
        assert_eq!(plan.padded.m, 4096);
        assert_eq!(plan.padded.n, 2112);
        assert_eq!(plan.padded.k % plan.tk, 0);
    }

    #[test]
    fn splitk_tiles_cover_grid() {
        let arch = gh200();
        let shape = GemmShape::new(4096, 2112, 7168);
        let s = Schedule::splitk(&arch, shape, 8);
        s.validate(&arch).unwrap();
        assert_eq!(s.logical, (32, 4));
        assert_eq!(s.tiles_used(), 1024);
        // Split-K widens per-tile N: Insight 3's TN = (2112/32)*8 = 528.
        let plan = s.plan(&arch, shape);
        assert_eq!(plan.tn, 528);
        assert_eq!(plan.tm, 128);
        assert_eq!(plan.splits, 8);
    }

    #[test]
    fn flat_remap_produces_wide_logical_grid() {
        let arch = gh200();
        let shape = GemmShape::new(64, 2112, 7168);
        let s = Schedule::flat_remap(&arch, shape, 8);
        s.validate(&arch).unwrap();
        assert_eq!(s.logical, (1, 128));
        let plan = s.plan(&arch, shape);
        assert_eq!(plan.tm, 64);
        // 2112 / 128 = 16.5 -> padded.
        assert!(plan.tn >= 16);
        assert_eq!(plan.remap.log_rows, 8);
        assert_eq!(plan.remap.log_cols, 128);
    }

    #[test]
    fn cache_key_is_injective_over_every_field() {
        let arch = gh200();
        let shape = GemmShape::new(4096, 2112, 7168);
        let base = Schedule::summa(&arch, shape);
        // Flipping any single field must change the key (Schedule::name
        // elides defaults like the reduce policy; the cache key may not).
        let variants = [
            Schedule { dataflow: Dataflow::Systolic, ..base.clone() },
            Schedule { logical: (16, 64), ..base.clone() },
            Schedule { tk: base.tk + 64, ..base.clone() },
            Schedule { pipeline_stages: 2, ..base.clone() },
            Schedule { double_buffer: !base.double_buffer, ..base.clone() },
            Schedule { opt_layout: !base.opt_layout, ..base.clone() },
            Schedule { reduce_policy: ReducePolicy::FirstGroup, ..base.clone() },
        ];
        for v in &variants {
            assert_ne!(v.cache_key(), base.cache_key(), "{}", v.cache_key());
        }
        assert_eq!(base.cache_key(), base.clone().cache_key());
        // The whole candidate space for a shape maps to distinct keys.
        let mut keys: Vec<String> =
            candidates(&arch, shape).iter().map(Schedule::cache_key).collect();
        let n = keys.len();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), n, "candidate cache keys must be unique");
    }

    #[test]
    fn splitk_validation_requires_mask_expressible_reduce_groups() {
        // 12x12 split by 2 would need row-stride-6 reduce groups, which no
        // AND mask expresses — validate must reject it (codegen would
        // panic), and candidate enumeration must therefore skip it.
        let mut arch = gh200();
        arch.rows = 12;
        arch.cols = 12;
        arch.hbm.channels_per_edge = 12;
        let shape = GemmShape::new(1024, 1024, 1024);
        let err = Schedule::splitk(&arch, shape, 2).validate(&arch).unwrap_err();
        assert!(err.to_string().contains("mask-expressible"), "{err:#}");
        for c in candidates(&arch, shape) {
            c.validate(&arch).unwrap();
            assert!(!matches!(c.dataflow, Dataflow::SplitKSumma { .. }), "{}", c.name());
        }
        // Power-of-two grid/split ratios stay valid.
        Schedule::splitk(&gh200(), shape, 8).validate(&gh200()).unwrap();
        let flat = GemmShape::new(64, 2112, 7168);
        Schedule::flat_remap(&gh200(), flat, 8).validate(&gh200()).unwrap();
    }

    #[test]
    fn validation_rejects_oversubscription() {
        let arch = ArchConfig::tiny(2, 2);
        let mut s = Schedule::summa(&arch, GemmShape::new(64, 64, 64));
        s.logical = (4, 4);
        assert!(s.validate(&arch).is_err());
    }

    #[test]
    fn validation_rejects_bad_group() {
        let arch = gh200();
        let shape = GemmShape::new(1024, 1024, 1024);
        let mut s = Schedule::summa(&arch, shape);
        s.dataflow = Dataflow::SystolicOverSumma { group: 3 };
        assert!(s.validate(&arch).is_err());
        s.dataflow = Dataflow::SystolicOverSumma { group: 2 };
        s.validate(&arch).unwrap();
    }

    #[test]
    fn candidates_cover_all_primitive_families() {
        let arch = gh200();
        let shape = GemmShape::new(4096, 2112, 7168);
        let cands = candidates(&arch, shape);
        assert!(cands.len() >= 8, "{}", cands.len());
        assert!(cands.iter().any(|s| s.dataflow == Dataflow::Baseline));
        assert!(cands.iter().any(|s| s.dataflow == Dataflow::Summa));
        assert!(cands.iter().any(|s| s.dataflow == Dataflow::Systolic));
        assert!(cands.iter().any(|s| matches!(s.dataflow, Dataflow::SplitKSumma { .. })));
        assert!(cands
            .iter()
            .any(|s| matches!(s.dataflow, Dataflow::SystolicOverSumma { .. })));
        // All enumerated candidates are feasible.
        for s in &cands {
            s.validate(&arch).unwrap();
            assert!(l1_estimate(&arch, shape, s) <= arch.tile.l1_bytes as u64, "{}", s.name());
        }
    }

    #[test]
    fn flat_shapes_get_remap_candidates() {
        let arch = gh200();
        let shape = GemmShape::new(64, 2112, 7168);
        let cands = candidates(&arch, shape);
        assert!(
            cands.iter().any(|s| s.logical.0 == 1 && s.logical.1 >= 32),
            "no flat remap candidate in {:?}",
            cands.iter().map(|s| s.name()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn names_are_distinct() {
        let arch = gh200();
        let shape = GemmShape::new(4096, 2112, 7168);
        let names: Vec<String> = candidates(&arch, shape).iter().map(|s| s.name()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len(), "{names:?}");
    }
}

//! Cluster-index remapping (paper §3.1.2).
//!
//! The physical tile grid is fixed (e.g. 32×32) but optimal mappings want
//! other logical shapes (1×1024, 2×8, 4×256 …). A [`Remap`] reinterprets
//! the physical grid as a logical grid through the shared row-major linear
//! index, and — the part that "integrates seamlessly with our mask-based
//! collectives" — synthesizes physical `(S, M)` masks for logical-topology
//! groups whenever the AND-mask hardware can express them (always true for
//! power-of-two grids, which is what the hardware template uses).

use crate::collective::{synthesize, Mask, TileCoord};

/// A logical view `log_rows × log_cols` of a physical `phys_rows ×
/// phys_cols` grid with the same tile count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Remap {
    pub phys_rows: usize,
    pub phys_cols: usize,
    pub log_rows: usize,
    pub log_cols: usize,
}

impl Remap {
    /// Identity remap (logical == physical).
    pub fn identity(rows: usize, cols: usize) -> Remap {
        Remap { phys_rows: rows, phys_cols: cols, log_rows: rows, log_cols: cols }
    }

    /// Reinterpret as `log_rows × log_cols`; tile counts must match.
    pub fn new(
        phys_rows: usize,
        phys_cols: usize,
        log_rows: usize,
        log_cols: usize,
    ) -> anyhow::Result<Remap> {
        anyhow::ensure!(
            phys_rows * phys_cols == log_rows * log_cols,
            "remap must preserve tile count: {}x{} vs {}x{}",
            phys_rows,
            phys_cols,
            log_rows,
            log_cols
        );
        Ok(Remap { phys_rows, phys_cols, log_rows, log_cols })
    }

    pub fn num_tiles(&self) -> usize {
        self.phys_rows * self.phys_cols
    }

    /// Physical tile of logical coordinate `(lr, lc)`.
    ///
    /// Panics when the coordinate lies outside the logical grid. The
    /// linear-index arithmetic would otherwise map it onto a *different,
    /// valid* tile — the old `debug_assert!` guard made that silent
    /// aliasing (wrong operands, wrong collective groups) the release-
    /// build behavior instead of a crash.
    pub fn to_phys(&self, lr: usize, lc: usize) -> TileCoord {
        assert!(
            lr < self.log_rows && lc < self.log_cols,
            "logical ({lr},{lc}) out of the {}x{} logical grid",
            self.log_rows,
            self.log_cols
        );
        TileCoord::from_linear(lr * self.log_cols + lc, self.phys_cols)
    }

    /// Logical coordinate of a physical tile.
    ///
    /// Panics when `t` lies outside the physical grid (the same release-
    /// mode aliasing hazard as [`Remap::to_phys`], in the other
    /// direction). Physical tiles beyond an under-subscribed logical
    /// grid still map past its last row — callers mapping a subset of
    /// the grid rely on that.
    pub fn to_logical(&self, t: TileCoord) -> (usize, usize) {
        assert!(
            t.row < self.phys_rows && t.col < self.phys_cols,
            "physical {t} out of the {}x{} grid",
            self.phys_rows,
            self.phys_cols
        );
        let lin = t.linear(self.phys_cols);
        (lin / self.log_cols, lin % self.log_cols)
    }

    /// Physical members of logical row `lr`.
    pub fn logical_row(&self, lr: usize) -> Vec<TileCoord> {
        (0..self.log_cols).map(|lc| self.to_phys(lr, lc)).collect()
    }

    /// Physical members of logical column `lc`.
    pub fn logical_col(&self, lc: usize) -> Vec<TileCoord> {
        (0..self.log_rows).map(|lr| self.to_phys(lr, lc)).collect()
    }

    /// Synthesized physical mask for logical row `lr`, if expressible.
    pub fn logical_row_mask(&self, lr: usize) -> Option<Mask> {
        synthesize(&self.logical_row(lr), self.phys_rows, self.phys_cols)
    }

    /// Synthesized physical mask for logical column `lc`, if expressible.
    pub fn logical_col_mask(&self, lc: usize) -> Option<Mask> {
        synthesize(&self.logical_col(lc), self.phys_rows, self.phys_cols)
    }

    /// Synthesized physical mask for a contiguous logical-linear range
    /// `[start, start + len)` (used by split-K reduction groups).
    ///
    /// Panics when the range runs past the grid's tile count — the
    /// linear indices would wrap into rows that do not exist.
    pub fn linear_range_mask(&self, start: usize, len: usize) -> Option<Mask> {
        assert!(
            start + len <= self.num_tiles(),
            "linear range [{start}, {}) out of the {}-tile grid",
            start + len,
            self.num_tiles()
        );
        let tiles: Vec<TileCoord> = (start..start + len)
            .map(|lin| TileCoord::from_linear(lin, self.phys_cols))
            .collect();
        synthesize(&tiles, self.phys_rows, self.phys_cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::check;

    #[test]
    fn identity_roundtrip() {
        let r = Remap::identity(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                let t = TileCoord::new(i, j);
                assert_eq!(r.to_phys(i, j), t);
                assert_eq!(r.to_logical(t), (i, j));
            }
        }
    }

    #[test]
    fn remap_preserves_count() {
        assert!(Remap::new(4, 4, 2, 8).is_ok());
        assert!(Remap::new(4, 4, 1, 16).is_ok());
        assert!(Remap::new(4, 4, 3, 5).is_err());
    }

    #[test]
    fn flat_remap_1xall() {
        // The paper's flat-GEMM case: 32x32 physical -> 1x1024 logical.
        let r = Remap::new(32, 32, 1, 1024).unwrap();
        assert_eq!(r.to_phys(0, 0), TileCoord::new(0, 0));
        assert_eq!(r.to_phys(0, 33), TileCoord::new(1, 1));
        assert_eq!(r.to_logical(TileCoord::new(31, 31)), (0, 1023));
        // Logical row 0 = everything: mask must be the all-group.
        let m = r.logical_row_mask(0).unwrap();
        assert_eq!(m.count(32, 32), 1024);
    }

    #[test]
    fn pow2_logical_rows_are_mask_expressible() {
        // 4x4 physical viewed as 2x8: logical row 0 = physical rows 0-1.
        let r = Remap::new(4, 4, 2, 8).unwrap();
        let m = r.logical_row_mask(0).unwrap();
        let members = m.members(4, 4);
        assert_eq!(members.len(), 8);
        assert!(members.iter().all(|t| t.row < 2));

        let m1 = r.logical_row_mask(1).unwrap();
        assert!(m1.members(4, 4).iter().all(|t| t.row >= 2));
    }

    #[test]
    fn pow2_logical_cols_are_mask_expressible() {
        // 4x4 as 8x2: logical col 0 = even physical linear indices.
        let r = Remap::new(4, 4, 8, 2).unwrap();
        let m = r.logical_col_mask(0).unwrap();
        let members = m.members(4, 4);
        assert_eq!(members.len(), 8);
        assert!(members.iter().all(|t| t.col % 2 == 0));
    }

    #[test]
    fn linear_range_masks() {
        let r = Remap::identity(4, 4);
        // Aligned pow2 range = half a physical row.
        let m = r.linear_range_mask(4, 4).unwrap(); // row 1
        assert_eq!(m.members(4, 4), r.logical_row(1));
        // A misaligned range crossing a row boundary is not expressible.
        assert!(r.linear_range_mask(2, 4).is_none());
    }

    #[test]
    #[should_panic(expected = "out of the 2x8 logical grid")]
    fn to_phys_rejects_out_of_range_logical_row() {
        // Logical row 2 of a 2x8 view would alias onto tile (1,0) in a
        // release build under the old debug_assert-only guard.
        Remap::new(4, 4, 2, 8).unwrap().to_phys(2, 0);
    }

    #[test]
    #[should_panic(expected = "out of the 2x8 logical grid")]
    fn to_phys_rejects_out_of_range_logical_col() {
        Remap::new(4, 4, 2, 8).unwrap().to_phys(0, 8);
    }

    #[test]
    #[should_panic(expected = "out of the 4x4 grid")]
    fn to_logical_rejects_out_of_range_physical() {
        Remap::new(4, 4, 2, 8).unwrap().to_logical(TileCoord::new(4, 0));
    }

    #[test]
    #[should_panic(expected = "out of the 16-tile grid")]
    fn linear_range_mask_rejects_overflowing_range() {
        Remap::identity(4, 4).linear_range_mask(12, 8);
    }

    #[test]
    fn bounds_hold_on_rectangular_grids() {
        // A 2x8 physical grid viewed as 4x4: every in-range coordinate
        // round-trips, in both directions, without tripping the guards.
        let r = Remap::new(2, 8, 4, 4).unwrap();
        for lr in 0..4 {
            for lc in 0..4 {
                let t = r.to_phys(lr, lc);
                assert!(t.row < 2 && t.col < 8);
                assert_eq!(r.to_logical(t), (lr, lc));
            }
        }
    }

    #[test]
    fn prop_roundtrip_and_mask_consistency() {
        check("remap roundtrip + mask member sets", 100, |rng| {
            let shapes: [(usize, usize, usize, usize); 6] = [
                (4, 4, 2, 8),
                (4, 4, 1, 16),
                (8, 8, 4, 16),
                (8, 8, 2, 32),
                (8, 8, 64, 1),
                (32, 32, 8, 128),
            ];
            let &(pr, pc, lr, lc) = rng.choose(&shapes);
            let r = Remap::new(pr, pc, lr, lc).unwrap();
            // Roundtrip.
            let t = TileCoord::new(rng.range(0, pr - 1), rng.range(0, pc - 1));
            let (a, b) = r.to_logical(t);
            assert_eq!(r.to_phys(a, b), t);
            // Every logical row/col mask, when expressible, covers exactly
            // the enumerated members.
            let row = rng.range(0, lr - 1);
            if let Some(m) = r.logical_row_mask(row) {
                assert!(m.covers_exactly(&r.logical_row(row), pr, pc));
            }
            let col = rng.range(0, lc - 1);
            if let Some(m) = r.logical_col_mask(col) {
                assert!(m.covers_exactly(&r.logical_col(col), pr, pc));
            }
        });
    }
}

//! Distributed HBM data layouts (paper §3.2) and preload images.
//!
//! SoftHier's HBM is software-managed, distributed, multi-channel; every
//! channel has a distinct address space. A [`MatrixLayout`] describes how an
//! `R × C` matrix is physically placed:
//!
//! * **Split scheme** — the matrix is partitioned into an `sr × sc` grid of
//!   *blocks* (the coarsest distribution unit); blocks go to channels
//!   round-robin (§3.2.1).
//! * **Placement scheme** — each block is decomposed into `tm × tn` *tiles*
//!   stored contiguously (row- or column-major tile order) in its channel's
//!   1-D address space (§3.2.2); `tm/tn` come from the workload tiling so a
//!   compute tile's DMA fetch is a single contiguous burst.
//!
//! The *base* layout the paper benchmarks against ("row-major fashion
//! without distribution across HBM channels") is the degenerate case:
//! one block, one channel, 1-row tiles.

pub mod preload;

use crate::collective::TileCoord;

/// Tile ordering inside a block's channel range.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Tiles laid out row-major within the block (Fig. 5 default).
    RowMajor,
    /// Tiles laid out column-major within the block.
    ColMajor,
}

/// How blocks map to HBM channels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChannelAssign {
    /// Round-robin over `count` channels starting at `first` (§3.2.1
    /// default).
    RoundRobin { first: usize, count: usize },
    /// Everything in one channel (the paper's unoptimized base layout).
    Single(usize),
}

/// One contiguous byte range in one HBM channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Run {
    pub channel: usize,
    pub offset: u64,
    pub bytes: u64,
}

/// A physical layout of an `rows × cols` element matrix over HBM channels.
#[derive(Debug, Clone, PartialEq)]
pub struct MatrixLayout {
    /// Byte offset added to every address in every channel this layout
    /// touches — how multiple matrices (A, B, C) share the same channels
    /// without overlapping. Assigned by the layout builder.
    pub base_offset: u64,
    /// Matrix rows (elements). May include tiling padding.
    pub rows: usize,
    /// Matrix cols (elements).
    pub cols: usize,
    pub elem_bytes: usize,
    /// Split scheme `(sr, sc)`: block grid dimensions.
    pub split: (usize, usize),
    /// Placement tile `(tm, tn)` in elements.
    pub tile: (usize, usize),
    pub placement: Placement,
    pub channels: ChannelAssign,
}

impl MatrixLayout {
    /// The paper's base layout: whole matrix row-major in a single channel.
    pub fn base(rows: usize, cols: usize, elem_bytes: usize, channel: usize) -> MatrixLayout {
        MatrixLayout {
            base_offset: 0,
            rows,
            cols,
            elem_bytes,
            split: (1, 1),
            tile: (1, cols),
            placement: Placement::RowMajor,
            channels: ChannelAssign::Single(channel),
        }
    }

    /// An optimized layout: split into `sr × sc` blocks round-robined over
    /// all `num_channels`, with the workload tile `(tm, tn)` as the
    /// placement unit so each fetch is one burst.
    pub fn optimized(
        rows: usize,
        cols: usize,
        elem_bytes: usize,
        split: (usize, usize),
        tile: (usize, usize),
        num_channels: usize,
    ) -> MatrixLayout {
        MatrixLayout {
            base_offset: 0,
            rows,
            cols,
            elem_bytes,
            split,
            tile,
            placement: Placement::RowMajor,
            channels: ChannelAssign::RoundRobin { first: 0, count: num_channels },
        }
    }

    /// Block height/width in elements.
    pub fn block_dims(&self) -> (usize, usize) {
        (self.rows / self.split.0, self.cols / self.split.1)
    }

    /// Structural validation: splits and tiles must divide evenly (callers
    /// pad the matrix to tile multiples first — same as SoftHier's DMA
    /// padding of ragged edges).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.rows > 0 && self.cols > 0, "empty matrix");
        anyhow::ensure!(self.elem_bytes > 0, "zero element size");
        let (sr, sc) = self.split;
        anyhow::ensure!(sr > 0 && sc > 0, "empty split");
        anyhow::ensure!(
            self.rows % sr == 0 && self.cols % sc == 0,
            "split {:?} does not divide matrix {}x{}",
            self.split,
            self.rows,
            self.cols
        );
        let (bm, bn) = self.block_dims();
        let (tm, tn) = self.tile;
        anyhow::ensure!(tm > 0 && tn > 0, "empty tile");
        anyhow::ensure!(
            bm % tm == 0 && bn % tn == 0,
            "tile {:?} does not divide block {}x{}",
            self.tile,
            bm,
            bn
        );
        if let ChannelAssign::RoundRobin { count, .. } = self.channels {
            anyhow::ensure!(count > 0, "round-robin over zero channels");
        }
        Ok(())
    }

    /// Channel that stores block `(bi, bj)`.
    pub fn channel_of_block(&self, bi: usize, bj: usize) -> usize {
        let lin = bi * self.split.1 + bj;
        match self.channels {
            ChannelAssign::Single(ch) => ch,
            ChannelAssign::RoundRobin { first, count } => first + lin % count,
        }
    }

    /// Byte offset of a block's slot within its channel. Round-robin stores
    /// each channel's blocks back-to-back in block-linear order.
    fn block_base(&self, bi: usize, bj: usize) -> u64 {
        let lin = bi * self.split.1 + bj;
        let (bm, bn) = self.block_dims();
        let block_bytes = (bm * bn * self.elem_bytes) as u64;
        let slot = match self.channels {
            ChannelAssign::Single(_) => lin,
            ChannelAssign::RoundRobin { count, .. } => lin / count,
        };
        self.base_offset + slot as u64 * block_bytes
    }

    /// Physical address of element `(r, c)`: `(channel, byte offset)`.
    pub fn addr_of(&self, r: usize, c: usize) -> (usize, u64) {
        // Hard assert: the `/`/`%` arithmetic below maps an out-of-range
        // coordinate onto a *different, valid* (channel, offset) pair, so
        // a debug-only guard made silent aliasing the release behavior.
        assert!(r < self.rows && c < self.cols, "({r},{c}) out of bounds");
        let (bm, bn) = self.block_dims();
        let (bi, bj) = (r / bm, c / bn);
        let (rr, cc) = (r % bm, c % bn);
        let (tm, tn) = self.tile;
        let (ti, tj) = (rr / tm, cc / tn);
        let tiles_per_row = bn / tn;
        let tiles_per_col = bm / tm;
        let ordinal = match self.placement {
            Placement::RowMajor => ti * tiles_per_row + tj,
            Placement::ColMajor => tj * tiles_per_col + ti,
        };
        let within = (rr % tm) * tn + (cc % tn);
        let off = self.block_base(bi, bj)
            + (ordinal * tm * tn + within) as u64 * self.elem_bytes as u64;
        (self.channel_of_block(bi, bj), off)
    }

    /// Contiguous runs covering the rectangle `rows [r0, r1) × cols
    /// [c0, c1)`, coalesced. This is what a tile's DMA engine issues; the
    /// run count is the burst count, which the HBM model charges
    /// per-request overhead for — strided (bad-layout) access patterns are
    /// therefore naturally slower, reproducing Fig. 7a's baseline gap.
    pub fn rect_runs(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<Run> {
        assert!(r0 < r1 && c0 < c1 && r1 <= self.rows && c1 <= self.cols,
            "bad rect [{r0},{r1})x[{c0},{c1}) on {}x{}", self.rows, self.cols);
        let (_, bn) = self.block_dims();
        let (_, tn) = self.tile;
        let mut runs: Vec<Run> = Vec::new();
        for r in r0..r1 {
            let mut c = c0;
            while c < c1 {
                // A contiguous span cannot cross a placement-tile column
                // boundary or a block column boundary.
                let tile_end = (c / tn + 1) * tn;
                let block_end = (c / bn + 1) * bn;
                let end = c1.min(tile_end).min(block_end);
                let (ch, off) = self.addr_of(r, c);
                let bytes = ((end - c) * self.elem_bytes) as u64;
                match runs.last_mut() {
                    Some(last) if last.channel == ch && last.offset + last.bytes == off => {
                        last.bytes += bytes;
                    }
                    _ => runs.push(Run { channel: ch, offset: off, bytes }),
                }
                c = end;
            }
        }
        runs
    }

    /// Total bytes this layout occupies in each channel (map: channel →
    /// bytes). Used to size preload images.
    pub fn channel_extents(&self) -> std::collections::BTreeMap<usize, u64> {
        let (bm, bn) = self.block_dims();
        let block_bytes = (bm * bn * self.elem_bytes) as u64;
        let mut map = std::collections::BTreeMap::new();
        for bi in 0..self.split.0 {
            for bj in 0..self.split.1 {
                let ch = self.channel_of_block(bi, bj);
                let end = self.block_base(bi, bj) + block_bytes;
                let e = map.entry(ch).or_insert(0u64);
                *e = (*e).max(end);
            }
        }
        map
    }

    /// Largest end-of-extent over all channels (used to stack matrices
    /// back-to-back in shared channels).
    pub fn max_extent(&self) -> u64 {
        self.channel_extents().values().copied().max().unwrap_or(0)
    }

    /// The set of channels this layout touches.
    pub fn channels_used(&self) -> Vec<usize> {
        self.channel_extents().keys().copied().collect()
    }
}

/// Layouts for one GEMM deployment (A, B, C matrices).
#[derive(Debug, Clone, PartialEq)]
pub struct GemmLayouts {
    pub a: MatrixLayout,
    pub b: MatrixLayout,
    pub c: MatrixLayout,
}

impl GemmLayouts {
    pub fn validate(&self) -> anyhow::Result<()> {
        self.a.validate()?;
        self.b.validate()?;
        self.c.validate()
    }
}

/// Where an HBM channel's controller sits on the mesh — re-exported helper
/// so layout-aware code doesn't need the arch module for tests.
pub fn nearest_edge_router(rows: usize, cols: usize, channel: usize, per_edge: usize) -> TileCoord {
    if channel < per_edge {
        TileCoord::new(channel % rows, 0)
    } else {
        TileCoord::new(rows - 1, (channel - per_edge) % cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::check;
    use crate::util::rng::Rng;

    fn opt_4x4() -> MatrixLayout {
        // 64x64 matrix, 4x4 blocks of 16x16, tiles of 8x8, 4 channels.
        MatrixLayout::optimized(64, 64, 4, (4, 4), (8, 8), 4)
    }

    #[test]
    fn validate_catches_bad_divisibility() {
        let mut l = opt_4x4();
        l.validate().unwrap();
        l.split = (3, 4);
        assert!(l.validate().is_err());
        let mut l2 = opt_4x4();
        l2.tile = (5, 8);
        assert!(l2.validate().is_err());
    }

    #[test]
    fn base_layout_is_row_major_single_channel() {
        let l = MatrixLayout::base(8, 8, 4, 2);
        l.validate().unwrap();
        for r in 0..8 {
            for c in 0..8 {
                let (ch, off) = l.addr_of(r, c);
                assert_eq!(ch, 2);
                assert_eq!(off, ((r * 8 + c) * 4) as u64);
            }
        }
    }

    #[test]
    fn round_robin_block_assignment() {
        let l = opt_4x4();
        // Fig. 5: blocks round-robin over channels in block-linear order.
        assert_eq!(l.channel_of_block(0, 0), 0);
        assert_eq!(l.channel_of_block(0, 1), 1);
        assert_eq!(l.channel_of_block(0, 3), 3);
        assert_eq!(l.channel_of_block(1, 0), 0);
    }

    #[test]
    fn addresses_within_channel_never_collide() {
        let l = opt_4x4();
        let mut seen = std::collections::HashSet::new();
        for r in 0..64 {
            for c in 0..64 {
                let key = l.addr_of(r, c);
                assert!(seen.insert(key), "collision at ({r},{c}) -> {key:?}");
            }
        }
    }

    #[test]
    fn placement_tile_fetch_is_one_run() {
        let l = opt_4x4();
        // A rect equal to one placement tile must coalesce to 1 burst.
        let runs = l.rect_runs(8, 16, 8, 16);
        assert_eq!(runs.len(), 1, "{runs:?}");
        assert_eq!(runs[0].bytes, 8 * 8 * 4);
    }

    #[test]
    fn base_layout_fetch_is_strided() {
        let l = MatrixLayout::base(64, 64, 4, 0);
        // A 8x8 rect from a row-major matrix = 8 separate bursts.
        let runs = l.rect_runs(0, 8, 8, 16);
        assert_eq!(runs.len(), 8, "{runs:?}");
        assert!(runs.iter().all(|r| r.bytes == 32));
    }

    #[test]
    fn side_by_side_tiles_do_not_coalesce() {
        let l = opt_4x4();
        // Two tiles side by side: element rows interleave between the two
        // tiles' address ranges, so every (row × tile) span is its own
        // burst — 8 rows × 2 tiles = 16 runs. (This is why the placement
        // tile should equal the fetch unit, §3.2.2.)
        let runs = l.rect_runs(0, 8, 0, 16);
        assert_eq!(runs.len(), 16, "{runs:?}");
    }

    #[test]
    fn stacked_tiles_coalesce_col_major() {
        let mut l = opt_4x4();
        l.placement = Placement::ColMajor;
        // Two vertically stacked tiles in column-major tile order are
        // back-to-back in the channel: one 512-byte burst.
        let runs = l.rect_runs(0, 16, 0, 8);
        assert_eq!(runs.len(), 1, "{runs:?}");
        assert_eq!(runs[0].bytes, 16 * 8 * 4);
    }

    #[test]
    fn rect_runs_cover_exactly_prop() {
        check("rect runs cover the rect bytes exactly", 100, |rng: &mut Rng| {
            let l = MatrixLayout::optimized(
                32,
                32,
                4,
                (*rng.choose(&[1usize, 2, 4]), *rng.choose(&[1usize, 2, 4])),
                (*rng.choose(&[4usize, 8]), *rng.choose(&[4usize, 8])),
                rng.range(1, 6),
            );
            l.validate().unwrap();
            let r0 = rng.range(0, 31);
            let r1 = rng.range(r0 + 1, 32);
            let c0 = rng.range(0, 31);
            let c1 = rng.range(c0 + 1, 32);
            let runs = l.rect_runs(r0, r1, c0, c1);
            let total: u64 = runs.iter().map(|r| r.bytes).sum();
            assert_eq!(total, ((r1 - r0) * (c1 - c0) * 4) as u64);
            // Runs must stay inside the channel extents.
            let extents = l.channel_extents();
            for run in &runs {
                assert!(run.offset + run.bytes <= extents[&run.channel]);
            }
        });
    }

    #[test]
    fn channel_extents_sum_to_matrix_bytes() {
        let l = opt_4x4();
        let total: u64 = l.channel_extents().values().sum();
        assert_eq!(total, 64 * 64 * 4);

        // Uneven round-robin still covers all bytes (6 channels, 16 blocks).
        let l = MatrixLayout::optimized(64, 64, 4, (4, 4), (8, 8), 6);
        let total: u64 = l.channel_extents().values().sum();
        assert!(total >= 64 * 64 * 4);
    }

    #[test]
    fn col_major_placement_differs() {
        let mut l = opt_4x4();
        let rm = l.addr_of(0, 8); // tile (0,1) row-major => ordinal 1
        l.placement = Placement::ColMajor;
        let cm = l.addr_of(0, 8); // col-major => ordinal 2 (tiles_per_col=2)
        assert_ne!(rm, cm);
    }
}

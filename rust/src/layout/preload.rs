//! Preload images: the DiT workflow's first stage (paper Fig. 4).
//!
//! "Raw data and the data layout description are processed into a preload
//! file. The preload file defines the initial input tensors and their
//! distribution across HBM channels." A [`Preload`] is exactly that: one
//! byte image per HBM channel, built by pushing matrices through their
//! [`MatrixLayout`](super::MatrixLayout) address functions. The functional
//! executor uses it as the initial HBM state; a binary file format
//! round-trips it to disk for inspection and replay.

use std::io::{Read, Write};

use anyhow::{ensure, Context};

use super::MatrixLayout;

const MAGIC: &[u8; 8] = b"DITPRELD";

/// Per-channel HBM byte images.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Preload {
    /// `images[ch]` = contents of channel `ch` from offset 0.
    pub images: Vec<Vec<u8>>,
}

impl Preload {
    /// Create with `channels` empty images.
    pub fn new(channels: usize) -> Preload {
        Preload { images: vec![Vec::new(); channels] }
    }

    fn ensure_len(&mut self, ch: usize, len: u64) {
        assert!(ch < self.images.len(), "channel {ch} out of range");
        if (self.images[ch].len() as u64) < len {
            self.images[ch].resize(len as usize, 0);
        }
    }

    /// Scatter an f32 matrix (row-major `rows × cols`) into the images
    /// according to `layout`. `layout.elem_bytes` must be 4 (functional
    /// verification is f32; perf-only layouts never build preloads).
    pub fn scatter_f32(&mut self, layout: &MatrixLayout, data: &[f32]) {
        assert_eq!(layout.elem_bytes, 4, "functional preloads are f32");
        assert_eq!(data.len(), layout.rows * layout.cols, "data/layout shape mismatch");
        for ext in layout.channel_extents() {
            self.ensure_len(ext.0, ext.1);
        }
        for r in 0..layout.rows {
            // Scatter row-by-row using coalesced runs (fast path: few runs).
            let runs = layout.rect_runs(r, r + 1, 0, layout.cols);
            let mut c = 0usize;
            for run in runs {
                let n = (run.bytes / 4) as usize;
                let dst = &mut self.images[run.channel]
                    [run.offset as usize..run.offset as usize + run.bytes as usize];
                for (i, chunk) in dst.chunks_exact_mut(4).enumerate() {
                    chunk.copy_from_slice(&data[r * layout.cols + c + i].to_le_bytes());
                }
                c += n;
            }
        }
    }

    /// Gather an f32 matrix back out of the images (inverse of
    /// [`Preload::scatter_f32`]); used to read C after functional runs.
    pub fn gather_f32(&self, layout: &MatrixLayout) -> Vec<f32> {
        assert_eq!(layout.elem_bytes, 4);
        let mut out = vec![0f32; layout.rows * layout.cols];
        for r in 0..layout.rows {
            let runs = layout.rect_runs(r, r + 1, 0, layout.cols);
            let mut c = 0usize;
            for run in runs {
                let src = &self.images[run.channel]
                    [run.offset as usize..(run.offset + run.bytes) as usize];
                for (i, chunk) in src.chunks_exact(4).enumerate() {
                    out[r * layout.cols + c + i] =
                        f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                }
                c += (run.bytes / 4) as usize;
            }
        }
        out
    }

    /// Serialize to the binary preload-file format.
    pub fn write_to(&self, w: &mut impl Write) -> anyhow::Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&(self.images.len() as u32).to_le_bytes())?;
        for img in &self.images {
            w.write_all(&(img.len() as u64).to_le_bytes())?;
            w.write_all(img)?;
        }
        Ok(())
    }

    /// Parse from the binary preload-file format.
    pub fn read_from(r: &mut impl Read) -> anyhow::Result<Preload> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("preload header")?;
        ensure!(&magic == MAGIC, "bad preload magic {magic:?}");
        let mut n4 = [0u8; 4];
        r.read_exact(&mut n4)?;
        let channels = u32::from_le_bytes(n4) as usize;
        ensure!(channels <= 4096, "implausible channel count {channels}");
        let mut images = Vec::with_capacity(channels);
        for _ in 0..channels {
            let mut n8 = [0u8; 8];
            r.read_exact(&mut n8)?;
            let len = u64::from_le_bytes(n8) as usize;
            let mut img = vec![0u8; len];
            r.read_exact(&mut img)?;
            images.push(img);
        }
        Ok(Preload { images })
    }

    /// Save to a file path.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        self.write_to(&mut f)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Preload> {
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        Preload::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::MatrixLayout;
    use crate::util::rng::Rng;

    #[test]
    fn scatter_gather_roundtrip_base() {
        let l = MatrixLayout::base(16, 16, 4, 0);
        let data = Rng::new(1).f32_vec(256);
        let mut p = Preload::new(1);
        p.scatter_f32(&l, &data);
        assert_eq!(p.gather_f32(&l), data);
    }

    #[test]
    fn scatter_gather_roundtrip_distributed() {
        let l = MatrixLayout::optimized(32, 32, 4, (4, 4), (8, 8), 5);
        let data = Rng::new(2).f32_vec(32 * 32);
        let mut p = Preload::new(5);
        p.scatter_f32(&l, &data);
        assert_eq!(p.gather_f32(&l), data);
    }

    #[test]
    fn two_matrices_share_channels_without_overlap_when_offset() {
        // A in channels 0..2, B in channels 2..4 (disjoint Single/RR sets).
        let la = MatrixLayout {
            channels: crate::layout::ChannelAssign::RoundRobin { first: 0, count: 2 },
            ..MatrixLayout::optimized(16, 16, 4, (2, 2), (8, 8), 2)
        };
        let lb = MatrixLayout {
            channels: crate::layout::ChannelAssign::RoundRobin { first: 2, count: 2 },
            ..MatrixLayout::optimized(16, 16, 4, (2, 2), (8, 8), 2)
        };
        let da = Rng::new(3).f32_vec(256);
        let db = Rng::new(4).f32_vec(256);
        let mut p = Preload::new(4);
        p.scatter_f32(&la, &da);
        p.scatter_f32(&lb, &db);
        assert_eq!(p.gather_f32(&la), da);
        assert_eq!(p.gather_f32(&lb), db);
    }

    #[test]
    fn file_roundtrip() {
        let l = MatrixLayout::optimized(16, 16, 4, (2, 2), (4, 4), 3);
        let mut p = Preload::new(3);
        p.scatter_f32(&l, &Rng::new(5).f32_vec(256));
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        let q = Preload::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn bad_magic_rejected() {
        let buf = b"NOTMAGIC\x00\x00\x00\x00".to_vec();
        assert!(Preload::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let l = MatrixLayout::base(8, 8, 4, 0);
        let mut p = Preload::new(1);
        p.scatter_f32(&l, &vec![1.0; 64]);
        let mut buf = Vec::new();
        p.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(Preload::read_from(&mut buf.as_slice()).is_err());
    }
}

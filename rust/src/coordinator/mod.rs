//! The deployment coordinator: DiT's end-to-end driver.
//!
//! Ties the stages of the paper's workflow (Fig. 4) together:
//!
//! * [`deploy`] — schedule → validated per-PE programs (performance
//!   element width) — the "Generate and Optimize" stage;
//! * [`deploy_functional`] — the same at f32 for numerical runs;
//! * [`verify`] — functional execution vs the PJRT golden GEMM (the
//!   "Benchmark … compares results against reference outputs" stage);
//! * [`autotune`] — "we iterate through our predefined schedule
//!   candidates, guided by the insights above, to automatically select
//!   the kernel achieving the best performance" (§4.1.4);
//! * [`engine`] — the parallel, batched, memoizing autotuner built on the
//!   same primitives ([`engine::Engine::tune_workload`] tunes a whole
//!   named GEMM suite, bit-identical to the serial path);
//! * [`cache`] — the persistent half of that memo-cache: a versioned
//!   on-disk `(arch fingerprint, shape, schedule) → RunStats` store, so
//!   interrupted or refined tuning sweeps resume instead of
//!   re-simulating ([`engine::Engine::with_cache`]), shardable for
//!   concurrent serving ([`engine::Engine::with_sharded_cache`]);
//! * [`shapedb`] — the serving layer on top of the engine: shape
//!   canonicalization + bucketing, analytic-ε-bounded nearest-neighbor
//!   schedule reuse, an asynchronous retune queue, and deterministic
//!   replayable request traces ([`shapedb::ScheduleServer`]).

pub mod cache;
pub mod engine;
pub mod shapedb;

use anyhow::Result;

use crate::arch::{ArchConfig, GemmShape};
use crate::codegen::generate;
pub use crate::ir::Deployment;
use crate::schedule::{candidates, Schedule};
use crate::sim::{simulate_in, RunStats, SimArena};

/// Lower a schedule for performance simulation (arch element width).
pub fn deploy(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> Result<Deployment> {
    generate(arch, shape, sched, arch.elem_bytes)
}

/// Lower a schedule for functional (f32) execution.
pub fn deploy_functional(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
) -> Result<Deployment> {
    generate(arch, shape, sched, 4)
}

/// Deploy with automatic output chunking: if the schedule's per-tile
/// working set exceeds L1 (huge shapes like 16384×32768), the problem is
/// split into `chunks` column slices executed back-to-back — the same
/// multi-pass strategy a real deployment uses when an output tile cannot
/// stay resident. Returns one deployment per chunk.
pub fn deploy_chunked(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
) -> Result<Vec<Deployment>> {
    let l1 = arch.tile.l1_bytes as u64;
    if crate::schedule::l1_estimate(arch, shape, sched) <= l1 {
        return Ok(vec![deploy(arch, shape, sched)?]);
    }
    let Some((chunks, tuned)) = chunking_for(arch, shape, sched) else {
        anyhow::bail!("no chunking makes {} fit L1 for {}", shape, sched.name())
    };
    let chunk_n = shape.n.div_ceil(chunks);
    let mut deps = Vec::with_capacity(chunks);
    let mut remaining = shape.n;
    while remaining > 0 {
        let n = remaining.min(chunk_n);
        deps.push(deploy(arch, GemmShape::new(shape.m, n, shape.k), &tuned)?);
        remaining -= n;
    }
    Ok(deps)
}

/// The chunking [`deploy_chunked`] would pick for an over-L1 working set:
/// `(chunks, retuned schedule)`, or `None` if no column split in the
/// ladder fits. Shared with [`crate::perfmodel::analytic`] so the analytic
/// latency estimate models exactly the multi-pass deployment the
/// simulator would run. Chooses the chunking whose re-derived K-panel
/// depth is largest (the matrix-engine fill efficiency grows with tk),
/// breaking ties toward fewer chunks (less A re-fetch traffic).
pub fn chunking_for(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
) -> Option<(usize, Schedule)> {
    let l1 = arch.tile.l1_bytes as u64;
    let mut best: Option<(usize, usize, Schedule)> = None; // (chunks, tk, sched)
    for chunks in [2usize, 4, 8, 16, 32, 64] {
        let chunk_n = shape.n.div_ceil(chunks);
        let chunk_shape = GemmShape::new(shape.m, chunk_n, shape.k);
        let tuned = crate::schedule::retune_tk(arch, chunk_shape, sched);
        if crate::schedule::l1_estimate(arch, chunk_shape, &tuned) <= l1
            && best.as_ref().map(|(_, tk, _)| tuned.tk > *tk).unwrap_or(true)
        {
            best = Some((chunks, tuned.tk, tuned));
        }
    }
    best.map(|(chunks, _, tuned)| (chunks, tuned))
}

/// Simulate a (possibly chunked) deployment: chunks execute sequentially,
/// so makespans add and traffic accumulates.
pub fn simulate_chunked(arch: &ArchConfig, deps: &[Deployment]) -> Result<RunStats> {
    simulate_chunked_in(arch, deps, &mut SimArena::new())
}

/// [`simulate_chunked`] reusing the caller's [`SimArena`] — the hot path
/// for tuning loops that simulate thousands of deployments.
pub fn simulate_chunked_in(
    arch: &ArchConfig,
    deps: &[Deployment],
    arena: &mut SimArena,
) -> Result<RunStats> {
    anyhow::ensure!(!deps.is_empty(), "no deployments");
    let mut acc: Option<RunStats> = None;
    for dep in deps {
        let s = simulate_in(arch, dep, arena)?;
        acc = Some(match acc {
            None => s,
            Some(mut a) => {
                a.makespan_ns += s.makespan_ns;
                a.useful_flops += s.useful_flops;
                a.total_flops += s.total_flops;
                a.hbm_read_bytes += s.hbm_read_bytes;
                a.hbm_write_bytes += s.hbm_write_bytes;
                a.noc_link_bytes += s.noc_link_bytes;
                a.compute_busy_ns += s.compute_busy_ns;
                a.supersteps += s.supersteps;
                let base = a.step_end_ns.last().copied().unwrap_or(0.0);
                a.step_end_ns.extend(s.step_end_ns.iter().map(|t| t + base));
                a
            }
        });
    }
    Ok(acc.unwrap())
}

/// Deploy (chunking if needed) and simulate in one call — what the paper-
/// figure benches use.
pub fn simulate_schedule(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
) -> Result<RunStats> {
    simulate_schedule_in(arch, shape, sched, &mut SimArena::new())
}

/// [`simulate_schedule`] reusing the caller's [`SimArena`]: identical
/// output, no per-call allocation of the simulator's resource tables.
pub fn simulate_schedule_in(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
    arena: &mut SimArena,
) -> Result<RunStats> {
    let deps = deploy_chunked(arch, shape, sched)?;
    simulate_chunked_in(arch, &deps, arena)
}

/// One scored autotuning candidate.
#[derive(Debug, Clone)]
pub struct Scored {
    pub schedule: Schedule,
    pub stats: RunStats,
}

/// Autotuning outcome: candidates ranked by simulated makespan.
#[derive(Debug, Clone)]
pub struct AutotuneResult {
    /// All scored candidates, best first.
    pub ranking: Vec<Scored>,
}

impl AutotuneResult {
    pub fn best(&self) -> &Scored {
        &self.ranking[0]
    }
}

/// Enumerate, lower, simulate and rank every candidate schedule.
/// Candidates that fail to lower (e.g. L1 overflow on an exotic shape) are
/// skipped — the tuner only returns deployable schedules.
pub fn autotune(arch: &ArchConfig, shape: GemmShape) -> Result<AutotuneResult> {
    let mut ranking = Vec::new();
    let mut arena = SimArena::new(); // one arena across the candidate scan
    for sched in candidates(arch, shape) {
        let Ok(stats) = simulate_schedule_in(arch, shape, &sched, &mut arena) else { continue };
        ranking.push(Scored { schedule: sched, stats });
    }
    anyhow::ensure!(!ranking.is_empty(), "no deployable schedule candidate for {shape}");
    ranking.sort_by(|a, b| a.stats.makespan_ns.total_cmp(&b.stats.makespan_ns));
    Ok(AutotuneResult { ranking })
}

/// Numerical verification report.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub shape: GemmShape,
    pub schedule: String,
    pub max_abs_diff: f32,
    pub tolerance: f32,
}

impl VerifyReport {
    pub fn passed(&self) -> bool {
        self.max_abs_diff <= self.tolerance
    }
}

/// Functionally execute a schedule and compare against the PJRT golden
/// GEMM (the JAX/Pallas artifact). Requires `make artifacts`.
pub fn verify(
    arch: &ArchConfig,
    shape: GemmShape,
    sched: &Schedule,
    oracle: &mut crate::runtime::Oracle,
    seed: u64,
) -> Result<VerifyReport> {
    let dep = deploy_functional(arch, shape, sched)?;
    let mut rng = crate::util::rng::Rng::new(seed);
    let a = rng.f32_vec(shape.m * shape.k);
    let b = rng.f32_vec(shape.k * shape.n);
    let got = crate::functional::run_gemm(arch, &dep, &a, &b)?;
    let want = oracle.gemm(shape.m, shape.n, shape.k, &a, &b)?;
    let diff = crate::functional::max_abs_diff(&got, &want);
    // f32 accumulation-order tolerance, scaled with K.
    let tolerance = 1e-5 * (shape.k as f32).sqrt().max(1.0) * 8.0;
    Ok(VerifyReport {
        shape,
        schedule: sched.name(),
        max_abs_diff: diff,
        tolerance,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Dataflow;

    #[test]
    fn autotune_ranks_candidates() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let result = autotune(&arch, shape).unwrap();
        assert!(result.ranking.len() >= 4);
        // Ranking is sorted.
        for w in result.ranking.windows(2) {
            assert!(w[0].stats.makespan_ns <= w[1].stats.makespan_ns);
        }
        // The naive base-layout baseline never wins.
        let best = result.best();
        assert!(
            !(best.schedule.dataflow == Dataflow::Baseline && !best.schedule.opt_layout),
            "baseline won autotuning: {}",
            best.schedule.name()
        );
    }

    #[test]
    fn autotune_prefers_remap_for_flat_gemm() {
        // Insight 4: flat GEMM wants cluster remapping + 3D tiling.
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(16, 512, 512);
        let result = autotune(&arch, shape).unwrap();
        let best = result.best();
        let flat_wins = best.schedule.logical.0 == 1
            || matches!(best.schedule.dataflow, Dataflow::SplitKSumma { .. });
        assert!(flat_wins, "best for flat was {}", best.schedule.name());
    }
}

//! The parallel, batched autotuning engine.
//!
//! `coordinator::autotune` evaluates one shape's candidates serially; this
//! module is the production-scale substrate on top of the same primitives:
//!
//! * **parallel** — candidate simulations run concurrently on a
//!   `std::thread` worker pool (everything on the hot path is plain data,
//!   so `ArchConfig`/`GemmShape`/`Schedule`/`Deployment`/`RunStats` are
//!   all `Send + Sync` — asserted at compile time below);
//! * **memoized** — results are cached under
//!   `(architecture fingerprint, shape, schedule)`, so repeated shapes in
//!   a workload (decode traffic repeats the same GEMMs every step) and
//!   repeated tuning runs cost zero new simulations;
//! * **batched** — [`Engine::tune_workload`] tunes a whole named suite
//!   ([`Workload`], e.g. a transformer layer's prefill + decode GEMMs)
//!   and returns per-shape best schedules plus an aggregate report.
//!
//! Results are **bit-identical** to the serial path: jobs are planned and
//! merged in candidate-enumeration order (worker completion order never
//! influences output), the simulator itself is deterministic, and the
//! final ranking uses the same stable sort as `autotune`.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::cache::{DiskCache, DiskKey, ShardedDiskCache};
use super::{simulate_schedule_in, AutotuneResult, Scored};
use crate::arch::workload::Workload;
use crate::arch::{ArchConfig, GemmShape};
use crate::graph::{OpKind, WorkloadGraph};
use crate::ir::Deployment;
use crate::schedule::{candidates, l1_estimate, Schedule};
use crate::sim::{RunStats, SimArena};

// The worker pool shares these across threads by reference; if a future
// refactor makes any of them thread-unsafe this fails to compile.
#[allow(dead_code)]
fn _assert_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<ArchConfig>();
    check::<GemmShape>();
    check::<Schedule>();
    check::<Deployment>();
    check::<RunStats>();
}

/// Stable fingerprint of an architecture: FNV-1a over its canonical
/// config text — the cache-key component that keeps results from
/// different SoftHier instances apart.
///
/// This used to hash with `DefaultHasher`, whose algorithm is explicitly
/// unspecified across Rust versions; that was harmless for the in-memory
/// memo-cache but a landmine for the persistent cache
/// ([`crate::coordinator::cache`]), where an on-disk key that drifts with
/// the toolchain silently invalidates every stored entry. FNV-1a is
/// pinned by specification ([`crate::util::fnv1a64`]), so fingerprints
/// are identical across Rust versions, platforms, and process runs.
pub fn arch_fingerprint(arch: &ArchConfig) -> u64 {
    crate::util::fnv1a64(arch.to_text().as_bytes())
}

/// Simulation memo-cache key.
#[derive(Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    arch_fp: u64,
    shape: GemmShape,
    sched: Schedule,
}

/// How the engine spends its simulation budget per shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TunePolicy {
    /// Simulate every candidate (the original behavior; the default).
    Exhaustive,
    /// Rank the candidate space with the closed-form model
    /// ([`crate::perfmodel::analytic`]) and simulate only the analytic
    /// top `top_k` plus `explore` deterministically-drawn extras from the
    /// remainder — the exploration band that keeps the tuner honest when
    /// the model misranks. Falls back to exhaustive for a shape when the
    /// analytic spread is too flat to trust (relative spread below
    /// [`FLAT_SPREAD`]) or when `top_k + explore` already covers the
    /// candidate set.
    Tiered { top_k: usize, explore: usize },
}

impl Default for TunePolicy {
    fn default() -> Self {
        TunePolicy::Exhaustive
    }
}

/// Default analytic head size for [`TunePolicy::Tiered`].
pub const DEFAULT_TOP_K: usize = 4;
/// Default exploration-band size for [`TunePolicy::Tiered`].
pub const DEFAULT_EXPLORE: usize = 2;
/// Relative analytic spread below which tiering falls back to exhaustive:
/// when every candidate is priced within 5% of the best, ranking noise
/// would dominate the selection.
pub const FLAT_SPREAD: f64 = 0.05;

impl TunePolicy {
    /// The tiered policy at its default knob settings.
    pub fn tiered_default() -> TunePolicy {
        TunePolicy::Tiered { top_k: DEFAULT_TOP_K, explore: DEFAULT_EXPLORE }
    }
}

/// One shape's candidate selection under the engine's policy.
struct Selection {
    /// Candidates to simulate and rank, in enumeration order.
    cands: Vec<Schedule>,
    /// Size of the full candidate enumeration.
    total: usize,
    /// Analytic estimates computed while selecting.
    rank_calls: usize,
}

/// Per-shape tuning outcome inside a workload report.
#[derive(Debug, Clone)]
pub struct ShapeResult {
    pub label: String,
    pub shape: GemmShape,
    pub count: usize,
    pub result: AutotuneResult,
}

/// Aggregate outcome of one [`Engine::tune_workload`] call.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub workload: String,
    pub arch: String,
    pub shapes: Vec<ShapeResult>,
    /// Simulations actually executed during this call.
    pub sim_calls: usize,
    /// Candidate evaluations served from the in-memory memo-cache (or
    /// deduplicated against an identical in-flight candidate) during this
    /// call.
    pub cache_hits: usize,
    /// Candidate evaluations served from the persistent on-disk cache
    /// ([`Engine::with_cache`]) during this call. Zero when no cache is
    /// attached.
    pub disk_hits: usize,
    /// Candidate simulations skipped by the tiering filter during this
    /// call ([`TunePolicy::Tiered`]) — counted against the full candidate
    /// enumeration, before any cache is consulted. Zero under
    /// [`TunePolicy::Exhaustive`].
    pub sims_saved: usize,
    /// Candidates rejected by the static checker
    /// ([`crate::analysis::check_schedule`]) before simulating during
    /// this call. Rejected candidates are cached as undeployable (the
    /// same negative-cache entry a failed simulation produces), so the
    /// ranking is bit-identical to an ungated run. Always zero for
    /// candidates produced by [`crate::schedule::candidates`], which
    /// pre-filters — nonzero only for externally supplied schedules.
    pub statically_rejected: usize,
    /// Closed-form latency estimates computed while ranking candidates
    /// during this call. Zero under [`TunePolicy::Exhaustive`].
    pub analytic_rank_calls: usize,
    /// Worker threads used for this call.
    pub workers: usize,
    /// Wall-clock tuning time, milliseconds.
    pub elapsed_ms: f64,
}

impl WorkloadReport {
    /// Simulated time for one workload pass: Σ count × best makespan.
    pub fn total_time_ns(&self) -> f64 {
        self.shapes
            .iter()
            .map(|s| s.count as f64 * s.result.best().stats.makespan_ns)
            .sum()
    }

    /// Useful FLOPs for one workload pass (counts applied).
    pub fn total_flops(&self) -> f64 {
        self.shapes.iter().map(|s| s.count as f64 * s.shape.flops()).sum()
    }

    /// Count-weighted aggregate throughput, TFLOP/s.
    pub fn aggregate_tflops(&self) -> f64 {
        let t = self.total_time_ns();
        if t <= 0.0 {
            return 0.0;
        }
        self.total_flops() / t / 1e3
    }

    /// Total GEMM executions per pass (counts applied).
    pub fn total_count(&self) -> usize {
        self.shapes.iter().map(|s| s.count).sum()
    }
}

/// Per-edge fusion outcome inside a [`GraphReport`].
#[derive(Debug, Clone)]
pub struct EdgeReport {
    /// Intermediate tensor name (e.g. `scores`).
    pub tensor: String,
    /// Producer / consumer op labels.
    pub from: String,
    pub to: String,
    /// Intermediate size at the architecture's element width.
    pub tensor_bytes: u64,
    /// Per-tile SPM share a resident intermediate occupies.
    pub share_bytes: u64,
    /// Whether the intermediate stays on-fabric
    /// ([`crate::graph::edge_is_resident`] under the tuned working sets).
    pub resident: bool,
    /// HBM bytes one pass saves by keeping it resident (zero if spilled).
    pub saved_hbm_bytes: u64,
}

/// Aggregate outcome of one [`Engine::tune_graph`] call: the per-GEMM
/// tuning report (identical — schedules, cache keys, stats — to tuning
/// the graph's edge-free lowering) plus the per-edge SPM-residency
/// classification and its HBM traffic accounting.
#[derive(Debug, Clone)]
pub struct GraphReport {
    pub graph: String,
    pub arch: String,
    /// The underlying per-GEMM tuning report (GEMM ops in graph order).
    pub report: WorkloadReport,
    pub edges: Vec<EdgeReport>,
    /// Measured HBM bytes of one pass with every edge spilled — the
    /// edge-free lowering: Σ count × (hbm_read + hbm_write) over each
    /// op's best schedule.
    pub unfused_hbm_bytes: u64,
    /// HBM bytes of one pass after resident edges skip the intermediate
    /// store + reload.
    pub fused_hbm_bytes: u64,
}

impl GraphReport {
    /// HBM bytes one fused pass saves vs the edge-free lowering.
    pub fn saved_hbm_bytes(&self) -> u64 {
        self.unfused_hbm_bytes - self.fused_hbm_bytes
    }

    /// Fraction of unfused traffic eliminated, in percent.
    pub fn saved_pct(&self) -> f64 {
        if self.unfused_hbm_bytes == 0 {
            return 0.0;
        }
        self.saved_hbm_bytes() as f64 / self.unfused_hbm_bytes as f64 * 100.0
    }

    pub fn resident_edges(&self) -> usize {
        self.edges.iter().filter(|e| e.resident).count()
    }

    /// Intermediate tensors that still round-trip through HBM (spilled
    /// edges), by name. A resident edge never appears here.
    pub fn hbm_transfers(&self) -> Vec<&str> {
        self.edges.iter().filter(|e| !e.resident).map(|e| e.tensor.as_str()).collect()
    }
}

/// The engine's persistent second level: one single-writer cache file
/// ([`Engine::with_cache`]), or a sharded directory whose per-shard
/// locks let concurrent tuning calls and a background retune writer
/// proceed without serializing on one file lock
/// ([`Engine::with_sharded_cache`], used by the serving layer in
/// [`crate::coordinator::shapedb`]). Both variants speak the same
/// `dit-sim-cache` v1 entry format and identical keys.
enum DiskBackend {
    Single(Mutex<DiskCache>),
    Sharded(ShardedDiskCache),
}

impl DiskBackend {
    fn get(&self, key: &DiskKey) -> Option<Option<RunStats>> {
        match self {
            DiskBackend::Single(d) => d.lock().unwrap().get(key).cloned(),
            DiskBackend::Sharded(s) => s.get(key),
        }
    }

    fn insert_deferred(&self, key: DiskKey, stats: Option<RunStats>) {
        match self {
            DiskBackend::Single(d) => d.lock().unwrap().insert_deferred(key, stats),
            DiskBackend::Sharded(s) => s.insert_deferred(key, stats),
        }
    }

    fn flush(&self) -> Result<()> {
        match self {
            DiskBackend::Single(d) => d.lock().unwrap().flush(),
            DiskBackend::Sharded(s) => s.flush(),
        }
    }

    /// Poison-tolerant (called from the engine's drop): a shard whose
    /// lock was poisoned by a panicking thread is skipped rather than
    /// double-panicking — worst case that shard just stays un-compacted.
    fn compact(&self) -> Result<()> {
        match self {
            DiskBackend::Single(d) => match d.lock() {
                Ok(mut d) => d.compact(),
                Err(_) => Ok(()),
            },
            DiskBackend::Sharded(s) => s.compact(),
        }
    }

    fn len(&self) -> usize {
        match self {
            DiskBackend::Single(d) => d.lock().unwrap().len(),
            DiskBackend::Sharded(s) => s.len(),
        }
    }

    fn loaded(&self) -> usize {
        match self {
            DiskBackend::Single(d) => d.lock().unwrap().loaded(),
            DiskBackend::Sharded(s) => s.loaded(),
        }
    }

    fn deployable_shapes_for(&self, arch_fp: u64) -> Vec<String> {
        match self {
            DiskBackend::Single(d) => d.lock().unwrap().deployable_shapes_for(arch_fp),
            DiskBackend::Sharded(s) => s.deployable_shapes_for(arch_fp),
        }
    }
}

/// The tuning engine: one architecture, a worker pool, a memo-cache —
/// and, optionally, a persistent on-disk cache behind it
/// ([`Engine::with_cache`] / [`Engine::with_sharded_cache`]).
pub struct Engine {
    arch: ArchConfig,
    arch_fp: u64,
    workers: usize,
    policy: TunePolicy,
    cache: Mutex<HashMap<CacheKey, Option<RunStats>>>,
    /// Persistent second-level cache. Lock order: `cache` before any
    /// disk/shard lock (both phase 1 and phase 3 follow it), never the
    /// reverse.
    disk: Option<DiskBackend>,
    sim_calls: AtomicUsize,
    cache_hits: AtomicUsize,
    disk_hits: AtomicUsize,
    sims_saved: AtomicUsize,
    analytic_rank_calls: AtomicUsize,
    static_rejects: AtomicUsize,
}

impl Engine {
    /// Engine for an architecture with a default worker pool: one worker
    /// per available core, clamped to [2, 16] so tuning is parallel even
    /// on constrained CI machines.
    pub fn new(arch: &ArchConfig) -> Engine {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Engine {
            arch: arch.clone(),
            arch_fp: arch_fingerprint(arch),
            workers: workers.clamp(2, 16),
            policy: TunePolicy::Exhaustive,
            cache: Mutex::new(HashMap::new()),
            disk: None,
            sim_calls: AtomicUsize::new(0),
            cache_hits: AtomicUsize::new(0),
            disk_hits: AtomicUsize::new(0),
            sims_saved: AtomicUsize::new(0),
            analytic_rank_calls: AtomicUsize::new(0),
            static_rejects: AtomicUsize::new(0),
        }
    }

    /// Override the worker-pool size (minimum 1).
    pub fn with_workers(mut self, n: usize) -> Engine {
        self.workers = n.max(1);
        self
    }

    /// Set the tuning policy ([`TunePolicy::Exhaustive`] by default).
    /// Tiering changes only *which* candidates are simulated — cache keys,
    /// enumeration order, and the ranking sort are untouched, so tiered
    /// and exhaustive runs share memo- and disk-cache entries freely.
    pub fn with_policy(mut self, policy: TunePolicy) -> Engine {
        self.policy = policy;
        self
    }

    /// Attach a persistent simulation cache at `path`
    /// ([`crate::coordinator::cache`]): existing entries are loaded now
    /// and consulted before simulating; new results are checkpointed at
    /// the end of every tuning call (an atomic full write first, cheap
    /// appends after, compaction on drop), so a killed run resumes from
    /// its last checkpoint. A missing file is a normal cold start; a
    /// corrupt one degrades to (partial) cold start with a warning on
    /// stderr — attaching never fails.
    pub fn with_cache(mut self, path: impl Into<std::path::PathBuf>) -> Engine {
        let disk = DiskCache::open(path);
        for w in disk.warnings() {
            eprintln!("warning: simulation cache: {w}");
        }
        self.disk = Some(DiskBackend::Single(Mutex::new(disk)));
        self
    }

    /// Attach a *sharded* persistent cache: a directory of per-shard
    /// JSONL files ([`crate::coordinator::cache::ShardedDiskCache`]),
    /// each behind its own lock, so concurrent tuning calls and the
    /// serving layer's background retune writer don't serialize on one
    /// file. Same key grammar, entry format, checkpoint-per-call, and
    /// compact-on-drop semantics as [`Engine::with_cache`]. `shards`
    /// must match the directory's original shard count
    /// ([`crate::coordinator::cache::DEFAULT_SHARDS`] everywhere
    /// in-repo); minimum 1.
    pub fn with_sharded_cache(
        mut self,
        dir: impl Into<std::path::PathBuf>,
        shards: usize,
    ) -> Engine {
        let disk = ShardedDiskCache::open_with(dir, shards);
        for w in disk.warnings() {
            eprintln!("warning: simulation cache: {w}");
        }
        self.disk = Some(DiskBackend::Sharded(disk));
        self
    }

    /// Configured worker-pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total simulations executed over the engine's lifetime.
    pub fn sim_calls(&self) -> usize {
        self.sim_calls.load(Ordering::Relaxed)
    }

    /// Total in-memory cache hits over the engine's lifetime.
    pub fn cache_hits(&self) -> usize {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Total on-disk cache hits over the engine's lifetime (0 without
    /// [`Engine::with_cache`]).
    pub fn disk_hits(&self) -> usize {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// The engine's tuning policy.
    pub fn policy(&self) -> TunePolicy {
        self.policy
    }

    /// Total candidate simulations skipped by tiering over the engine's
    /// lifetime (0 under [`TunePolicy::Exhaustive`]).
    pub fn sims_saved(&self) -> usize {
        self.sims_saved.load(Ordering::Relaxed)
    }

    /// Total closed-form ranking estimates over the engine's lifetime.
    pub fn analytic_rank_calls(&self) -> usize {
        self.analytic_rank_calls.load(Ordering::Relaxed)
    }

    /// Total candidates the static checker rejected before simulation
    /// over the engine's lifetime ([`crate::analysis::check_schedule`]).
    pub fn statically_rejected(&self) -> usize {
        self.static_rejects.load(Ordering::Relaxed)
    }

    /// Cached simulation entries currently held in memory.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Is a persistent cache attached?
    pub fn has_disk_cache(&self) -> bool {
        self.disk.is_some()
    }

    /// Entries currently held by the attached persistent cache.
    pub fn disk_len(&self) -> usize {
        self.disk.as_ref().map(DiskBackend::len).unwrap_or(0)
    }

    /// Entries the attached persistent cache loaded from disk at open.
    pub fn disk_loaded(&self) -> usize {
        self.disk.as_ref().map(DiskBackend::loaded).unwrap_or(0)
    }

    /// Persist the attached cache now (no-op without one, or with nothing
    /// new to write). Called automatically at the end of every tuning
    /// call and on drop; exposed for callers that want the error.
    pub fn flush_cache(&self) -> Result<()> {
        if let Some(disk) = &self.disk {
            disk.flush()?;
        }
        Ok(())
    }

    /// Distinct shapes the attached persistent cache holds for *this*
    /// engine's architecture with at least one deployable schedule, in
    /// deterministic `(m, n, k)` order. Empty without a cache. The
    /// schedule server ([`crate::coordinator::shapedb`]) rebuilds its
    /// shape database from exactly this list at open — every shape here
    /// re-tunes without simulating (its selected candidates are all on
    /// disk, and candidate selection is cache-independent).
    pub fn cached_shapes(&self) -> Vec<GemmShape> {
        let Some(disk) = &self.disk else {
            return Vec::new();
        };
        let mut shapes: Vec<GemmShape> = disk
            .deployable_shapes_for(self.arch_fp)
            .iter()
            .filter_map(|s| GemmShape::parse(s).ok())
            .collect();
        shapes.sort_by_key(|s| (s.m, s.n, s.k));
        shapes.dedup();
        shapes
    }

    /// Parallel, memoized autotune of a single shape. Bit-identical to
    /// `coordinator::autotune` on the same architecture and shape.
    pub fn tune(&self, shape: GemmShape) -> Result<AutotuneResult> {
        let w = Workload::single("adhoc", shape);
        let mut rep = self.tune_workload(&w)?;
        Ok(rep.shapes.remove(0).result)
    }

    /// Tune every GEMM in a workload on the engine's default architecture.
    pub fn tune_workload(&self, w: &Workload) -> Result<WorkloadReport> {
        self.tune_on(&self.arch, self.arch_fp, w)
    }

    /// Tune a workload on an *arbitrary* architecture, sharing this
    /// engine's memo-cache and counters: the cache key includes the
    /// architecture fingerprint, so a hardware design-space sweep reuses
    /// one engine (and every simulation it has ever run) across candidate
    /// configs. Safe to call concurrently from several threads — the DSE
    /// sweep parallelizes at the config level on top of this.
    pub fn tune_workload_on(&self, arch: &ArchConfig, w: &Workload) -> Result<WorkloadReport> {
        let fp =
            if *arch == self.arch { self.arch_fp } else { arch_fingerprint(arch) };
        self.tune_on(arch, fp, w)
    }

    /// Tune a multi-op workload graph: tune every GEMM op exactly as the
    /// edge-free lowering would (same candidate selection, same cache
    /// keys, bit-identical schedules), then classify each edge as
    /// SPM-resident or spilled under the *tuned* working sets and account
    /// the HBM store + reload each resident intermediate skips.
    ///
    /// Co-tuning note: candidate selection is per-op, but residency is
    /// judged against the winning schedules' actual L1 footprints
    /// ([`crate::schedule::l1_estimate`]) on both endpoints — the shared
    /// rule in [`crate::graph::edge_is_resident`], which
    /// `perfmodel::analytic`'s chain estimate and the static checker's
    /// graph pass apply identically.
    pub fn tune_graph(&self, g: &WorkloadGraph) -> Result<GraphReport> {
        g.validate()?;
        let w = g.to_workload();
        let report = self.tune_workload(&w)?;
        let arch = &self.arch;

        // `to_workload` emits GEMM ops in graph order, so the k-th GEMM
        // op maps to the k-th shape result.
        let mut shape_idx: HashMap<usize, usize> = HashMap::new();
        for op in &g.ops {
            if matches!(op.kind, OpKind::Gemm(_)) {
                let next = shape_idx.len();
                shape_idx.insert(op.id.0, next);
            }
        }
        let mut tuned_need = |op: &crate::graph::GraphOp, shape: GemmShape| -> u64 {
            let best = &report.shapes[shape_idx[&op.id.0]].result.best().schedule;
            l1_estimate(arch, shape, best)
        };

        let mut edges = Vec::with_capacity(g.edges.len());
        for e in &g.edges {
            let share = crate::graph::tensor_share_bytes(arch, &e.tensor);
            let need_from = crate::graph::op_need_bytes(arch, g, g.op(e.from), &mut tuned_need);
            let need_to = crate::graph::op_need_bytes(arch, g, g.op(e.to), &mut tuned_need);
            let resident = crate::graph::edge_is_resident(arch, share, need_from, need_to);
            let saved =
                if resident { crate::graph::edge_saved_bytes(arch, g, e) } else { 0 };
            edges.push(EdgeReport {
                tensor: e.tensor.name.clone(),
                from: g.op(e.from).label.clone(),
                to: g.op(e.to).label.clone(),
                tensor_bytes: e.tensor.bytes(arch),
                share_bytes: share,
                resident,
                saved_hbm_bytes: saved,
            });
        }

        let unfused: u64 = report
            .shapes
            .iter()
            .map(|s| {
                let st = &s.result.best().stats;
                s.count as u64 * (st.hbm_read_bytes + st.hbm_write_bytes)
            })
            .sum();
        let saved: u64 = edges.iter().map(|e| e.saved_hbm_bytes).sum();
        // Saved traffic is a strict subset of measured traffic: each
        // resident edge only credits its GEMM endpoints, whose best runs
        // read the full (padded ≥ logical) A and wrote the full C.
        debug_assert!(saved <= unfused, "saved {saved} > measured {unfused}");
        Ok(GraphReport {
            graph: g.name.clone(),
            arch: arch.name.clone(),
            report,
            edges,
            unfused_hbm_bytes: unfused,
            fused_hbm_bytes: unfused.saturating_sub(saved),
        })
    }

    /// One shape's candidate selection under the engine's policy. The
    /// selection is a pure function of `(arch, shape, policy)` — it never
    /// consults the memo- or disk-cache — so a tiered run's output is
    /// deterministic regardless of what happens to be cached, and phase 4
    /// can rank exactly the selected set.
    fn select_candidates(&self, arch: &ArchConfig, arch_fp: u64, shape: GemmShape) -> Selection {
        let cands = candidates(arch, shape);
        let total = cands.len();
        let TunePolicy::Tiered { top_k, explore } = self.policy else {
            return Selection { cands, total, rank_calls: 0 };
        };
        let top_k = top_k.max(1); // a head of zero would tune nothing
        if top_k + explore >= total {
            return Selection { cands, total, rank_calls: 0 };
        }
        let est: Vec<f64> = cands
            .iter()
            .map(|s| {
                crate::perfmodel::analytic::estimate_ns(arch, shape, s)
                    .unwrap_or(f64::INFINITY)
            })
            .collect();
        // Flat-spread fallback: when the model prices every deployable
        // candidate within FLAT_SPREAD of the best, its ranking is noise —
        // simulate the whole set rather than trust it.
        let lo = est.iter().copied().filter(|v| v.is_finite()).fold(f64::INFINITY, f64::min);
        let hi =
            est.iter().copied().filter(|v| v.is_finite()).fold(f64::NEG_INFINITY, f64::max);
        if !lo.is_finite() || hi - lo < FLAT_SPREAD * lo {
            return Selection { cands, total, rank_calls: total };
        }
        // Head: the analytic top-k, ties broken by enumeration index (a
        // total, deterministic order).
        let mut order: Vec<usize> = (0..total).collect();
        order.sort_by(|&a, &b| est[a].total_cmp(&est[b]).then(a.cmp(&b)));
        let mut keep = vec![false; total];
        for &i in order.iter().take(top_k) {
            keep[i] = true;
        }
        // Exploration band: a deterministic pseudo-random draw from the
        // deployable remainder, keyed on (arch fingerprint, shape,
        // schedule key) — the same stable identifiers the disk cache uses,
        // so the band is bit-stable across runs, processes, and cache
        // states.
        let mut rest: Vec<usize> =
            order.iter().copied().skip(top_k).filter(|&i| est[i].is_finite()).collect();
        rest.sort_by_key(|&i| {
            let tag = format!("{arch_fp:016x}|{shape}|{}", cands[i].cache_key());
            (crate::util::fnv1a64(tag.as_bytes()), i)
        });
        for &i in rest.iter().take(explore) {
            keep[i] = true;
        }
        // Filtering preserves enumeration order, so downstream phases see
        // the same order exhaustive tuning would.
        let cands: Vec<Schedule> = cands
            .into_iter()
            .zip(&keep)
            .filter_map(|(s, &k)| k.then_some(s))
            .collect();
        Selection { cands, total, rank_calls: total }
    }

    /// Shared implementation: select candidates per item (all of them, or
    /// the analytic head + exploration band under [`TunePolicy::Tiered`]),
    /// simulate all selected not-yet-cached candidates on the worker pool,
    /// and assemble a per-item ranking plus aggregate statistics.
    fn tune_on(&self, arch: &ArchConfig, arch_fp: u64, w: &Workload) -> Result<WorkloadReport> {
        let t0 = std::time::Instant::now();

        struct Job {
            key: CacheKey,
            shape: GemmShape,
            sched: Schedule,
        }

        // Phase 0 — select (serial, deterministic, cache-independent):
        // fix each item's candidate set once; phases 1 and 4 both walk
        // exactly this set, so tiered output cannot depend on what an
        // earlier (possibly exhaustive) run happened to leave in a cache.
        let selections: Vec<Selection> =
            w.items.iter().map(|i| self.select_candidates(arch, arch_fp, i.shape)).collect();
        let saved_this_call: usize =
            selections.iter().map(|s| s.total - s.cands.len()).sum();
        let ranked_this_call: usize = selections.iter().map(|s| s.rank_calls).sum();
        self.sims_saved.fetch_add(saved_this_call, Ordering::Relaxed);
        self.analytic_rank_calls.fetch_add(ranked_this_call, Ordering::Relaxed);

        // Phase 1 — plan (serial, deterministic): one job per selected
        // candidate not already cached, deduplicated across repeated
        // shapes. A miss in memory falls through to the persistent cache
        // (when attached): a disk hit promotes the entry into memory, so
        // every later lookup — including phase 4's ranking assembly — sees
        // one store.
        let mut jobs: Vec<Job> = Vec::new();
        let mut hits_this_call = 0usize;
        let mut disk_hits_this_call = 0usize;
        {
            let mut cache = self.cache.lock().unwrap();
            let mut pending: HashSet<CacheKey> = HashSet::new();
            for (item, sel) in w.items.iter().zip(&selections) {
                let shape_text = item.shape.to_string();
                for sched in &sel.cands {
                    let key =
                        CacheKey { arch_fp, shape: item.shape, sched: sched.clone() };
                    if cache.contains_key(&key) || pending.contains(&key) {
                        hits_this_call += 1;
                        continue;
                    }
                    if let Some(disk) = &self.disk {
                        let dkey = DiskKey {
                            arch_fp,
                            shape: shape_text.clone(),
                            sched: sched.cache_key(),
                        };
                        // Per-key lookup: the backend takes its own file
                        // or shard lock inside (lock order: memo-cache
                        // before disk, as documented on the field).
                        if let Some(stats) = disk.get(&dkey) {
                            cache.insert(key, stats);
                            disk_hits_this_call += 1;
                            continue;
                        }
                    }
                    pending.insert(key.clone());
                    jobs.push(Job { key, shape: item.shape, sched: sched.clone() });
                }
            }
        }
        self.cache_hits.fetch_add(hits_this_call, Ordering::Relaxed);
        self.disk_hits.fetch_add(disk_hits_this_call, Ordering::Relaxed);

        // Phase 2 — evaluate: workers pull jobs off a shared index; each
        // result lands in its job's own slot, so completion order is
        // irrelevant to the merged output. Each job is first vetted by
        // the static checker: a rejected candidate is recorded as None
        // without entering the simulator — bit-identical to the ungated
        // behavior, because checker-reject ⟺ the deployment would have
        // failed to lower (the lockstep contract pinned by
        // `crate::analysis`'s tests), and a failed lowering was already
        // recorded as None. Candidates that pass the checker but fail to
        // lower for any residual reason are still recorded as None (the
        // serial path skips them identically).
        let workers = self.workers.min(jobs.len()).max(1);
        let results: Vec<Mutex<Option<Option<RunStats>>>> =
            (0..jobs.len()).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let rejected = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    // One simulation arena per worker: the resource tables
                    // and route scratch are reused across every job this
                    // thread evaluates (output is identical to a fresh
                    // arena per call — pinned by the golden tests).
                    let mut arena = SimArena::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let job = &jobs[i];
                        if crate::analysis::check_schedule(arch, job.shape, &job.sched)
                            .rejected()
                        {
                            rejected.fetch_add(1, Ordering::Relaxed);
                            *results[i].lock().unwrap() = Some(None);
                            continue;
                        }
                        let stats =
                            simulate_schedule_in(arch, job.shape, &job.sched, &mut arena).ok();
                        self.sim_calls.fetch_add(1, Ordering::Relaxed);
                        *results[i].lock().unwrap() = Some(stats);
                    }
                });
            }
        });
        let rejected_this_call = rejected.into_inner();
        self.static_rejects.fetch_add(rejected_this_call, Ordering::Relaxed);

        // Phase 3 — commit results to the cache in job (= enumeration)
        // order, mirroring every new entry (failures included — they are
        // a deliberate negative-cache) into the persistent store.
        {
            let mut cache = self.cache.lock().unwrap();
            for (job, cell) in jobs.iter().zip(&results) {
                let stats = cell.lock().unwrap().take().expect("worker completed every job");
                if let Some(disk) = &self.disk {
                    let dkey = DiskKey {
                        arch_fp,
                        shape: job.shape.to_string(),
                        sched: job.sched.cache_key(),
                    };
                    // Deferred: no auto-flush here — file I/O happens in
                    // the explicit checkpoint below, after the memo-cache
                    // lock is released.
                    disk.insert_deferred(dkey, stats.clone());
                }
                cache.insert(job.key.clone(), stats);
            }
        }
        // Checkpoint: one flush per tuning call (a DSE sweep therefore
        // persists after every evaluated config — appends after the first
        // rewrite, so sweep-total checkpoint I/O stays linear). Done
        // outside the memo-cache lock: concurrent wave configs queue
        // behind the disk lock only, never behind planning/ranking.
        // Failure only costs durability, never correctness.
        if let Some(disk) = &self.disk {
            if let Err(e) = disk.flush() {
                eprintln!("warning: simulation cache: {e:#}");
            }
        }

        // Phase 4 — assemble per-item rankings entirely from the cache,
        // walking exactly the phase-0 selection in enumeration order + the
        // same stable sort the serial autotuner uses. This is what makes
        // parallel == serial (and a tiered run independent of cache
        // history: cached-but-unselected candidates never leak into the
        // ranking), bit for bit.
        let cache = self.cache.lock().unwrap();
        let mut shapes = Vec::with_capacity(w.items.len());
        for (item, sel) in w.items.iter().zip(&selections) {
            let mut ranking = Vec::new();
            for sched in &sel.cands {
                let key = CacheKey { arch_fp, shape: item.shape, sched: sched.clone() };
                if let Some(Some(stats)) = cache.get(&key) {
                    ranking.push(Scored { schedule: key.sched, stats: stats.clone() });
                }
            }
            anyhow::ensure!(
                !ranking.is_empty(),
                "no deployable schedule candidate for {} ({})",
                item.shape,
                item.label
            );
            ranking.sort_by(|a, b| a.stats.makespan_ns.total_cmp(&b.stats.makespan_ns));
            shapes.push(ShapeResult {
                label: item.label.clone(),
                shape: item.shape,
                count: item.count,
                result: AutotuneResult { ranking },
            });
        }

        Ok(WorkloadReport {
            workload: w.name.clone(),
            arch: arch.name.clone(),
            shapes,
            sim_calls: jobs.len() - rejected_this_call,
            cache_hits: hits_this_call,
            disk_hits: disk_hits_this_call,
            sims_saved: saved_this_call,
            statically_rejected: rejected_this_call,
            analytic_rank_calls: ranked_this_call,
            workers,
            elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }
}

impl Drop for Engine {
    /// Last-chance persistence: whatever the engine learned reaches disk
    /// even when the caller never flushes explicitly, and the file is
    /// compacted to its canonical sorted image (per-call checkpoints
    /// append for cheapness — see [`DiskCache::compact`]). Errors are
    /// demoted to a warning (a drop cannot propagate them).
    fn drop(&mut self) {
        if let Some(disk) = &self.disk {
            if let Err(e) = disk.compact() {
                eprintln!("warning: simulation cache flush on drop failed: {e:#}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::autotune;

    #[test]
    fn engine_tune_matches_serial_autotune() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let engine = Engine::new(&arch).with_workers(3);
        let par = engine.tune(shape).unwrap();
        let ser = autotune(&arch, shape).unwrap();
        assert_eq!(par.ranking.len(), ser.ranking.len());
        for (p, s) in par.ranking.iter().zip(&ser.ranking) {
            assert_eq!(p.schedule, s.schedule);
            assert_eq!(p.stats.makespan_ns.to_bits(), s.stats.makespan_ns.to_bits());
        }
    }

    #[test]
    fn tiered_simulates_fewer_candidates() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let exhaustive = Engine::new(&arch).with_workers(2);
        let tiered =
            Engine::new(&arch).with_workers(2).with_policy(TunePolicy::tiered_default());
        let full = exhaustive.tune(shape).unwrap();
        let head = tiered.tune(shape).unwrap();
        assert!(
            tiered.sim_calls() < exhaustive.sim_calls(),
            "tiered {} !< exhaustive {}",
            tiered.sim_calls(),
            exhaustive.sim_calls()
        );
        assert_eq!(
            tiered.sims_saved(),
            exhaustive.sim_calls() - tiered.sim_calls(),
            "saved + simulated must cover the full candidate set"
        );
        assert!(tiered.analytic_rank_calls() >= full.ranking.len());
        assert_eq!(exhaustive.sims_saved(), 0);
        assert_eq!(exhaustive.analytic_rank_calls(), 0);
        // The tiered ranking is a subset of the exhaustive one, in the
        // same simulated order with bit-identical stats.
        let mut it = full.ranking.iter();
        for t in &head.ranking {
            let m = it
                .find(|s| s.schedule == t.schedule)
                .expect("tiered result missing from exhaustive ranking");
            assert_eq!(t.stats.makespan_ns.to_bits(), m.stats.makespan_ns.to_bits());
        }
    }

    #[test]
    fn tiered_report_counts_selection() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let engine =
            Engine::new(&arch).with_policy(TunePolicy::Tiered { top_k: 2, explore: 1 });
        let w = Workload::single("s", shape);
        let rep = engine.tune_workload(&w).unwrap();
        let total = crate::schedule::candidates(&arch, shape).len();
        assert!(rep.sims_saved > 0, "nothing saved on a {total}-candidate shape");
        assert_eq!(rep.sim_calls + rep.sims_saved, total);
        assert_eq!(rep.analytic_rank_calls, total);
        assert!(rep.shapes[0].result.ranking.len() <= 3);
    }

    #[test]
    fn fingerprint_distinguishes_architectures() {
        assert_ne!(
            arch_fingerprint(&ArchConfig::tiny(4, 4)),
            arch_fingerprint(&ArchConfig::tiny(2, 2))
        );
        assert_eq!(
            arch_fingerprint(&ArchConfig::tiny(4, 4)),
            arch_fingerprint(&ArchConfig::tiny(4, 4))
        );
    }

    #[test]
    fn fingerprint_separates_mesh_geometries_with_equal_tile_counts() {
        // 16x4, 4x16, and 8x8 instances with identical per-tile
        // parameters share a tile count but are different machines: the
        // canonical config text includes rows and cols, so their
        // fingerprints — and therefore their disk-cache keys — differ
        // even when every other field (including the name) matches.
        let mk = |rows, cols| {
            let mut a = ArchConfig::tiny(rows, cols);
            a.name = "geom".into();
            a.hbm.channels_per_edge = 4;
            a
        };
        let fps = [
            arch_fingerprint(&mk(16, 4)),
            arch_fingerprint(&mk(4, 16)),
            arch_fingerprint(&mk(8, 8)),
        ];
        assert_eq!(mk(16, 4).num_tiles(), mk(8, 8).num_tiles());
        assert_ne!(fps[0], fps[1], "transposed mesh is a different machine");
        assert_ne!(fps[0], fps[2], "rectangle must not alias its square twin");
        assert_ne!(fps[1], fps[2]);
    }

    #[test]
    fn fingerprint_is_the_specified_stable_hash() {
        // The fingerprint keys on-disk cache entries, so it must be
        // exactly FNV-1a over the canonical config text — any other
        // (unspecified) hash would invalidate persisted caches whenever
        // the toolchain changes.
        for arch in [ArchConfig::tiny(4, 4), ArchConfig::gh200_like(), ArchConfig::a100_like()] {
            assert_eq!(
                arch_fingerprint(&arch),
                crate::util::fnv1a64(arch.to_text().as_bytes()),
                "{}",
                arch.name
            );
        }
    }

    #[test]
    fn tune_workload_on_shares_cache_across_architectures() {
        let a4 = ArchConfig::tiny(4, 4);
        let a2 = ArchConfig::tiny(2, 2);
        let engine = Engine::new(&a4);
        let w = Workload::single("s", GemmShape::new(64, 64, 64));
        let r4 = engine.tune_workload_on(&a4, &w).unwrap();
        let r2 = engine.tune_workload_on(&a2, &w).unwrap();
        assert!(r4.sim_calls > 0, "first arch simulates");
        assert!(r2.sim_calls > 0, "a different arch cannot reuse the first's entries");
        assert_eq!(r2.arch, a2.name);
        // Re-tuning either architecture is now fully memoized.
        assert_eq!(engine.tune_workload_on(&a2, &w).unwrap().sim_calls, 0);
        assert_eq!(engine.tune_workload_on(&a4, &w).unwrap().sim_calls, 0);
        // The default-arch path hits the same cache entries bit for bit.
        let d = engine.tune_workload(&w).unwrap();
        assert_eq!(d.sim_calls, 0);
        assert_eq!(
            d.shapes[0].result.best().stats.makespan_ns.to_bits(),
            r4.shapes[0].result.best().stats.makespan_ns.to_bits()
        );
    }

    #[test]
    fn with_cache_resumes_across_engine_instances() {
        let path = std::env::temp_dir()
            .join(format!("dit-engine-cache-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let arch = ArchConfig::tiny(2, 2);
        let w = Workload::single("s", GemmShape::new(64, 64, 64));
        let cold = Engine::new(&arch).with_cache(&path).tune_workload(&w).unwrap();
        assert!(cold.sim_calls > 0, "cold run simulates");
        assert_eq!(cold.disk_hits, 0, "nothing on disk yet");
        assert!(path.exists(), "tuning call checkpoints to disk");
        // A brand-new engine (fresh memory cache) resumes purely from
        // disk: zero simulations, bit-identical ranking.
        let engine = Engine::new(&arch).with_cache(&path);
        assert!(engine.disk_loaded() > 0);
        let warm = engine.tune_workload(&w).unwrap();
        assert_eq!(warm.sim_calls, 0, "everything served from disk");
        assert!(warm.disk_hits > 0);
        assert_eq!(warm.disk_hits, engine.disk_hits());
        let (a, b) = (&cold.shapes[0].result, &warm.shapes[0].result);
        assert_eq!(a.ranking.len(), b.ranking.len());
        for (x, y) in a.ranking.iter().zip(&b.ranking) {
            assert_eq!(x.schedule, y.schedule);
            assert_eq!(x.stats.makespan_ns.to_bits(), y.stats.makespan_ns.to_bits());
            assert_eq!(x.stats.spm_bytes, y.stats.spm_bytes);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn with_sharded_cache_resumes_and_reports_shapes() {
        let dir = std::env::temp_dir()
            .join(format!("dit-engine-shard-cache-{}", std::process::id()));
        let _ = crate::coordinator::cache::ShardedDiskCache::clear(&dir);
        let arch = ArchConfig::tiny(2, 2);
        let shapes = [GemmShape::new(64, 64, 64), GemmShape::new(32, 64, 64)];
        {
            let engine = Engine::new(&arch).with_sharded_cache(&dir, 4);
            for s in shapes {
                assert!(engine.tune(s).is_ok());
            }
            assert!(engine.sim_calls() > 0, "cold run simulates");
        } // drop compacts every shard
        let engine = Engine::new(&arch).with_sharded_cache(&dir, 4);
        assert!(engine.disk_loaded() > 0, "shards reload");
        // The cached-shape inventory is exactly the tuned set, sorted.
        assert_eq!(engine.cached_shapes(), vec![shapes[1], shapes[0]]);
        // A different architecture sees none of them.
        let other = Engine::new(&ArchConfig::tiny(4, 4)).with_sharded_cache(&dir, 4);
        assert!(other.cached_shapes().is_empty());
        // Warm re-tune is served purely from the sharded store.
        for s in shapes {
            assert!(engine.tune(s).is_ok());
        }
        assert_eq!(engine.sim_calls(), 0, "warm run must not simulate");
        assert!(engine.disk_hits() > 0);
        drop(engine);
        drop(other);
        crate::coordinator::cache::ShardedDiskCache::clear(&dir).unwrap();
    }

    #[test]
    fn checker_gate_rejects_nothing_on_enumerated_candidates() {
        // `schedule::candidates` pre-filters to deployable schedules, so
        // the phase-2 static gate must pass every enumerated candidate —
        // the counters below pin that the gate never perturbs a normal
        // tuning run (rejection is reserved for externally supplied
        // schedules, exercised in tests/analysis.rs).
        let arch = ArchConfig::tiny(4, 4);
        let engine = Engine::new(&arch).with_workers(2);
        let w = Workload::single("s", GemmShape::new(128, 128, 256));
        let rep = engine.tune_workload(&w).unwrap();
        assert_eq!(rep.statically_rejected, 0);
        assert_eq!(engine.statically_rejected(), 0);
        assert!(rep.sim_calls > 0, "accepted candidates still simulate");
    }

    #[test]
    fn empty_workload_is_ok() {
        let arch = ArchConfig::tiny(2, 2);
        let engine = Engine::new(&arch);
        let rep = engine.tune_workload(&Workload::new("empty")).unwrap();
        assert!(rep.shapes.is_empty());
        assert_eq!(rep.sim_calls, 0);
        assert_eq!(rep.total_count(), 0);
        assert_eq!(rep.aggregate_tflops(), 0.0);
    }

    #[test]
    fn undeployable_item_reports_cleanly() {
        let arch = ArchConfig::tiny(2, 2);
        // Absurd K with tiny L1: every candidate overflows even chunked.
        let mut w = Workload::new("bad");
        w.push("huge", GemmShape::new(1 << 20, 1 << 20, 64), 1);
        let err = engine_err(&arch, &w);
        assert!(err.contains("no deployable schedule candidate"), "{err}");
    }

    fn engine_err(arch: &ArchConfig, w: &Workload) -> String {
        match Engine::new(arch).tune_workload(w) {
            Ok(_) => panic!("expected failure"),
            Err(e) => format!("{e:#}"),
        }
    }

    #[test]
    fn graph_single_gemm_is_bit_identical_to_flat_tuning() {
        // Acceptance contract: a degenerate (edge-free) single-GEMM graph
        // goes through exactly the flat path — same schedules, same cache
        // keys, same stats, bit for bit.
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(128, 128, 256);
        let flat_engine = Engine::new(&arch).with_workers(2);
        let flat = flat_engine.tune(shape).unwrap();
        let graph_engine = Engine::new(&arch).with_workers(2);
        let g = WorkloadGraph::from_workload(&Workload::single("adhoc", shape));
        let rep = graph_engine.tune_graph(&g).unwrap();
        assert!(rep.edges.is_empty());
        assert_eq!(rep.unfused_hbm_bytes, rep.fused_hbm_bytes);
        let via_graph = &rep.report.shapes[0].result;
        assert_eq!(via_graph.ranking.len(), flat.ranking.len());
        for (p, s) in via_graph.ranking.iter().zip(&flat.ranking) {
            assert_eq!(p.schedule, s.schedule);
            assert_eq!(p.schedule.cache_key(), s.schedule.cache_key());
            assert_eq!(p.stats.makespan_ns.to_bits(), s.stats.makespan_ns.to_bits());
        }
        // And the memo entries collide: re-tuning the flat workload on
        // the graph engine is pure cache hits.
        assert_eq!(graph_engine.tune(shape).unwrap().ranking.len(), flat.ranking.len());
        assert_eq!(
            graph_engine.sim_calls(),
            flat_engine.sim_calls(),
            "graph path must not add cache entries for a single GEMM"
        );
    }

    #[test]
    fn graph_fusion_saves_hbm_traffic_on_tiny_attention() {
        let arch = ArchConfig::tiny(4, 4);
        let g = WorkloadGraph::attention_prefill("attn", 64, 32, 2);
        let engine = Engine::new(&arch).with_workers(2);
        let rep = engine.tune_graph(&g).unwrap();
        // 64x64 f32 scores over 16 tiles share out to 1 KiB/tile — far
        // under the 256 KiB L1 even with both GEMM working sets, so both
        // edges stay resident.
        assert_eq!(rep.resident_edges(), 2, "edges: {:?}", rep.edges);
        assert!(rep.hbm_transfers().is_empty());
        assert!(
            rep.fused_hbm_bytes < rep.unfused_hbm_bytes,
            "fused {} !< unfused {}",
            rep.fused_hbm_bytes,
            rep.unfused_hbm_bytes
        );
        // Each edge credits exactly one GEMM endpoint (the other side is
        // softmax glue): scores skips qk's C store, probs skips av's A
        // load — 64*64*4 bytes x count 2, per edge.
        let per_edge = 64 * 64 * 4 * 2;
        assert_eq!(rep.saved_hbm_bytes(), 2 * per_edge);
        assert!(rep.saved_pct() > 0.0 && rep.saved_pct() < 100.0);
    }
}

//! The serving-scale schedule service: shape canonicalization +
//! bucketing, bounded nearest-neighbor schedule reuse, and an
//! asynchronous retune queue on top of the tuning engine.
//!
//! A production serving tier sees millions of *distinct* `(M, N, K)`
//! requests — ragged batches and variable sequence lengths perturb M
//! constantly while N and K (weight matrices) repeat exactly. Tuning
//! every distinct shape from scratch is hopeless at traffic rates;
//! serving a *neighboring* shape's schedule is nearly free and, per the
//! GOMA direction already powering the tiered tuner, its cost can be
//! *bounded analytically* before anything is served. The
//! [`ScheduleServer`] turns the tuner into a low-latency lookup service
//! with three outcomes per request:
//!
//! * **exact hit** — the canonical shape is in the database with a
//!   schedule tuned for it. Zero engine work, zero simulations.
//! * **neighbor hit** — another shape in the same bucket donates its
//!   schedule (K-depth re-derived via [`crate::schedule::retune_tk`]).
//!   Served **iff** the analytic penalty of the borrowed schedule on
//!   the true shape is at most ε relative to the analytic best for
//!   that shape — `estimate(borrowed)/min_candidate_estimate − 1 ≤ ε`
//!   — and an exact retune is enqueued so the shape upgrades to an
//!   exact entry when [`ScheduleServer::drain_retunes`] runs. No
//!   simulations on the serving path; only closed-form estimates.
//! * **miss** — no qualifying donor: the engine tunes the shape
//!   synchronously (simulating) and the result becomes an exact entry.
//!
//! ## Canonicalization and bucketing
//!
//! `C = A·B` implies `Cᵀ = Bᵀ·Aᵀ`, so `(M, N, K)` and `(N, M, K)` are
//! the same tuning problem with the roles of the output dimensions
//! swapped: requests are canonicalized to `M ≤ N` ([`canonicalize`]),
//! and the served schedule targets the canonical orientation (the
//! `swapped` flag in [`ServeResult`] tells the caller to transpose).
//! Buckets group canonical shapes that may plausibly donate to each
//! other: exact `N` and `K` (weights repeat exactly) with M rounded up
//! to the next power of two ([`m_bucket`]) — 63 and 64 share a bucket;
//! 65 does not, it buckets with 66..128. Bucketing only *scopes the
//! donor search*; the ε bound is what actually admits a schedule.
//!
//! ## Persistence and determinism
//!
//! The server's engine writes through a sharded persistent cache
//! ([`crate::coordinator::cache::ShardedDiskCache`]) so concurrent
//! serve calls and the retune writer don't serialize on one file lock.
//! On open, the database is rebuilt from the cache's deployable shapes
//! ([`crate::coordinator::engine::Engine::cached_shapes`]): each
//! re-tunes without simulating (candidate selection is
//! cache-independent and every selected candidate is on disk), so a
//! warm server answers the whole working set from exact entries and
//! re-qualified neighbors — zero simulations. Every serving decision is
//! deterministic: the database iterates in `BTreeMap` order, donor ties
//! break toward the smallest shape key, the engine is bit-identical,
//! and the replayable trace format ([`zipf_trace`], [`parse_trace`])
//! contains no run-time randomness.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::arch::{ArchConfig, GemmShape};
use crate::coordinator::engine::{Engine, TunePolicy};
use crate::perfmodel::analytic::estimate_ns;
use crate::schedule::{candidates, retune_tk, Schedule};
use crate::util::rng::Rng;

/// Default neighbor-reuse quality bound: a borrowed schedule may cost at
/// most this much more than the analytic best for the true shape.
pub const DEFAULT_EPSILON: f64 = 0.1;

/// Canonical transpose form: `(M, N, K) ≡ (N, M, K)` via `Cᵀ = Bᵀ·Aᵀ`,
/// canonicalized to `M ≤ N`. Returns the canonical shape and whether the
/// request was swapped (i.e. the served schedule targets the transposed
/// problem and the caller consumes `Cᵀ`).
pub fn canonicalize(shape: GemmShape) -> (GemmShape, bool) {
    if shape.m > shape.n {
        (GemmShape::new(shape.n, shape.m, shape.k), true)
    } else {
        (shape, false)
    }
}

/// The M-bucketing rule: round up to the next power of two, so a bucket
/// holds `(2^(b-1), 2^b]` and boundary shapes bucket with the shapes
/// most likely to donate well (63 → 64, 64 → 64, 65 → 128).
pub fn m_bucket(m: usize) -> usize {
    m.next_power_of_two()
}

/// A bucket groups canonical shapes with exact `(N, K)` and M in the
/// same power-of-two band — the donor-search scope for neighbor reuse.
pub fn bucket_key(canon: GemmShape) -> (usize, usize, usize) {
    (m_bucket(canon.m), canon.n, canon.k)
}

fn shape_key(s: GemmShape) -> (usize, usize, usize) {
    (s.m, s.n, s.k)
}

/// The analytic best over the full candidate enumeration for `shape` —
/// the denominator of the neighbor-reuse penalty. `None` when no
/// candidate is deployable (the engine would fail to tune it too).
pub fn analytic_best_ns(arch: &ArchConfig, shape: GemmShape) -> Option<f64> {
    candidates(arch, shape)
        .iter()
        .filter_map(|s| estimate_ns(arch, shape, s))
        .fold(None, |best, v| Some(best.map_or(v, |b: f64| b.min(v))))
}

/// One database entry: a schedule the server will hand out for a
/// canonical shape.
#[derive(Debug, Clone)]
pub struct DbEntry {
    /// Canonical shape this entry answers.
    pub shape: GemmShape,
    /// The schedule served (exact-tuned, or borrowed + tk-retuned).
    pub schedule: Schedule,
    /// Exact (simulated best for this very shape) vs borrowed.
    pub exact: bool,
    /// Analytic penalty vs the shape's analytic best (0 for exact).
    pub penalty: f64,
    /// Donor shape a borrowed entry came from.
    pub donor: Option<GemmShape>,
}

/// How a request was answered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeOutcome {
    /// Served a schedule tuned for exactly this canonical shape.
    Exact,
    /// Served a neighbor's schedule under the ε bound (retune enqueued
    /// the first time this shape was answered this way).
    Neighbor,
    /// No qualifying donor: tuned synchronously.
    Miss,
}

/// One request's answer.
#[derive(Debug, Clone)]
pub struct ServeResult {
    /// The shape as requested.
    pub shape: GemmShape,
    /// Its canonical transpose form (`M ≤ N`).
    pub canonical: GemmShape,
    /// The schedule targets the canonical orientation; `true` means the
    /// request arrived transposed relative to it.
    pub swapped: bool,
    pub schedule: Schedule,
    pub outcome: ServeOutcome,
    /// Analytic penalty of the served schedule vs the analytic best for
    /// the canonical shape (0 for exact entries).
    pub penalty: f64,
    /// Donor shape, when the schedule was borrowed.
    pub donor: Option<GemmShape>,
}

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Neighbor-reuse quality bound ([`DEFAULT_EPSILON`]); must be ≥ 0
    /// (0 admits only penalty-free borrows).
    pub epsilon: f64,
    /// Tuning policy for misses, retunes, and the warm rebuild. Cold
    /// and warm opens of one cache path must use the same policy.
    pub policy: TunePolicy,
    /// Engine worker-pool override (`None` = engine default).
    pub workers: Option<usize>,
    /// Shard count for the persistent cache directory; must match the
    /// directory's original count.
    pub shards: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            epsilon: DEFAULT_EPSILON,
            policy: TunePolicy::tiered_default(),
            workers: None,
            shards: crate::coordinator::cache::DEFAULT_SHARDS,
        }
    }
}

/// Aggregate serving statistics (see [`ScheduleServer::stats`]).
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub exact_hits: usize,
    pub neighbor_hits: usize,
    pub misses: usize,
    /// Retunes completed by [`ScheduleServer::drain_retunes`].
    pub retunes_done: usize,
    /// Retunes still queued.
    pub queue_depth: usize,
    /// Exact entries currently in the database.
    pub db_exact: usize,
    /// Borrowed entries currently in the database.
    pub db_borrowed: usize,
    /// Time-to-schedule percentiles over every request served, µs.
    pub p50_us: f64,
    pub p99_us: f64,
    /// Engine-lifetime simulation count (rebuild + misses + retunes).
    pub sim_calls: usize,
}

impl ServeStats {
    /// Requests answered without a synchronous tune.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        (self.exact_hits + self.neighbor_hits) as f64 / self.requests as f64
    }
}

/// The serving layer: a shape database over a tuning [`Engine`].
///
/// All methods take `&self` — the server is shared across serving
/// threads behind an `Arc`, with the database and retune queue behind
/// their own locks (never held across engine or analytic calls).
pub struct ScheduleServer {
    arch: ArchConfig,
    epsilon: f64,
    engine: Engine,
    /// bucket key → (canonical shape key → entry), both BTreeMaps so
    /// donor iteration order is deterministic.
    db: Mutex<BTreeMap<(usize, usize, usize), BTreeMap<(usize, usize, usize), DbEntry>>>,
    /// Canonical shapes awaiting an exact retune, FIFO.
    retunes: Mutex<VecDeque<GemmShape>>,
    requests: AtomicUsize,
    exact_hits: AtomicUsize,
    neighbor_hits: AtomicUsize,
    misses: AtomicUsize,
    retunes_done: AtomicUsize,
    /// Time-to-schedule per request, µs (reporting only — never feeds a
    /// serving decision, so wall-clock noise cannot break determinism).
    latencies_us: Mutex<Vec<f64>>,
}

impl ScheduleServer {
    /// Open a server backed by a sharded persistent cache at `dir`,
    /// rebuilding the shape database from every deployable shape the
    /// cache already knows for this architecture (zero simulations when
    /// the cache was written by a server with the same policy).
    pub fn open(
        arch: &ArchConfig,
        dir: impl Into<PathBuf>,
        cfg: ServeConfig,
    ) -> Result<ScheduleServer> {
        anyhow::ensure!(cfg.epsilon >= 0.0, "epsilon must be >= 0, got {}", cfg.epsilon);
        let mut engine =
            Engine::new(arch).with_policy(cfg.policy).with_sharded_cache(dir, cfg.shards.max(1));
        if let Some(w) = cfg.workers {
            engine = engine.with_workers(w);
        }
        let server = Self::from_engine(arch, engine, cfg.epsilon);
        server.rebuild()?;
        Ok(server)
    }

    /// A purely in-memory server (no persistent cache): everything else
    /// behaves identically. Used by tests and cache-less CLI replays.
    pub fn in_memory(arch: &ArchConfig, cfg: ServeConfig) -> Result<ScheduleServer> {
        anyhow::ensure!(cfg.epsilon >= 0.0, "epsilon must be >= 0, got {}", cfg.epsilon);
        let mut engine = Engine::new(arch).with_policy(cfg.policy);
        if let Some(w) = cfg.workers {
            engine = engine.with_workers(w);
        }
        Ok(Self::from_engine(arch, engine, cfg.epsilon))
    }

    fn from_engine(arch: &ArchConfig, engine: Engine, epsilon: f64) -> ScheduleServer {
        ScheduleServer {
            arch: arch.clone(),
            epsilon,
            engine,
            db: Mutex::new(BTreeMap::new()),
            retunes: Mutex::new(VecDeque::new()),
            requests: AtomicUsize::new(0),
            exact_hits: AtomicUsize::new(0),
            neighbor_hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            retunes_done: AtomicUsize::new(0),
            latencies_us: Mutex::new(Vec::new()),
        }
    }

    /// Rebuild exact entries from the persistent cache's shape
    /// inventory. Each shape re-tunes through the engine; with a cache
    /// written under the same policy this is pure disk replay
    /// (bit-identical best, zero simulations).
    fn rebuild(&self) -> Result<usize> {
        let shapes = self.engine.cached_shapes();
        for &shape in &shapes {
            // Defensive: a cache shared with non-serving tuning runs may
            // hold non-canonical orientations; the database only ever
            // keys canonical shapes.
            let (canon, _) = canonicalize(shape);
            let result = self.engine.tune(canon)?;
            self.insert_exact(canon, result.best().schedule.clone());
        }
        Ok(shapes.len())
    }

    fn insert_exact(&self, canon: GemmShape, schedule: Schedule) {
        let entry =
            DbEntry { shape: canon, schedule, exact: true, penalty: 0.0, donor: None };
        self.db
            .lock()
            .unwrap()
            .entry(bucket_key(canon))
            .or_default()
            .insert(shape_key(canon), entry);
    }

    /// The neighbor-reuse bound ε this server enforces.
    pub fn epsilon(&self) -> f64 {
        self.epsilon
    }

    /// Engine-lifetime simulation count (rebuild + misses + retunes).
    pub fn sim_calls(&self) -> usize {
        self.engine.sim_calls()
    }

    /// Exact retunes currently queued.
    pub fn queue_depth(&self) -> usize {
        self.retunes.lock().unwrap().len()
    }

    /// Persistent-cache entry count (0 for in-memory servers).
    pub fn disk_len(&self) -> usize {
        self.engine.disk_len()
    }

    /// Persistent-cache entries preloaded when this server opened.
    pub fn disk_loaded(&self) -> usize {
        self.engine.disk_loaded()
    }

    /// Persist the engine's cache now (no-op for in-memory servers).
    pub fn flush(&self) -> Result<()> {
        self.engine.flush_cache()
    }

    fn record_latency(&self, t0: std::time::Instant) {
        self.latencies_us.lock().unwrap().push(t0.elapsed().as_secs_f64() * 1e6);
    }

    /// Answer one schedule request. See the module docs for the
    /// exact-hit / neighbor-hit / miss contract.
    pub fn serve(&self, shape: GemmShape) -> Result<ServeResult> {
        let t0 = std::time::Instant::now();
        self.requests.fetch_add(1, Ordering::Relaxed);
        let (canon, swapped) = canonicalize(shape);
        let bkey = bucket_key(canon);
        let skey = shape_key(canon);

        // Fast path: database hit — exact, or a borrow answered before.
        let hit = self
            .db
            .lock()
            .unwrap()
            .get(&bkey)
            .and_then(|bucket| bucket.get(&skey))
            .cloned();
        if let Some(entry) = hit {
            let outcome = if entry.exact {
                self.exact_hits.fetch_add(1, Ordering::Relaxed);
                ServeOutcome::Exact
            } else {
                self.neighbor_hits.fetch_add(1, Ordering::Relaxed);
                ServeOutcome::Neighbor
            };
            self.record_latency(t0);
            return Ok(ServeResult {
                shape,
                canonical: canon,
                swapped,
                schedule: entry.schedule,
                outcome,
                penalty: entry.penalty,
                donor: entry.donor,
            });
        }

        // Donor search: exact entries in this bucket, in BTreeMap (shape
        // key) order; the snapshot is cloned so no lock is held across
        // the analytic calls below. Minimum penalty wins, ties toward
        // the earlier donor — fully deterministic. When the home bucket
        // has no exact donors, the adjacent power-of-two M bands (half,
        // then double, same exact (N, K)) are borrowed from instead: the
        // admission bound below is identical — a cross-band borrow still
        // has to price within ε of the shape's own candidate best — so
        // widening the donor pool can only turn misses into neighbor
        // hits, never weaken the served-quality contract.
        let donors: Vec<DbEntry> = {
            let db = self.db.lock().unwrap();
            let exact_of = |key: &(usize, usize, usize)| -> Vec<DbEntry> {
                db.get(key)
                    .map(|bucket| bucket.values().filter(|e| e.exact).cloned().collect())
                    .unwrap_or_default()
            };
            let mut donors = exact_of(&bkey);
            if donors.is_empty() {
                let mut bands = Vec::new();
                if bkey.0 / 2 >= 1 && bkey.0 / 2 != bkey.0 {
                    bands.push((bkey.0 / 2, bkey.1, bkey.2));
                }
                bands.push((bkey.0 * 2, bkey.1, bkey.2));
                for band in bands {
                    donors.extend(exact_of(&band));
                }
            }
            donors
        };
        if !donors.is_empty() {
            if let Some(best_ns) = analytic_best_ns(&self.arch, canon) {
                let mut chosen: Option<(f64, Schedule, GemmShape)> = None;
                for d in &donors {
                    let cand = retune_tk(&self.arch, canon, &d.schedule);
                    let Some(est) = estimate_ns(&self.arch, canon, &cand) else {
                        continue; // donor's schedule doesn't deploy here
                    };
                    let penalty = est / best_ns - 1.0;
                    if chosen.as_ref().map_or(true, |(p, _, _)| penalty < *p) {
                        chosen = Some((penalty, cand, d.shape));
                    }
                }
                if let Some((penalty, schedule, donor)) = chosen {
                    if penalty <= self.epsilon {
                        let entry = DbEntry {
                            shape: canon,
                            schedule: schedule.clone(),
                            exact: false,
                            penalty,
                            donor: Some(donor),
                        };
                        // or_insert: a concurrent exact tune (or an
                        // identical concurrent borrow) that landed first
                        // wins; this request still serves its own
                        // qualifying answer below.
                        self.db
                            .lock()
                            .unwrap()
                            .entry(bkey)
                            .or_default()
                            .entry(skey)
                            .or_insert(entry);
                        self.retunes.lock().unwrap().push_back(canon);
                        self.neighbor_hits.fetch_add(1, Ordering::Relaxed);
                        self.record_latency(t0);
                        return Ok(ServeResult {
                            shape,
                            canonical: canon,
                            swapped,
                            schedule,
                            outcome: ServeOutcome::Neighbor,
                            penalty,
                            donor: Some(donor),
                        });
                    }
                }
            }
        }

        // Miss: tune synchronously (the only serving path that
        // simulates) and remember the exact result.
        let result = self.engine.tune(canon).with_context(|| {
            format!("tuning {canon} (requested as {shape}) on miss")
        })?;
        let schedule = result.best().schedule.clone();
        self.insert_exact(canon, schedule.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.record_latency(t0);
        Ok(ServeResult {
            shape,
            canonical: canon,
            swapped,
            schedule,
            outcome: ServeOutcome::Miss,
            penalty: 0.0,
            donor: None,
        })
    }

    /// Serve every GEMM op of a multi-op workload graph, in graph order.
    /// Each op's shape canonicalizes per-op through the same transpose +
    /// power-of-two-M bucketing as [`ScheduleServer::serve`] — a graph
    /// request is exactly as cacheable as its constituent GEMMs, and the
    /// softmax/elementwise glue carries no schedule. Returns `(op label,
    /// serve result)` pairs for the GEMM ops.
    pub fn serve_graph(
        &self,
        g: &crate::graph::WorkloadGraph,
    ) -> Result<Vec<(String, ServeResult)>> {
        g.validate()?;
        let mut out = Vec::new();
        for op in &g.ops {
            if let crate::graph::OpKind::Gemm(shape) = op.kind {
                out.push((op.label.clone(), self.serve(shape)?));
            }
        }
        Ok(out)
    }

    /// Run up to `max` queued exact retunes (FIFO), upgrading borrowed
    /// entries to exact. Shapes already upgraded (e.g. a duplicate queue
    /// entry from a concurrent borrow) are skipped without counting
    /// against `max`... and without tuning. Returns retunes performed.
    pub fn drain_retunes(&self, max: usize) -> Result<usize> {
        let mut done = 0usize;
        while done < max {
            let Some(canon) = self.retunes.lock().unwrap().pop_front() else {
                break;
            };
            let already_exact = self
                .db
                .lock()
                .unwrap()
                .get(&bucket_key(canon))
                .and_then(|b| b.get(&shape_key(canon)))
                .map(|e| e.exact)
                .unwrap_or(false);
            if already_exact {
                continue;
            }
            let result = self
                .engine
                .tune(canon)
                .with_context(|| format!("retuning {canon} from the queue"))?;
            self.insert_exact(canon, result.best().schedule.clone());
            self.retunes_done.fetch_add(1, Ordering::Relaxed);
            done += 1;
        }
        Ok(done)
    }

    /// Aggregate statistics snapshot.
    pub fn stats(&self) -> ServeStats {
        let (db_exact, db_borrowed) = {
            let db = self.db.lock().unwrap();
            let exact =
                db.values().flat_map(|b| b.values()).filter(|e| e.exact).count();
            let total: usize = db.values().map(|b| b.len()).sum();
            (exact, total - exact)
        };
        let (p50_us, p99_us) = {
            let lat = self.latencies_us.lock().unwrap();
            (percentile(&lat, 0.50), percentile(&lat, 0.99))
        };
        ServeStats {
            requests: self.requests.load(Ordering::Relaxed),
            exact_hits: self.exact_hits.load(Ordering::Relaxed),
            neighbor_hits: self.neighbor_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            retunes_done: self.retunes_done.load(Ordering::Relaxed),
            queue_depth: self.queue_depth(),
            db_exact,
            db_borrowed,
            p50_us,
            p99_us,
            sim_calls: self.engine.sim_calls(),
        }
    }
}

/// Nearest-rank percentile over an unsorted sample (0 for an empty one).
fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

// ---------------------------------------------------------------------
// Replayable request traces.

/// Parse a trace: one `MxNxK` per line; `#` starts a comment; blank
/// lines are ignored. Fails on the first malformed shape or if the
/// trace holds none at all.
pub fn parse_trace(text: &str) -> Result<Vec<GemmShape>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        out.push(
            GemmShape::parse(line).with_context(|| format!("trace line {}", i + 1))?,
        );
    }
    anyhow::ensure!(!out.is_empty(), "trace holds no shapes");
    Ok(out)
}

/// [`parse_trace`] from a file.
pub fn load_trace(path: impl AsRef<std::path::Path>) -> Result<Vec<GemmShape>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    parse_trace(&text).with_context(|| format!("parsing trace {}", path.display()))
}

/// The shape universe serve traces draw from: a serving-mix cross
/// product of small-to-modest M values (including the bucket-boundary
/// straddlers 31/32/33 and 63/64/65) with a few exact `(N, K)` weight
/// pairs. Ordered popular-first — Zipf rank follows this order.
pub fn trace_universe() -> Vec<GemmShape> {
    let ms = [64, 32, 16, 63, 96, 65, 8, 33, 31, 128, 48, 24];
    let nks = [(512, 512), (768, 512), (512, 768), (1024, 512)];
    let mut out = Vec::with_capacity(ms.len() * nks.len());
    for &m in &ms {
        for &(n, k) in &nks {
            out.push(GemmShape::new(m, n, k));
        }
    }
    out
}

/// Generate a deterministic Zipf-distributed request trace over
/// [`trace_universe`] (exponent 1.1). One request in eight arrives
/// transposed (`N×M×K`) to exercise canonicalization. Same `(seed,
/// len)` ⇒ identical trace, on every platform — the committed trace
/// under `traces/` was produced by exactly this procedure, and replays
/// involve no randomness at all.
pub fn zipf_trace(seed: u64, len: usize) -> Vec<GemmShape> {
    let pool = trace_universe();
    let weights: Vec<f64> =
        (0..pool.len()).map(|i| 1.0 / ((i + 1) as f64).powf(1.1)).collect();
    let total: f64 = weights.iter().sum();
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * total;
        let mut acc = 0.0;
        let mut idx = pool.len() - 1;
        for (i, w) in weights.iter().enumerate() {
            acc += w;
            if u < acc {
                idx = i;
                break;
            }
        }
        let mut shape = pool[idx];
        if rng.below(8) == 0 {
            shape = GemmShape::new(shape.n, shape.m, shape.k);
        }
        out.push(shape);
    }
    out
}

/// Render a trace to the committed text format, with a regeneration
/// header.
pub fn render_trace(shapes: &[GemmShape], seed: u64) -> String {
    let mut out = String::new();
    out.push_str("# Deterministic Zipf-distributed GEMM request trace for `dit serve`.\n");
    out.push_str(&format!(
        "# Generated by shapedb::zipf_trace(seed={seed}, len={}); regenerate with\n",
        shapes.len()
    ));
    out.push_str(&format!(
        "#   dit serve --gen-trace <path> --seed {seed} --len {}\n",
        shapes.len()
    ));
    out.push_str("# One MxNxK request per line; `#` starts a comment.\n");
    for s in shapes {
        out.push_str(&format!("{s}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonicalize_edge_cases() {
        // Unit dimensions.
        assert_eq!(canonicalize(GemmShape::new(1, 1, 1)), (GemmShape::new(1, 1, 1), false));
        assert_eq!(
            canonicalize(GemmShape::new(1, 4096, 64)),
            (GemmShape::new(1, 4096, 64), false)
        );
        assert_eq!(
            canonicalize(GemmShape::new(4096, 1, 64)),
            (GemmShape::new(1, 4096, 64), true)
        );
        // K never moves.
        assert_eq!(
            canonicalize(GemmShape::new(128, 64, 1)),
            (GemmShape::new(64, 128, 1), true)
        );
        // Transpose-symmetric shapes are their own canonical form.
        assert_eq!(
            canonicalize(GemmShape::new(64, 64, 256)),
            (GemmShape::new(64, 64, 256), false)
        );
    }

    #[test]
    fn transposed_pair_shares_one_canonical_key() {
        let a = canonicalize(GemmShape::new(63, 4096, 4096)).0;
        let b = canonicalize(GemmShape::new(4096, 63, 4096)).0;
        assert_eq!(shape_key(a), shape_key(b));
        assert_eq!(bucket_key(a), bucket_key(b));
    }

    #[test]
    fn bucket_boundaries_straddle_as_documented() {
        assert_eq!(m_bucket(1), 1);
        assert_eq!(m_bucket(2), 2);
        assert_eq!(m_bucket(3), 4);
        assert_eq!(m_bucket(63), 64);
        assert_eq!(m_bucket(64), 64);
        assert_eq!(m_bucket(65), 128);
        // 63 and 64 share a bucket; 65 lands one bucket up with 128.
        let nk = |m| bucket_key(GemmShape::new(m, 512, 512));
        assert_eq!(nk(63), nk(64));
        assert_ne!(nk(64), nk(65));
        assert_eq!(nk(65), nk(128));
        // Exact N and K: same M band, different weights, different bucket.
        assert_ne!(
            bucket_key(GemmShape::new(63, 512, 512)),
            bucket_key(GemmShape::new(63, 768, 512))
        );
    }

    #[test]
    fn zipf_trace_is_deterministic_and_well_formed() {
        let a = zipf_trace(7, 256);
        let b = zipf_trace(7, 256);
        assert_eq!(a, b, "same seed, same trace");
        assert_ne!(zipf_trace(8, 256), a, "different seed, different trace");
        assert_eq!(a.len(), 256);
        let universe = trace_universe();
        let mut transposed = 0usize;
        for s in &a {
            let (canon, swapped) = canonicalize(*s);
            assert!(
                universe.contains(&canon),
                "{s} is outside the canonical universe"
            );
            transposed += swapped as usize;
        }
        assert!(transposed > 0, "no transposed requests in 256 draws");
        // Zipf head: the most popular universe shape dominates.
        let head = universe[0];
        let head_count = a.iter().filter(|s| canonicalize(**s).0 == head).count();
        assert!(head_count * 4 > a.len(), "head shape only {head_count}/256");
    }

    #[test]
    fn trace_roundtrips_through_render_and_parse() {
        let shapes = zipf_trace(7, 64);
        let text = render_trace(&shapes, 7);
        assert_eq!(parse_trace(&text).unwrap(), shapes);
        // Comments and blanks are tolerated; junk is not.
        assert_eq!(
            parse_trace("# c\n\n 8x16x32 # tail\n").unwrap(),
            vec![GemmShape::new(8, 16, 32)]
        );
        assert!(parse_trace("8x16\n").is_err());
        assert!(parse_trace("# only comments\n").is_err());
    }

    #[test]
    fn percentile_nearest_rank() {
        assert_eq!(percentile(&[], 0.99), 0.0);
        assert_eq!(percentile(&[5.0], 0.5), 5.0);
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 51.0); // round((99)*0.5)=50 → 51.0
    }

    #[test]
    fn cross_band_borrow_honors_the_epsilon_contract() {
        // Seed only the M=64 band, then request a shape whose own band
        // (M=128) is empty: the adjacent-band fallback must serve it as
        // a neighbor borrow, and the admission bound must be the same ε
        // contract in-band borrows honor — penalty = est/best − 1 ≤ ε
        // against the *requested* shape's own analytic candidate best.
        // ε is widened vs the serving default because adjacent-band M
        // deltas are coarser than in-band ones; the *contract* under
        // test is ε-parametric and unchanged.
        let arch = ArchConfig::tiny(4, 4);
        let cfg = ServeConfig { epsilon: 0.25, ..ServeConfig::default() };
        let server = ScheduleServer::in_memory(&arch, cfg).unwrap();
        let seed = GemmShape::new(64, 512, 512);
        let req = GemmShape::new(96, 512, 512);
        assert_ne!(bucket_key(seed), bucket_key(req), "must live in different bands");
        assert_eq!(bucket_key(seed).0 * 2, bucket_key(req).0, "adjacent bands");

        let seeded = server.serve(seed).unwrap().outcome;
        assert!(matches!(seeded, ServeOutcome::Exact | ServeOutcome::Miss));
        let r = server.serve(req).unwrap();
        assert_eq!(r.outcome, ServeOutcome::Neighbor, "cross-band borrow expected");
        assert_eq!(r.donor, Some(seed));
        assert!(r.penalty >= 0.0 && r.penalty <= server.epsilon(), "penalty {}", r.penalty);
        // Re-derive the bound from first principles, like tests/serve.rs
        // does for in-band borrows.
        let best = analytic_best_ns(&arch, req).unwrap();
        let est = estimate_ns(&arch, req, &r.schedule).unwrap();
        assert!((est / best - 1.0 - r.penalty).abs() < 1e-12);
        // The borrow lands in the requester's own bucket and repeats as
        // a database hit.
        let again = server.serve(req).unwrap();
        assert_eq!(again.outcome, ServeOutcome::Neighbor);
        assert_eq!(server.stats().db_borrowed, 1);
        // An unrelated (N, K) pair never borrows across weights.
        let other = server.serve(GemmShape::new(96, 768, 512)).unwrap();
        assert_eq!(other.outcome, ServeOutcome::Miss, "no donor shares this (N, K)");
    }

    #[test]
    fn graph_requests_canonicalize_per_op() {
        use crate::graph::WorkloadGraph;
        let arch = ArchConfig::tiny(4, 4);
        let server = ScheduleServer::in_memory(&arch, ServeConfig::default()).unwrap();
        let g = WorkloadGraph::attention_prefill("attn", 64, 32, 2);
        let first = server.serve_graph(&g).unwrap();
        assert_eq!(first.len(), 2, "two GEMM ops, no schedule for softmax");
        assert_eq!(first[0].0, "attn/qk");
        // av (64x32x512) canonicalizes by transpose to 32x64x512;
        // repeating the graph is pure database hits through the same
        // per-op transpose + bucketing path single-GEMM requests use.
        let again = server.serve_graph(&g).unwrap();
        for (label, r) in &again {
            assert_eq!(r.outcome, ServeOutcome::Exact, "{label} should hit");
        }
        let s = server.stats();
        assert_eq!(s.requests, 4);
        assert_eq!(s.exact_hits, 2);
    }
}

//! The persistent simulation cache: a versioned on-disk store mapping
//! `(architecture fingerprint, shape, schedule key) → RunStats`.
//!
//! DiT's pitch is that deployment cost is amortized by caching tuned
//! mappings across a coupled hardware/software design space; until now
//! the engine's memo-cache lived only in memory, so an interrupted or
//! refined sweep re-simulated everything. This module is the disk half
//! of that cache, following the replay/checkpoint pattern of autotuners
//! like Ansor and AKG:
//!
//! * **stable keys** — the architecture fingerprint is FNV-1a over the
//!   canonical config text ([`crate::coordinator::engine::arch_fingerprint`]),
//!   the shape is its `MxNxK` text, and the schedule is
//!   [`crate::schedule::Schedule::cache_key`] (every field encoded). All
//!   three are pinned by specification, so a cache written by one build
//!   is read bit-for-bit by every other build, platform, and Rust
//!   version.
//! * **lossless values** — [`RunStats`] serializes through
//!   [`crate::util::json`]'s exact-integer representation and
//!   shortest-roundtrip floats, so a resumed sweep is *bit-identical* to
//!   a cold one.
//! * **amortized-linear persistence** — the first [`DiskCache::flush`]
//!   writes the whole file atomically (temp file + rename); later
//!   flushes *append* only the entries added since the previous flush
//!   (the line-oriented layout exists exactly for this), and
//!   [`DiskCache::compact`] — run when the owning engine drops —
//!   rewrites one sorted, deduplicated image. Total I/O across a sweep
//!   is O(entries), not O(checkpoints × entries), while a kill at any
//!   point still leaves a loadable file: a torn final append line
//!   degrades to one skipped entry, a crash mid-rewrite leaves the
//!   previous image (plus a stray temp file, which loading ignores and
//!   [`DiskCache::clear`] removes).
//! * **corruption tolerance** — a truncated or unparseable entry, a
//!   foreign format/version header, or a wholly garbled file degrades to
//!   a (partial) cold start with a recorded warning. Opening **never**
//!   fails and **never** panics; the worst outcome is re-simulating.
//!
//! ## File layout (`dit-sim-cache` v1)
//!
//! Line-oriented JSON. The first line is the header; every further line
//! is one entry:
//!
//! ```text
//! {"format":"dit-sim-cache","version":1}
//! {"fp":"00530ff383b1c8eb","shape":"64x64x64","sched":"summa|l4x4|tk64|ps1|db1|ol1|rprr","stats":{...}}
//! {"fp":"00530ff383b1c8eb","shape":"64x64x64","sched":"systolic|l4x4|tk64|ps1|db1|ol1|rprr","stats":null}
//! ```
//!
//! `stats: null` records a candidate that failed to lower — persisting
//! the failure means a resumed sweep skips it without retrying. Rewrites
//! and appended batches are each written in sorted key order, and a
//! compacted file is one sorted image: equal cache contents produce
//! byte-identical compacted files (diffable checkpoints). Loading
//! tolerates duplicate keys (last wins), which is what makes appended
//! batches and retried appends safe.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::sim::RunStats;
use crate::util::json::Json;

/// Magic format tag in the header line.
pub const FORMAT: &str = "dit-sim-cache";

/// On-disk format version. Bump when the key grammar or the `RunStats`
/// field set changes incompatibly; readers treat any other version as a
/// cold start (never a misread).
pub const VERSION: i64 = 1;

/// Auto-flush cadence for direct [`DiskCache::insert`] users: the cache
/// persists itself after this many dirty entries even when the caller
/// never flushes explicitly. (The engine batch-commits with
/// [`DiskCache::insert_deferred`] and flushes once per tuning call
/// instead, keeping file I/O out of its lock scope.)
pub const DEFAULT_FLUSH_EVERY: usize = 256;

/// Distinguishes concurrent flushes (same process) in temp-file names.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// The on-disk cache key. All three components are stable text/values by
/// construction — see the module docs. The derived `Ord` (field order:
/// fingerprint, shape, schedule) is the canonical on-disk sort order.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DiskKey {
    /// [`crate::coordinator::engine::arch_fingerprint`] of the instance.
    pub arch_fp: u64,
    /// `MxNxK` shape text.
    pub shape: String,
    /// [`crate::schedule::Schedule::cache_key`] text.
    pub sched: String,
}

/// A persistent `(arch, shape, schedule) → Option<RunStats>` store.
///
/// `None` values record candidates that failed to lower (a deliberate
/// negative-cache, mirroring the in-memory memo-cache).
pub struct DiskCache {
    path: PathBuf,
    entries: HashMap<DiskKey, Option<RunStats>>,
    /// Entries read from disk at open time.
    loaded: usize,
    /// Keys inserted since the last successful flush (not yet on disk).
    dirty: Vec<DiskKey>,
    /// May flush() extend the on-disk file by appending? True only when
    /// the file is known intact (clean load, or we wrote it ourselves);
    /// false forces the next flush to be a full atomic rewrite, which is
    /// also how a damaged file heals.
    appendable: bool,
    /// The on-disk layout contains appended batches (not one sorted
    /// image); compact() canonicalizes it.
    needs_compact: bool,
    flush_every: usize,
    /// After a failed auto-flush, retry only once this many entries are
    /// dirty (prevents an error storm on every subsequent insert while
    /// keeping explicit flush()/compact() calls retrying immediately).
    auto_retry_at: usize,
    warnings: Vec<String>,
}

impl DiskCache {
    /// Open (or create-on-first-flush) a cache at `path`, loading every
    /// parseable entry. Infallible by design: any corruption — missing
    /// file aside, which is a normal first run — degrades to a partial or
    /// full cold start and is recorded in [`DiskCache::warnings`].
    pub fn open(path: impl Into<PathBuf>) -> DiskCache {
        let path = path.into();
        let mut cache = DiskCache {
            path,
            entries: HashMap::new(),
            loaded: 0,
            dirty: Vec::new(),
            appendable: false,
            needs_compact: false,
            flush_every: DEFAULT_FLUSH_EVERY,
            auto_retry_at: 0,
            warnings: Vec::new(),
        };
        cache.load();
        cache
    }

    fn load(&mut self) {
        let text = match std::fs::read_to_string(&self.path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return,
            Err(e) => {
                self.warnings.push(format!(
                    "cannot read {} ({e}); starting cold",
                    self.path.display()
                ));
                return;
            }
        };
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let Some(header) = lines.next() else {
            self.warnings
                .push(format!("{} is empty (no header); starting cold", self.path.display()));
            return;
        };
        match Json::parse(header) {
            Ok(h)
                if h.get("format").and_then(Json::as_str) == Some(FORMAT)
                    && h.get("version").and_then(Json::as_i64) == Some(VERSION) => {}
            Ok(h) => {
                self.warnings.push(format!(
                    "{} has foreign header {} (want format {FORMAT:?} v{VERSION}); starting cold",
                    self.path.display(),
                    h.render()
                ));
                return;
            }
            Err(e) => {
                self.warnings.push(format!(
                    "{} header is unparseable ({e}); starting cold",
                    self.path.display()
                ));
                return;
            }
        }
        let mut skipped = 0usize;
        let mut first_err = String::new();
        let mut prev: Option<DiskKey> = None;
        let mut unsorted = false;
        for line in lines {
            match Self::parse_entry(line) {
                Ok((key, stats)) => {
                    // Appended batches / duplicate keys show up as keys
                    // out of canonical order; remember so compact() knows
                    // the layout needs canonicalizing.
                    if prev.as_ref().is_some_and(|p| *p >= key) {
                        unsorted = true;
                    }
                    prev = Some(key.clone());
                    self.entries.insert(key, stats);
                }
                Err(e) => {
                    skipped += 1;
                    if first_err.is_empty() {
                        first_err = format!("{e:#}");
                    }
                }
            }
        }
        if skipped > 0 {
            self.warnings.push(format!(
                "{}: {skipped} unreadable entr{} skipped (first: {first_err}); \
                 they degrade to cache misses",
                self.path.display(),
                if skipped == 1 { "y" } else { "ies" }
            ));
        }
        self.loaded = self.entries.len();
        // A cleanly-loaded file is safe to extend by appending; anything
        // damaged forces the next flush to a full rewrite (which heals it).
        self.appendable = skipped == 0;
        // A non-canonical or damaged layout is compacted at the next
        // compact() (the engine's drop), even if nothing new is inserted.
        self.needs_compact = unsorted || skipped > 0;
    }

    fn parse_entry(line: &str) -> Result<(DiskKey, Option<RunStats>)> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad entry line: {e}"))?;
        let fp_hex = j
            .get("fp")
            .and_then(Json::as_str)
            .context("entry missing string field `fp`")?;
        let arch_fp = u64::from_str_radix(fp_hex, 16)
            .with_context(|| format!("entry fingerprint {fp_hex:?} is not hex"))?;
        let shape = j
            .get("shape")
            .and_then(Json::as_str)
            .context("entry missing string field `shape`")?
            .to_string();
        let sched = j
            .get("sched")
            .and_then(Json::as_str)
            .context("entry missing string field `sched`")?
            .to_string();
        let stats = match j.get("stats") {
            Some(Json::Null) => None,
            Some(s) => Some(RunStats::from_json(s).context("entry stats")?),
            None => anyhow::bail!("entry missing field `stats`"),
        };
        Ok((DiskKey { arch_fp, shape, sched }, stats))
    }

    fn entry_line(key: &DiskKey, stats: &Option<RunStats>) -> String {
        Json::obj()
            .field("fp", format!("{:016x}", key.arch_fp))
            .field("shape", key.shape.as_str())
            .field("sched", key.sched.as_str())
            .field("stats", match stats {
                Some(s) => s.to_json(),
                None => Json::Null,
            })
            .render()
    }

    /// The cache file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Problems encountered while loading (corrupt entries, foreign
    /// headers, ...). Empty on a clean open.
    pub fn warnings(&self) -> &[String] {
        &self.warnings
    }

    /// Entries currently held (loaded + inserted).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries read from disk when the cache was opened.
    pub fn loaded(&self) -> usize {
        self.loaded
    }

    /// Entries recording a candidate that failed to lower.
    pub fn infeasible_count(&self) -> usize {
        self.entries.values().filter(|s| s.is_none()).count()
    }

    /// Per-fingerprint entry counts, descending (for `cache stats`).
    pub fn fingerprint_counts(&self) -> Vec<(u64, usize)> {
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for key in self.entries.keys() {
            *counts.entry(key.arch_fp).or_insert(0) += 1;
        }
        let mut out: Vec<(u64, usize)> = counts.into_iter().collect();
        out.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        out
    }

    /// Look up one entry.
    pub fn get(&self, key: &DiskKey) -> Option<&Option<RunStats>> {
        self.entries.get(key)
    }

    /// Insert one entry without any flush: callers that batch-commit
    /// under a lock (the engine's phase 3) use this and flush explicitly
    /// right after, keeping file I/O out of their critical section.
    /// Updating an existing key re-marks it dirty too, so every insert —
    /// new or overwrite — is durable by the next flush (the appended
    /// duplicate line wins on load; flush dedups within a batch).
    pub fn insert_deferred(&mut self, key: DiskKey, stats: Option<RunStats>) {
        self.entries.insert(key.clone(), stats);
        self.dirty.push(key);
    }

    /// Insert one entry; auto-flushes every [`DiskCache::flush_every`]
    /// dirty entries. A failed auto-flush is demoted to a warning and
    /// the entries stay dirty — explicit [`DiskCache::flush`] /
    /// [`DiskCache::compact`] calls (the per-tuning-call checkpoint, the
    /// engine's drop) retry immediately; the auto path retries after
    /// another `flush_every` insertions to avoid an error storm.
    pub fn insert(&mut self, key: DiskKey, stats: Option<RunStats>) {
        self.insert_deferred(key, stats);
        if self.dirty.len() >= self.flush_every.max(self.auto_retry_at) {
            if let Err(e) = self.flush() {
                let msg = format!("auto-flush of {} failed: {e:#}", self.path.display());
                eprintln!("warning: simulation cache: {msg}");
                self.warnings.push(msg);
                self.auto_retry_at = self.dirty.len() + self.flush_every;
            }
        }
    }

    /// Override the auto-flush cadence (minimum 1).
    pub fn set_flush_every(&mut self, n: usize) {
        self.flush_every = n.max(1);
    }

    /// Persist everything not yet on disk. The first flush (or any flush
    /// over a damaged file) atomically rewrites the whole file; later
    /// flushes append just the dirty entries, so total checkpoint I/O
    /// over a sweep is linear in entries. On failure the entries stay
    /// dirty and the next flush retries. No-op when nothing is dirty.
    pub fn flush(&mut self) -> Result<()> {
        if self.dirty.is_empty() {
            return Ok(());
        }
        if !self.appendable {
            return self.rewrite();
        }
        let mut batch = std::mem::take(&mut self.dirty);
        batch.sort();
        batch.dedup(); // a key updated twice since the last flush writes once
        let mut out = String::new();
        for key in &batch {
            out.push_str(&Self::entry_line(key, &self.entries[key]));
            out.push('\n');
        }
        let append = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .and_then(|mut f| std::io::Write::write_all(&mut f, out.as_bytes()));
        match append {
            Ok(()) => {
                self.needs_compact = true;
                self.auto_retry_at = 0;
                Ok(())
            }
            Err(e) => {
                // Keep the batch dirty for a retry, and stop trusting the
                // file: the next flush does a full atomic rewrite, which
                // self-heals whatever broke the append (file deleted or
                // renamed underneath us, truncated by another process,
                // ...). A partially-appended batch is harmless either
                // way: loading tolerates both the torn line and the
                // duplicates the rewrite removes.
                self.dirty = batch;
                self.appendable = false;
                Err(anyhow::Error::new(e)
                    .context(format!("appending to {}", self.path.display())))
            }
        }
    }

    /// Canonicalize the on-disk file to one sorted, deduplicated image
    /// (equal contents ⇒ byte-identical files), flushing anything dirty
    /// on the way. No-op when the file is already compact and clean.
    /// Called by the engine when it drops.
    pub fn compact(&mut self) -> Result<()> {
        if self.dirty.is_empty() && !self.needs_compact {
            return Ok(());
        }
        self.rewrite()
    }

    /// Atomically rewrite the full cache: write `path.tmp.<pid>.<seq>` in
    /// the same directory, then rename it over `path`, in sorted key
    /// order.
    fn rewrite(&mut self) -> Result<()> {
        let mut keys: Vec<DiskKey> = self.entries.keys().cloned().collect();
        keys.sort();
        let mut out = String::new();
        out.push_str(&Json::obj().field("format", FORMAT).field("version", VERSION).render());
        out.push('\n');
        for key in &keys {
            out.push_str(&Self::entry_line(key, &self.entries[key]));
            out.push('\n');
        }
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() && !parent.exists() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating cache directory {}", parent.display()))?;
            }
        }
        let tmp = self.temp_path();
        std::fs::write(&tmp, &out)
            .with_context(|| format!("writing cache temp file {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, &self.path) {
            // Leave no stray temp file behind on a failed rename.
            let _ = std::fs::remove_file(&tmp);
            return Err(anyhow::Error::new(e).context(format!(
                "renaming {} over {}",
                tmp.display(),
                self.path.display()
            )));
        }
        self.dirty.clear();
        self.appendable = true;
        self.needs_compact = false;
        self.auto_retry_at = 0;
        Ok(())
    }

    fn temp_path(&self) -> PathBuf {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let name = self
            .path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| "cache".to_string());
        self.path
            .with_file_name(format!("{name}.tmp.{}.{seq}", std::process::id()))
    }

    /// Distinct shape texts recorded for `arch_fp` with at least one
    /// successfully-simulated schedule, sorted. Shapes where *every*
    /// candidate failed to lower are excluded — re-tuning them would fail
    /// again. The schedule server rebuilds its shape database from this.
    pub fn deployable_shapes_for(&self, arch_fp: u64) -> Vec<String> {
        let mut shapes: Vec<String> = self
            .entries
            .iter()
            .filter(|(k, v)| k.arch_fp == arch_fp && v.is_some())
            .map(|(k, _)| k.shape.clone())
            .collect();
        shapes.sort();
        shapes.dedup();
        shapes
    }

    /// Delete the cache file and any stray temp files a killed writer
    /// left beside it. Returns `(file_removed, temp_files_removed)`.
    pub fn clear(path: impl AsRef<Path>) -> Result<(bool, usize)> {
        let path = path.as_ref();
        let removed = match std::fs::remove_file(path) {
            Ok(()) => true,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => false,
            Err(e) => {
                return Err(e).with_context(|| format!("removing {}", path.display()));
            }
        };
        let mut temps = 0usize;
        if let (Some(parent), Some(name)) = (path.parent(), path.file_name()) {
            let parent = if parent.as_os_str().is_empty() { Path::new(".") } else { parent };
            let prefix = format!("{}.tmp.", name.to_string_lossy());
            if let Ok(dir) = std::fs::read_dir(parent) {
                for ent in dir.flatten() {
                    if ent.file_name().to_string_lossy().starts_with(&prefix)
                        && std::fs::remove_file(ent.path()).is_ok()
                    {
                        temps += 1;
                    }
                }
            }
        }
        Ok((removed, temps))
    }
}

/// Default shard count for [`ShardedDiskCache`].
pub const DEFAULT_SHARDS: usize = 8;

/// A concurrent, sharded variant of [`DiskCache`]: a *directory* holding
/// `shard-NN.jsonl` files, each an ordinary single-writer cache behind
/// its own lock. Keys are range-partitioned by a stable FNV-1a
/// fingerprint of the full key text, so a given key always lives in the
/// same shard across processes and runs — and concurrent readers plus a
/// background retune writer touching *different* shards never serialize
/// on one file lock (the serving layer's whole point,
/// [`crate::coordinator::shapedb`]).
///
/// Each shard file uses the exact v1 format above; a sharded directory
/// is therefore N independent, individually-recoverable caches. The
/// shard count is a fixed property of the directory: reopen with the
/// same count (everything in-repo uses [`DEFAULT_SHARDS`] unless a test
/// overrides it) — a different count would still *load* safely but
/// route lookups to the wrong shard, degrading to cache misses.
pub struct ShardedDiskCache {
    dir: PathBuf,
    shards: Vec<std::sync::Mutex<DiskCache>>,
}

impl ShardedDiskCache {
    /// Open (or create-on-first-flush) a sharded cache directory with
    /// [`DEFAULT_SHARDS`] shards. Infallible, like [`DiskCache::open`]:
    /// corruption in any shard degrades that shard to a (partial) cold
    /// start with a recorded warning.
    pub fn open(dir: impl Into<PathBuf>) -> ShardedDiskCache {
        Self::open_with(dir, DEFAULT_SHARDS)
    }

    /// Open with an explicit shard count (minimum 1).
    pub fn open_with(dir: impl Into<PathBuf>, shards: usize) -> ShardedDiskCache {
        let dir = dir.into();
        let shards = (0..shards.max(1))
            .map(|i| std::sync::Mutex::new(DiskCache::open(dir.join(Self::shard_name(i)))))
            .collect();
        ShardedDiskCache { dir, shards }
    }

    fn shard_name(i: usize) -> String {
        format!("shard-{i:02}.jsonl")
    }

    /// The cache directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of shards this handle routes across.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard owns `key`: a range partition of the key-text
    /// fingerprint (`⌊fp · n / 2⁶⁴⌋`), stable by the same argument as
    /// the on-disk key grammar itself.
    fn shard_of(&self, key: &DiskKey) -> usize {
        let tag = format!("{:016x}|{}|{}", key.arch_fp, key.shape, key.sched);
        let fp = crate::util::fnv1a64(tag.as_bytes());
        ((fp as u128 * self.shards.len() as u128) >> 64) as usize
    }

    /// Look up one entry (cloned out from under the owning shard's lock).
    pub fn get(&self, key: &DiskKey) -> Option<Option<RunStats>> {
        self.shards[self.shard_of(key)].lock().unwrap().get(key).cloned()
    }

    /// Insert one entry without flushing, into the owning shard only.
    pub fn insert_deferred(&self, key: DiskKey, stats: Option<RunStats>) {
        self.shards[self.shard_of(&key)].lock().unwrap().insert_deferred(key, stats);
    }

    /// Flush every shard, reporting the first failure (every shard is
    /// still attempted; unflushed entries stay dirty for a retry).
    pub fn flush(&self) -> Result<()> {
        let mut first: Option<anyhow::Error> = None;
        for shard in &self.shards {
            if let Err(e) = shard.lock().unwrap().flush() {
                first.get_or_insert(e);
            }
        }
        match first {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Compact every shard to its canonical sorted image. Poison-tolerant
    /// (the engine calls this from its drop): a shard whose lock was
    /// poisoned by a panicking thread is skipped, not double-panicked on.
    pub fn compact(&self) -> Result<()> {
        let mut first: Option<anyhow::Error> = None;
        for shard in &self.shards {
            if let Ok(mut shard) = shard.lock() {
                if let Err(e) = shard.compact() {
                    first.get_or_insert(e);
                }
            }
        }
        match first {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Entries currently held across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries loaded from disk at open, across all shards.
    pub fn loaded(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().loaded()).sum()
    }

    /// Failed-to-lower entries across all shards.
    pub fn infeasible_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().infeasible_count()).sum()
    }

    /// Load warnings from every shard, prefixed with the shard file name.
    pub fn warnings(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (i, shard) in self.shards.iter().enumerate() {
            for w in shard.lock().unwrap().warnings() {
                out.push(format!("{}: {w}", Self::shard_name(i)));
            }
        }
        out
    }

    /// Per-fingerprint entry counts aggregated across shards, descending.
    pub fn fingerprint_counts(&self) -> Vec<(u64, usize)> {
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for shard in &self.shards {
            for (fp, n) in shard.lock().unwrap().fingerprint_counts() {
                *counts.entry(fp).or_insert(0) += n;
            }
        }
        let mut out: Vec<(u64, usize)> = counts.into_iter().collect();
        out.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        out
    }

    /// [`DiskCache::deployable_shapes_for`] merged across shards, sorted
    /// and deduplicated.
    pub fn deployable_shapes_for(&self, arch_fp: u64) -> Vec<String> {
        let mut shapes: Vec<String> = Vec::new();
        for shard in &self.shards {
            shapes.extend(shard.lock().unwrap().deployable_shapes_for(arch_fp));
        }
        shapes.sort();
        shapes.dedup();
        shapes
    }

    /// Delete every shard file (and stray temp files) under `dir`, then
    /// the directory itself if that leaves it empty — no orphan shards
    /// survive a clear. A missing directory is not an error. Returns
    /// `(shard_files_removed, temp_files_removed)`.
    pub fn clear(dir: impl AsRef<Path>) -> Result<(usize, usize)> {
        let dir = dir.as_ref();
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((0, 0)),
            Err(e) => return Err(e).with_context(|| format!("reading {}", dir.display())),
        };
        let (mut files, mut temps) = (0usize, 0usize);
        for ent in entries.flatten() {
            let name = ent.file_name().to_string_lossy().into_owned();
            if !name.starts_with("shard-") {
                continue;
            }
            if name.ends_with(".jsonl") {
                std::fs::remove_file(ent.path())
                    .with_context(|| format!("removing {}", ent.path().display()))?;
                files += 1;
            } else if name.contains(".jsonl.tmp.") && std::fs::remove_file(ent.path()).is_ok() {
                temps += 1;
            }
        }
        // Remove the now-empty directory; a directory holding foreign
        // files is deliberately left in place.
        let _ = std::fs::remove_dir(dir);
        Ok((files, temps))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(fp: u64, shape: &str, sched: &str) -> DiskKey {
        DiskKey { arch_fp: fp, shape: shape.into(), sched: sched.into() }
    }

    fn stats(makespan: f64, spm: u64) -> RunStats {
        RunStats {
            makespan_ns: makespan,
            useful_flops: 2e6,
            total_flops: 2.5e6,
            hbm_read_bytes: 123,
            hbm_write_bytes: 456,
            noc_link_bytes: 789,
            spm_bytes: spm,
            peak_tflops: 10.0,
            hbm_peak_gbps: 100.0,
            supersteps: 3,
            compute_busy_ns: 0.5,
            num_tiles: 4,
            step_end_ns: vec![1.0, 2.0, makespan],
        }
    }

    fn temp_file(tag: &str) -> PathBuf {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "dit-cache-unit-{tag}-{}-{seq}.jsonl",
            std::process::id()
        ))
    }

    #[test]
    fn roundtrip_including_negative_entries() {
        let path = temp_file("roundtrip");
        let mut c = DiskCache::open(&path);
        assert!(c.warnings().is_empty(), "{:?}", c.warnings());
        assert_eq!(c.len(), 0);
        c.insert(key(7, "64x64x64", "summa"), Some(stats(1000.0, (1 << 53) + 1)));
        c.insert(key(7, "64x64x64", "systolic"), None);
        c.flush().unwrap();
        let c2 = DiskCache::open(&path);
        assert!(c2.warnings().is_empty(), "{:?}", c2.warnings());
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.loaded(), 2);
        assert_eq!(c2.infeasible_count(), 1);
        let got = c2.get(&key(7, "64x64x64", "summa")).unwrap().as_ref().unwrap();
        assert_eq!(got.makespan_ns.to_bits(), 1000.0f64.to_bits());
        assert_eq!(got.spm_bytes, (1 << 53) + 1, "u64 counter survives past 2^53");
        assert!(
            matches!(c2.get(&key(7, "64x64x64", "systolic")), Some(None)),
            "negative entry round-trips"
        );
        assert!(c2.get(&key(8, "64x64x64", "summa")).is_none(), "foreign fp misses");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flush_is_deterministic_and_idempotent() {
        let a = temp_file("det-a");
        let b = temp_file("det-b");
        for path in [&a, &b] {
            let mut c = DiskCache::open(path);
            // Insertion order differs; file bytes must not.
            if path == &a {
                c.insert(key(1, "s", "x"), None);
                c.insert(key(2, "s", "x"), Some(stats(1.0, 2)));
            } else {
                c.insert(key(2, "s", "x"), Some(stats(1.0, 2)));
                c.insert(key(1, "s", "x"), None);
            }
            c.flush().unwrap();
        }
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
        // A flush with nothing pending rewrites nothing (mtime aside, the
        // bytes stay identical).
        let mut c = DiskCache::open(&a);
        let before = std::fs::read(&a).unwrap();
        c.flush().unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), before);
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn later_flushes_append_and_compact_canonicalizes() {
        let path = temp_file("append");
        let mut c = DiskCache::open(&path);
        c.insert(key(2, "s", "x"), None);
        c.flush().unwrap(); // first flush: full atomic rewrite
        let first = std::fs::read_to_string(&path).unwrap();
        c.insert(key(1, "s", "x"), Some(stats(1.0, 2)));
        c.flush().unwrap(); // second flush: appends, never rewrites
        let appended = std::fs::read_to_string(&path).unwrap();
        assert!(appended.starts_with(&first), "append extends the file in place");
        assert_eq!(appended.lines().count(), 3, "header + two entries");
        // Compaction canonicalizes to the sorted image: byte-identical to
        // a one-shot write of the same contents.
        c.compact().unwrap();
        let canon_path = temp_file("append-canon");
        let mut canon = DiskCache::open(&canon_path);
        canon.insert(key(1, "s", "x"), Some(stats(1.0, 2)));
        canon.insert(key(2, "s", "x"), None);
        canon.flush().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&canon_path).unwrap());
        // Both layouts load to the same entries.
        let back = DiskCache::open(&path);
        assert_eq!(back.len(), 2);
        assert!(back.warnings().is_empty(), "{:?}", back.warnings());
        // Compacting an already-compact clean cache is a no-op.
        let mut back = back;
        back.compact().unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&canon_path).unwrap());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&canon_path);
    }

    #[test]
    fn auto_flush_after_n_insertions() {
        let path = temp_file("autoflush");
        let mut c = DiskCache::open(&path);
        c.set_flush_every(3);
        c.insert(key(1, "a", "x"), None);
        c.insert(key(1, "b", "x"), None);
        assert!(!path.exists(), "below the cadence nothing is written");
        c.insert(key(1, "c", "x"), None);
        assert!(path.exists(), "third insert crosses the cadence");
        assert_eq!(DiskCache::open(&path).len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn overwriting_an_entry_stays_durable() {
        let path = temp_file("overwrite");
        let mut c = DiskCache::open(&path);
        c.insert(key(1, "s", "x"), Some(stats(1.0, 2)));
        c.flush().unwrap();
        // An update to an existing key must reach disk on the next flush
        // (the appended duplicate line wins on load).
        c.insert(key(1, "s", "x"), Some(stats(9.0, 3)));
        c.flush().unwrap();
        let back = DiskCache::open(&path);
        assert!(back.warnings().is_empty(), "{:?}", back.warnings());
        assert_eq!(back.len(), 1, "duplicate lines collapse on load");
        let got = back.get(&key(1, "s", "x")).unwrap().as_ref().unwrap();
        assert_eq!(got.makespan_ns.to_bits(), 9.0f64.to_bits(), "last write wins");
        assert_eq!(got.spm_bytes, 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clear_removes_file_and_stray_temps() {
        let path = temp_file("clear");
        let mut c = DiskCache::open(&path);
        c.insert(key(1, "a", "x"), None);
        c.flush().unwrap();
        let stray = path.with_file_name(format!(
            "{}.tmp.99999.0",
            path.file_name().unwrap().to_string_lossy()
        ));
        std::fs::write(&stray, "half-written").unwrap();
        let (removed, temps) = DiskCache::clear(&path).unwrap();
        assert!(removed);
        assert_eq!(temps, 1);
        assert!(!path.exists() && !stray.exists());
        // Clearing a missing cache is not an error.
        assert_eq!(DiskCache::clear(&path).unwrap(), (false, 0));
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "dit-cache-shard-unit-{tag}-{}-{seq}",
            std::process::id()
        ))
    }

    #[test]
    fn sharded_roundtrip_spreads_and_reloads() {
        let dir = temp_dir("roundtrip");
        let c = ShardedDiskCache::open_with(&dir, 4);
        assert_eq!(c.shard_count(), 4);
        assert!(c.warnings().is_empty(), "{:?}", c.warnings());
        for i in 0..64u64 {
            let stats = (i % 3 != 0).then(|| stats(i as f64 + 1.0, i));
            c.insert_deferred(key(7, &format!("{}x64x64", i + 1), "summa"), stats);
        }
        c.flush().unwrap();
        // With 64 distinct keys over 4 shards, every shard should own
        // some (the partition is a fixed fingerprint range split; an
        // empty shard here would mean the routing collapsed).
        let populated = (0..4)
            .filter(|i| dir.join(ShardedDiskCache::shard_name(*i)).exists())
            .count();
        assert!(populated >= 2, "only {populated}/4 shard files written");
        let back = ShardedDiskCache::open_with(&dir, 4);
        assert!(back.warnings().is_empty(), "{:?}", back.warnings());
        assert_eq!(back.len(), 64);
        assert_eq!(back.loaded(), 64);
        assert!(back.infeasible_count() > 0);
        for i in 0..64u64 {
            let got = back.get(&key(7, &format!("{}x64x64", i + 1), "summa"));
            let got = got.expect("key routed back to its shard");
            if i % 3 == 0 {
                assert!(got.is_none(), "negative entry survives for {i}");
            } else {
                assert_eq!(got.unwrap().makespan_ns.to_bits(), (i as f64 + 1.0).to_bits());
            }
        }
        assert_eq!(back.fingerprint_counts(), vec![(7, 64)]);
        // Only shapes with at least one feasible schedule are deployable.
        let shapes = back.deployable_shapes_for(7);
        assert!(!shapes.contains(&"1x64x64".to_string()), "i=0 is infeasible-only");
        assert!(shapes.contains(&"2x64x64".to_string()));
        let mut sorted = shapes.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(shapes, sorted, "deployable shapes are sorted and distinct");
        ShardedDiskCache::clear(&dir).unwrap();
    }

    #[test]
    fn sharded_concurrent_readers_and_writer() {
        let dir = temp_dir("concurrent");
        let c = ShardedDiskCache::open_with(&dir, 4);
        for i in 0..32u64 {
            c.insert_deferred(key(1, &format!("{}x8x8", i + 1), "s"), Some(stats(1.0, i)));
        }
        c.flush().unwrap();
        let c = std::sync::Arc::new(c);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let c = c.clone();
                s.spawn(move || {
                    for round in 0..50u64 {
                        let i = (t * 13 + round) % 32;
                        assert!(
                            c.get(&key(1, &format!("{}x8x8", i + 1), "s")).is_some(),
                            "reader lost key {i}"
                        );
                    }
                });
            }
            let w = c.clone();
            s.spawn(move || {
                for i in 32..64u64 {
                    w.insert_deferred(key(1, &format!("{}x8x8", i + 1), "s"), None);
                    if i % 8 == 0 {
                        w.flush().unwrap();
                    }
                }
                w.flush().unwrap();
            });
        });
        assert_eq!(c.len(), 64);
        c.compact().unwrap();
        assert_eq!(ShardedDiskCache::open_with(&dir, 4).len(), 64);
        ShardedDiskCache::clear(&dir).unwrap();
    }

    #[test]
    fn sharded_clear_leaves_no_orphans() {
        let dir = temp_dir("clear");
        let c = ShardedDiskCache::open_with(&dir, 4);
        for i in 0..16u64 {
            c.insert_deferred(key(1, &format!("{}x8x8", i + 1), "s"), None);
        }
        c.flush().unwrap();
        drop(c);
        // A stray temp from a killed shard writer must go too.
        let stray = dir.join("shard-01.jsonl.tmp.99999.0");
        std::fs::write(&stray, "half-written").unwrap();
        let (files, temps) = ShardedDiskCache::clear(&dir).unwrap();
        assert!(files > 0, "no shard files removed");
        assert_eq!(temps, 1);
        assert!(!dir.exists(), "empty directory is removed with its shards");
        // Clearing a missing directory is not an error.
        assert_eq!(ShardedDiskCache::clear(&dir).unwrap(), (0, 0));
    }
}

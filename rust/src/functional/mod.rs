//! Functional (f32) execution of the per-PE IR — the numerical half of the
//! DiT "Benchmark" stage (paper Fig. 4): "executes the compiled binary …
//! and compares results against reference outputs to validate correctness".
//!
//! The same [`Deployment`] the performance simulator times is executed here
//! with real data over a [`Preload`] HBM image, honouring the IR's BSP
//! semantics exactly:
//!
//! 1. **stage** — every communication op snapshots its source bytes
//!    (L1 buffers and HBM reads) *as of superstep entry*;
//! 2. **compute** — MMADs run in program order per tile, mutating only
//!    their C accumulators (validation guarantees no compute/comm race);
//! 3. **commit** — staged messages and DMA payloads land in destination
//!    buffers / HBM at the superstep boundary.
//!
//! Because the executor interprets the *same* programs the timing model
//! runs, a numerical pass here certifies that the deployment's data
//! movement (layouts, masks, reductions, wavefronts) is correct — which is
//! then cross-checked against the JAX/Pallas golden GEMM through
//! [`crate::runtime`].

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::arch::ArchConfig;
use crate::ir::{Deployment, Op, Program};
use crate::layout::preload::Preload;
use crate::layout::Run;

/// Per-tile L1 state: one byte vector per declared buffer.
struct TileState {
    bufs: Vec<Vec<u8>>,
}

impl TileState {
    fn new(prog: &Program) -> TileState {
        TileState { bufs: prog.bufs.iter().map(|b| vec![0u8; b.bytes as usize]).collect() }
    }
}

fn read_f32(bytes: &[u8], n: usize) -> Vec<f32> {
    bytes[..n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

fn write_f32(bytes: &mut [u8], data: &[f32]) {
    for (chunk, v) in bytes.chunks_exact_mut(4).zip(data) {
        chunk.copy_from_slice(&v.to_le_bytes());
    }
}

/// Read the concatenated bytes of HBM `runs` from a preload image.
fn read_runs(hbm: &Preload, runs: &[Run]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(runs.iter().map(|r| r.bytes as usize).sum());
    for r in runs {
        let img = hbm
            .images
            .get(r.channel)
            .with_context(|| format!("channel {} missing in preload", r.channel))?;
        let end = (r.offset + r.bytes) as usize;
        if end > img.len() {
            bail!("run past end of channel {} image: {} > {}", r.channel, end, img.len());
        }
        out.extend_from_slice(&img[r.offset as usize..end]);
    }
    Ok(out)
}

/// Write concatenated bytes back to HBM `runs`.
fn write_runs(hbm: &mut Preload, runs: &[Run], data: &[u8]) -> Result<()> {
    let mut cur = 0usize;
    for r in runs {
        let img = hbm
            .images
            .get_mut(r.channel)
            .with_context(|| format!("channel {} missing in preload", r.channel))?;
        let end = (r.offset + r.bytes) as usize;
        if end > img.len() {
            img.resize(end, 0);
        }
        img[r.offset as usize..end].copy_from_slice(&data[cur..cur + r.bytes as usize]);
        cur += r.bytes as usize;
    }
    Ok(())
}

/// Naive-but-blocked f32 GEMM kernel: `c[m×n] += a[m×k] @ b[k×n]`.
/// i-k-j loop order keeps the inner loop contiguous in both `b` and `c`.
pub fn mmad_f32(a: &[f32], b: &[f32], c: &mut [f32], m: usize, n: usize, k: usize) {
    assert!(
        a.len() >= m * k && b.len() >= k * n && c.len() >= m * n,
        "mmad_f32 {m}x{n}x{k}: operand buffers too small ({}, {}, {})",
        a.len(),
        b.len(),
        c.len()
    );
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // padding rows/cols short-circuit
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Execute a deployment functionally over a preloaded HBM image.
///
/// The deployment must have been generated at `elem = 4` (f32): the
/// functional path always computes in f32, like the FP8 engine's f32
/// accumulators.
pub fn execute(arch: &ArchConfig, dep: &Deployment, hbm: &mut Preload) -> Result<()> {
    crate::ir::validate(arch, dep)?;
    if dep.layouts.a.elem_bytes != 4 {
        bail!(
            "functional execution requires an f32 deployment (elem_bytes = 4), got {}",
            dep.layouts.a.elem_bytes
        );
    }
    let mut states: Vec<TileState> = dep.programs.iter().map(TileState::new).collect();
    let index: HashMap<crate::collective::TileCoord, usize> =
        dep.programs.iter().enumerate().map(|(i, p)| (p.tile, i)).collect();
    let n_steps = dep.supersteps();

    for step in 0..n_steps {
        // ---- Phase 1: stage communication sources (superstep-entry state).
        // tag -> payload for NoC traffic; DMA payloads staged separately.
        let mut messages: HashMap<u32, Vec<u8>> = HashMap::new();
        let mut reduce_acc: HashMap<u32, Vec<f32>> = HashMap::new();
        let mut dma_in: Vec<(usize, u32, Vec<u8>)> = Vec::new(); // (tile idx, dst buf, bytes)
        let mut dma_out: Vec<(Vec<Run>, Vec<u8>)> = Vec::new();

        for (ti, prog) in dep.programs.iter().enumerate() {
            let Some(ss) = prog.steps.get(step) else { continue };
            for op in &ss.ops {
                match op {
                    Op::DmaIn { runs, dst } => {
                        let data = read_runs(hbm, runs)?;
                        dma_in.push((ti, dst.0, data));
                    }
                    Op::DmaOut { src, runs } => {
                        let total: usize = runs.iter().map(|r| r.bytes as usize).sum();
                        let data = states[ti].bufs[src.0 as usize][..total].to_vec();
                        dma_out.push((runs.clone(), data));
                    }
                    Op::Multicast { src, bytes, tag, .. } => {
                        let data = states[ti].bufs[src.0 as usize][..*bytes as usize].to_vec();
                        if messages.insert(*tag, data).is_some() {
                            bail!("duplicate multicast tag {tag} at step {step}");
                        }
                    }
                    Op::Send { src, bytes, tag, .. } => {
                        let data = states[ti].bufs[src.0 as usize][..*bytes as usize].to_vec();
                        if messages.insert(*tag, data).is_some() {
                            bail!("duplicate send tag {tag} at step {step}");
                        }
                    }
                    Op::Reduce { src, bytes, tag, .. } => {
                        let contrib =
                            read_f32(&states[ti].bufs[src.0 as usize], *bytes as usize / 4);
                        match reduce_acc.get_mut(tag) {
                            Some(acc) => {
                                for (a, c) in acc.iter_mut().zip(&contrib) {
                                    *a += c;
                                }
                            }
                            None => {
                                reduce_acc.insert(*tag, contrib);
                            }
                        }
                    }
                    _ => {}
                }
            }
        }

        // ---- Phase 2: compute (program order per tile).
        for (ti, prog) in dep.programs.iter().enumerate() {
            let Some(ss) = prog.steps.get(step) else { continue };
            for op in &ss.ops {
                if let Op::Mmad { a, b, c, m, n, k, init } = op {
                    let av = read_f32(&states[ti].bufs[a.0 as usize], m * k);
                    let bv = read_f32(&states[ti].bufs[b.0 as usize], k * n);
                    let mut cv = if *init {
                        vec![0f32; m * n]
                    } else {
                        read_f32(&states[ti].bufs[c.0 as usize], m * n)
                    };
                    mmad_f32(&av, &bv, &mut cv, *m, *n, *k);
                    write_f32(&mut states[ti].bufs[c.0 as usize], &cv);
                }
            }
        }

        // ---- Phase 3: commit communication.
        for (ti, dst, data) in dma_in {
            states[ti].bufs[dst as usize][..data.len()].copy_from_slice(&data);
        }
        for (runs, data) in dma_out {
            write_runs(hbm, &runs, &data)?;
        }
        for prog in &dep.programs {
            let Some(ss) = prog.steps.get(step) else { continue };
            let ti = index[&prog.tile];
            for op in &ss.ops {
                match op {
                    Op::RecvMulticast { dst, bytes, tag, .. }
                    | Op::Recv { dst, bytes, tag, .. } => {
                        let data = messages
                            .get(tag)
                            .with_context(|| format!("no payload for tag {tag} step {step}"))?;
                        states[ti].bufs[dst.0 as usize][..*bytes as usize]
                            .copy_from_slice(&data[..*bytes as usize]);
                    }
                    Op::Multicast { group, dst, bytes, tag, .. } => {
                        // Root self-delivery if the root is a group member.
                        if group.contains(prog.tile) {
                            let data = messages.get(tag).unwrap().clone();
                            states[ti].bufs[dst.0 as usize][..*bytes as usize]
                                .copy_from_slice(&data[..*bytes as usize]);
                        }
                    }
                    Op::Reduce { root, dst, bytes, tag, .. } => {
                        if prog.tile == *root {
                            let acc = reduce_acc
                                .get(tag)
                                .with_context(|| format!("no reduce acc for tag {tag}"))?;
                            write_f32(
                                &mut states[ti].bufs[dst.0 as usize][..*bytes as usize],
                                acc,
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
    }
    Ok(())
}

/// End-to-end functional GEMM: scatter inputs per the deployment's layouts
/// (the Preload stage), execute, gather C (cropping padding).
pub fn run_gemm(arch: &ArchConfig, dep: &Deployment, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
    let shape = dep.shape;
    let pad = dep.padded;
    anyhow::ensure!(a.len() == shape.m * shape.k, "A shape mismatch");
    anyhow::ensure!(b.len() == shape.k * shape.n, "B shape mismatch");

    // Pad inputs to the deployment's padded dimensions.
    let mut a_pad = vec![0f32; pad.m * pad.k];
    for r in 0..shape.m {
        a_pad[r * pad.k..r * pad.k + shape.k].copy_from_slice(&a[r * shape.k..(r + 1) * shape.k]);
    }
    let mut b_pad = vec![0f32; pad.k * pad.n];
    for r in 0..shape.k {
        b_pad[r * pad.n..r * pad.n + shape.n].copy_from_slice(&b[r * shape.n..(r + 1) * shape.n]);
    }

    let mut hbm = Preload::new(arch.hbm.num_channels());
    hbm.scatter_f32(&dep.layouts.a, &a_pad);
    hbm.scatter_f32(&dep.layouts.b, &b_pad);
    // Reserve C's extent.
    hbm.scatter_f32(&dep.layouts.c, &vec![0f32; pad.m * pad.n]);

    execute(arch, dep, &mut hbm)?;

    let c_pad = hbm.gather_f32(&dep.layouts.c);
    let mut c = vec![0f32; shape.m * shape.n];
    for r in 0..shape.m {
        c[r * shape.n..(r + 1) * shape.n]
            .copy_from_slice(&c_pad[r * pad.n..r * pad.n + shape.n]);
    }
    Ok(c)
}

/// Max |x - y| over two f32 slices (helper for verification paths).
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchConfig, GemmShape};
    use crate::codegen::generate;
    use crate::schedule::{candidates, Schedule};
    use crate::util::rng::Rng;

    /// CPU reference GEMM.
    fn gemm_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        mmad_f32(a, b, &mut c, m, n, k);
        c
    }

    fn check_schedule(arch: &ArchConfig, shape: GemmShape, sched: &Schedule, tol: f32) {
        let dep = generate(arch, shape, sched, 4)
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        let mut rng = Rng::new(0xF00D);
        let a = rng.f32_vec(shape.m * shape.k);
        let b = rng.f32_vec(shape.k * shape.n);
        let got = run_gemm(arch, &dep, &a, &b)
            .unwrap_or_else(|e| panic!("{}: {e}", sched.name()));
        let want = gemm_ref(&a, &b, shape.m, shape.n, shape.k);
        let diff = max_abs_diff(&got, &want);
        assert!(diff <= tol, "{} on {shape}: max diff {diff}", sched.name());
    }

    #[test]
    fn summa_numerics_match_reference() {
        let arch = ArchConfig::tiny(4, 4);
        check_schedule(&arch, GemmShape::new(64, 64, 64), &Schedule::summa(&arch, GemmShape::new(64, 64, 64)), 1e-4);
    }

    #[test]
    fn every_candidate_schedule_is_numerically_correct() {
        // THE core functional signal: all dataflows (SUMMA, systolic,
        // hierarchical, split-K, remapped) compute the same GEMM.
        let arch = ArchConfig::tiny(4, 4);
        for shape in [GemmShape::new(64, 64, 128), GemmShape::new(48, 80, 96)] {
            for sched in candidates(&arch, shape) {
                check_schedule(&arch, shape, &sched, 1e-3);
            }
        }
    }

    #[test]
    fn flat_remap_numerics() {
        let arch = ArchConfig::tiny(4, 4);
        let shape = GemmShape::new(16, 264, 512);
        let sched = Schedule::flat_remap(&arch, shape, 4);
        check_schedule(&arch, shape, &sched, 1e-3);
    }

    #[test]
    fn ragged_shapes_pad_correctly() {
        let arch = ArchConfig::tiny(4, 4);
        // Deliberately prime-ish dims exercise padding in every direction.
        let shape = GemmShape::new(37, 53, 41);
        check_schedule(&arch, shape, &Schedule::summa(&arch, shape), 1e-4);
    }

    #[test]
    fn rejects_non_f32_deployment() {
        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(32, 32, 32);
        let dep = generate(&arch, shape, &Schedule::summa(&arch, shape), 1).unwrap();
        let mut hbm = Preload::new(arch.hbm.num_channels());
        assert!(execute(&arch, &dep, &mut hbm).is_err());
    }

    #[test]
    fn mmad_f32_matches_manual() {
        let a = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let b = [5.0, 6.0, 7.0, 8.0]; // 2x2
        let mut c = vec![0f32; 4];
        mmad_f32(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
        // Accumulate on top.
        mmad_f32(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![38.0, 44.0, 86.0, 100.0]);
    }
}

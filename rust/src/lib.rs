//! # DiT — Design in Tiles
//!
//! Automated GEMM deployment on tile-based many-PE accelerators: a full
//! reproduction of *"Design in Tiles: Automating GEMM Deployment on
//! Tile-Based Many-PE Accelerators"* (CS.DC 2025).
//!
//! The crate contains everything the paper's system needs (see
//! `DESIGN.md` for the inventory and substitution notes):
//!
//! * [`analysis`] — the static deployment checker: a pass manager over
//!   `(arch, shape, schedule/deployment)` emitting structured
//!   diagnostics with stable `DIT-Exxx` codes (SPM capacity, remap
//!   geometry, HBM edge rule, chunking, dataflow compatibility, BSP
//!   deadlock), zero simulations — the `dit check` lint and the
//!   engine/DSE pre-validation gate.
//! * [`arch`] — parametric SoftHier architecture descriptions (GH200-like,
//!   A100-like, arbitrary grids) + config-file parsing, plus named GEMM
//!   workload suites ([`arch::workload`]: transformer prefill/decode
//!   traffic).
//! * [`graph`] — multi-op workload graphs: GEMM + softmax/elementwise
//!   programs with named intermediate edges, topological iteration, and
//!   the SPM-residency rule that lets the tuner keep producer/consumer
//!   intermediates on-fabric (skipping the HBM store + reload).
//! * [`collective`] — the mask-based NoC collective group calculus
//!   (`(i & M_row) = S_row ∧ (j & M_col) = S_col`) and mask synthesis.
//! * [`layout`] — distributed multi-channel HBM data layouts (split scheme,
//!   placement scheme) and preload images.
//! * [`ir`] — the per-PE BSP-superstep program IR (explicit data movement,
//!   workload mapping, inter-tile communication) + validation.
//! * [`schedule`] — the deployment-schedule abstraction: tiling/mapping,
//!   cluster-index remap, dataflow patterns, candidate enumeration.
//! * [`codegen`] — schedule → IR lowering for SUMMA / systolic /
//!   hierarchical / split-K / baseline dataflows.
//! * [`sim`] — the event-driven SoftHier performance model: mesh NoC with
//!   multicast/reduction trees and link contention, HBM channel queues,
//!   matrix-engine timing, BSP barriers.
//! * [`functional`] — functional (f32) execution of the same IR over a
//!   preloaded HBM image, for numerical verification.
//! * [`runtime`] — PJRT loader/executor for the JAX/Pallas golden GEMM
//!   artifacts (`artifacts/*.hlo.txt`); the correctness oracle.
//! * [`perfmodel`] — rooflines + analytical GPU baselines (CUTLASS /
//!   DeepGEMM calibrated) used by the paper-figure benches, and the
//!   deterministic [`perfmodel::EnergyModel`] over the simulator's
//!   traffic counters (pJ/byte, pJ/MAC, static W/tile).
//! * [`coordinator`] — the end-to-end deployment driver, the
//!   insight-guided schedule autotuner, the parallel batched
//!   workload-tuning engine ([`coordinator::engine`]), and the
//!   persistent simulation cache ([`coordinator::cache`]): interrupted
//!   or refined tuning sweeps resume from disk instead of re-simulating.
//! * [`dse`] — hardware design-space exploration: sweep mesh/CE/SPM/HBM
//!   axes, co-tune every candidate instance with the engine, and report
//!   Pareto frontiers over achieved TFLOP/s, a silicon-cost proxy, and
//!   energy per workload pass (2- and 3-axis, plus weighted
//!   scalarization for a single ranked winner).
//! * [`report`] — tables, CSV, and ASCII plots for the bench harness.
//! * [`util`] — zero-dependency substrates: config text parser, JSON
//!   writer, PRNG, mini property-test harness.

pub mod analysis;
pub mod arch;
pub mod cli;
pub mod codegen;
pub mod collective;
pub mod coordinator;
pub mod dse;
pub mod functional;
pub mod graph;
pub mod ir;
pub mod layout;
pub mod perfmodel;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod util;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::arch::workload::Workload;
    pub use crate::arch::{ArchConfig, GemmShape};
    pub use crate::collective::{Mask, TileCoord};
    pub use crate::coordinator::engine::Engine;
    pub use crate::graph::WorkloadGraph;
    pub use crate::dse::{run_sweep, DseOptions, Objective, SweepSpec};
    pub use crate::layout::{MatrixLayout, Placement};
    pub use crate::perfmodel::EnergyModel;
}

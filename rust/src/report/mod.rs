//! Reporting: markdown/CSV tables and ASCII plots for the bench harness.
//!
//! Every paper figure/table bench renders through these helpers so the
//! regenerated rows/series are uniform and diffable (`bench_output.txt`,
//! EXPERIMENTS.md).

use std::fmt::Write as _;

use crate::util::json::Json;

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as a column-aligned markdown table.
    pub fn markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let padded: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep, &widths));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Render as a JSON array of objects.
    pub fn json(&self) -> Json {
        let mut arr = Json::arr();
        for row in &self.rows {
            let mut obj = Json::obj();
            for (h, c) in self.headers.iter().zip(row) {
                obj = match c.parse::<f64>() {
                    Ok(v) => obj.field(h, v),
                    Err(_) => obj.field(h, c.as_str()),
                };
            }
            arr = arr.push(obj);
        }
        arr
    }
}

/// Render a workload-tuning report (one row per GEMM in the suite, best
/// schedule and throughput each) — the `tune-workload` CLI/bench table.
pub fn workload_summary(rep: &crate::coordinator::engine::WorkloadReport) -> Table {
    let mut t = Table::new(
        format!("workload '{}' on {}", rep.workload, rep.arch),
        &["gemm", "shape", "count", "best schedule", "TFLOP/s", "util %", "time/op", "total"],
    );
    for s in &rep.shapes {
        let best = s.result.best();
        t.row(vec![
            s.label.clone(),
            s.shape.to_string(),
            s.count.to_string(),
            best.schedule.name(),
            format!("{:.1}", best.stats.tflops()),
            format!("{:.1}", 100.0 * best.stats.utilization()),
            crate::util::human_time_ns(best.stats.makespan_ns),
            crate::util::human_time_ns(best.stats.makespan_ns * s.count as f64),
        ]);
    }
    t
}

/// One-line engine counter summary for a workload report: simulations
/// executed vs. candidates served from the in-memory memo-cache vs. the
/// persistent on-disk cache — printed under every `tune-workload` table
/// so cache effectiveness is visible at a glance.
pub fn workload_counters(rep: &crate::coordinator::engine::WorkloadReport) -> String {
    format!(
        "engine     : {} simulations, {} statically rejected, {} saved by tiering, \
         {} memo hits, {} disk hits, {} workers, {:.0} ms wall",
        rep.sim_calls, rep.statically_rejected, rep.sims_saved, rep.cache_hits,
        rep.disk_hits, rep.workers, rep.elapsed_ms
    )
}

/// Render a graph-tuning edge table: one row per intermediate edge with
/// its size, per-tile SPM share, residency verdict, and the HBM bytes a
/// resident edge saves per pass — printed with the per-GEMM
/// [`workload_summary`] by `dit tune-workload --graph`.
pub fn graph_edges(rep: &crate::coordinator::engine::GraphReport) -> Table {
    let mut t = Table::new(
        format!("graph '{}' edges on {}", rep.graph, rep.arch),
        &["edge", "producer", "consumer", "bytes", "B/tile", "residency", "HBM saved"],
    );
    for e in &rep.edges {
        t.row(vec![
            e.tensor.clone(),
            e.from.clone(),
            e.to.clone(),
            crate::util::human_bytes(e.tensor_bytes),
            e.share_bytes.to_string(),
            if e.resident { "SPM-resident".into() } else { "spilled".into() },
            crate::util::human_bytes(e.saved_hbm_bytes),
        ]);
    }
    t
}

/// One-line fusion counter summary for a graph report (see
/// [`workload_counters`]): fused vs unfused HBM traffic and the
/// resident-edge tally.
pub fn graph_counters(rep: &crate::coordinator::engine::GraphReport) -> String {
    format!(
        "fusion     : {}/{} edges SPM-resident, {} unfused -> {} fused HBM bytes \
         ({} saved, {:.1}%)",
        rep.resident_edges(),
        rep.edges.len(),
        crate::util::human_bytes(rep.unfused_hbm_bytes),
        crate::util::human_bytes(rep.fused_hbm_bytes),
        crate::util::human_bytes(rep.saved_hbm_bytes()),
        rep.saved_pct()
    )
}

/// Render a serving-replay summary (hit/miss breakdown, database
/// composition, time-to-schedule percentiles) — the `dit serve`
/// CLI/bench table.
pub fn serve_summary(stats: &crate::coordinator::shapedb::ServeStats) -> Table {
    let pct = |n: usize| {
        if stats.requests == 0 {
            "0.0".to_string()
        } else {
            format!("{:.1}", 100.0 * n as f64 / stats.requests as f64)
        }
    };
    let mut t = Table::new(
        format!("serve replay: {} requests", stats.requests),
        &["outcome", "count", "% of requests"],
    );
    t.row(vec!["exact hit".into(), stats.exact_hits.to_string(), pct(stats.exact_hits)]);
    t.row(vec![
        "neighbor hit".into(),
        stats.neighbor_hits.to_string(),
        pct(stats.neighbor_hits),
    ]);
    t.row(vec!["miss (tuned)".into(), stats.misses.to_string(), pct(stats.misses)]);
    t
}

/// One-line counter summary for a serving replay (see
/// [`workload_counters`]): database composition, retune-queue state,
/// time-to-schedule percentiles, and the engine's simulation count.
pub fn serve_counters(stats: &crate::coordinator::shapedb::ServeStats) -> String {
    format!(
        "server     : {} exact + {} borrowed db entries, {} retunes done, {} queued, \
         p50 {:.0} us, p99 {:.0} us, {} simulations",
        stats.db_exact,
        stats.db_borrowed,
        stats.retunes_done,
        stats.queue_depth,
        stats.p50_us,
        stats.p99_us,
        stats.sim_calls
    )
}

/// One-line engine counter summary for a DSE sweep (see
/// [`workload_counters`]); includes how many entries the persistent
/// cache started with, so a resumed sweep is recognizable from the log.
pub fn dse_counters(res: &crate::dse::DseResult) -> String {
    format!(
        "engine     : {} simulations, {} configs statically rejected, {} saved by \
         tiering, {} memo hits, {} disk hits ({} entries preloaded), {:.0} ms wall",
        res.sim_calls, res.statically_rejected, res.sims_saved, res.cache_hits,
        res.disk_hits, res.disk_loaded, res.elapsed_ms
    )
}

/// Render a DSE sweep (one row per evaluated configuration, frontier rows
/// starred) — the `dse` CLI/bench table.
pub fn dse_summary(res: &crate::dse::DseResult) -> Table {
    let mut t = Table::new(
        format!(
            "DSE sweep '{}' over workload '{}' ({} evaluated, {} pruned, {} infeasible)",
            res.spec_name,
            res.workload,
            res.points.len(),
            res.pruned.len(),
            res.infeasible.len()
        ),
        &[
            "config", "mesh", "peak TF", "HBM GB/s", "cost", "TFLOP/s", "util %", "roofline",
            "energy mJ", "TF/W", "frontier", "3-axis",
        ],
    );
    for p in &res.points {
        t.row(vec![
            p.arch.name.clone(),
            format!("{}x{}", p.arch.rows, p.arch.cols),
            format!("{:.0}", p.arch.peak_tflops()),
            format!("{:.0}", p.arch.hbm.total_gbps()),
            format!("{:.0}", p.cost),
            format!("{:.1}", p.tflops),
            format!("{:.1}", 100.0 * p.utilization()),
            format!("{:.0}", p.roofline_tflops),
            format!("{:.2}", p.energy_j * 1e3),
            format!("{:.2}", p.tflops_per_w),
            if p.on_frontier { "*".into() } else { String::new() },
            if p.on_frontier3 { "*".into() } else { String::new() },
        ]);
    }
    t
}

/// The TFLOPS-vs-cost scatter for a DSE sweep: frontier points as `*`,
/// dominated points as `o`.
pub fn dse_plot(res: &crate::dse::DseResult) -> AsciiPlot {
    let mut plot = AsciiPlot::new(
        format!("DSE frontier: '{}' on '{}'", res.spec_name, res.workload),
        "cost (proxy units)",
        "achieved TFLOP/s",
    );
    let frontier: Vec<(f64, f64)> = res.frontier_curve();
    let dominated: Vec<(f64, f64)> = res
        .points
        .iter()
        .filter(|p| !p.on_frontier)
        .map(|p| (p.cost, p.tflops))
        .collect();
    plot.series('o', dominated);
    plot.series('*', frontier);
    plot
}

/// The 3-axis (cost, TFLOP/s, energy) frontier rendered as its three
/// pairwise projections: points on the 3-axis frontier as `*`, dominated
/// points as `o`. Energy is plotted in mJ (the plot axes are log-scaled,
/// so only the label changes).
pub fn dse_plot_projections(res: &crate::dse::DseResult) -> Vec<AsciiPlot> {
    let axes = [
        ("cost (proxy units)", "achieved TFLOP/s"),
        ("energy per pass (mJ)", "achieved TFLOP/s"),
        ("cost (proxy units)", "energy per pass (mJ)"),
    ];
    let mut out = Vec::with_capacity(axes.len());
    for (i, (xl, yl)) in axes.iter().enumerate() {
        let mut dominated: Vec<(f64, f64)> = Vec::new();
        let mut frontier: Vec<(f64, f64)> = Vec::new();
        for p in &res.points {
            let xy = match i {
                0 => (p.cost, p.tflops),
                1 => (p.energy_j * 1e3, p.tflops),
                _ => (p.cost, p.energy_j * 1e3),
            };
            if p.on_frontier3 {
                frontier.push(xy);
            } else {
                dominated.push(xy);
            }
        }
        let mut plot = AsciiPlot::new(
            format!(
                "DSE 3-axis frontier projection: '{}' on '{}'",
                res.spec_name, res.workload
            ),
            *xl,
            *yl,
        );
        plot.series('o', dominated);
        plot.series('*', frontier);
        out.push(plot);
    }
    out
}

/// An ASCII scatter/line plot on log-log axes — enough to eyeball a
/// roofline (Fig. 7a) in terminal output.
pub struct AsciiPlot {
    pub title: String,
    pub width: usize,
    pub height: usize,
    pub x_label: String,
    pub y_label: String,
    series: Vec<(char, Vec<(f64, f64)>)>,
}

impl AsciiPlot {
    pub fn new(title: impl Into<String>, x_label: impl Into<String>, y_label: impl Into<String>) -> AsciiPlot {
        AsciiPlot {
            title: title.into(),
            width: 72,
            height: 20,
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    pub fn series(&mut self, marker: char, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push((marker, points));
        self
    }

    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|(_, pts)| pts.iter().copied())
            .filter(|(x, y)| *x > 0.0 && *y > 0.0)
            .collect();
        if all.is_empty() {
            return format!("{}\n(no data)\n", self.title);
        }
        let (mut x0, mut x1, mut y0, mut y1) = (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
        for (x, y) in &all {
            x0 = x0.min(*x);
            x1 = x1.max(*x);
            y0 = y0.min(*y);
            y1 = y1.max(*y);
        }
        // Pad the log range slightly.
        let (lx0, lx1) = (x0.ln() - 0.1, x1.ln() + 0.1);
        let (ly0, ly1) = (y0.ln() - 0.1, y1.ln() + 0.1);
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (marker, pts) in &self.series {
            for (x, y) in pts {
                if *x <= 0.0 || *y <= 0.0 {
                    continue;
                }
                let px = ((x.ln() - lx0) / (lx1 - lx0) * (self.width - 1) as f64).round() as usize;
                let py = ((y.ln() - ly0) / (ly1 - ly0) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - py.min(self.height - 1);
                grid[row][px.min(self.width - 1)] = *marker;
            }
        }
        let mut out = format!("{} (log-log; y: {}, x: {})\n", self.title, self.y_label, self.x_label);
        let _ = writeln!(out, "  ^ {:.3e} .. {:.3e}", y0, y1);
        for row in grid {
            let _ = writeln!(out, "  |{}", row.into_iter().collect::<String>());
        }
        let _ = writeln!(out, "  +{}", "-".repeat(self.width));
        let _ = writeln!(out, "   {:.3e} .. {:.3e}", x0, x1);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_markdown_alignment() {
        let mut t = Table::new("demo", &["name", "tflops"]);
        t.row(vec!["summa".into(), "1234.5".into()]);
        t.row(vec!["x".into(), "9".into()]);
        let md = t.markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| summa | 1234.5 |"));
        assert!(md.contains("| x     | 9      |"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.csv().contains("\"x,y\""));
    }

    #[test]
    fn json_rows_parse_numbers() {
        let mut t = Table::new("t", &["name", "v"]);
        t.row(vec!["s".into(), "2.5".into()]);
        assert_eq!(t.json().render(), r#"[{"name":"s","v":2.5}]"#);
    }

    #[test]
    fn plot_renders_markers() {
        let mut p = AsciiPlot::new("roofline", "intensity", "tflops");
        p.series('o', vec![(1.0, 10.0), (100.0, 1000.0)]);
        p.series('x', vec![(10.0, 50.0)]);
        let s = p.render();
        assert!(s.contains('o'));
        assert!(s.contains('x'));
    }

    #[test]
    fn plot_handles_empty() {
        let p = AsciiPlot::new("empty", "x", "y");
        assert!(p.render().contains("no data"));
    }

    #[test]
    fn dse_summary_marks_frontier_rows() {
        use crate::arch::ArchConfig;
        use crate::coordinator::engine::WorkloadReport;
        use crate::dse::{DsePoint, DseResult};

        fn mk(
            name: &str,
            rows: usize,
            cols: usize,
            cost: f64,
            tflops: f64,
            energy_j: f64,
            on_frontier: bool,
        ) -> DsePoint {
            let mut arch = ArchConfig::tiny(rows, cols);
            arch.name = name.to_string();
            DsePoint {
                arch,
                cost,
                tflops,
                roofline_tflops: tflops * 2.0,
                energy_j,
                tflops_per_w: if energy_j > 0.0 { 1.0 / energy_j } else { 0.0 },
                on_frontier,
                on_frontier3: on_frontier,
                report: WorkloadReport {
                    workload: "w".into(),
                    arch: name.to_string(),
                    shapes: vec![],
                    sim_calls: 0,
                    cache_hits: 0,
                    disk_hits: 0,
                    sims_saved: 0,
                    statically_rejected: 0,
                    analytic_rank_calls: 0,
                    workers: 1,
                    elapsed_ms: 0.0,
                },
            }
        }
        let res = DseResult {
            spec_name: "demo".into(),
            workload: "w".into(),
            objectives: vec![crate::dse::Objective::Perf, crate::dse::Objective::Cost],
            points: vec![
                mk("cheap", 2, 2, 10.0, 5.0, 0.002, true),
                mk("dud", 2, 2, 20.0, 4.0, 0.003, false),
                mk("rect", 16, 4, 30.0, 6.0, 0.004, true),
            ],
            pruned: vec![],
            infeasible: vec![],
            sim_calls: 3,
            cache_hits: 1,
            disk_hits: 2,
            disk_loaded: 5,
            sims_saved: 4,
            statically_rejected: 1,
            analytic_rank_calls: 12,
            elapsed_ms: 1.0,
        };
        let counters = dse_counters(&res);
        assert!(counters.contains("3 simulations"), "{counters}");
        assert!(counters.contains("1 configs statically rejected"), "{counters}");
        assert!(counters.contains("2 disk hits (5 entries preloaded)"), "{counters}");
        let md = dse_summary(&res).markdown();
        assert!(md.contains("DSE sweep 'demo'"), "{md}");
        assert!(md.contains("cheap"), "{md}");
        assert!(md.contains("16x4"), "rectangular mesh column renders rows x cols: {md}");
        assert!(md.contains('*'), "frontier rows are starred: {md}");
        assert!(md.contains("energy mJ") && md.contains("2.00"), "energy column: {md}");
        let plot = dse_plot(&res).render();
        assert!(plot.contains('*') && plot.contains('o'), "{plot}");
        assert!((res.interpolation_at(10.0) - 5.0).abs() < 1e-12);
        let projections = dse_plot_projections(&res);
        assert_eq!(projections.len(), 3);
        for p in &projections {
            let s = p.render();
            assert!(s.contains('*') && s.contains('o'), "{s}");
        }
    }

    #[test]
    fn workload_summary_renders_rows_and_aggregates() {
        use crate::arch::{ArchConfig, GemmShape};
        use crate::coordinator::engine::{ShapeResult, WorkloadReport};
        use crate::coordinator::{AutotuneResult, Scored};
        use crate::schedule::Schedule;
        use crate::sim::RunStats;

        let arch = ArchConfig::tiny(2, 2);
        let shape = GemmShape::new(64, 64, 64);
        let stats = RunStats {
            makespan_ns: 1000.0,
            useful_flops: 2e6,
            total_flops: 2e6,
            hbm_read_bytes: 100,
            hbm_write_bytes: 50,
            noc_link_bytes: 10,
            spm_bytes: 200,
            peak_tflops: 10.0,
            hbm_peak_gbps: 100.0,
            supersteps: 4,
            compute_busy_ns: 500.0,
            num_tiles: 4,
            step_end_ns: vec![],
        };
        let sched = Schedule::summa(&arch, shape);
        let rep = WorkloadReport {
            workload: "demo".into(),
            arch: arch.name.clone(),
            shapes: vec![ShapeResult {
                label: "qkv".into(),
                shape,
                count: 2,
                result: AutotuneResult {
                    ranking: vec![Scored { schedule: sched.clone(), stats }],
                },
            }],
            sim_calls: 1,
            cache_hits: 0,
            disk_hits: 3,
            sims_saved: 2,
            statically_rejected: 0,
            analytic_rank_calls: 6,
            workers: 2,
            elapsed_ms: 1.0,
        };
        let counters = workload_counters(&rep);
        assert!(counters.contains("1 simulations"), "{counters}");
        assert!(counters.contains("0 statically rejected"), "{counters}");
        assert!(counters.contains("3 disk hits"), "{counters}");
        let md = workload_summary(&rep).markdown();
        assert!(md.contains("workload 'demo'"), "{md}");
        assert!(md.contains("qkv"), "{md}");
        assert!(md.contains(&sched.name()), "{md}");
        // aggregate: 2 × (2·64³) flops over 2 × 1000 ns = 0.524288 TFLOP/s.
        assert!((rep.aggregate_tflops() - 0.524288).abs() < 1e-9, "{}", rep.aggregate_tflops());
        assert_eq!(rep.total_count(), 2);
    }
}

//! Paper-figure bench harness: regenerates every table and figure of the
//! evaluation section (`cargo bench`, or `cargo bench -- fig9` to filter).
//!
//! | id     | paper content                                              |
//! |--------|------------------------------------------------------------|
//! | table1 | system specification                                       |
//! | fig1   | CUTLASS utilization A100 vs GH200 (GPU baseline model)     |
//! | fig7a  | roofline: baseline/SUMMA x base/optimal layout             |
//! | fig7b  | dataflow-pattern comparison (2D tiling)                    |
//! | fig7c  | 2D SUMMA vs 3D split-K SUMMA                               |
//! | fig7d  | flat GEMM: 2D vs 3D + cluster remap                        |
//! | fig8   | pipeline stages: compute- vs store-intensive               |
//! | fig9   | compute-bound GEMM vs GH200 CUTLASS/DeepGEMM               |
//! | fig10  | flat GEMM TFLOPS vs GH200                                  |
//! | fig11  | flat GEMM HBM bandwidth utilization                        |
//! | fig12  | portability: SoftHier-A100/GH200 vs the matching GPUs      |
//! | workload | transformer serving-suite batched autotuning (engine)    |
//!
//! Absolute numbers come from the analytical-contention SoftHier model and
//! the calibrated GPU baselines (see DESIGN.md §Substitutions); the point
//! of comparison with the paper is the *shape* of each result (who wins,
//! by what factor, where crossovers sit). Results are archived in
//! EXPERIMENTS.md.

use std::time::Instant;

use dit::arch::workload::Workload;
use dit::arch::{ArchConfig, GemmShape};
use dit::coordinator::engine::Engine;
use dit::coordinator::{autotune, simulate_schedule};
use dit::perfmodel::{ridge_intensity, roofline_tflops, workloads, GpuSpec};
use dit::report::{AsciiPlot, Table};
use dit::schedule::{retune_tk, Dataflow, Schedule};
use dit::sim::RunStats;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let want = |id: &str| {
        args.iter().all(|a| a.starts_with('-'))
            || args.iter().any(|a| a == id || id.starts_with(a.as_str()))
    };
    let t0 = Instant::now();
    if want("table1") {
        table1();
    }
    if want("fig1") {
        fig1();
    }
    if want("fig7a") {
        fig7a();
    }
    if want("fig7b") {
        fig7b();
    }
    if want("fig7c") {
        fig7c();
    }
    if want("fig7d") {
        fig7d();
    }
    if want("fig8") {
        fig8();
    }
    if want("fig9") {
        fig9();
    }
    if want("fig10") {
        fig10();
    }
    if want("fig11") {
        fig11();
    }
    if want("fig12") {
        fig12();
    }
    if want("workload") {
        workload_bench();
    }
    eprintln!("\n[bench harness completed in {:.1?}]", t0.elapsed());
}

fn sim(arch: &ArchConfig, shape: GemmShape, sched: &Schedule) -> RunStats {
    simulate_schedule(arch, shape, sched)
        .unwrap_or_else(|e| panic!("{} on {shape}: {e}", sched.name()))
}

/// Best-of-candidates for a shape — "we iterate through our predefined
/// schedule candidates ... to automatically select the kernel achieving the
/// best performance" (§4.1.4).
fn best(arch: &ArchConfig, shape: GemmShape) -> (Schedule, RunStats) {
    let r = autotune(arch, shape).expect("autotune");
    (r.best().schedule.clone(), r.best().stats.clone())
}

// --------------------------------------------------------------------
fn table1() {
    let a = ArchConfig::gh200_like();
    let mut t = Table::new(
        "Table 1: System Specifications (GH200-matched SoftHier instance)",
        &["item", "value", "paper"],
    );
    t.row(vec![
        "system".into(),
        format!("{}x{} tiles, {}-bit NoC links", a.rows, a.cols, a.noc.link_bits),
        "32x32 tiles, 4096-bit NoC link width".into(),
    ]);
    t.row(vec![
        "hbm".into(),
        format!(
            "{}x2 channels (west+south), {:.0} GB/s total",
            a.hbm.channels_per_edge,
            a.hbm.total_gbps()
        ),
        "32x2 channels, 4 TB/s".into(),
    ]);
    t.row(vec![
        "tile".into(),
        format!(
            "{}x{} CE array @ {:.3} GHz = {:.2} TFLOPS FP8, {} KB L1 @ {:.0} GB/s",
            a.tile.ce_m,
            a.tile.ce_n,
            a.tile.clock_ghz,
            a.tile.peak_tflops(),
            a.tile.l1_bytes / 1024,
            a.tile.l1_gbps
        ),
        "64x16 CE, 1.93 TFLOPS FP8, 384 KB".into(),
    ]);
    t.row(vec![
        "summary".into(),
        format!("{:.0} TFLOPS peak, {:.0} GB/s HBM", a.peak_tflops(), a.hbm.total_gbps()),
        "1979 TFLOPS, 4 TB/s".into(),
    ]);
    print!("\n{}", t.markdown());
}

// --------------------------------------------------------------------
fn fig1() {
    let a100 = GpuSpec::a100();
    let gh200 = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 1: CUTLASS utilization, A100 vs GH200 (analytical GPU baseline)",
        &["shape", "A100 util %", "GH200 util %"],
    );
    for shape in workloads::compute_bound() {
        t.row(vec![
            shape.to_string(),
            format!("{:.1}", 100.0 * a100.utilization(a100.cutlass_tflops(shape))),
            format!("{:.1}", 100.0 * gh200.utilization(gh200.cutlass_tflops(shape))),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: the newer/larger GH200 shows LOWER average utilization than A100)");
}

// --------------------------------------------------------------------
fn fig7a() {
    let arch = ArchConfig::gh200_like();
    let shape = workloads::compute_intensive();
    let mk = |dataflow: Dataflow, opt: bool| {
        let base = match dataflow {
            Dataflow::Baseline => Schedule::baseline(&arch, shape),
            _ => Schedule::summa(&arch, shape),
        };
        retune_tk(&arch, shape, &Schedule { opt_layout: opt, ..base })
    };
    let series = [
        ("baseline w/o optimal layout", mk(Dataflow::Baseline, false)),
        ("baseline w/ optimal layout", mk(Dataflow::Baseline, true)),
        ("SUMMA w/o optimal layout", mk(Dataflow::Summa, false)),
        ("SUMMA w/ optimal layout", mk(Dataflow::Summa, true)),
    ];
    let mut t = Table::new(
        format!("Fig 7a: roofline, {shape} (ridge {:.0} FLOP/B)", ridge_intensity(&arch)),
        &["schedule", "intensity FLOP/B", "TFLOP/s", "roofline ceiling", "util %"],
    );
    let mut plot = AsciiPlot::new("Fig 7a roofline", "operational intensity (FLOP/B)", "TFLOP/s");
    let mut pts = Vec::new();
    for (name, sched) in &series {
        let stats = sim(&arch, shape, sched);
        t.row(vec![
            name.to_string(),
            format!("{:.1}", stats.intensity()),
            format!("{:.1}", stats.tflops()),
            format!("{:.1}", roofline_tflops(&arch, stats.intensity())),
            format!("{:.1}", 100.0 * stats.utilization()),
        ]);
        pts.push((stats.intensity(), stats.tflops()));
    }
    // Roofline ceiling curve.
    let ceiling: Vec<(f64, f64)> = (0..40)
        .map(|i| {
            let x = 1.5f64.powi(i);
            (x, roofline_tflops(&arch, x))
        })
        .collect();
    plot.series('*', pts);
    plot.series('.', ceiling);
    print!("\n{}", t.markdown());
    print!("{}", plot.render());
    println!("(paper: layout lifts baseline toward the memory ceiling; SUMMA lifts intensity;\n SUMMA + optimal layout approaches the compute ceiling)");
}

// --------------------------------------------------------------------
fn fig7b() {
    let arch = ArchConfig::gh200_like();
    let shapes = [
        GemmShape::new(4096, 2112, 7168),
        GemmShape::new(4096, 4096, 7168),
        GemmShape::new(4096, 7168, 2048),
        GemmShape::new(8192, 8192, 4096),
    ];
    let mut t = Table::new(
        "Fig 7b: dataflow patterns, 2D tiling (TFLOP/s)",
        &["shape", "baseline", "SUMMA", "systolic", "sys/SUMMA g4", "SUMMA/sys g2"],
    );
    for shape in shapes {
        let b = retune_tk(&arch, shape, &Schedule { opt_layout: true, ..Schedule::baseline(&arch, shape) });
        let s = Schedule::summa(&arch, shape);
        let sy = Schedule::systolic(&arch, shape);
        let h1 = retune_tk(&arch, shape, &Schedule {
            dataflow: Dataflow::SystolicOverSumma { group: 4 },
            ..Schedule::summa(&arch, shape)
        });
        let h2 = retune_tk(&arch, shape, &Schedule {
            dataflow: Dataflow::SummaOverSystolic { group: 2 },
            ..Schedule::summa(&arch, shape)
        });
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", sim(&arch, shape, &b).tflops()),
            format!("{:.0}", sim(&arch, shape, &s).tflops()),
            format!("{:.0}", sim(&arch, shape, &sy).tflops()),
            format!("{:.0}", sim(&arch, shape, &h1).tflops()),
            format!("{:.0}", sim(&arch, shape, &h2).tflops()),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: whether tiles start simultaneously drives the differences;\n SUMMA leads on compute-intensive shapes)");
}

// --------------------------------------------------------------------
fn fig7c() {
    let arch = ArchConfig::gh200_like();
    let shape = GemmShape::new(4096, 2112, 7168);
    let mut t = Table::new(
        "Fig 7c: 2D SUMMA vs 3D (split-K) SUMMA",
        &["schedule", "TN", "TFLOP/s", "util %"],
    );
    let s2d = Schedule::summa(&arch, shape);
    let st = sim(&arch, shape, &s2d);
    t.row(vec![
        "2D SUMMA".into(),
        format!("{}", s2d.plan(&arch, shape).tn),
        format!("{:.0}", st.tflops()),
        format!("{:.1}", 100.0 * st.utilization()),
    ]);
    for splits in [2, 4, 8] {
        let s = Schedule::splitk(&arch, shape, splits);
        let stats = sim(&arch, shape, &s);
        t.row(vec![
            format!("3D SUMMA split-K={splits}"),
            format!("{}", s.plan(&arch, shape).tn),
            format!("{:.0}", stats.tflops()),
            format!("{:.1}", 100.0 * stats.utilization()),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper Insight 3: 3D tiling turns the ragged TN=66 slices into\n matrix-engine-friendly TN=528 tiles and lifts utilization)");
}

// --------------------------------------------------------------------
fn fig7d() {
    let arch = ArchConfig::gh200_like();
    let shape = GemmShape::new(64, 2112, 7168);
    let mut t = Table::new(
        "Fig 7d: flat GEMM (LLM decode) — cluster dimension remap",
        &["schedule", "logical grid", "TFLOP/s", "HBM util %"],
    );
    let s2d = Schedule::summa(&arch, shape);
    let st = sim(&arch, shape, &s2d);
    t.row(vec![
        "2D SUMMA (32x32)".into(),
        "32x32".into(),
        format!("{:.0}", st.tflops()),
        format!("{:.1}", 100.0 * st.hbm_utilization()),
    ]);
    for splits in [8, 16, 32] {
        let s = Schedule::flat_remap(&arch, shape, splits);
        let stats = sim(&arch, shape, &s);
        t.row(vec![
            format!("3D split-K={splits} + remap"),
            format!("1x{} x{splits}", s.logical.1),
            format!("{:.0}", stats.tflops()),
            format!("{:.1}", 100.0 * stats.hbm_utilization()),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper Insight 4: remapping 32x32 -> 1x1024 logical with 3D tiling\n gives hardware-favorable tiles and much higher bandwidth use)");
}

// --------------------------------------------------------------------
fn fig8() {
    let arch = ArchConfig::gh200_like();
    let cases = [
        ("compute-intensive (Fig 8a)", workloads::compute_intensive()),
        ("store-intensive (Fig 8b)", workloads::store_intensive()),
    ];
    let mut t = Table::new(
        "Fig 8: pipeline stages (makespan, microseconds; lower is better)",
        &["case", "1 stage", "2 stages", "4 stages", "8 stages"],
    );
    for (name, shape) in cases {
        let mut row = vec![format!("{name} {shape}")];
        for stages in [1usize, 2, 4, 8] {
            let s = Schedule { pipeline_stages: stages, ..Schedule::summa(&arch, shape) };
            let stats = sim(&arch, shape, &s);
            row.push(format!("{:.1}", stats.makespan_ns / 1e3));
        }
        t.row(row);
    }
    print!("\n{}", t.markdown());
    println!("(paper: pipelining only wastes time on compute-intensive shapes, but\n reduces HBM store contention on store-intensive ones — up to a point)");
}

// --------------------------------------------------------------------
fn fig9() {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 9: compute-bound GEMM vs GH200 (TFLOP/s)",
        &["shape", "DiT (best)", "schedule", "CUTLASS", "DeepGEMM", "speedup"],
    );
    for shape in workloads::compute_bound() {
        let (sched, stats) = best(&arch, shape);
        let cut = gpu.cutlass_tflops(shape);
        let deep = gpu.deepgemm_tflops(shape);
        let best_gpu = cut.max(deep);
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", stats.tflops()),
            sched.name(),
            format!("{:.0}", cut),
            format!("{:.0}", deep),
            format!("{:.2}x", stats.tflops() / best_gpu),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: 1.2-1.5x higher TFLOPS than either library for all matrices)");
}

// --------------------------------------------------------------------
fn fig10() {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 10: flat GEMM performance vs GH200 (TFLOP/s)",
        &["shape", "DiT (best)", "schedule", "CUTLASS", "DeepGEMM", "speedup"],
    );
    for shape in workloads::flat() {
        let (sched, stats) = best(&arch, shape);
        let cut = gpu.cutlass_tflops(shape);
        let deep = gpu.deepgemm_tflops(shape);
        let best_gpu = cut.max(deep);
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", stats.tflops()),
            sched.name(),
            format!("{:.0}", cut),
            format!("{:.0}", deep),
            format!("{:.2}x", stats.tflops() / best_gpu),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: ~1.2-2.0x speedup in the memory-bound decode regime)");
}

// --------------------------------------------------------------------
fn fig11() {
    let arch = ArchConfig::gh200_like();
    let gpu = GpuSpec::gh200();
    let mut t = Table::new(
        "Fig 11: flat GEMM HBM bandwidth utilization",
        &["shape", "DiT GB/s", "DiT util %", "GPU GB/s", "GPU util %"],
    );
    for shape in workloads::flat() {
        let (_, stats) = best(&arch, shape);
        let gpu_tflops = gpu.cutlass_tflops(shape).max(gpu.deepgemm_tflops(shape));
        let gpu_bw = gpu.achieved_gbps(shape, gpu_tflops);
        t.row(vec![
            shape.to_string(),
            format!("{:.0}", stats.hbm_gbps()),
            format!("{:.1}", 100.0 * stats.hbm_utilization()),
            format!("{:.0}", gpu_bw),
            format!("{:.1}", 100.0 * gpu_bw / gpu.hbm_gbps),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: DiT achieves higher HBM bandwidth utilization in this regime)");
}

// --------------------------------------------------------------------
fn workload_bench() {
    let arch = ArchConfig::gh200_like();
    let engine = Engine::new(&arch);
    let suite = Workload::builtin("transformer").expect("builtin suite");
    let rep = engine.tune_workload(&suite).expect("tune_workload");
    print!("\n{}", dit::report::workload_summary(&rep).markdown());
    println!(
        "aggregate: {:.0} TFLOP/s weighted over {} GEMM executions ({} per pass)",
        rep.aggregate_tflops(),
        rep.total_count(),
        dit::util::human_time_ns(rep.total_time_ns()),
    );
    println!(
        "engine: {} simulations, {} cache hits, {} workers, {:.0} ms wall",
        rep.sim_calls, rep.cache_hits, rep.workers, rep.elapsed_ms
    );
    println!("(repeated decode-step GEMMs are memoized — a serving mix tunes mostly from cache)");
}

// --------------------------------------------------------------------
fn fig12() {
    let mut t = Table::new(
        "Fig 12: portability — utilization on spec-matched SoftHier vs real GPU",
        &["shape", "SoftHier-A100 %", "A100 CUTLASS %", "SoftHier-GH200 %", "GH200 CUTLASS %"],
    );
    let sh_a100 = ArchConfig::a100_like();
    let sh_gh200 = ArchConfig::gh200_like();
    let a100 = GpuSpec::a100();
    let gh200 = GpuSpec::gh200();
    for shape in workloads::compute_bound() {
        let (_, sa) = best(&sh_a100, shape);
        let (_, sg) = best(&sh_gh200, shape);
        t.row(vec![
            shape.to_string(),
            format!("{:.1}", 100.0 * sa.utilization()),
            format!("{:.1}", 100.0 * a100.utilization(a100.cutlass_tflops(shape))),
            format!("{:.1}", 100.0 * sg.utilization()),
            format!("{:.1}", 100.0 * gh200.utilization(gh200.cutlass_tflops(shape))),
        ]);
    }
    print!("\n{}", t.markdown());
    println!("(paper: CUTLASS drops on GH200; SoftHier utilization stays consistently\n high as the architecture scales — and beats its spec-matched GPU)");
}
